//! Quickstart: the smallest useful SFL-GA program.
//!
//! Builds the native pure-Rust runtime from the built-in manifest (no
//! artifacts needed), trains the split model with gradient aggregation
//! for 20 rounds on the synthetic MNIST workload, and prints accuracy +
//! communication + simulated latency.
//!
//! Run with:  cargo run --release --example quickstart

use sfl_ga::coordinator::{RunMetrics, SchemeKind, TrainConfig, Trainer};
use sfl_ga::model::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::builtin();

    let cfg = TrainConfig {
        dataset: "mnist".into(),
        scheme: SchemeKind::SflGa,
        num_clients: 10,
        rounds: 20,
        eval_every: 5,
        ..Default::default()
    };
    let cut = 2; // client owns conv1+conv2; server owns the fc stack

    println!("SFL-GA quickstart: {} clients, cut v={cut}, {} rounds", cfg.num_clients, cfg.rounds);
    let mut trainer = Trainer::native(&manifest, cfg)?;
    let mut metrics = RunMetrics::new(SchemeKind::SflGa, "mnist");
    for stats in trainer.run(cut)? {
        metrics.push(&stats);
        if let Some((loss, acc)) = stats.test {
            println!(
                "round {:>3}: test_loss {loss:.4}  test_acc {acc:.3}  total comm {:.1} MB  simulated latency {:.1} s",
                stats.round,
                metrics.total_comm_mb(),
                metrics.total_latency_s(),
            );
        }
    }
    println!(
        "done: {:.1}% accuracy for {:.1} MB of traffic",
        100.0 * metrics.final_accuracy(),
        metrics.total_comm_mb()
    );
    Ok(())
}
