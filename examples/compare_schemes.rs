//! Scheme comparison: SFL-GA vs SFL vs PSL vs FL on one workload, printing
//! the paper's headline table — accuracy, total communication and
//! simulated latency side by side (the Fig. 4/5 story in one screen).
//!
//! Run with:  cargo run --release --example compare_schemes [-- --rounds 60]

use sfl_ga::coordinator::{RunMetrics, SchemeKind, TrainConfig, Trainer};
use sfl_ga::model::Manifest;
use sfl_ga::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let rounds = args.parse_or("rounds", 60usize)?;
    let dataset = args.str_or("dataset", "mnist");
    let cut = args.parse_or("cut", 2usize)?;

    let manifest = Manifest::builtin();

    println!("scheme    final_acc   comm_MB   latency_s   (dataset={dataset}, cut=v{cut}, {rounds} rounds)");
    for scheme in SchemeKind::all() {
        let cfg = TrainConfig {
            dataset: dataset.clone(),
            scheme,
            rounds,
            eval_every: rounds, // evaluate once at the end
            seed: args.parse_or("seed", 17u64)?,
            // Every scheme runs through the same parallel round engine
            // (--threads N; 0 = auto); the table is thread-count invariant.
            threads: args.threads()?,
            // Scenario flags (--partition/--participation/--straggler)
            // compare the schemes under heterogeneity.
            scenario: args.scenario()?,
            ..Default::default()
        };
        let mut trainer = Trainer::native(&manifest, cfg)?;
        let mut metrics = RunMetrics::new(scheme, &dataset);
        for stats in trainer.run(cut)? {
            metrics.push(&stats);
        }
        println!(
            "{:<8} {:>9.3} {:>9.1} {:>11.1}",
            scheme.name(),
            metrics.final_accuracy(),
            metrics.total_comm_mb(),
            metrics.total_latency_s()
        );
    }
    Ok(())
}
