//! Joint CCC strategy demo (Algorithm 1): trains the DDQN cut-selection
//! agent against the convex resource allocator and shows (a) the reward
//! convergence and (b) the learned policy's cut choice vs channel state,
//! compared with the per-state exhaustive optimum.
//!
//! Run with:  cargo run --release --example ccc_optimizer [-- --episodes 200]

use sfl_ga::ccc::{self, CccConfig, CutPolicy, DdqnCut};
use sfl_ga::coordinator::AllocPolicy;
use sfl_ga::model::registry;
use sfl_ga::privacy;
use sfl_ga::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let episodes = args.parse_or("episodes", 200usize)?;
    let epsilon = args.parse_or("epsilon", 1e-3f64)?;
    let seed = args.parse_or("seed", 17u64)?;

    // --model vgg gives the agent an 11-action menu, txf a 3-action one.
    let manifest = registry::manifest(&args.model()?)?;
    let spec = manifest.for_dataset("mnist")?.clone();
    println!(
        "privacy ε={epsilon}: feasible cuts = {:?}",
        privacy::feasible_cuts(&spec, epsilon)
    );

    let cfg = CccConfig {
        epsilon,
        episodes,
        steps_per_episode: 20,
        alloc: AllocPolicy::Equal, // fast inner loop for the demo
        ..Default::default()
    };
    let mut env =
        ccc::Env::new(spec.clone(), Default::default(), Default::default(), cfg, 10, seed);
    println!("training Algorithm 1 agent: {episodes} episodes x 20 steps ...");
    let trained = ccc::train(&mut env, seed ^ 0xA1);
    for (ep, r) in trained.episode_rewards.iter().enumerate() {
        if ep % (episodes / 10).max(1) == 0 || ep + 1 == episodes {
            println!("  episode {ep:>5}: reward {r:8.2}");
        }
    }

    // Inspect the learned policy against brute force on fresh states.
    let mut policy = DdqnCut::new(trained.agent, &spec, epsilon)?;
    let mut agree = 0;
    let trials = 20;
    println!("\nstate-by-state: learned cut vs exhaustive best (fresh channel draws)");
    for t in 0..trials {
        let (state, feat) = env.reset();
        let learned = policy.select(t, &feat);
        // Exhaustive: evaluate the true cost of every feasible menu cut.
        let best = spec
            .menu()
            .ids()
            .filter(|&v| privacy::cut_feasible(&spec, v, epsilon))
            .min_by(|&a, &b| {
                let ca = cost(&env, &state, a);
                let cb = cost(&env, &state, b);
                ca.partial_cmp(&cb).unwrap()
            })
            .unwrap();
        if learned == best {
            agree += 1;
        }
        if t < 5 {
            println!("  draw {t}: learned v={learned}, exhaustive v={best}");
        }
    }
    println!("policy matches exhaustive optimum on {agree}/{trials} fresh draws");
    Ok(())
}

fn cost(env: &ccc::Env, state: &sfl_ga::wireless::ChannelState, v: usize) -> f64 {
    let (g, chi, psi) = env.cost_components(state, v);
    env.cfg.w * g + chi + psi
}
