//! End-to-end driver: trains the full stack — Rust coordinator over the
//! native pure-Rust runtime — for several hundred rounds on the synthetic
//! corpus, logging the loss curve, accuracy, communication and simulated
//! wall latency.
//!
//! Run with:  cargo run --release --example train_sfl_ga [-- --rounds 300]

use sfl_ga::coordinator::{RunMetrics, SchemeKind, TrainConfig, Trainer};
use sfl_ga::model::Manifest;
use sfl_ga::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let rounds = args.parse_or("rounds", 300usize)?;
    let dataset = args.str_or("dataset", "mnist");
    let cut = args.parse_or("cut", 2usize)?;

    let manifest = Manifest::builtin();
    let cfg = TrainConfig {
        dataset: dataset.clone(),
        scheme: SchemeKind::SflGa,
        num_clients: 10,
        rounds,
        eval_every: 10,
        samples_per_client: 512,
        seed: args.parse_or("seed", 17u64)?,
        // Parallel round engine (--threads N; 0 = auto, 1 = serial).
        // The loss/accuracy series is bitwise identical either way.
        threads: args.threads()?,
        // Scenario flags (--partition/--participation/--straggler).
        scenario: args.scenario()?,
        ..Default::default()
    };

    println!("# SFL-GA end-to-end training driver");
    println!("# dataset={dataset} cut=v{cut} clients={} rounds={rounds}", cfg.num_clients);
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::native(&manifest, cfg)?;
    println!("# round engine: {} worker thread(s)", trainer.threads());
    println!("# round,train_loss,test_loss,test_acc,cum_comm_mb,cum_latency_s");
    let mut metrics = RunMetrics::new(SchemeKind::SflGa, &dataset);
    for stats in trainer.run(cut)? {
        metrics.push(&stats);
        let row = metrics.rows.last().unwrap();
        if row.evaluated {
            println!(
                "{},{:.4},{:.4},{:.4},{:.2},{:.2}",
                row.round,
                row.train_loss,
                row.test_loss,
                row.test_acc,
                row.cum_comm_mb,
                row.cum_latency_s,
            );
        }
    }
    metrics.write_csv("results/end_to_end.csv")?;
    println!(
        "# finished in {:.1}s wall: acc={:.3}, comm={:.1} MB, simulated latency={:.1}s",
        t0.elapsed().as_secs_f64(),
        metrics.final_accuracy(),
        metrics.total_comm_mb(),
        metrics.total_latency_s()
    );
    println!("# series written to results/end_to_end.csv");
    Ok(())
}
