//! Heterogeneity sweep: how every scheme degrades as the data
//! distribution skews — SFL-GA vs SFL vs PSL vs FL at Dirichlet
//! α ∈ {0.1, 0.5, ∞} (∞ = IID), optionally under partial participation
//! and compute stragglers.
//!
//! The paper evaluates on IID data; this driver probes the scenario axis
//! cut-layer studies (arXiv:2412.15536) and resource-heterogeneity work
//! (AdaptSFL, arXiv:2403.13101) show matters: label skew shrinks every
//! scheme's accuracy, and partial participation widens the gap between
//! gradient-aggregation and model-aggregation traffic.
//!
//! Run with:
//!   cargo run --release --example heterogeneity_sweep
//!   cargo run --release --example heterogeneity_sweep -- \
//!     --rounds 60 --participation 0.5 --straggler 0.25x4
//!
//! Note: `--partition` is not accepted here — the sweep IS the partition
//! axis; `--participation`/`--straggler` apply to every cell.

use sfl_ga::coordinator::{RunMetrics, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::partition::Partition;
use sfl_ga::model::Manifest;
use sfl_ga::scenario::{ScenarioConfig, StragglerConfig};
use sfl_ga::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let rounds = args.parse_or("rounds", 40usize)?;
    let dataset = args.str_or("dataset", "mnist");
    let cut = args.parse_or("cut", 2usize)?;
    let participation = args.parse_or("participation", 1.0f64)?;
    let straggler = match args.get("straggler") {
        Some(s) => StragglerConfig::parse(s)?,
        None => StragglerConfig::default(),
    };

    let manifest = Manifest::builtin();
    // α = ∞ is IID: the Dirichlet proportions concentrate on uniform.
    let alphas: [(Partition, &str); 3] = [
        (Partition::Dirichlet(0.1), "alpha=0.1"),
        (Partition::Dirichlet(0.5), "alpha=0.5"),
        (Partition::Iid, "alpha=inf (iid)"),
    ];

    println!(
        "# heterogeneity sweep: dataset={dataset} cut=v{cut} rounds={rounds} \
         participation={participation} straggler={}x{}",
        straggler.frac, straggler.factor
    );
    println!("{:<16} {:<10} {:>9} {:>9} {:>11}", "partition", "scheme", "final_acc", "comm_MB", "latency_s");
    for (partition, label) in &alphas {
        for scheme in SchemeKind::all() {
            let cfg = TrainConfig {
                dataset: dataset.clone(),
                scheme,
                rounds,
                eval_every: rounds, // evaluate once at the end
                seed: args.parse_or("seed", 17u64)?,
                threads: args.threads()?,
                scenario: ScenarioConfig {
                    partition: partition.clone(),
                    participation,
                    straggler: straggler.clone(),
                },
                ..Default::default()
            };
            let mut trainer = Trainer::native(&manifest, cfg)?;
            let mut metrics = RunMetrics::new(scheme, &dataset);
            for stats in trainer.run(cut)? {
                metrics.push(&stats);
            }
            println!(
                "{:<16} {:<10} {:>9.3} {:>9.1} {:>11.1}",
                label,
                scheme.name(),
                metrics.final_accuracy(),
                metrics.total_comm_mb(),
                metrics.total_latency_s()
            );
        }
    }
    Ok(())
}
