//! Vendored minimal drop-in for the `anyhow` crate.
//!
//! The build must succeed from a clean checkout with no crates.io access
//! (CI runners and the offline dev container alike), so this workspace
//! vendors the subset of `anyhow` the codebase actually uses:
//!
//! * [`Error`] — an opaque, `Send + Sync` error value built from a message
//!   or from any `std::error::Error` (source chains are flattened eagerly).
//! * [`Result<T>`] — alias with the error type defaulted.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//!
//! Like the real crate, [`Error`] deliberately does NOT implement
//! `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
//! conversion coherent with the reflexive `From<Error> for Error`, which is
//! what makes `?` work uniformly.

use std::fmt;

/// Opaque error value: a message, with any source chain already flattened
/// into it ("outer: middle: root").
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The chain is pre-flattened, so `{}` and `{:#}` coincide.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn anyhow_formats_and_captures() {
        let name = "x";
        let e = anyhow!("unknown computation '{name}'");
        assert_eq!(e.to_string(), "unknown computation 'x'");
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(e.to_string(), "1 + 2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn open() -> crate::Result<String> {
            let s = std::fs::read_to_string("/nonexistent/definitely/missing")?;
            Ok(s)
        }
        assert!(open().is_err());
    }

    #[test]
    fn ensure_with_and_without_message() {
        fn check(v: usize) -> crate::Result<()> {
            ensure!(v > 0);
            ensure!(v < 10, "v {v} too large");
            Ok(())
        }
        assert!(check(5).is_ok());
        assert_eq!(check(0).unwrap_err().to_string(), "condition failed: v > 0");
        assert_eq!(check(11).unwrap_err().to_string(), "v 11 too large");
    }

    #[test]
    fn bail_returns_error() {
        fn f() -> crate::Result<()> {
            bail!("nope: {}", 3);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope: 3");
    }
}
