//! Synthetic dataset substrate (offline substitute for MNIST / Fashion-
//! MNIST / CIFAR-10 — see DESIGN.md §Substitutions).
//!
//! Each class c gets a smoothed random template T_c; a sample is a
//! randomly shifted, scaled copy of its class template plus pixel noise:
//!     x = α · shift(T_c, δ) + σ · ε.
//! Shift invariance makes convolution the right inductive bias (so cut
//! placement matters like it does on image data), class templates make the
//! task learnable, and the noise level keeps it non-trivial.  Shapes,
//! class count and dataset sizes match the real datasets.

#[cfg(feature = "mnist")]
pub mod idx;
pub mod init;
pub mod partition;
pub mod population;

use crate::model::ShapeSpec;
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

/// In-memory dataset: row-major samples + integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub input_shape: Vec<usize>,
    pub classes: usize,
    /// len = n_samples * input_elems.
    pub x: Vec<f32>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        let e = self.input_elems();
        &self.x[i * e..(i + 1) * e]
    }

    /// Gather samples `idx` into a batch tensor + one-hot label tensor.
    pub fn batch(&self, idx: &[usize]) -> (Tensor, Tensor) {
        let e = self.input_elems();
        let mut xb = Vec::with_capacity(idx.len() * e);
        let mut yb = vec![0.0f32; idx.len() * self.classes];
        for (row, &i) in idx.iter().enumerate() {
            xb.extend_from_slice(self.sample(i));
            yb[row * self.classes + self.labels[i] as usize] = 1.0;
        }
        let mut shape = vec![idx.len()];
        shape.extend_from_slice(&self.input_shape);
        (Tensor::new(xb, shape), Tensor::new(yb, vec![idx.len(), self.classes]))
    }
}

/// Generator parameters per logical dataset name.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub noise: f64,
    pub shift_max: i64,
    pub template_smoothing: usize,
    pub seed: u64,
}

impl SynthConfig {
    pub fn for_dataset(name: &str) -> SynthConfig {
        let cfg = |noise, shift_max, template_smoothing, seed| SynthConfig {
            noise,
            shift_max,
            template_smoothing,
            seed,
        };
        match name {
            // fmnist: same shape as mnist, harder (more noise, bigger shifts).
            "fmnist" => cfg(0.45, 3, 2, 0xF0),
            "cifar10" => cfg(0.55, 3, 2, 0xC1),
            // mnist (default): mild noise, small shifts.
            _ => cfg(0.30, 2, 3, 0x30),
        }
    }
}

/// Smooth a (h, w, c) image in-place with `iters` 3x3 box filters.
fn box_smooth(img: &mut [f32], h: usize, w: usize, c: usize, iters: usize) {
    let mut tmp = vec![0.0f32; img.len()];
    for _ in 0..iters {
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    let mut acc = 0.0;
                    let mut cnt = 0.0;
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let yy = y as i64 + dy;
                            let xx = x as i64 + dx;
                            if (0..h as i64).contains(&yy) && (0..w as i64).contains(&xx) {
                                acc += img[(yy as usize * w + xx as usize) * c + ch];
                                cnt += 1.0;
                            }
                        }
                    }
                    tmp[(y * w + x) * c + ch] = acc / cnt;
                }
            }
        }
        img.copy_from_slice(&tmp);
    }
}

/// Shift a (h, w, c) image by (dy, dx), zero-filling borders.
pub(crate) fn shift(img: &[f32], h: usize, w: usize, c: usize, dy: i64, dx: i64, out: &mut [f32]) {
    out.fill(0.0);
    for y in 0..h as i64 {
        for x in 0..w as i64 {
            let sy = y - dy;
            let sx = x - dx;
            if (0..h as i64).contains(&sy) && (0..w as i64).contains(&sx) {
                let src = ((sy as usize * w) + sx as usize) * c;
                let dst = ((y as usize * w) + x as usize) * c;
                out[dst..dst + c].copy_from_slice(&img[src..src + c]);
            }
        }
    }
}

/// Class templates for the spec's geometry, from the dataset-identity
/// seed in `cfg` (stable across runs and across train/test splits).  Both
/// the eager [`generate`] and the lazy per-client
/// [`population::ClientSampler`] draw samples against these — ONE
/// implementation keeps the two substrates pixel-compatible.
pub fn class_templates(spec: &ShapeSpec, cfg: &SynthConfig) -> Vec<Vec<f32>> {
    let (h, w, c) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
    let e = h * w * c;
    let mut trng = Pcg::new(cfg.seed, 0x7E47u64);
    (0..spec.classes)
        .map(|_| {
            let mut t: Vec<f32> = (0..e).map(|_| trng.normal() as f32).collect();
            box_smooth(&mut t, h, w, c, cfg.template_smoothing);
            // Normalize template energy so classes are equally separable.
            let norm = (t.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / e as f64)
                .sqrt()
                .max(1e-6) as f32;
            t.iter_mut().for_each(|v| *v /= norm);
            t
        })
        .collect()
}

/// Generate `n` samples of dataset `name` with the spec's input geometry.
pub fn generate(spec: &ShapeSpec, name: &str, n: usize, seed: u64) -> Dataset {
    let cfg = SynthConfig::for_dataset(name);
    let (h, w, c) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
    let e = h * w * c;
    let classes = spec.classes;
    let templates = class_templates(spec, &cfg);

    let mut rng = Pcg::new(seed ^ cfg.seed.rotate_left(17), 0xDA7A);
    let mut x = vec![0.0f32; n * e];
    let mut labels = Vec::with_capacity(n);
    let mut shifted = vec![0.0f32; e];
    for i in 0..n {
        let cls = rng.below(classes);
        labels.push(cls as u8);
        let dy = rng.below(2 * cfg.shift_max as usize + 1) as i64 - cfg.shift_max;
        let dx = rng.below(2 * cfg.shift_max as usize + 1) as i64 - cfg.shift_max;
        shift(&templates[cls], h, w, c, dy, dx, &mut shifted);
        let alpha = rng.range(0.8, 1.2) as f32;
        let row = &mut x[i * e..(i + 1) * e];
        for (o, &s) in row.iter_mut().zip(&shifted) {
            *o = alpha * s + (cfg.noise * rng.normal()) as f32;
        }
    }
    Dataset { input_shape: spec.input_shape.clone(), classes, x, labels }
}

/// Split sample indices across `n_clients`: IID (uniform) or label-skewed
/// via a symmetric Dirichlet(alpha) per class (standard non-IID protocol).
///
/// Convenience wrapper over [`partition::Partition::indices`] — the full
/// strategy set (including pathological shard skew) lives there.
pub fn partition(
    ds: &Dataset,
    n_clients: usize,
    dirichlet_alpha: Option<f64>,
    seed: u64,
) -> Vec<Vec<usize>> {
    let strategy = match dirichlet_alpha {
        None => partition::Partition::Iid,
        Some(alpha) => partition::Partition::Dirichlet(alpha),
    };
    strategy.indices(&ds.labels, ds.classes, n_clients, seed)
}

/// Cycling mini-batch iterator over one client's shard.
#[derive(Clone, Debug)]
pub struct Batcher {
    indices: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Pcg,
}

impl Batcher {
    pub fn new(mut indices: Vec<usize>, batch: usize, seed: u64) -> Batcher {
        assert!(!indices.is_empty(), "empty shard");
        let mut rng = Pcg::new(seed, 0xBA7C);
        rng.shuffle(&mut indices);
        Batcher { indices, cursor: 0, batch, rng }
    }

    /// Next `batch` indices, reshuffling at epoch boundaries; wraps so the
    /// batch size is always exact (samples may repeat across the seam).
    pub fn next_batch(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.batch);
        while out.len() < self.batch {
            if self.cursor >= self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.cursor = 0;
            }
            out.push(self.indices[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn mnist_spec() -> ShapeSpec {
        Manifest::builtin().for_dataset("mnist").unwrap().clone()
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = mnist_spec();
        let a = generate(&spec, "mnist", 64, 1);
        let b = generate(&spec, "mnist", 64, 1);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
        let c = generate(&spec, "mnist", 64, 2);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn all_classes_present_and_bounded() {
        let spec = mnist_spec();
        let ds = generate(&spec, "mnist", 500, 3);
        let mut seen = vec![false; ds.classes];
        for &l in &ds.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "some class missing in 500 draws");
        assert!(ds.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn classes_are_separable_by_template_correlation() {
        // Nearest-template classification on clean correlation should beat
        // chance by a wide margin — the task is learnable.
        let spec = mnist_spec();
        let ds = generate(&spec, "mnist", 400, 7);
        // Recover templates by averaging samples per class.
        let e = ds.input_elems();
        let mut means = vec![vec![0.0f64; e]; ds.classes];
        let mut counts = vec![0usize; ds.classes];
        for i in 0..ds.len() {
            let c = ds.labels[i] as usize;
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(ds.sample(i)) {
                *m += v as f64;
            }
        }
        for (m, &cnt) in means.iter_mut().zip(&counts) {
            m.iter_mut().for_each(|v| *v /= cnt.max(1) as f64);
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let s = ds.sample(i);
            let best = (0..ds.classes)
                .max_by(|&a, &b| {
                    let da: f64 = means[a].iter().zip(s).map(|(m, &v)| m * v as f64).sum();
                    let db: f64 = means[b].iter().zip(s).map(|(m, &v)| m * v as f64).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy only {acc}");
    }

    #[test]
    fn iid_partition_is_balanced_and_complete() {
        let spec = mnist_spec();
        let ds = generate(&spec, "mnist", 1000, 5);
        let shards = partition(&ds, 10, None, 1);
        assert_eq!(shards.iter().map(Vec::len).sum::<usize>(), 1000);
        assert!(shards.iter().all(|s| s.len() == 100));
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn dirichlet_partition_skews_labels() {
        let spec = mnist_spec();
        let ds = generate(&spec, "mnist", 2000, 6);
        let shards = partition(&ds, 10, Some(0.2), 2);
        assert_eq!(shards.iter().map(Vec::len).sum::<usize>(), 2000);
        // With alpha=0.2 at least one client should be visibly skewed:
        // its most common label > 30% of its data.
        let skewed = shards.iter().filter(|s| !s.is_empty()).any(|s| {
            let mut hist = [0usize; 10];
            for &i in s.iter() {
                hist[ds.labels[i] as usize] += 1;
            }
            let max = *hist.iter().max().unwrap();
            max as f64 > 0.3 * s.len() as f64
        });
        assert!(skewed, "no skew detected at alpha=0.2");
    }

    #[test]
    fn batcher_cycles_with_exact_size() {
        let mut b = Batcher::new((0..7).collect(), 3, 9);
        let mut seen = vec![0usize; 7];
        for _ in 0..7 {
            let batch = b.next_batch();
            assert_eq!(batch.len(), 3);
            for i in batch {
                seen[i] += 1;
            }
        }
        // 21 draws over 7 items: every item drawn ≥ 2 times.
        assert!(seen.iter().all(|&c| c >= 2), "{seen:?}");
    }

    #[test]
    fn batch_tensor_shapes_and_onehot() {
        let spec = mnist_spec();
        let ds = generate(&spec, "mnist", 50, 8);
        let (x, y) = ds.batch(&[0, 1, 2]);
        assert_eq!(x.shape, vec![3, 28, 28, 1]);
        assert_eq!(y.shape, vec![3, 10]);
        for row in 0..3 {
            let r = &y.data[row * 10..(row + 1) * 10];
            assert_eq!(r.iter().sum::<f32>(), 1.0);
            assert_eq!(r[ds.labels[row] as usize], 1.0);
        }
    }
}
