//! Client data partitioning — the data axis of the scenario engine.
//!
//! A [`Partition`] strategy splits one dataset's sample indices across N
//! clients.  Three strategies cover the standard federated-learning
//! evaluation protocols (see DESIGN.md §Scenarios for the math):
//!
//! * **IID** — a uniform shuffle dealt round-robin: every client sees the
//!   global label distribution and |D^n| is equal up to one sample.
//! * **Dirichlet(α)** — label skew: for every class c a proportion vector
//!   p_c ~ Dir(α·1_N) decides how that class's samples split across
//!   clients.  α → ∞ recovers IID; α → 0 assigns each class to
//!   essentially one client.  This is the standard non-IID benchmark
//!   protocol (Hsu et al. 2019), and the protocol cut-layer studies such
//!   as arXiv:2412.15536 sweep.
//! * **Shards(s)** — pathological skew (McMahan et al. 2017): sort
//!   indices by label, slice into N·s contiguous shards, deal s shards to
//!   each client.  Each client then holds at most ~s·⌈spanned labels⌉
//!   distinct classes regardless of α-style randomness.
//!
//! All strategies are deterministic in `seed`, and every sample is
//! assigned to exactly one client (full coverage).  Skewed strategies can
//! produce empty shards (e.g. Dirichlet with small α);
//! [`Partition::indices`] repairs those by moving single samples from the
//! largest shard, so every client can always build a [`super::Batcher`].
//!
//! The per-client shard sizes drive the aggregation weights ρ^n = |D^n|/|D|
//! the trainer reduces with (sample-count-weighted FedAvg) — see
//! [`crate::coordinator::Trainer`].

use crate::util::rng::Pcg;

/// How sample indices are split across clients.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum Partition {
    /// Uniform shuffle, round-robin deal (every client ≈ the global
    /// distribution).
    #[default]
    Iid,
    /// Symmetric-Dirichlet label skew with concentration α > 0.
    Dirichlet(f64),
    /// Pathological label skew: label-sorted shards, `s ≥ 1` shards per
    /// client.
    Shards(usize),
}

impl Partition {
    /// Parse the CLI syntax: `iid` | `dirichlet:<alpha>` | `shards:<s>`.
    pub fn parse(s: &str) -> anyhow::Result<Partition> {
        let lower = s.to_ascii_lowercase();
        if lower == "iid" {
            return Ok(Partition::Iid);
        }
        if let Some(a) = lower.strip_prefix("dirichlet:") {
            let alpha: f64 = a
                .parse()
                .map_err(|e| anyhow::anyhow!("--partition dirichlet:{a}: {e}"))?;
            anyhow::ensure!(
                alpha.is_finite() && alpha > 0.0,
                "dirichlet alpha must be finite and > 0, got {alpha}"
            );
            return Ok(Partition::Dirichlet(alpha));
        }
        if let Some(k) = lower.strip_prefix("shards:") {
            let s: usize = k
                .parse()
                .map_err(|e| anyhow::anyhow!("--partition shards:{k}: {e}"))?;
            anyhow::ensure!(s >= 1, "shards per client must be >= 1");
            return Ok(Partition::Shards(s));
        }
        anyhow::bail!("unknown partition '{s}' (iid|dirichlet:<alpha>|shards:<s>)")
    }

    /// Human/CSV-friendly name ("iid", "dirichlet(0.3)", "shards(2)").
    pub fn name(&self) -> String {
        match self {
            Partition::Iid => "iid".to_string(),
            Partition::Dirichlet(a) => format!("dirichlet({a})"),
            Partition::Shards(s) => format!("shards({s})"),
        }
    }

    /// Split sample indices `0..labels.len()` across `n_clients`.
    ///
    /// Deterministic in `seed`; every sample lands in exactly one shard
    /// and every shard is non-empty (skew-induced empties are repaired by
    /// moving single samples from the largest shard).  `classes` is the
    /// label-space size (Dirichlet draws one proportion vector per class,
    /// present or not, so the RNG stream only depends on the config).
    pub fn indices(
        &self,
        labels: &[u8],
        classes: usize,
        n_clients: usize,
        seed: u64,
    ) -> Vec<Vec<usize>> {
        assert!(n_clients > 0, "need at least one client");
        assert!(
            labels.len() >= n_clients,
            "cannot split {} samples across {} clients",
            labels.len(),
            n_clients
        );
        let mut rng = Pcg::new(seed, 0x59117u64);
        let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
        match *self {
            Partition::Iid => {
                let mut idx: Vec<usize> = (0..labels.len()).collect();
                rng.shuffle(&mut idx);
                for (i, s) in idx.into_iter().enumerate() {
                    shards[i % n_clients].push(s);
                }
            }
            Partition::Dirichlet(alpha) => {
                for cls in 0..classes {
                    let mut members: Vec<usize> = (0..labels.len())
                        .filter(|&i| labels[i] as usize == cls)
                        .collect();
                    rng.shuffle(&mut members);
                    let props = rng.dirichlet(alpha, n_clients);
                    let mut start = 0usize;
                    for (ci, &p) in props.iter().enumerate() {
                        let take = if ci + 1 == n_clients {
                            members.len() - start
                        } else {
                            ((p * members.len() as f64).round() as usize)
                                .min(members.len() - start)
                        };
                        shards[ci].extend_from_slice(&members[start..start + take]);
                        start += take;
                    }
                }
                for s in &mut shards {
                    rng.shuffle(s);
                }
            }
            Partition::Shards(per_client) => {
                let per_client = per_client.max(1);
                let total_shards = n_clients * per_client;
                // Label-sorted order (stable by index) → contiguous runs
                // of each class.
                let mut order: Vec<usize> = (0..labels.len()).collect();
                order.sort_by_key(|&i| (labels[i], i));
                // Slice into near-equal contiguous chunks; the first
                // `rem` chunks absorb the remainder.
                let base = order.len() / total_shards;
                let rem = order.len() % total_shards;
                let mut chunks: Vec<Vec<usize>> = Vec::with_capacity(total_shards);
                let mut start = 0usize;
                for c in 0..total_shards {
                    let take = base + usize::from(c < rem);
                    chunks.push(order[start..start + take].to_vec());
                    start += take;
                }
                rng.shuffle(&mut chunks);
                for (c, chunk) in chunks.into_iter().enumerate() {
                    shards[c % n_clients].extend_from_slice(&chunk);
                }
                for s in &mut shards {
                    rng.shuffle(s);
                }
            }
        }
        repair_empty_shards(&mut shards);
        shards
    }
}

/// Move single samples from the largest shard into each empty shard so
/// every client can batch.  Deterministic: empties are filled in client
/// order, donors are the largest shard (lowest index on ties), donating
/// their last element.
fn repair_empty_shards(shards: &mut [Vec<usize>]) {
    for i in 0..shards.len() {
        if !shards[i].is_empty() {
            continue;
        }
        let donor = shards
            .iter()
            .enumerate()
            .max_by(|(ai, a), (bi, b)| a.len().cmp(&b.len()).then(bi.cmp(ai)))
            .map(|(j, _)| j)
            .unwrap();
        assert!(shards[donor].len() > 1, "not enough samples to cover every client");
        let moved = shards[donor].pop().unwrap();
        shards[i].push(moved);
    }
}

/// Per-class label fractions of one shard (statistics for tests and
/// diagnostics; each row sums to 1 for a non-empty shard).
pub fn label_marginals(labels: &[u8], classes: usize, shard: &[usize]) -> Vec<f64> {
    let mut hist = vec![0.0f64; classes];
    for &i in shard {
        hist[labels[i] as usize] += 1.0;
    }
    if !shard.is_empty() {
        let n = shard.len() as f64;
        for h in &mut hist {
            *h /= n;
        }
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Balanced synthetic label vector: `n` samples over `classes` labels.
    fn labels(n: usize, classes: usize) -> Vec<u8> {
        (0..n).map(|i| (i % classes) as u8).collect()
    }

    fn assert_full_coverage(shards: &[Vec<usize>], n: usize) {
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "not a partition of 0..{n}");
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        assert_eq!(Partition::parse("iid").unwrap(), Partition::Iid);
        assert_eq!(Partition::parse("IID").unwrap(), Partition::Iid);
        assert_eq!(Partition::parse("dirichlet:0.3").unwrap(), Partition::Dirichlet(0.3));
        assert_eq!(Partition::parse("shards:2").unwrap(), Partition::Shards(2));
        assert!(Partition::parse("dirichlet:-1").is_err());
        assert!(Partition::parse("dirichlet:nope").is_err());
        assert!(Partition::parse("shards:0").is_err());
        assert!(Partition::parse("zipf:2").is_err());
        assert_eq!(Partition::Dirichlet(0.3).name(), "dirichlet(0.3)");
    }

    #[test]
    fn every_strategy_covers_all_samples_nonempty() {
        let ls = labels(1000, 10);
        for p in [Partition::Iid, Partition::Dirichlet(0.1), Partition::Shards(2)] {
            let shards = p.indices(&ls, 10, 10, 7);
            assert_eq!(shards.len(), 10);
            assert_full_coverage(&shards, 1000);
            assert!(shards.iter().all(|s| !s.is_empty()), "{} left an empty shard", p.name());
        }
    }

    #[test]
    fn strategies_are_deterministic_in_seed() {
        let ls = labels(500, 10);
        for p in [Partition::Iid, Partition::Dirichlet(0.5), Partition::Shards(3)] {
            let a = p.indices(&ls, 10, 8, 42);
            let b = p.indices(&ls, 10, 8, 42);
            assert_eq!(a, b, "{} not deterministic", p.name());
            let c = p.indices(&ls, 10, 8, 43);
            assert_ne!(a, c, "{} ignores the seed", p.name());
        }
    }

    #[test]
    fn iid_marginals_are_near_uniform() {
        let ls = labels(2000, 10);
        for shard in Partition::Iid.indices(&ls, 10, 10, 3) {
            for m in label_marginals(&ls, 10, &shard) {
                assert!((m - 0.1).abs() < 0.08, "IID marginal {m} far from 0.1");
            }
        }
    }

    #[test]
    fn dirichlet_skew_grows_as_alpha_shrinks() {
        // Mean max-marginal across clients: α=0.1 must be much more
        // concentrated than α=10 (which is near IID's 0.1).
        let ls = labels(2000, 10);
        let mean_max = |alpha: f64| {
            let shards = Partition::Dirichlet(alpha).indices(&ls, 10, 10, 5);
            let sum: f64 = shards
                .iter()
                .map(|s| {
                    label_marginals(&ls, 10, s)
                        .into_iter()
                        .fold(0.0f64, f64::max)
                })
                .sum();
            sum / shards.len() as f64
        };
        let skewed = mean_max(0.1);
        let mild = mean_max(10.0);
        assert!(skewed > 0.35, "alpha=0.1 max-marginal only {skewed}");
        assert!(mild < 0.3, "alpha=10 max-marginal {mild} too skewed");
        assert!(skewed > 1.5 * mild, "no separation: {skewed} vs {mild}");
    }

    #[test]
    fn shards_limit_distinct_labels_per_client() {
        // 2000 samples, 10 classes, s=2 shards of 100 contiguous
        // label-sorted samples: each shard spans ≤ 2 labels, so every
        // client sees ≤ 4 distinct labels (vs ~10 under IID).
        let ls = labels(2000, 10);
        let shards = Partition::Shards(2).indices(&ls, 10, 10, 9);
        assert_full_coverage(&shards, 2000);
        for s in &shards {
            let distinct = label_marginals(&ls, 10, s).iter().filter(|&&m| m > 0.0).count();
            assert!(distinct <= 4, "client has {distinct} labels under shards:2");
        }
    }

    #[test]
    fn empty_shards_are_repaired() {
        // 4 samples of one class across 4 clients under extreme skew:
        // Dirichlet will pile everything on few clients; repair must
        // leave everyone with at least one sample.
        let ls = vec![0u8; 4];
        let shards = Partition::Dirichlet(0.01).indices(&ls, 10, 4, 1);
        assert_eq!(shards.len(), 4);
        assert!(shards.iter().all(|s| !s.is_empty()));
        assert_full_coverage(&shards, 4);
    }
}
