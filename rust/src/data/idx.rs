//! Real-dataset loading: the IDX container format MNIST and
//! Fashion-MNIST ship in (`--features mnist`).
//!
//! The IDX header is 4 magic bytes — `00 00 <type> <ndims>` with type
//! `0x08` (unsigned byte) for both files — followed by `ndims` big-endian
//! u32 dimension sizes and the raw payload.  Images are
//! `n × rows × cols` u8, labels are `n` u8; we normalize pixels to
//! `[0, 1]` f32 in the crate's existing row-major `[h, w, c]` sample
//! layout (c = 1 for these datasets, so the byte order maps directly).
//!
//! Loading is strictly additive to the synthetic substrate: the trainer
//! keeps calling [`super::generate`], and callers that want real data
//! use [`load_or_synthetic`], which reads the conventional file pair
//! from [`data_dir`] (the `SFLGA_MNIST_DIR` environment variable,
//! default `data/mnist`) and silently falls back to the synthetic
//! generator when the files are absent — so a checkout without the
//! ~11 MB of downloads behaves exactly like the default build.  Only
//! *present-but-malformed* files are an error: a corrupt download should
//! never be papered over with synthetic data.  Files must be
//! uncompressed (`gunzip` the official archives); there is no flate
//! dependency to gate on.

use std::path::{Path, PathBuf};

use super::{generate, Dataset};
use crate::model::ShapeSpec;

/// IDX element-type code for unsigned byte payloads.
const TYPE_U8: u8 = 0x08;

/// Which half of the official file pair to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

impl Split {
    /// The conventional file-name stems (`train-*` / `t10k-*`).
    fn stem(self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::Test => "t10k",
        }
    }
}

/// Directory the loader looks in: `SFLGA_MNIST_DIR` if set, else
/// `data/mnist` relative to the working directory.
pub fn data_dir() -> PathBuf {
    std::env::var_os("SFLGA_MNIST_DIR").map_or_else(|| PathBuf::from("data/mnist"), PathBuf::from)
}

/// Parse one IDX payload: returns the dimension sizes and the raw bytes.
///
/// Validates the magic (two zero bytes, u8 element type, expected rank),
/// the advertised dimensions against the actual byte count, and guards
/// the product against overflow — arbitrary headers must error, never
/// panic or over-allocate.
pub fn parse_idx(bytes: &[u8], want_rank: usize) -> anyhow::Result<(Vec<usize>, &[u8])> {
    anyhow::ensure!(bytes.len() >= 4, "IDX header truncated: {} bytes", bytes.len());
    anyhow::ensure!(
        bytes[0] == 0 && bytes[1] == 0,
        "bad IDX magic {:02x}{:02x}.. (want 0000..)",
        bytes[0],
        bytes[1]
    );
    anyhow::ensure!(
        bytes[2] == TYPE_U8,
        "IDX element type 0x{:02x} unsupported (want 0x08 = u8)",
        bytes[2]
    );
    let rank = bytes[3] as usize;
    anyhow::ensure!(
        rank == want_rank,
        "IDX rank {rank} (want {want_rank}: magic 0x0000{TYPE_U8:02x}{want_rank:02x})"
    );
    let header = 4 + 4 * rank;
    anyhow::ensure!(bytes.len() >= header, "IDX header truncated: {} bytes", bytes.len());
    let mut dims = Vec::with_capacity(rank);
    let mut total = 1usize;
    for i in 0..rank {
        let off = 4 + 4 * i;
        let d = u32::from_be_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        total = total
            .checked_mul(d)
            .ok_or_else(|| anyhow::anyhow!("IDX dimensions overflow: {dims:?} x {d}"))?;
        dims.push(d);
    }
    let payload = &bytes[header..];
    anyhow::ensure!(
        payload.len() == total,
        "IDX payload is {} bytes, header {dims:?} promises {total}",
        payload.len()
    );
    Ok((dims, payload))
}

/// Load one `images + labels` IDX file pair into a [`Dataset`] with the
/// spec's geometry.  Errors if either file is unreadable or malformed,
/// if the two disagree on the sample count, or if the image geometry
/// does not match the spec (these datasets are single-channel, so the
/// spec must be `h x w x 1`).
pub fn load_pair(images: &Path, labels: &Path, spec: &ShapeSpec) -> anyhow::Result<Dataset> {
    let (h, w, c) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
    anyhow::ensure!(c == 1, "IDX images are single-channel; spec {} wants c={c}", spec.key);
    let img_bytes = std::fs::read(images)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", images.display()))?;
    let lbl_bytes = std::fs::read(labels)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", labels.display()))?;
    let (idims, pixels) =
        parse_idx(&img_bytes, 3).map_err(|e| anyhow::anyhow!("{}: {e}", images.display()))?;
    let (ldims, label_bytes) =
        parse_idx(&lbl_bytes, 1).map_err(|e| anyhow::anyhow!("{}: {e}", labels.display()))?;
    anyhow::ensure!(
        idims[0] == ldims[0],
        "{} has {} images but {} has {} labels",
        images.display(),
        idims[0],
        labels.display(),
        ldims[0]
    );
    anyhow::ensure!(
        idims[1] == h && idims[2] == w,
        "images are {}x{}, spec {} wants {h}x{w}",
        idims[1],
        idims[2],
        spec.key
    );
    for (i, &l) in label_bytes.iter().enumerate() {
        anyhow::ensure!(
            (l as usize) < spec.classes,
            "label {l} at sample {i} out of range (classes = {})",
            spec.classes
        );
    }
    // u8 -> [0,1] f32; row-major h*w with c=1 is already the sample layout.
    let x: Vec<f32> = pixels.iter().map(|&p| p as f32 / 255.0).collect();
    Ok(Dataset {
        input_shape: spec.input_shape.clone(),
        classes: spec.classes,
        x,
        labels: label_bytes.to_vec(),
    })
}

/// The conventional file pair for a split under `dir`:
/// `{train,t10k}-images-idx3-ubyte` + `{train,t10k}-labels-idx1-ubyte`.
pub fn split_paths(dir: &Path, split: Split) -> (PathBuf, PathBuf) {
    let stem = split.stem();
    (
        dir.join(format!("{stem}-images-idx3-ubyte")),
        dir.join(format!("{stem}-labels-idx1-ubyte")),
    )
}

/// Real data when present, synthetic otherwise.
///
/// Looks for the split's file pair under [`data_dir`]; if both exist
/// they MUST parse (a corrupt file is an error, not a fallback), and the
/// first `n` samples are returned.  If either file is absent — or the
/// dataset name has no IDX distribution (cifar10) — this is exactly
/// [`generate`]`(spec, name, n, seed)`.
pub fn load_or_synthetic(
    spec: &ShapeSpec,
    name: &str,
    split: Split,
    n: usize,
    seed: u64,
) -> anyhow::Result<Dataset> {
    load_or_synthetic_from(&data_dir(), spec, name, split, n, seed)
}

/// [`load_or_synthetic`] against an explicit directory instead of the
/// `SFLGA_MNIST_DIR` lookup (tests use this to avoid mutating process
/// environment under the parallel test runner).
pub fn load_or_synthetic_from(
    dir: &Path,
    spec: &ShapeSpec,
    name: &str,
    split: Split,
    n: usize,
    seed: u64,
) -> anyhow::Result<Dataset> {
    let (images, labels) = split_paths(dir, split);
    let idx_shaped = matches!(name, "mnist" | "fmnist");
    if !(idx_shaped && images.exists() && labels.exists()) {
        return Ok(generate(spec, name, n, seed));
    }
    let mut ds = load_pair(&images, &labels, spec)?;
    anyhow::ensure!(ds.len() >= n, "{} has {} samples, need {n}", images.display(), ds.len());
    ds.x.truncate(n * ds.input_elems());
    ds.labels.truncate(n);
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn spec() -> ShapeSpec {
        Manifest::builtin().for_dataset("mnist").unwrap().clone()
    }

    /// Serialize a tiny IDX pair: `n` 28x28 images whose pixel (i, j) is
    /// `(sample + i + j) % 256`, labels `sample % 10`.
    fn fake_pair(n: usize) -> (Vec<u8>, Vec<u8>) {
        let mut img = vec![0u8, 0, TYPE_U8, 3];
        for d in [n as u32, 28, 28] {
            img.extend_from_slice(&d.to_be_bytes());
        }
        for s in 0..n {
            for i in 0..28usize {
                for j in 0..28usize {
                    img.push(((s + i + j) % 256) as u8);
                }
            }
        }
        let mut lbl = vec![0u8, 0, TYPE_U8, 1];
        lbl.extend_from_slice(&(n as u32).to_be_bytes());
        lbl.extend((0..n).map(|s| (s % 10) as u8));
        (img, lbl)
    }

    /// A scratch dir under the target-adjacent tmp root, cleaned on drop.
    struct TmpDir(PathBuf);
    impl TmpDir {
        fn new(tag: &str) -> TmpDir {
            let d = std::env::temp_dir().join(format!("sfl_ga_idx_{tag}_{}", std::process::id()));
            std::fs::create_dir_all(&d).unwrap();
            TmpDir(d)
        }
    }
    impl Drop for TmpDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    #[test]
    fn well_formed_pair_loads_normalized() {
        let tmp = TmpDir::new("ok");
        let (img, lbl) = fake_pair(5);
        let (ip, lp) = split_paths(&tmp.0, Split::Train);
        std::fs::write(&ip, &img).unwrap();
        std::fs::write(&lp, &lbl).unwrap();
        let ds = load_pair(&ip, &lp, &spec()).unwrap();
        assert_eq!(ds.len(), 5);
        assert_eq!(ds.input_shape, vec![28, 28, 1]);
        assert_eq!(ds.labels, vec![0, 1, 2, 3, 4]);
        // Pixel (0,0) of sample 3 is byte 3 -> 3/255.
        assert_eq!(ds.sample(3)[0], 3.0 / 255.0);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn malformed_headers_are_clean_errors() {
        let s = spec();
        let (img, lbl) = fake_pair(2);
        // Wrong element type.
        let mut bad = img.clone();
        bad[2] = 0x0D;
        assert!(parse_idx(&bad, 3).unwrap_err().to_string().contains("element type"));
        // Wrong rank (labels parsed as images).
        assert!(parse_idx(&lbl, 3).unwrap_err().to_string().contains("rank"));
        // Truncated payload.
        let mut short = img.clone();
        short.truncate(img.len() - 9);
        assert!(parse_idx(&short, 3).unwrap_err().to_string().contains("promises"));
        // Count mismatch between the pair.
        let tmp = TmpDir::new("mismatch");
        let (ip, lp) = split_paths(&tmp.0, Split::Train);
        let (_, lbl3) = fake_pair(3);
        std::fs::write(&ip, &img).unwrap();
        std::fs::write(&lp, &lbl3).unwrap();
        let err = load_pair(&ip, &lp, &s).unwrap_err().to_string();
        assert!(err.contains("2 images") && err.contains("3 labels"), "{err}");
    }

    #[test]
    fn absent_files_fall_back_to_synthetic() {
        let tmp = TmpDir::new("absent");
        let s = spec();
        let ds = load_or_synthetic_from(&tmp.0, &s, "mnist", Split::Train, 16, 7).unwrap();
        let synth = generate(&s, "mnist", 16, 7);
        assert_eq!(ds.x, synth.x, "fallback must be the synthetic substrate verbatim");
        assert_eq!(ds.labels, synth.labels);
    }

    #[test]
    fn present_files_shadow_synthetic_and_truncate_to_n() {
        let tmp = TmpDir::new("shadow");
        let (img, lbl) = fake_pair(8);
        let (ip, lp) = split_paths(&tmp.0, Split::Train);
        std::fs::write(&ip, &img).unwrap();
        std::fs::write(&lp, &lbl).unwrap();
        let s = spec();
        let ds = load_or_synthetic_from(&tmp.0, &s, "mnist", Split::Train, 6, 7).unwrap();
        let too_many = load_or_synthetic_from(&tmp.0, &s, "mnist", Split::Train, 9, 7);
        // cifar10 has no IDX distribution: same dir, still synthetic.
        let cifar = Manifest::builtin().for_dataset("cifar10").unwrap().clone();
        let cds = load_or_synthetic_from(&tmp.0, &cifar, "cifar10", Split::Train, 4, 7).unwrap();
        assert_eq!(ds.len(), 6);
        assert_eq!(ds.labels, vec![0, 1, 2, 3, 4, 5]);
        assert!(too_many.unwrap_err().to_string().contains("need 9"));
        assert_eq!(cds.x, generate(&cifar, "cifar10", 4, 7).x);
    }
}
