//! Parameter initialization mirroring the python side: He-normal weights,
//! zero biases.  (Bit-identical parity with jax.random is not required —
//! both inits draw from the same distribution family; equivalence tests
//! compare *computations* under identical weights, which travel through
//! the artifacts as explicit inputs.)

use crate::model::{InitKind, ShapeSpec};
use crate::tensor::Params;
use crate::util::rng::Pcg;

/// Initialize every parameter array per the spec's declared [`InitKind`]:
/// He-normal weights, zero biases, unit layernorm gains.  Only HeNormal
/// consumes rng draws, so constant-init arrays (which is all the builtin
/// model's rank-1 params are) leave the draw sequence — and with it the
/// builtin init bytes — exactly as before the registry refactor.
pub fn init_params(spec: &ShapeSpec, seed: u64) -> Params {
    let mut rng = Pcg::new(seed, 0x1417);
    spec.params
        .iter()
        .map(|p| match p.init {
            InitKind::Zero => vec![0.0f32; p.size()],
            InitKind::One => vec![1.0f32; p.size()],
            InitKind::HeNormal => {
                let fan_in: usize = p.shape[..p.shape.len() - 1].iter().product();
                let std = (2.0 / fan_in as f64).sqrt();
                (0..p.size()).map(|_| (rng.normal() * std) as f32).collect()
            }
        })
        .collect()
}

/// Split a full parameter set at cut v: (client-side, server-side).
pub fn split_params(spec: &ShapeSpec, cut: usize, params: &[Vec<f32>]) -> (Params, Params) {
    let nc = spec.cut(cut).client_params;
    (params[..nc].to_vec(), params[nc..].to_vec())
}

/// Reassemble a full parameter set from the two halves.
pub fn join_params(wc: &[Vec<f32>], ws: &[Vec<f32>]) -> Params {
    let mut out = wc.to_vec();
    out.extend_from_slice(ws);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn spec() -> ShapeSpec {
        Manifest::builtin().for_dataset("mnist").unwrap().clone()
    }

    #[test]
    fn init_shapes_match_manifest() {
        let spec = spec();
        let p = init_params(&spec, 0);
        assert_eq!(p.len(), spec.params.len());
        for (buf, ps) in p.iter().zip(&spec.params) {
            assert_eq!(buf.len(), ps.size());
        }
    }

    #[test]
    fn biases_zero_weights_scaled() {
        let spec = spec();
        let p = init_params(&spec, 1);
        for (buf, ps) in p.iter().zip(&spec.params) {
            if ps.shape.len() == 1 {
                assert!(buf.iter().all(|&x| x == 0.0), "{} not zero", ps.name);
            } else {
                let fan_in: usize = ps.shape[..ps.shape.len() - 1].iter().product();
                let want_std = (2.0 / fan_in as f64).sqrt();
                let var: f64 =
                    buf.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / buf.len() as f64;
                assert!(
                    (var.sqrt() / want_std - 1.0).abs() < 0.2,
                    "{}: std {} vs He {}",
                    ps.name,
                    var.sqrt(),
                    want_std
                );
            }
        }
    }

    #[test]
    fn layernorm_gains_init_to_one() {
        let m = crate::model::registry::manifest("txf").unwrap();
        let spec = m.for_dataset("mnist").unwrap();
        let p = init_params(spec, 5);
        let mut gains = 0;
        for (buf, ps) in p.iter().zip(&spec.params) {
            if ps.init == InitKind::One {
                gains += 1;
                assert!(buf.iter().all(|&x| x == 1.0), "{} not ones", ps.name);
            }
        }
        assert_eq!(gains, 4, "two blocks x two layernorms");
    }

    #[test]
    fn split_join_roundtrip() {
        let spec = spec();
        let p = init_params(&spec, 2);
        for v in 1..=4 {
            let (wc, ws) = split_params(&spec, v, &p);
            assert_eq!(wc.len(), spec.cut(v).client_params);
            assert_eq!(join_params(&wc, &ws), p);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let spec = spec();
        let a = init_params(&spec, 3);
        let b = init_params(&spec, 4);
        assert_ne!(a[0], b[0]);
    }
}
