//! Lazy per-client data substrate for the virtual population
//! (DESIGN.md §Population).
//!
//! The eager path ([`crate::data::generate`] + `Partition::indices` +
//! [`crate::data::Batcher`]) materializes the whole federation's data up
//! front — O(N·spc) memory, fine for tens of clients, fatal for a
//! million.  [`ClientSampler`] replaces it with pure functions: sample
//! `s` of client `i` is a deterministic function of
//! `(run_seed, client_id, s)` alone, synthesized on demand against the
//! SAME class templates ([`crate::data::class_templates`]) and the same
//! per-sample transform (shift + scale + pixel noise) the eager
//! generator applies.  A round materializes only the drawn cohort's
//! batches; nothing about a client persists between rounds, so resident
//! state is O(cohort · batch) however large N grows, and any derivation
//! order yields identical bits (`tests/population.rs`).
//!
//! Partition strategies translate to per-client *label laws*:
//! * `Iid` — every sample's class uniform over the classes;
//! * `Dirichlet(α)` — client i draws a categorical p_i ~ Dir(α·1_C) from
//!   its keyed stream once, then labels i.i.d. from p_i (the virtual
//!   dual of the eager per-class Dirichlet allocation: same marginal
//!   skew law, client-local instead of dataset-global);
//! * `Shards(s)` — client i holds s seeded distinct classes, labels
//!   uniform among them (pathological skew).
//!
//! Every client contributes the same `samples_per_client`, so the
//! FedAvg weights ρ^n = |D^n|/|D| are uniformly 1/N — no O(N) weight
//! vector needs to exist.

use crate::data::partition::Partition;
use crate::data::{class_templates, shift, SynthConfig};
use crate::model::ShapeSpec;
use crate::runtime::Tensor;
use crate::util::rng::{mix2, mix3, Pcg};

/// Pcg stream tag for the per-client label-law draw.
const STREAM_LABEL: u64 = 0x1ABE;
/// Pcg stream tag for per-sample synthesis (shared with the eager
/// generator's sample stream so the transforms stay recognizably one
/// substrate, though the seeding is per-sample here).
const STREAM_SAMPLE: u64 = 0xDA7A;
/// Pcg stream tag for a batch's with-replacement index draws.
const STREAM_BATCH: u64 = 0xBA7C;

/// A client's label law, derived once per batch from its keyed stream.
enum LabelLaw {
    Uniform,
    /// Cumulative class probabilities (Dirichlet label skew).
    Cumulative(Vec<f64>),
    /// The distinct classes this client holds (shard skew).
    Classes(Vec<usize>),
}

/// Stateless per-client sample source: any `(client, sample)` pair
/// synthesizes on demand in O(pixels), independent of N and of what was
/// derived before.
#[derive(Clone, Debug)]
pub struct ClientSampler {
    input_shape: Vec<usize>,
    classes: usize,
    cfg: SynthConfig,
    templates: Vec<Vec<f32>>,
    /// Run-level sample-stream seed — the same
    /// `seed ^ cfg.seed.rotate_left(17)` fold `generate` applies, so
    /// train streams stay domain-separated from the test split.
    data_seed: u64,
    partition: Partition,
    samples_per_client: usize,
    batch: usize,
}

impl ClientSampler {
    pub fn new(
        spec: &ShapeSpec,
        name: &str,
        partition: Partition,
        samples_per_client: usize,
        seed: u64,
    ) -> ClientSampler {
        assert!(samples_per_client > 0, "empty client shards");
        let cfg = SynthConfig::for_dataset(name);
        ClientSampler {
            input_shape: spec.input_shape.clone(),
            classes: spec.classes,
            templates: class_templates(spec, &cfg),
            data_seed: seed ^ cfg.seed.rotate_left(17),
            cfg,
            partition,
            samples_per_client,
            batch: spec.train_batch,
        }
    }

    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn samples_per_client(&self) -> usize {
        self.samples_per_client
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Resident bytes one materialized batch occupies (x + one-hot y) —
    /// the unit of the trainer's peak-residency accounting.
    pub fn batch_bytes(&self) -> usize {
        self.batch * (self.input_elems() + self.classes) * std::mem::size_of::<f32>()
    }

    /// Client `client`'s label law under the partition strategy.
    fn label_law(&self, client: u64) -> LabelLaw {
        match self.partition {
            Partition::Iid => LabelLaw::Uniform,
            Partition::Dirichlet(alpha) => {
                let mut rng = Pcg::new(mix2(self.data_seed, client), STREAM_LABEL);
                let p = rng.dirichlet(alpha, self.classes);
                let mut cum = Vec::with_capacity(self.classes);
                let mut acc = 0.0;
                for v in p {
                    acc += v;
                    cum.push(acc);
                }
                LabelLaw::Cumulative(cum)
            }
            Partition::Shards(s) => {
                let s = s.clamp(1, self.classes);
                let mut rng = Pcg::new(mix2(self.data_seed, client), STREAM_LABEL);
                let mut all: Vec<usize> = (0..self.classes).collect();
                rng.shuffle(&mut all);
                all.truncate(s);
                LabelLaw::Classes(all)
            }
        }
    }

    /// Draw a class from the law using ONE uniform from `rng` (so every
    /// law consumes the same sample-stream prefix).
    fn draw_label(&self, law: &LabelLaw, rng: &mut Pcg) -> usize {
        match law {
            LabelLaw::Uniform => rng.below(self.classes),
            LabelLaw::Cumulative(cum) => {
                let u = rng.uniform();
                cum.iter().position(|&c| u < c).unwrap_or(self.classes - 1)
            }
            LabelLaw::Classes(cs) => cs[rng.below(cs.len())],
        }
    }

    /// Synthesize sample `s` of `client` into `row` (len = input elems);
    /// returns its label.  Pure in `(data_seed, client, s)` — the same
    /// shift + scale + pixel-noise transform the eager generator applies,
    /// keyed per sample instead of drawn sequentially.
    fn sample_into(&self, client: u64, s: u64, law: &LabelLaw, row: &mut [f32]) -> usize {
        let (h, w, c) = (self.input_shape[0], self.input_shape[1], self.input_shape[2]);
        let mut rng = Pcg::new(mix3(self.data_seed, client, s), STREAM_SAMPLE);
        let cls = self.draw_label(law, &mut rng);
        let dy = rng.below(2 * self.cfg.shift_max as usize + 1) as i64 - self.cfg.shift_max;
        let dx = rng.below(2 * self.cfg.shift_max as usize + 1) as i64 - self.cfg.shift_max;
        shift(&self.templates[cls], h, w, c, dy, dx, row);
        let alpha = rng.range(0.8, 1.2) as f32;
        for o in row.iter_mut() {
            *o = alpha * *o + (self.cfg.noise * rng.normal()) as f32;
        }
        cls
    }

    /// One sample as an owned (pixels, label) pair — testing/diagnostics.
    pub fn sample(&self, client: u64, s: u64) -> (Vec<f32>, usize) {
        let law = self.label_law(client);
        let mut row = vec![0.0f32; self.input_elems()];
        let label = self.sample_into(client, s, &law, &mut row);
        (row, label)
    }

    /// The batch client `client` trains on at global step `step`
    /// (= round·τ + epoch): `train_batch` indices drawn with replacement
    /// from the client's `samples_per_client`-sized virtual shard, each
    /// synthesized on the spot.  Pure in `(data_seed, client, step)` —
    /// identical bits whether it runs on the coordinator, a worker, or
    /// twice (`tests/population.rs` pins derivation-order independence).
    pub fn batch(&self, client: u64, step: u64) -> (Tensor, Tensor) {
        let e = self.input_elems();
        let k = self.batch;
        let law = self.label_law(client);
        let mut brng = Pcg::new(mix3(self.data_seed, client, step), STREAM_BATCH);
        let mut xb = vec![0.0f32; k * e];
        let mut yb = vec![0.0f32; k * self.classes];
        for row in 0..k {
            let s = brng.below(self.samples_per_client) as u64;
            let label = self.sample_into(client, s, &law, &mut xb[row * e..(row + 1) * e]);
            yb[row * self.classes + label] = 1.0;
        }
        let mut shape = vec![k];
        shape.extend_from_slice(&self.input_shape);
        (Tensor::new(xb, shape), Tensor::new(yb, vec![k, self.classes]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn spec() -> ShapeSpec {
        Manifest::builtin_with_batches(8, 32).for_dataset("mnist").unwrap().clone()
    }

    fn sampler(partition: Partition, seed: u64) -> ClientSampler {
        ClientSampler::new(&spec(), "mnist", partition, 48, seed)
    }

    #[test]
    fn samples_are_pure_functions_of_their_key() {
        let a = sampler(Partition::Iid, 7);
        let b = sampler(Partition::Iid, 7);
        // Same key → same bits, regardless of instance or call order.
        let (x1, l1) = a.sample(3, 5);
        let _ = a.sample(900_000_000_000, 2); // interleave an unrelated derivation
        let (x2, l2) = a.sample(3, 5);
        let (x3, l3) = b.sample(3, 5);
        assert_eq!(l1, l2);
        assert_eq!(l1, l3);
        assert_eq!(x1, x2);
        assert_eq!(x1, x3);
        // Different client / sample / seed all change the pixels.
        assert_ne!(x1, a.sample(4, 5).0);
        assert_ne!(x1, a.sample(3, 6).0);
        assert_ne!(x1, sampler(Partition::Iid, 8).sample(3, 5).0);
    }

    #[test]
    fn batches_are_deterministic_and_shaped() {
        let s = sampler(Partition::Iid, 11);
        let (x, y) = s.batch(2, 0);
        assert_eq!(x.shape, vec![8, 28, 28, 1]);
        assert_eq!(y.shape, vec![8, 10]);
        for row in 0..8 {
            let r = &y.data[row * 10..(row + 1) * 10];
            assert_eq!(r.iter().sum::<f32>(), 1.0);
        }
        let (x2, y2) = s.batch(2, 0);
        assert_eq!(x.data, x2.data);
        assert_eq!(y.data, y2.data);
        // Steps advance the stream; clients differ.
        assert_ne!(x.data, s.batch(2, 1).0.data);
        assert_ne!(x.data, s.batch(3, 0).0.data);
        assert!(x.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dirichlet_law_skews_labels_per_client() {
        let s = sampler(Partition::Dirichlet(0.2), 13);
        // With α = 0.2 at least one of the first clients should be
        // visibly skewed: most common label > 30% of its draws.
        let skewed = (0..8u64).any(|client| {
            let mut hist = [0usize; 10];
            for i in 0..200u64 {
                hist[s.sample(client, i).1] += 1;
            }
            *hist.iter().max().unwrap() > 60
        });
        assert!(skewed, "no visible label skew at alpha=0.2");
    }

    #[test]
    fn shards_law_restricts_the_label_set() {
        let s = sampler(Partition::Shards(2), 17);
        for client in 0..6u64 {
            let mut seen = std::collections::BTreeSet::new();
            for i in 0..100u64 {
                seen.insert(s.sample(client, i).1);
            }
            assert!(seen.len() <= 2, "client {client} saw {} classes", seen.len());
        }
        // Different clients hold (mostly) different shards.
        let shard_of = |client: u64| {
            (0..100u64).map(|i| s.sample(client, i).1).collect::<std::collections::BTreeSet<_>>()
        };
        assert!((1..6u64).any(|c| shard_of(c) != shard_of(0)), "all clients share one shard");
    }

    #[test]
    fn iid_law_covers_all_classes() {
        let s = sampler(Partition::Iid, 19);
        let mut seen = vec![false; 10];
        for i in 0..300u64 {
            seen[s.sample(0, i).1] = true;
        }
        assert!(seen.iter().all(|&x| x), "some class never drawn");
    }

    #[test]
    fn distant_clients_derive_in_constant_memory() {
        // A u64-scale client id works exactly like a small one — nothing
        // proportional to the id (or any population size) is allocated.
        let s = sampler(Partition::Dirichlet(0.5), 23);
        let (x, l) = s.sample(u64::MAX - 1, 0);
        assert!(l < 10);
        assert!(x.iter().all(|v| v.is_finite()));
        let (bx, _) = s.batch(u64::MAX - 1, 7);
        assert_eq!(bx.shape[0], 8);
    }
}
