//! Rust mirror of the L2 manifest: model architecture metadata.
//!
//! Three sources produce the same typed specs:
//!
//! * [`registry::manifest`] — the model zoo (DESIGN.md §Model registry):
//!   named architectures, each declared as a [`graph::Layer`] sequence
//!   from which the parameter table, per-architecture [`CutMenu`],
//!   φ(v), smashed shapes and FLOP workloads are all derived.
//! * [`Manifest::builtin`] — the paper's split-CNN architecture
//!   (`python/compile/layers.py`) expressed through the same graph, so a
//!   clean checkout needs no artifacts to run the native backend.
//! * [`Manifest::load`] — parses `artifacts/manifest.json` (written by
//!   `python/compile/aot.py`) for the PJRT/AOT path.
//!
//! The specs feed the runtime (buffer shapes), the latency model (γ
//! workloads of eqs 14–16) and the privacy model (φ(v)/q of eq 17).
//! There is no crate-wide cut-count constant: every `ShapeSpec` carries
//! its own menu (`menu()`), and all cut validation funnels through
//! [`CutMenu::validate`].

use std::collections::BTreeMap;
use std::ops::RangeInclusive;
use std::path::Path;

use crate::util::json::Json;

pub mod graph;
pub mod registry;

pub use graph::{Layer, LayerSpec};

/// Roles compiled per cut; global roles are `full_grad` and `eval`.
pub const CUT_ROLES: [&str; 3] = ["client_fwd", "server_grad", "client_grad"];

/// The set of valid cut ids for one architecture: `1..=len`, where cut
/// `v` places layers `1..=v` on the client.  This is the single shared
/// validation helper — CLI parsing, `NetTrainer::run_round` and the
/// protocol nodes all call [`CutMenu::validate`] so an out-of-menu cut
/// is one error path, not three.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CutMenu {
    len: usize,
}

impl CutMenu {
    pub fn new(len: usize) -> CutMenu {
        CutMenu { len }
    }

    /// Number of cuts in the menu.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// All valid cut ids, ascending.
    pub fn ids(&self) -> RangeInclusive<usize> {
        1..=self.len
    }

    pub fn contains(&self, v: usize) -> bool {
        (1..=self.len).contains(&v)
    }

    /// Validate a cut id against the menu, returning it on success.
    pub fn validate(&self, v: usize) -> anyhow::Result<usize> {
        anyhow::ensure!(
            self.contains(v),
            "cut {v} outside the model's menu 1..={}",
            self.len
        );
        Ok(v)
    }
}

/// How a parameter array is initialised (`data/init.rs`).  Weights draw
/// He-normal values; biases are zeros and layernorm gains are ones —
/// neither consumes RNG draws, which keeps the builtin CNN's init
/// stream byte-identical to the pre-registry code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    HeNormal,
    Zero,
    One,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub block: usize,
    pub init: InitKind,
}

impl ParamSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct CutSpec {
    pub cut: usize,
    /// φ(v): client-side model size in parameters.
    pub phi: usize,
    /// Number of leading parameter arrays owned by the client.
    pub client_params: usize,
    /// Smashed-data shape at the train batch size (batch first).
    pub smashed_shape: Vec<usize>,
    /// Per-sample FLOPs: γ_F^c, γ_B^c, γ_F^s, γ_B^s (eqs 14–16).
    pub flops_client_fwd: f64,
    pub flops_client_bwd: f64,
    pub flops_server_fwd: f64,
    pub flops_server_bwd: f64,
    /// role -> artifact file name.
    pub artifacts: BTreeMap<String, String>,
}

impl CutSpec {
    /// Smashed elements per *sample* (shape without the batch dim).
    pub fn smashed_per_sample(&self) -> usize {
        self.smashed_shape[1..].iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ShapeSpec {
    pub key: String,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub total_params: usize,
    pub params: Vec<ParamSpec>,
    /// The declarative layer graph (empty for manifest-only specs whose
    /// parameter table does not describe an executable conv/dense chain
    /// — those still drive the latency/privacy models, but the native
    /// backend rejects them).
    pub layers: Vec<Layer>,
    pub cuts: Vec<CutSpec>,
    /// Global artifacts: full_grad, eval.
    pub artifacts: BTreeMap<String, String>,
}

impl ShapeSpec {
    /// This architecture's cut menu.
    pub fn menu(&self) -> CutMenu {
        CutMenu::new(self.cuts.len())
    }

    /// Menu length — the number of valid cut points.
    pub fn num_cuts(&self) -> usize {
        self.cuts.len()
    }

    pub fn cut(&self, v: usize) -> &CutSpec {
        assert!(self.menu().contains(v), "cut {v} outside menu 1..={}", self.cuts.len());
        &self.cuts[v - 1]
    }

    /// Input elements per sample.
    pub fn input_per_sample(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        self.params.iter().map(|p| p.shape.clone()).collect()
    }

    /// φ(v)/q — the privacy-relevant client model fraction.
    pub fn phi_fraction(&self, v: usize) -> f64 {
        self.cut(v).phi as f64 / self.total_params as f64
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub train_batch: usize,
    pub eval_batch: usize,
    pub shapes: BTreeMap<String, ShapeSpec>,
    /// dataset name -> shape key (mnist/fmnist share "28x28x1").
    pub datasets: BTreeMap<String, String>,
}

/// Architecture constants of the paper's split CNN (§V-A, [33] plus one
/// fc128 block so every cut moves parameters) — mirrors
/// `python/compile/layers.py::ModelSpec`.  `TRAIN_BATCH`/`EVAL_BATCH`
/// double as the registry-wide batch defaults.
mod arch {
    pub const KERNEL: usize = 5;
    pub const CONV1: usize = 32;
    pub const CONV2: usize = 64;
    pub const FC1: usize = 512;
    pub const FC2: usize = 128;
    pub const CLASSES: usize = 10;
    pub const TRAIN_BATCH: usize = 32;
    pub const EVAL_BATCH: usize = 256;
}

/// Build one shape key's spec from the architecture constants, routed
/// through the layer graph.  The graph emits the same parameter names,
/// blocks, FLOP products (summed in the same ascending order) and
/// artifact names as the pre-registry hand-written code — builtin specs
/// are byte-identical, so JAX goldens and run digests stand.
fn builtin_shape(key: &str, h: usize, w: usize, c: usize, tb: usize, eb: usize) -> ShapeSpec {
    use arch::{CLASSES, CONV1, CONV2, FC1, FC2, KERNEL};
    let flat = (h / 4) * (w / 4) * CONV2;
    let layers = vec![
        Layer::new("conv1", LayerSpec::Conv { h, w, ic: c, k: KERNEL, oc: CONV1, pool: true }),
        Layer::new(
            "conv2",
            LayerSpec::Conv { h: h / 2, w: w / 2, ic: CONV1, k: KERNEL, oc: CONV2, pool: true },
        ),
        Layer::new("fc1", LayerSpec::Dense { din: flat, dout: FC1, relu: true }),
        Layer::new("fc2", LayerSpec::Dense { din: FC1, dout: FC2, relu: true }),
        Layer::new("fc3", LayerSpec::Dense { din: FC2, dout: CLASSES, relu: false }),
    ];
    graph::build_shape(key, vec![h, w, c], CLASSES, layers, tb, eb)
}

impl Manifest {
    /// The paper's architecture as a built-in spec source: no
    /// `artifacts/manifest.json` (and therefore no Python) required.
    /// Batch sizes are the paper's §V-A defaults (train 32, eval 256).
    pub fn builtin() -> Manifest {
        Self::builtin_with_batches(arch::TRAIN_BATCH, arch::EVAL_BATCH)
    }

    /// Built-in specs with custom batch sizes (tests use small batches to
    /// keep native-backend compute cheap).
    pub fn builtin_with_batches(train_batch: usize, eval_batch: usize) -> Manifest {
        let mut shapes = BTreeMap::new();
        for (key, h, w, c) in [("28x28x1", 28, 28, 1), ("32x32x3", 32, 32, 3)] {
            shapes.insert(key.to_string(), builtin_shape(key, h, w, c, train_batch, eval_batch));
        }
        let datasets = [("mnist", "28x28x1"), ("fmnist", "28x28x1"), ("cifar10", "32x32x3")]
            .into_iter()
            .map(|(d, k)| (d.to_string(), k.to_string()))
            .collect();
        Manifest { train_batch, eval_batch, shapes, datasets }
    }

    pub fn load<P: AsRef<Path>>(dir: P) -> anyhow::Result<Manifest> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display())
        })?;
        let json = Json::parse(&text)?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> anyhow::Result<Manifest> {
        let format = json.at(&["format"])?.as_usize()?;
        anyhow::ensure!(format == 1, "unsupported manifest format {format}");
        let train_batch = json.at(&["train_batch"])?.as_usize()?;
        let eval_batch = json.at(&["eval_batch"])?.as_usize()?;

        let mut shapes = BTreeMap::new();
        for (key, sj) in json.at(&["shapes"])?.as_obj()? {
            shapes.insert(key.clone(), parse_shape(key, sj, train_batch, eval_batch)?);
        }
        let mut datasets = BTreeMap::new();
        for (ds, kj) in json.at(&["datasets"])?.as_obj()? {
            let key = kj.as_str()?.to_string();
            anyhow::ensure!(shapes.contains_key(&key), "dataset {ds} maps to unknown shape {key}");
            datasets.insert(ds.clone(), key);
        }
        Ok(Manifest { train_batch, eval_batch, shapes, datasets })
    }

    /// Resolve a dataset name ("mnist") to its shape spec.
    pub fn for_dataset(&self, dataset: &str) -> anyhow::Result<&ShapeSpec> {
        let key = self
            .datasets
            .get(dataset)
            .ok_or_else(|| anyhow::anyhow!(
                "unknown dataset '{dataset}' (have: {:?})",
                self.datasets.keys().collect::<Vec<_>>()
            ))?;
        Ok(&self.shapes[key])
    }
}

fn parse_shape(
    key: &str,
    json: &Json,
    train_batch: usize,
    eval_batch: usize,
) -> anyhow::Result<ShapeSpec> {
    let params = json
        .at(&["params"])?
        .as_arr()?
        .iter()
        .map(|p| {
            let shape = p.at(&["shape"])?.usize_array()?;
            Ok(ParamSpec {
                name: p.at(&["name"])?.as_str()?.to_string(),
                // Manifest JSON carries no init kind; rank 1 arrays are
                // biases (zeros), everything else is a He-normal weight
                // — exactly the rule `data/init.rs` always applied.
                init: if shape.len() == 1 { InitKind::Zero } else { InitKind::HeNormal },
                shape,
                block: p.at(&["block"])?.as_usize()?,
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    // The menu length is whatever the manifest declares: cut ids must be
    // a dense "1".."N" key set.
    let num_cuts = json.at(&["cuts"])?.as_obj()?.len();
    anyhow::ensure!(num_cuts >= 1, "{key}: empty cut menu");
    let mut cuts = Vec::with_capacity(num_cuts);
    for v in 1..=num_cuts {
        let cj = json
            .at(&["cuts", &v.to_string()])
            .map_err(|e| anyhow::anyhow!("{key}: cut ids must be dense 1..={num_cuts}: {e}"))?;
        let mut artifacts = BTreeMap::new();
        for (role, f) in cj.at(&["artifacts"])?.as_obj()? {
            artifacts.insert(role.clone(), f.as_str()?.to_string());
        }
        for role in CUT_ROLES {
            anyhow::ensure!(artifacts.contains_key(role), "{key} cut {v} missing role {role}");
        }
        cuts.push(CutSpec {
            cut: v,
            phi: cj.at(&["phi"])?.as_usize()?,
            client_params: cj.at(&["client_params"])?.as_usize()?,
            smashed_shape: cj.at(&["smashed_shape"])?.usize_array()?,
            flops_client_fwd: cj.at(&["flops_client_fwd"])?.as_f64()?,
            flops_client_bwd: cj.at(&["flops_client_bwd"])?.as_f64()?,
            flops_server_fwd: cj.at(&["flops_server_fwd"])?.as_f64()?,
            flops_server_bwd: cj.at(&["flops_server_bwd"])?.as_f64()?,
            artifacts,
        });
    }

    let mut artifacts = BTreeMap::new();
    for (role, f) in json.at(&["artifacts"])?.as_obj()? {
        artifacts.insert(role.clone(), f.as_str()?.to_string());
    }
    for role in ["full_grad", "eval"] {
        anyhow::ensure!(artifacts.contains_key(role), "{key} missing global role {role}");
    }

    let input_shape = json.at(&["input_shape"])?.usize_array()?;
    // Best-effort graph recovery: a manifest whose params are (w, b)
    // pairs chaining through the input geometry gets an executable layer
    // graph; anything else (latency/privacy-only toy specs) gets an
    // empty one and is rejected by the native backend only.
    let layers = graph::layers_from_params(&input_shape, &params).unwrap_or_default();

    let spec = ShapeSpec {
        key: key.to_string(),
        input_shape,
        classes: json.at(&["classes"])?.as_usize()?,
        train_batch,
        eval_batch,
        total_params: json.at(&["total_params"])?.as_usize()?,
        params,
        layers,
        cuts,
        artifacts,
    };

    // Cross-checks: φ must equal the sum of client-owned parameter sizes.
    for cut in &spec.cuts {
        let phi_sum: usize = spec.params[..cut.client_params].iter().map(|p| p.size()).sum();
        anyhow::ensure!(
            phi_sum == cut.phi,
            "{key} cut {}: phi {} != sum of client param sizes {phi_sum}",
            cut.cut,
            cut.phi
        );
    }
    let total: usize = spec.params.iter().map(|p| p.size()).sum();
    anyhow::ensure!(total == spec.total_params, "{key}: total_params mismatch");
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest_json() -> String {
        // Two-param toy: conv (block 1, 8 params) + fc (block 2, 4 params).
        let cut_tpl = |phi: usize, nc: usize| {
            format!(
                r#"{{"phi": {phi}, "client_params": {nc}, "smashed_shape": [2, 3],
                 "flops_client_fwd": 10, "flops_client_bwd": 20,
                 "flops_server_fwd": 30, "flops_server_bwd": 40,
                 "artifacts": {{"client_fwd": "a", "server_grad": "b", "client_grad": "c"}}}}"#
            )
        };
        format!(
            r#"{{"format": 1, "train_batch": 2, "eval_batch": 4,
             "shapes": {{"toy": {{
               "input_shape": [4], "classes": 2, "total_params": 12,
               "params": [{{"name": "w1", "shape": [2, 4], "block": 1}},
                          {{"name": "w2", "shape": [4], "block": 2}}],
               "cuts": {{"1": {c1}, "2": {c2}, "3": {c2}, "4": {c2}}},
               "artifacts": {{"full_grad": "f", "eval": "e"}}
             }}}},
             "datasets": {{"toyset": "toy"}}}}"#,
            c1 = cut_tpl(8, 1),
            c2 = cut_tpl(12, 2),
        )
    }

    #[test]
    fn parses_toy_manifest() {
        let json = Json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(&json).unwrap();
        let spec = m.for_dataset("toyset").unwrap();
        assert_eq!(spec.total_params, 12);
        assert_eq!(spec.cut(1).phi, 8);
        assert_eq!(spec.cut(1).smashed_per_sample(), 3);
        assert_eq!(spec.phi_fraction(1), 8.0 / 12.0);
        assert_eq!(spec.param_shapes(), vec![vec![2, 4], vec![4]]);
        // The menu length comes from the manifest, not a constant.
        assert_eq!(spec.menu().len(), 4);
        // No executable conv/dense chain behind these params.
        assert!(spec.layers.is_empty());
    }

    #[test]
    fn rejects_phi_mismatch() {
        let text = toy_manifest_json().replace("\"phi\": 8", "\"phi\": 9");
        let json = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(&json).is_err());
    }

    #[test]
    fn rejects_sparse_cut_ids() {
        let text = toy_manifest_json().replace(r#""4": "#, r#""7": "#);
        let json = Json::parse(&text).unwrap();
        let err = Manifest::from_json(&json).unwrap_err().to_string();
        assert!(err.contains("dense"), "{err}");
    }

    #[test]
    fn unknown_dataset_is_error() {
        let json = Json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(&json).unwrap();
        assert!(m.for_dataset("nope").is_err());
    }

    #[test]
    fn cut_menu_validates() {
        let menu = CutMenu::new(4);
        assert_eq!(menu.len(), 4);
        assert!(!menu.is_empty());
        assert_eq!(menu.ids().collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        assert!(menu.contains(1) && menu.contains(4));
        assert!(!menu.contains(0) && !menu.contains(5));
        assert_eq!(menu.validate(3).unwrap(), 3);
        let err = menu.validate(5).unwrap_err().to_string();
        assert!(err.contains("menu 1..=4"), "{err}");
        assert!(menu.validate(0).is_err());
    }

    #[test]
    fn builtin_manifest_is_consistent() {
        let m = Manifest::builtin();
        assert_eq!(m.train_batch, 32);
        assert_eq!(m.eval_batch, 256);
        for ds in ["mnist", "fmnist", "cifar10"] {
            let spec = m.for_dataset(ds).unwrap();
            assert_eq!(spec.cuts.len(), 4);
            assert_eq!(spec.menu(), CutMenu::new(4));
            // Five layers behind the four cuts.
            assert_eq!(spec.layers.len(), 5);
            // φ(v) monotone non-decreasing (paper's Assumption 4 premise).
            for w in spec.cuts.windows(2) {
                assert!(w[0].phi <= w[1].phi);
            }
            // φ cross-check against the declared client parameter prefix.
            for cut in &spec.cuts {
                let phi: usize = spec.params[..cut.client_params].iter().map(|p| p.size()).sum();
                assert_eq!(phi, cut.phi, "{ds} cut {}", cut.cut);
            }
            // Client+server FLOPs sum to the same total at every cut.
            let t0 = spec.cuts[0].flops_client_fwd + spec.cuts[0].flops_server_fwd;
            for c in &spec.cuts {
                assert!((c.flops_client_fwd + c.flops_server_fwd - t0).abs() < 1.0);
            }
            let total: usize = spec.params.iter().map(|p| p.size()).sum();
            assert_eq!(total, spec.total_params);
        }
        // mnist and fmnist share one shape key; cifar10 differs.
        assert_eq!(m.datasets["mnist"], m.datasets["fmnist"]);
        assert_ne!(m.datasets["mnist"], m.datasets["cifar10"]);
    }

    #[test]
    fn builtin_mnist_matches_paper_geometry() {
        let m = Manifest::builtin();
        let spec = m.for_dataset("mnist").unwrap();
        // Known sizes of the McMahan CNN + fc128 (layers.py param_specs).
        assert_eq!(spec.total_params, 1_725_194);
        assert_eq!(spec.cut(1).phi, 832);
        assert_eq!(spec.cut(2).phi, 832 + 51_264);
        assert_eq!(spec.cut(1).smashed_shape, vec![32, 14, 14, 32]);
        assert_eq!(spec.cut(2).smashed_shape, vec![32, 7, 7, 64]);
        assert_eq!(spec.cut(3).smashed_shape, vec![32, 512]);
        assert_eq!(spec.cut(4).smashed_shape, vec![32, 128]);
        assert_eq!(spec.cut(4).client_params, 8);
        assert_eq!(spec.input_per_sample(), 784);
        // The graph route preserves the hand-written parameter table.
        assert_eq!(spec.params[0].name, "conv1_w");
        assert_eq!(spec.params[0].init, InitKind::HeNormal);
        assert_eq!(spec.params[9].name, "fc3_b");
        assert_eq!(spec.params[9].init, InitKind::Zero);
        assert_eq!(spec.cut(1).artifacts["client_fwd"], "28x28x1_v1_client_fwd.hlo.txt");
        assert_eq!(spec.artifacts["eval"], "28x28x1_eval.hlo.txt");
    }

    #[test]
    fn builtin_with_batches_scales_smashed_shapes() {
        let m = Manifest::builtin_with_batches(8, 40);
        let spec = m.for_dataset("cifar10").unwrap();
        assert_eq!(spec.train_batch, 8);
        assert_eq!(spec.eval_batch, 40);
        assert_eq!(spec.cut(2).smashed_shape, vec![8, 8, 8, 64]);
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration-style check against the artifacts dir when built:
        // the AOT manifest must agree with the built-in spec source.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        let b = Manifest::builtin();
        for ds in ["mnist", "fmnist", "cifar10"] {
            let spec = m.for_dataset(ds).unwrap();
            let bspec = b.for_dataset(ds).unwrap();
            assert_eq!(spec.total_params, bspec.total_params);
            for (c, bc) in spec.cuts.iter().zip(&bspec.cuts) {
                assert_eq!(c.phi, bc.phi);
                assert_eq!(c.client_params, bc.client_params);
                assert_eq!(c.smashed_shape[1..], bc.smashed_shape[1..]);
            }
        }
    }
}
