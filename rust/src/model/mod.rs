//! Rust mirror of the L2 manifest: model architecture metadata.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) is the
//! single source of truth for parameter shapes, cut-point sizes φ(v),
//! smashed-data shapes and per-side FLOP counts.  This module parses it
//! into typed specs used by the runtime (buffer shapes), the latency model
//! (γ workloads of eqs 14–16) and the privacy model (φ(v)/q of eq 17).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

pub const NUM_CUTS: usize = 4;

/// Roles compiled per cut; global roles are `full_grad` and `eval`.
pub const CUT_ROLES: [&str; 3] = ["client_fwd", "server_grad", "client_grad"];

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub block: usize,
}

impl ParamSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct CutSpec {
    pub cut: usize,
    /// φ(v): client-side model size in parameters.
    pub phi: usize,
    /// Number of leading parameter arrays owned by the client.
    pub client_params: usize,
    /// Smashed-data shape at the train batch size (batch first).
    pub smashed_shape: Vec<usize>,
    /// Per-sample FLOPs: γ_F^c, γ_B^c, γ_F^s, γ_B^s (eqs 14–16).
    pub flops_client_fwd: f64,
    pub flops_client_bwd: f64,
    pub flops_server_fwd: f64,
    pub flops_server_bwd: f64,
    /// role -> artifact file name.
    pub artifacts: BTreeMap<String, String>,
}

impl CutSpec {
    /// Smashed elements per *sample* (shape without the batch dim).
    pub fn smashed_per_sample(&self) -> usize {
        self.smashed_shape[1..].iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ShapeSpec {
    pub key: String,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub total_params: usize,
    pub params: Vec<ParamSpec>,
    pub cuts: Vec<CutSpec>,
    /// Global artifacts: full_grad, eval.
    pub artifacts: BTreeMap<String, String>,
}

impl ShapeSpec {
    pub fn cut(&self, v: usize) -> &CutSpec {
        assert!((1..=NUM_CUTS).contains(&v), "cut {v} out of range");
        &self.cuts[v - 1]
    }

    /// Input elements per sample.
    pub fn input_per_sample(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        self.params.iter().map(|p| p.shape.clone()).collect()
    }

    /// φ(v)/q — the privacy-relevant client model fraction.
    pub fn phi_fraction(&self, v: usize) -> f64 {
        self.cut(v).phi as f64 / self.total_params as f64
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub train_batch: usize,
    pub eval_batch: usize,
    pub shapes: BTreeMap<String, ShapeSpec>,
    /// dataset name -> shape key (mnist/fmnist share "28x28x1").
    pub datasets: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(dir: P) -> anyhow::Result<Manifest> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text)?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> anyhow::Result<Manifest> {
        let format = json.at(&["format"])?.as_usize()?;
        anyhow::ensure!(format == 1, "unsupported manifest format {format}");
        let train_batch = json.at(&["train_batch"])?.as_usize()?;
        let eval_batch = json.at(&["eval_batch"])?.as_usize()?;

        let mut shapes = BTreeMap::new();
        for (key, sj) in json.at(&["shapes"])?.as_obj()? {
            shapes.insert(key.clone(), parse_shape(key, sj, train_batch, eval_batch)?);
        }
        let mut datasets = BTreeMap::new();
        for (ds, kj) in json.at(&["datasets"])?.as_obj()? {
            let key = kj.as_str()?.to_string();
            anyhow::ensure!(shapes.contains_key(&key), "dataset {ds} maps to unknown shape {key}");
            datasets.insert(ds.clone(), key);
        }
        Ok(Manifest { train_batch, eval_batch, shapes, datasets })
    }

    /// Resolve a dataset name ("mnist") to its shape spec.
    pub fn for_dataset(&self, dataset: &str) -> anyhow::Result<&ShapeSpec> {
        let key = self
            .datasets
            .get(dataset)
            .ok_or_else(|| anyhow::anyhow!(
                "unknown dataset '{dataset}' (have: {:?})",
                self.datasets.keys().collect::<Vec<_>>()
            ))?;
        Ok(&self.shapes[key])
    }
}

fn parse_shape(
    key: &str,
    json: &Json,
    train_batch: usize,
    eval_batch: usize,
) -> anyhow::Result<ShapeSpec> {
    let params = json
        .at(&["params"])?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.at(&["name"])?.as_str()?.to_string(),
                shape: p.at(&["shape"])?.usize_array()?,
                block: p.at(&["block"])?.as_usize()?,
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    let mut cuts = Vec::new();
    for v in 1..=NUM_CUTS {
        let cj = json.at(&["cuts", &v.to_string()])?;
        let mut artifacts = BTreeMap::new();
        for (role, f) in cj.at(&["artifacts"])?.as_obj()? {
            artifacts.insert(role.clone(), f.as_str()?.to_string());
        }
        for role in CUT_ROLES {
            anyhow::ensure!(artifacts.contains_key(role), "{key} cut {v} missing role {role}");
        }
        cuts.push(CutSpec {
            cut: v,
            phi: cj.at(&["phi"])?.as_usize()?,
            client_params: cj.at(&["client_params"])?.as_usize()?,
            smashed_shape: cj.at(&["smashed_shape"])?.usize_array()?,
            flops_client_fwd: cj.at(&["flops_client_fwd"])?.as_f64()?,
            flops_client_bwd: cj.at(&["flops_client_bwd"])?.as_f64()?,
            flops_server_fwd: cj.at(&["flops_server_fwd"])?.as_f64()?,
            flops_server_bwd: cj.at(&["flops_server_bwd"])?.as_f64()?,
            artifacts,
        });
    }

    let mut artifacts = BTreeMap::new();
    for (role, f) in json.at(&["artifacts"])?.as_obj()? {
        artifacts.insert(role.clone(), f.as_str()?.to_string());
    }
    for role in ["full_grad", "eval"] {
        anyhow::ensure!(artifacts.contains_key(role), "{key} missing global role {role}");
    }

    let spec = ShapeSpec {
        key: key.to_string(),
        input_shape: json.at(&["input_shape"])?.usize_array()?,
        classes: json.at(&["classes"])?.as_usize()?,
        train_batch,
        eval_batch,
        total_params: json.at(&["total_params"])?.as_usize()?,
        params,
        cuts,
        artifacts,
    };

    // Cross-checks: φ must equal the sum of client-owned parameter sizes.
    for cut in &spec.cuts {
        let phi_sum: usize = spec.params[..cut.client_params].iter().map(|p| p.size()).sum();
        anyhow::ensure!(
            phi_sum == cut.phi,
            "{key} cut {}: phi {} != sum of client param sizes {phi_sum}",
            cut.cut,
            cut.phi
        );
    }
    let total: usize = spec.params.iter().map(|p| p.size()).sum();
    anyhow::ensure!(total == spec.total_params, "{key}: total_params mismatch");
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest_json() -> String {
        // Two-param toy: conv (block 1, 8 params) + fc (block 2, 4 params).
        let cut_tpl = |phi: usize, nc: usize| {
            format!(
                r#"{{"phi": {phi}, "client_params": {nc}, "smashed_shape": [2, 3],
                 "flops_client_fwd": 10, "flops_client_bwd": 20,
                 "flops_server_fwd": 30, "flops_server_bwd": 40,
                 "artifacts": {{"client_fwd": "a", "server_grad": "b", "client_grad": "c"}}}}"#
            )
        };
        format!(
            r#"{{"format": 1, "train_batch": 2, "eval_batch": 4,
             "shapes": {{"toy": {{
               "input_shape": [4], "classes": 2, "total_params": 12,
               "params": [{{"name": "w1", "shape": [2, 4], "block": 1}},
                          {{"name": "w2", "shape": [4], "block": 2}}],
               "cuts": {{"1": {c1}, "2": {c2}, "3": {c2}, "4": {c2}}},
               "artifacts": {{"full_grad": "f", "eval": "e"}}
             }}}},
             "datasets": {{"toyset": "toy"}}}}"#,
            c1 = cut_tpl(8, 1),
            c2 = cut_tpl(12, 2),
        )
    }

    #[test]
    fn parses_toy_manifest() {
        let json = Json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(&json).unwrap();
        let spec = m.for_dataset("toyset").unwrap();
        assert_eq!(spec.total_params, 12);
        assert_eq!(spec.cut(1).phi, 8);
        assert_eq!(spec.cut(1).smashed_per_sample(), 3);
        assert_eq!(spec.phi_fraction(1), 8.0 / 12.0);
        assert_eq!(spec.param_shapes(), vec![vec![2, 4], vec![4]]);
    }

    #[test]
    fn rejects_phi_mismatch() {
        let text = toy_manifest_json().replace("\"phi\": 8", "\"phi\": 9");
        let json = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(&json).is_err());
    }

    #[test]
    fn unknown_dataset_is_error() {
        let json = Json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(&json).unwrap();
        assert!(m.for_dataset("nope").is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration-style check against the artifacts dir when built.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        for ds in ["mnist", "fmnist", "cifar10"] {
            let spec = m.for_dataset(ds).unwrap();
            assert_eq!(spec.cuts.len(), NUM_CUTS);
            // φ(v) monotone non-decreasing (paper's Assumption 4 premise).
            for w in spec.cuts.windows(2) {
                assert!(w[0].phi <= w[1].phi);
            }
            // Client+server FLOPs sum to the same total at every cut.
            let t0 = spec.cuts[0].flops_client_fwd + spec.cuts[0].flops_server_fwd;
            for c in &spec.cuts {
                assert!((c.flops_client_fwd + c.flops_server_fwd - t0).abs() < 1.0);
            }
        }
    }
}
