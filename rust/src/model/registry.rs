//! The model registry: named architectures, each a declarative layer
//! graph (DESIGN.md §Model registry).  Every architecture is built for
//! the same two input geometries the builtin CNN supports — 28x28x1
//! (mnist/fmnist) and 32x32x3 (cifar10) — so `Manifest::for_dataset`
//! works uniformly across the zoo.
//!
//! | model     | layers                                   | cuts |
//! |-----------|------------------------------------------|------|
//! | `builtin` | conv5x5 ->x2 + 3 dense (hand-written twin) | 4    |
//! | `vgg`     | 10 conv3x3 (2 pools) + 2 dense           | 11   |
//! | `txf`     | patch-embed + 2 transformer blocks + head | 3    |
//!
//! `builtin` routes through [`Manifest::builtin_with_batches`], which
//! builds the same graph — byte-identical params/FLOPs/artifacts to the
//! pre-registry hand-written spec, so JAX goldens and run digests stand.

use super::graph::{build_shape, Layer, LayerSpec};
use super::{arch, Manifest, ShapeSpec};
use std::collections::BTreeMap;

/// Names accepted by `--model` / `RunSetup::model`, in display order.
pub const MODELS: [&str; 3] = ["builtin", "vgg", "txf"];

/// Look up an architecture by name with the default batch geometry.
pub fn manifest(name: &str) -> anyhow::Result<Manifest> {
    manifest_with_batches(name, arch::TRAIN_BATCH, arch::EVAL_BATCH)
}

/// Look up an architecture by name with explicit train/eval batch sizes.
pub fn manifest_with_batches(
    name: &str,
    train_batch: usize,
    eval_batch: usize,
) -> anyhow::Result<Manifest> {
    match name {
        "builtin" => Ok(Manifest::builtin_with_batches(train_batch, eval_batch)),
        "vgg" => Ok(zoo_manifest("vgg", vgg_layers, train_batch, eval_batch)),
        "txf" => Ok(zoo_manifest("txf", txf_layers, train_batch, eval_batch)),
        other => anyhow::bail!(
            "unknown model '{other}' (available: {})",
            MODELS.join(", ")
        ),
    }
}

/// Assemble a two-geometry manifest for one zoo architecture, mirroring
/// the builtin's dataset->shape routing.
fn zoo_manifest(
    name: &str,
    layers: fn(usize, usize, usize, usize) -> Vec<Layer>,
    train_batch: usize,
    eval_batch: usize,
) -> Manifest {
    let mut shapes = BTreeMap::new();
    let mut datasets = BTreeMap::new();
    for (h, w, c) in [(28, 28, 1), (32, 32, 3)] {
        let key = format!("{name}-{h}x{w}x{c}");
        let spec: ShapeSpec = build_shape(
            &key,
            vec![h, w, c],
            arch::CLASSES,
            layers(h, w, c, arch::CLASSES),
            train_batch,
            eval_batch,
        );
        shapes.insert(key, spec);
    }
    for ds in ["mnist", "fmnist"] {
        datasets.insert(ds.to_string(), format!("{name}-28x28x1"));
    }
    datasets.insert("cifar10".to_string(), format!("{name}-32x32x3"));
    Manifest { train_batch, eval_batch, shapes, datasets }
}

/// VGG-ish deep CNN: ten 3x3 convs in a rising channel plan with pools
/// after conv2 and conv4 (28 -> 14 -> 7, or 32 -> 16 -> 8), then a
/// 64-wide dense and the logits layer.  12 layers = an 11-cut menu, and
/// small enough (~8.5 MFLOPs/sample fwd at 28x28) that debug-mode CI
/// exercises every cut.
fn vgg_layers(h: usize, w: usize, c: usize, classes: usize) -> Vec<Layer> {
    // (out-channels, pool-after) per conv layer.
    const PLAN: [(usize, bool); 10] = [
        (8, false),
        (8, true),
        (16, false),
        (16, true),
        (24, false),
        (24, false),
        (32, false),
        (32, false),
        (48, false),
        (48, false),
    ];
    let (mut ch, mut cw, mut cc) = (h, w, c);
    let mut layers = Vec::with_capacity(PLAN.len() + 2);
    for (i, &(oc, pool)) in PLAN.iter().enumerate() {
        layers.push(Layer::new(
            &format!("conv{}", i + 1),
            LayerSpec::Conv { h: ch, w: cw, ic: cc, k: 3, oc, pool },
        ));
        if pool {
            ch /= 2;
            cw /= 2;
        }
        cc = oc;
    }
    let flat = ch * cw * cc;
    layers.push(Layer::new("fc1", LayerSpec::Dense { din: flat, dout: 64, relu: true }));
    layers.push(Layer::new("fc2", LayerSpec::Dense { din: 64, dout: classes, relu: false }));
    layers
}

/// Tiny transformer-block stack: non-overlapping 4x4 patch embedding
/// into dm=32 tokens, two pre-LN blocks (2 heads, dff=64), and a dense
/// head over the flattened tokens.  Cuts sit at block boundaries:
/// after embed (v=1), after block 1 (v=2), after block 2 (v=3).
fn txf_layers(h: usize, w: usize, c: usize, classes: usize) -> Vec<Layer> {
    const PATCH: usize = 4;
    const DM: usize = 32;
    const HEADS: usize = 2;
    const DFF: usize = 64;
    assert!(h % PATCH == 0 && w % PATCH == 0, "input not patch-divisible");
    let tokens = (h / PATCH) * (w / PATCH);
    vec![
        Layer::new("embed", LayerSpec::Embed { h, w, c, patch: PATCH, dm: DM }),
        Layer::new("blk1", LayerSpec::TxfBlock { tokens, dm: DM, heads: HEADS, dff: DFF }),
        Layer::new("blk2", LayerSpec::TxfBlock { tokens, dm: DM, heads: HEADS, dff: DFF }),
        Layer::new("head", LayerSpec::Dense { din: tokens * DM, dout: classes, relu: false }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_round_trips_through_the_registry() {
        let reg = manifest("builtin").unwrap();
        let hand = Manifest::builtin();
        assert_eq!(reg.datasets, hand.datasets);
        let (a, b) = (reg.for_dataset("mnist").unwrap(), hand.for_dataset("mnist").unwrap());
        assert_eq!(a.total_params, b.total_params);
        assert_eq!(a.cuts.len(), b.cuts.len());
        assert_eq!(a.artifacts, b.artifacts);
    }

    #[test]
    fn vgg_has_a_deep_menu() {
        let m = manifest("vgg").unwrap();
        let spec = m.for_dataset("mnist").unwrap();
        assert_eq!(spec.layers.len(), 12);
        assert_eq!(spec.menu().len(), 11);
        assert_eq!(spec.cut(1).smashed_shape, vec![arch::TRAIN_BATCH, 28, 28, 8]);
        assert_eq!(spec.cut(2).smashed_shape, vec![arch::TRAIN_BATCH, 14, 14, 8]);
        // fc1 fan-in chains from the last conv through both pools.
        assert_eq!(spec.cut(11).smashed_shape, vec![arch::TRAIN_BATCH, 64]);
        let cifar = m.for_dataset("cifar10").unwrap();
        assert_eq!(cifar.cut(4).smashed_shape, vec![arch::TRAIN_BATCH, 8, 8, 16]);
    }

    #[test]
    fn txf_cuts_sit_at_block_boundaries() {
        let m = manifest("txf").unwrap();
        let spec = m.for_dataset("mnist").unwrap();
        assert_eq!(spec.layers.len(), 4);
        assert_eq!(spec.menu().len(), 3);
        for v in 1..=3 {
            assert_eq!(spec.cut(v).smashed_shape, vec![arch::TRAIN_BATCH, 49, 32]);
        }
        // Two identical blocks: φ grows by exactly one block's params.
        let blk = spec.cut(2).phi - spec.cut(1).phi;
        assert_eq!(spec.cut(3).phi - spec.cut(2).phi, blk);
        let cifar = m.for_dataset("cifar10").unwrap();
        assert_eq!(cifar.cut(1).smashed_shape, vec![arch::TRAIN_BATCH, 64, 32]);
    }

    #[test]
    fn unknown_model_is_a_clean_error() {
        let err = manifest("resnet").unwrap_err().to_string();
        assert!(err.contains("unknown model"), "{err}");
        assert!(err.contains("builtin"), "{err}");
    }

    #[test]
    fn batch_overrides_reach_every_shape() {
        let m = manifest_with_batches("vgg", 8, 40).unwrap();
        for spec in m.shapes.values() {
            assert_eq!(spec.train_batch, 8);
            assert_eq!(spec.eval_batch, 40);
            assert_eq!(spec.cut(1).smashed_shape[0], 8);
        }
    }
}
