//! The declarative layer graph every architecture in the registry is
//! specified as: a sequence of typed [`LayerSpec`]s with fully resolved
//! geometry, from which [`build_shape`] derives everything the rest of
//! the crate consumes — parameter tables, the per-architecture cut menu,
//! φ(v), smashed shapes and the eq-14–16 FLOP workloads.
//!
//! A cut may be placed after any layer except the last (cut `v` puts
//! layers `1..=v` on the client), so an `L`-layer graph has an `L-1`-cut
//! menu.  The builtin CNN expressed through this graph is byte-identical
//! to the hand-written spec it replaced: same parameter names, shapes
//! and block ids, the same `(2·MACs) as f64` FLOP values summed in the
//! same ascending-layer order, and the same artifact file names — which
//! is why every JAX golden and checkpoint digest survives the refactor.

use super::{CutSpec, InitKind, ParamSpec, ShapeSpec, CUT_ROLES};
use std::collections::BTreeMap;

/// One named layer of an architecture graph.  The name prefixes the
/// layer's parameter names (`conv1` -> `conv1_w`, `conv1_b`).
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub spec: LayerSpec,
}

impl Layer {
    pub fn new(name: &str, spec: LayerSpec) -> Layer {
        Layer { name: name.to_string(), spec }
    }
}

/// Typed layer spec with resolved input geometry: every variant knows
/// its own input shape, so param shapes, activation shapes and FLOPs are
/// all local derivations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    /// SAME conv `k`x`k` + relu on an `h`x`w`x`ic` input, optionally
    /// followed by a 2x2 max-pool.
    Conv { h: usize, w: usize, ic: usize, k: usize, oc: usize, pool: bool },
    /// Dense `din -> dout`, relu unless it is the logits layer.
    Dense { din: usize, dout: usize, relu: bool },
    /// Non-overlapping `patch`x`patch` patch embedding of an `h`x`w`x`c`
    /// image into `(h/patch)·(w/patch)` tokens of width `dm`.
    Embed { h: usize, w: usize, c: usize, patch: usize, dm: usize },
    /// Pre-LN transformer block on `[tokens, dm]` activations:
    /// x + MHSA(LN(x)) then + MLP(LN(·)) with a GELU hidden of `dff`.
    TxfBlock { tokens: usize, dm: usize, heads: usize, dff: usize },
}

impl LayerSpec {
    /// Input elements per sample.
    pub fn in_elems(&self) -> usize {
        match *self {
            LayerSpec::Conv { h, w, ic, .. } => h * w * ic,
            LayerSpec::Dense { din, .. } => din,
            LayerSpec::Embed { h, w, c, .. } => h * w * c,
            LayerSpec::TxfBlock { tokens, dm, .. } => tokens * dm,
        }
    }

    /// Output activation shape per sample (no batch dim) — the smashed
    /// shape when the cut sits after this layer.
    pub fn out_shape(&self) -> Vec<usize> {
        match *self {
            LayerSpec::Conv { h, w, oc, pool, .. } => {
                if pool {
                    vec![h / 2, w / 2, oc]
                } else {
                    vec![h, w, oc]
                }
            }
            LayerSpec::Dense { dout, .. } => vec![dout],
            LayerSpec::Embed { h, w, patch, dm, .. } => vec![(h / patch) * (w / patch), dm],
            LayerSpec::TxfBlock { tokens, dm, .. } => vec![tokens, dm],
        }
    }

    /// Output elements per sample.
    pub fn out_elems(&self) -> usize {
        self.out_shape().iter().product()
    }

    /// Number of parameter arrays this layer owns.
    pub fn num_params(&self) -> usize {
        match self {
            LayerSpec::TxfBlock { .. } => 16,
            _ => 2,
        }
    }

    /// The layer's parameter table (manifest order), named `{name}_*` and
    /// assigned to `block`.
    pub fn param_specs(&self, name: &str, block: usize) -> Vec<ParamSpec> {
        let p = |suffix: &str, shape: Vec<usize>, init: InitKind| ParamSpec {
            name: format!("{name}_{suffix}"),
            shape,
            block,
            init,
        };
        match *self {
            LayerSpec::Conv { ic, k, oc, .. } => vec![
                p("w", vec![k, k, ic, oc], InitKind::HeNormal),
                p("b", vec![oc], InitKind::Zero),
            ],
            LayerSpec::Dense { din, dout, .. } => vec![
                p("w", vec![din, dout], InitKind::HeNormal),
                p("b", vec![dout], InitKind::Zero),
            ],
            LayerSpec::Embed { c, patch, dm, .. } => vec![
                p("w", vec![patch * patch * c, dm], InitKind::HeNormal),
                p("b", vec![dm], InitKind::Zero),
            ],
            LayerSpec::TxfBlock { dm, dff, .. } => vec![
                p("ln1_g", vec![dm], InitKind::One),
                p("ln1_b", vec![dm], InitKind::Zero),
                p("wq", vec![dm, dm], InitKind::HeNormal),
                p("bq", vec![dm], InitKind::Zero),
                p("wk", vec![dm, dm], InitKind::HeNormal),
                p("bk", vec![dm], InitKind::Zero),
                p("wv", vec![dm, dm], InitKind::HeNormal),
                p("bv", vec![dm], InitKind::Zero),
                p("wo", vec![dm, dm], InitKind::HeNormal),
                p("bo", vec![dm], InitKind::Zero),
                p("ln2_g", vec![dm], InitKind::One),
                p("ln2_b", vec![dm], InitKind::Zero),
                p("w1", vec![dm, dff], InitKind::HeNormal),
                p("b1", vec![dff], InitKind::Zero),
                p("w2", vec![dff, dm], InitKind::HeNormal),
                p("b2", vec![dm], InitKind::Zero),
            ],
        }
    }

    /// Per-sample forward FLOPs (2 per multiply-add), as an exact integer
    /// cast to f64 — the γ workloads of eqs 14–16.
    pub fn fwd_flops(&self) -> f64 {
        match *self {
            LayerSpec::Conv { h, w, ic, k, oc, .. } => (2 * k * k * ic * oc * h * w) as f64,
            LayerSpec::Dense { din, dout, .. } => (2 * din * dout) as f64,
            LayerSpec::Embed { h, w, c, patch, dm } => {
                let t = (h / patch) * (w / patch);
                (2 * t * patch * patch * c * dm) as f64
            }
            LayerSpec::TxfBlock { tokens, dm, dff, .. } => {
                let qkvo = 4 * 2 * tokens * dm * dm; // the four dm x dm projections
                let attn = 2 * 2 * tokens * tokens * dm; // scores QKᵀ + weighted sum PV
                let mlp = 2 * (2 * tokens * dm * dff); // two dense layers
                let ln = 2 * 8 * tokens * dm; // two layernorms
                (qkvo + attn + mlp + ln) as f64
            }
        }
    }
}

/// Build a [`ShapeSpec`] from a layer graph: parameter table in layer
/// order (layer `i` is block `i+1`), cut menu `1..=L-1`, φ/smashed/FLOP
/// tables derived per cut, and the standard artifact naming scheme.
pub fn build_shape(
    key: &str,
    input_shape: Vec<usize>,
    classes: usize,
    layers: Vec<Layer>,
    train_batch: usize,
    eval_batch: usize,
) -> ShapeSpec {
    assert!(layers.len() >= 2, "{key}: a graph needs at least two layers to have a cut");
    assert_eq!(
        layers[0].spec.in_elems(),
        input_shape.iter().product::<usize>(),
        "{key}: first layer does not accept the input shape"
    );
    for pair in layers.windows(2) {
        assert_eq!(
            pair[0].spec.out_elems(),
            pair[1].spec.in_elems(),
            "{key}: {} -> {} activation mismatch",
            pair[0].name,
            pair[1].name
        );
    }
    let mut params = Vec::new();
    for (i, layer) in layers.iter().enumerate() {
        params.extend(layer.spec.param_specs(&layer.name, i + 1));
    }
    let fwd: Vec<f64> = layers.iter().map(|l| l.spec.fwd_flops()).collect();
    let num_cuts = layers.len() - 1;
    let mut cuts = Vec::with_capacity(num_cuts);
    for v in 1..=num_cuts {
        let mut artifacts = BTreeMap::new();
        for role in CUT_ROLES {
            artifacts.insert(role.to_string(), format!("{key}_v{v}_{role}.hlo.txt"));
        }
        let mut smashed_shape = vec![train_batch];
        smashed_shape.extend(layers[v - 1].spec.out_shape());
        cuts.push(CutSpec {
            cut: v,
            phi: params.iter().filter(|p| p.block <= v).map(ParamSpec::size).sum(),
            client_params: params.iter().filter(|p| p.block <= v).count(),
            smashed_shape,
            flops_client_fwd: fwd[..v].iter().sum(),
            flops_client_bwd: 2.0 * fwd[..v].iter().sum::<f64>(),
            flops_server_fwd: fwd[v..].iter().sum(),
            flops_server_bwd: 2.0 * fwd[v..].iter().sum::<f64>(),
            artifacts,
        });
    }
    let mut artifacts = BTreeMap::new();
    for role in ["full_grad", "eval"] {
        artifacts.insert(role.to_string(), format!("{key}_{role}.hlo.txt"));
    }
    ShapeSpec {
        key: key.to_string(),
        input_shape,
        classes,
        train_batch,
        eval_batch,
        total_params: params.iter().map(ParamSpec::size).sum(),
        params,
        layers,
        cuts,
        artifacts,
    }
}

/// Recover a conv/dense layer graph from a parameter table — the
/// derivation the native backend used to do itself, kept for manifests
/// parsed from JSON (the AOT path has no explicit graph).  Errors when
/// the params are not (weight, bias) pairs chaining through the input
/// geometry; callers treat that as "no executable graph" (privacy/
/// latency-only toy specs).
pub fn layers_from_params(
    input_shape: &[usize],
    params: &[ParamSpec],
) -> anyhow::Result<Vec<Layer>> {
    anyhow::ensure!(input_shape.len() == 3, "expected [h, w, c] inputs, got {input_shape:?}");
    anyhow::ensure!(
        !params.is_empty() && params.len() % 2 == 0,
        "expected (weight, bias) parameter pairs"
    );
    let n_blocks = params.len() / 2;
    let (mut h, mut w, mut c) = (input_shape[0], input_shape[1], input_shape[2]);
    let mut layers = Vec::with_capacity(n_blocks);
    for bi in 0..n_blocks {
        let wshape = &params[2 * bi].shape;
        let bshape = &params[2 * bi + 1].shape;
        let wname = &params[2 * bi].name;
        let name = wname.trim_end_matches("_w");
        anyhow::ensure!(bshape.len() == 1, "{wname}: bias must be rank 1");
        match wshape.len() {
            4 => {
                let k = wshape[0];
                let oc = wshape[3];
                anyhow::ensure!(wshape[1] == k && k % 2 == 1, "{wname}: bad kernel");
                anyhow::ensure!(wshape[2] == c, "{wname}: in-channels {} != {c}", wshape[2]);
                anyhow::ensure!(bshape[0] == oc, "{wname}: bias/filters mismatch");
                anyhow::ensure!(h % 2 == 0 && w % 2 == 0, "{wname}: pool needs even h/w");
                layers.push(Layer::new(name, LayerSpec::Conv { h, w, ic: c, k, oc, pool: true }));
                h /= 2;
                w /= 2;
                c = oc;
            }
            2 => {
                let (din, dout) = (wshape[0], wshape[1]);
                anyhow::ensure!(
                    din == h * w * c,
                    "{wname}: dense fan-in {din} != upstream {}",
                    h * w * c
                );
                anyhow::ensure!(bshape[0] == dout, "{wname}: bias/out mismatch");
                layers.push(Layer::new(
                    name,
                    LayerSpec::Dense { din, dout, relu: bi + 1 < n_blocks },
                ));
                h = 1;
                w = 1;
                c = dout;
            }
            r => anyhow::bail!("{wname}: unsupported weight rank {r}"),
        }
    }
    Ok(layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Vec<Layer> {
        vec![
            Layer::new("conv1", LayerSpec::Conv { h: 8, w: 8, ic: 1, k: 3, oc: 4, pool: true }),
            Layer::new("fc1", LayerSpec::Dense { din: 4 * 4 * 4, dout: 6, relu: true }),
            Layer::new("fc2", LayerSpec::Dense { din: 6, dout: 3, relu: false }),
        ]
    }

    #[test]
    fn menu_has_one_cut_per_non_final_layer() {
        let spec = build_shape("t", vec![8, 8, 1], 3, tiny_graph(), 2, 4);
        assert_eq!(spec.cuts.len(), 2);
        assert_eq!(spec.menu().len(), 2);
        assert_eq!(spec.cut(1).smashed_shape, vec![2, 4, 4, 4]);
        assert_eq!(spec.cut(2).smashed_shape, vec![2, 6]);
    }

    #[test]
    fn phi_counts_client_prefix() {
        let spec = build_shape("t", vec![8, 8, 1], 3, tiny_graph(), 2, 4);
        assert_eq!(spec.cut(1).phi, 3 * 3 * 1 * 4 + 4);
        assert_eq!(spec.cut(1).client_params, 2);
        assert_eq!(spec.cut(2).client_params, 4);
        assert_eq!(spec.total_params, 40 + 64 * 6 + 6 + 6 * 3 + 3);
    }

    #[test]
    fn flops_split_conserves_total() {
        let spec = build_shape("t", vec![8, 8, 1], 3, tiny_graph(), 2, 4);
        let t0 = spec.cuts[0].flops_client_fwd + spec.cuts[0].flops_server_fwd;
        for c in &spec.cuts {
            assert_eq!(c.flops_client_fwd + c.flops_server_fwd, t0);
            assert_eq!(c.flops_client_bwd, 2.0 * c.flops_client_fwd);
        }
    }

    #[test]
    fn txf_block_owns_sixteen_params_with_unit_gammas() {
        let blk = LayerSpec::TxfBlock { tokens: 9, dm: 8, heads: 2, dff: 16 };
        let ps = blk.param_specs("blk1", 2);
        assert_eq!(ps.len(), 16);
        assert_eq!(ps[0].name, "blk1_ln1_g");
        assert_eq!(ps[0].init, InitKind::One);
        assert_eq!(ps[2].shape, vec![8, 8]);
        assert_eq!(blk.in_elems(), blk.out_elems());
    }

    #[test]
    fn layers_recovered_from_params_match_the_graph() {
        let spec = build_shape("t", vec![8, 8, 1], 3, tiny_graph(), 2, 4);
        let rec = layers_from_params(&spec.input_shape, &spec.params).unwrap();
        assert_eq!(rec.len(), 3);
        assert_eq!(rec[0].spec, spec.layers[0].spec);
        assert_eq!(rec[2].spec, LayerSpec::Dense { din: 6, dout: 3, relu: false });
    }

    #[test]
    fn mismatched_chain_panics() {
        let bad = vec![
            Layer::new("fc1", LayerSpec::Dense { din: 4, dout: 5, relu: true }),
            Layer::new("fc2", LayerSpec::Dense { din: 6, dout: 3, relu: false }),
        ];
        assert!(std::panic::catch_unwind(|| build_shape("t", vec![4], 3, bad, 2, 4)).is_err());
    }
}
