//! `repro` — SFL-GA reproduction CLI (leader entrypoint).
//!
//! Subcommands:
//!   train     run one training configuration and dump metrics CSV
//!   optimize  run Algorithm 1 (joint CCC) and report the reward curve
//!   figures   regenerate the paper's evaluation figures (3–8)
//!   info      print manifest / model-splitting summary
//!
//! Everything runs on the built-in manifest + native pure-Rust backend;
//! no artifacts, Python or PJRT required (see DESIGN.md §Backends).
//!
//! The networked runtime ships as two sibling binaries: `sfl-coordinator`
//! (listener, round engine, fault policy) and `sfl-participant` (stateless
//! compute peer).  See DESIGN.md §Transport.

use std::path::{Path, PathBuf};

use sfl_ga::ccc::{self, CccConfig};
use sfl_ga::coordinator::{AllocPolicy, RunMetrics, SchemeKind, TrainConfig, Trainer};
use sfl_ga::figures::{self, FigCtx};
use sfl_ga::model::registry;
use sfl_ga::util::cli::Args;
use sfl_ga::util::logging;
use sfl_ga::{info, privacy};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    logging::set_level(logging::level_from_str(&args.str_or("log", "info")));
    let results_dir = PathBuf::from(args.str_or("results", "results"));
    let seed = args.parse_or("seed", 17u64)?;

    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args, &results_dir, seed),
        Some("optimize") => cmd_optimize(&args, seed),
        Some("figures") => cmd_figures(&args, &results_dir, seed),
        Some("info") | None => cmd_info(&args),
        Some(other) => anyhow::bail!("unknown subcommand '{other}' (train|optimize|figures|info)"),
    }
}

fn cmd_train(args: &Args, results_dir: &Path, seed: u64) -> anyhow::Result<()> {
    let model = args.model()?;
    let manifest = registry::manifest(&model)?;
    let dataset = args.str_or("dataset", "mnist");
    let scheme = SchemeKind::parse(&args.str_or("scheme", "sfl-ga"))?;
    let cut = args.parse_or("cut", 2usize)?;
    manifest
        .for_dataset(&dataset)?
        .menu()
        .validate(cut)
        .map_err(|e| anyhow::anyhow!("--cut: {e} (model '{model}')"))?;
    let scenario = args.scenario()?;
    let cfg = TrainConfig {
        dataset: dataset.clone(),
        model: model.clone(),
        scheme,
        num_clients: args.parse_or("clients", 10usize)?,
        rounds: args.parse_or("rounds", 100usize)?,
        tau: args.parse_or("tau", 1usize)?,
        lr: args.parse_or("lr", 0.02f32)?,
        samples_per_client: args.parse_or("samples-per-client", 256usize)?,
        scenario: scenario.clone(),
        seed,
        eval_every: args.parse_or("eval-every", 5usize)?,
        threads: args.threads()?,
        alloc: if args.flag("equal-alloc") { AllocPolicy::Equal } else { AllocPolicy::Optimal },
        comp: sfl_ga::latency::ComputeConfig {
            // --f-spread 0.5 → clients draw 50–100% of f_client_max (30b).
            f_client_spread: args.parse_or("f-spread", 0.0f64)?,
            ..Default::default()
        },
        ..Default::default()
    };
    info!(
        "training {} ({model}) on {dataset} [{}], cut v={cut}, {} rounds",
        scheme.name(),
        scenario.describe(),
        cfg.rounds
    );
    let mut trainer = Trainer::native(&manifest, cfg)?;
    info!("backend: {} ({} round-engine threads)", trainer.backend_name(), trainer.threads());
    let mut metrics = RunMetrics::new(scheme, &dataset);
    for stats in trainer.run(cut)? {
        metrics.push(&stats);
        if let Some((tl, ta)) = stats.test {
            info!(
                "round {:>4}  train_loss {:.4}  test_loss {:.4}  test_acc {:.3}  comm {:.1} MB  latency {:.1}s",
                stats.round,
                stats.train_loss,
                tl,
                ta,
                metrics.total_comm_mb(),
                metrics.total_latency_s(),
            );
        }
    }
    let out = results_dir.join(format!("train_{}_{}_{}_v{}.csv", scheme.name(), model, dataset, cut));
    metrics.write_csv(&out)?;
    info!("wrote {}", out.display());
    Ok(())
}

fn cmd_optimize(args: &Args, seed: u64) -> anyhow::Result<()> {
    let model = args.model()?;
    let manifest = registry::manifest(&model)?;
    let dataset = args.str_or("dataset", "mnist");
    let spec = manifest.for_dataset(&dataset)?.clone();
    let cfg = CccConfig {
        epsilon: args.parse_or("epsilon", 1e-4f64)?,
        episodes: args.parse_or("episodes", 300usize)?,
        steps_per_episode: args.parse_or("steps", 20usize)?,
        alloc: if args.flag("equal-alloc") { AllocPolicy::Equal } else { AllocPolicy::Optimal },
        ..Default::default()
    };
    let clients = args.parse_or("clients", 10usize)?;
    let scenario = args.scenario()?;
    info!(
        "Algorithm 1 ({model}) on {dataset} [{}]: eps={}, {} episodes x {} steps, {clients} clients",
        scenario.describe(),
        cfg.epsilon,
        cfg.episodes,
        cfg.steps_per_episode,
    );
    let mut env = ccc::Env::with_scenario(
        spec,
        Default::default(),
        Default::default(),
        cfg,
        clients,
        seed,
        scenario,
    );
    let trained = ccc::train(&mut env, seed ^ 0xA1);
    let n = trained.episode_rewards.len();
    for (ep, r) in trained.episode_rewards.iter().enumerate() {
        if ep % (n / 20).max(1) == 0 || ep + 1 == n {
            info!("episode {ep:>5}: reward {r:.2}");
        }
    }
    Ok(())
}

fn cmd_figures(args: &Args, results_dir: &Path, seed: u64) -> anyhow::Result<()> {
    let mut ctx = FigCtx::new(results_dir, args.flag("fast"), seed)?;
    ctx.threads = args.threads()?;
    // Figures reproduce the paper's setup by default; scenario flags let
    // the same harnesses replot under heterogeneity.
    ctx.scenario = args.scenario()?;
    if args.flag("all") {
        figures::run_all(&ctx)?;
    } else {
        let fig = args.parse_or("fig", 0usize)?;
        anyhow::ensure!(fig != 0, "pass --fig N (3..8) or --all");
        figures::run(&ctx, fig)?;
    }
    info!("figure CSVs in {}", results_dir.display());
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let model = args.model()?;
    let manifest = registry::manifest(&model)?;
    println!("SFL-GA reproduction — manifest summary (model: {model})\n");
    for (ds, key) in &manifest.datasets {
        let spec = &manifest.shapes[key];
        println!(
            "dataset {ds:<8} shape {key:<8} params {:>9}  train_batch {}  eval_batch {}",
            spec.total_params,
            spec.train_batch,
            spec.eval_batch,
        );
        for cut in &spec.cuts {
            println!(
                "  cut v={}: phi={:>8} ({:.2}% of q)  smashed/sample={:>5}  privacy margin={:.2e}",
                cut.cut,
                cut.phi,
                100.0 * cut.phi as f64 / spec.total_params as f64,
                cut.smashed_per_sample(),
                privacy::leakage_margin(spec, cut.cut),
            );
        }
    }
    Ok(())
}
