//! Figure 7 — convergence of Algorithm 1: episode reward vs episode under
//! different privacy constraints ε.  Tighter ε forbids small cuts, forcing
//! costlier actions and a lower reward plateau.

use crate::ccc::{self, CccConfig};
use crate::coordinator::AllocPolicy;
use crate::util::csvio::CsvWriter;

use super::FigCtx;

pub fn run(ctx: &FigCtx) -> anyhow::Result<()> {
    let episodes = if ctx.fast { 120 } else { 500 };
    let ds = "mnist";
    let spec = ctx.manifest.for_dataset(ds)?.clone();
    let mut w = CsvWriter::create(
        ctx.out("fig7_mnist.csv"),
        &["epsilon", "episode", "reward", "reward_smoothed"],
    )?;
    for eps in [1e-3, 5e-4, 1e-4] {
        let cfg = CccConfig {
            epsilon: eps,
            episodes,
            steps_per_episode: 20,
            // Equal allocation in the reward loop keeps 500-episode runs
            // tractable; the χ/ψ ordering across cuts is preserved.
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        // Scenario flags carry through: stragglers/participation shift
        // the allocator costs the reward is built from.
        let mut env = ccc::Env::with_scenario(
            spec.clone(),
            Default::default(),
            Default::default(),
            cfg,
            10,
            ctx.seed,
            ctx.scenario.clone(),
        );
        let trained = ccc::train(&mut env, ctx.seed ^ 0x77);
        let mut smooth = f64::NAN;
        for (ep, &r) in trained.episode_rewards.iter().enumerate() {
            smooth = if smooth.is_nan() { r } else { 0.9 * smooth + 0.1 * r };
            w.row(&[
                format!("{eps}"),
                ep.to_string(),
                format!("{r:.3}"),
                format!("{smooth:.3}"),
            ])?;
        }
        let tail: f64 = trained.episode_rewards[episodes - episodes / 10..]
            .iter()
            .sum::<f64>()
            / (episodes / 10) as f64;
        crate::info!("fig7 eps={eps}: converged reward ≈ {tail:.1}");
    }
    Ok(())
}
