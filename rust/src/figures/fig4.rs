//! Figure 4 — communication overhead (MB) vs test accuracy for SFL-GA,
//! traditional SFL and PSL.  The headline claim: SFL-GA reaches the same
//! accuracy with a fraction of the traffic (e.g. <20 MB vs >40 MB for SFL
//! at ~94% on MNIST).

use crate::coordinator::{RunMetrics, SchemeKind, TrainConfig, Trainer};
use crate::util::csvio::CsvWriter;

use super::FigCtx;

pub const CUT: usize = 2;

pub fn run(ctx: &FigCtx) -> anyhow::Result<()> {
    let rounds = if ctx.fast { 30 } else { 100 };
    for ds in ctx.datasets() {
        let mut w = CsvWriter::create(
            ctx.out(&format!("fig4_{ds}.csv")),
            &["scheme", "round", "cum_comm_mb", "test_acc"],
        )?;
        for scheme in [SchemeKind::SflGa, SchemeKind::Sfl, SchemeKind::Psl] {
            let cfg = TrainConfig {
                dataset: ds.to_string(),
                scheme,
                rounds,
                eval_every: if ctx.fast { 5 } else { 4 },
                seed: ctx.seed,
                threads: ctx.threads,
                scenario: ctx.scenario.clone(),
                ..Default::default()
            };
            let mut trainer = Trainer::native(&ctx.manifest, cfg)?;
            let mut metrics = RunMetrics::new(scheme, ds);
            for stats in trainer.run(CUT)? {
                metrics.push(&stats);
                let row = metrics.rows.last().unwrap();
                if row.evaluated {
                    w.row(&[
                        scheme.name().to_string(),
                        row.round.to_string(),
                        format!("{:.4}", row.cum_comm_mb),
                        format!("{:.4}", row.test_acc),
                    ])?;
                }
            }
            crate::info!(
                "fig4 {ds} {}: acc {:.3} at {:.1} MB",
                scheme.name(),
                metrics.final_accuracy(),
                metrics.total_comm_mb()
            );
        }
    }
    Ok(())
}
