//! Figure 8 — expected round latency vs total bandwidth (5–30 MHz) for
//! SFL-GA, SFL, PSL and FL (MNIST).  Pure timing-model sweep: more
//! bandwidth → faster rounds for everyone; SFL-GA lowest among the split
//! schemes (broadcast beats unicast, no model-aggregation traffic).

use crate::coordinator::SchemeKind;
use crate::coordinator::timing::{AllocPolicy, round_latency};
use crate::latency::ComputeConfig;
use crate::scenario::ScenarioConfig;
use crate::util::csvio::CsvWriter;
use crate::wireless::{Channel, ChannelState, NetConfig};

use super::FigCtx;

pub const CUT: usize = 2;
pub const CLIENTS: usize = 10;

pub fn run(ctx: &FigCtx) -> anyhow::Result<()> {
    let draws = if ctx.fast { 10 } else { 40 };
    let spec = ctx.manifest.for_dataset("mnist")?.clone();
    // Scenario flags carry through the pure timing sweep too: straggler
    // capacities and per-draw participation cohorts, resolved exactly
    // like the trainer resolves them.
    let mut comp = ComputeConfig::default();
    let caps = ctx.scenario.resolve_caps(&comp, CLIENTS, ctx.seed);
    let mut w = CsvWriter::create(
        ctx.out("fig8_mnist.csv"),
        &["scheme", "bandwidth_mhz", "mean_round_latency_s"],
    )?;
    for bw_mhz in [5.0, 10.0, 15.0, 20.0, 25.0, 30.0] {
        let net = NetConfig { bandwidth: bw_mhz * 1e6, ..Default::default() };
        let mut channel = Channel::new(net.clone(), CLIENTS, ctx.seed ^ bw_mhz as u64);
        // Each draw is one round: a channel state plus (under partial
        // participation) its cohort, shared across the four schemes.
        // The cohort RNG is re-derived per bandwidth point, like the
        // channel, so every point averages over the same cohort sequence
        // and adding/removing a bandwidth never shifts the others.
        let mut part_rng = ScenarioConfig::part_rng(ctx.seed ^ bw_mhz as u64);
        let rounds: Vec<(ChannelState, Vec<f64>)> = (0..draws)
            .map(|_| {
                let st = channel.draw_round();
                let cohort = ctx.scenario.draw_participants(&mut part_rng, CLIENTS);
                let gains = cohort.iter().map(|&i| st.gains[i]).collect();
                let cohort_caps = cohort.iter().map(|&i| caps[i]).collect();
                (ChannelState { gains }, cohort_caps)
            })
            .collect();
        for scheme in SchemeKind::all() {
            let mean: f64 = rounds
                .iter()
                .map(|(st, cohort_caps)| {
                    comp.client_caps = cohort_caps.clone();
                    round_latency(
                        scheme,
                        &spec,
                        spec.cut(CUT),
                        &net,
                        &comp,
                        st,
                        AllocPolicy::Optimal,
                        1,
                    )
                    .total()
                })
                .sum::<f64>()
                / draws as f64;
            w.row(&[
                scheme.name().to_string(),
                format!("{bw_mhz}"),
                format!("{mean:.4}"),
            ])?;
            crate::info!("fig8 {} @ {bw_mhz} MHz: {mean:.3}s/round", scheme.name());
        }
    }
    Ok(())
}
