//! Figure 8 — expected round latency vs total bandwidth (5–30 MHz) for
//! SFL-GA, SFL, PSL and FL (MNIST).  Pure timing-model sweep: more
//! bandwidth → faster rounds for everyone; SFL-GA lowest among the split
//! schemes (broadcast beats unicast, no model-aggregation traffic).

use crate::coordinator::SchemeKind;
use crate::coordinator::timing::{AllocPolicy, round_latency};
use crate::latency::ComputeConfig;
use crate::util::csvio::CsvWriter;
use crate::wireless::{Channel, NetConfig};

use super::FigCtx;

pub const CUT: usize = 2;

pub fn run(ctx: &FigCtx) -> anyhow::Result<()> {
    let draws = if ctx.fast { 10 } else { 40 };
    let spec = ctx.manifest.for_dataset("mnist")?.clone();
    let comp = ComputeConfig::default();
    let mut w = CsvWriter::create(
        ctx.out("fig8_mnist.csv"),
        &["scheme", "bandwidth_mhz", "mean_round_latency_s"],
    )?;
    for bw_mhz in [5.0, 10.0, 15.0, 20.0, 25.0, 30.0] {
        let net = NetConfig { bandwidth: bw_mhz * 1e6, ..Default::default() };
        let mut channel = Channel::new(net.clone(), 10, ctx.seed ^ bw_mhz as u64);
        let states: Vec<_> = (0..draws).map(|_| channel.draw_round()).collect();
        for scheme in SchemeKind::all() {
            let mean: f64 = states
                .iter()
                .map(|st| {
                    round_latency(
                        scheme,
                        &spec,
                        spec.cut(CUT),
                        &net,
                        &comp,
                        st,
                        AllocPolicy::Optimal,
                        1,
                    )
                    .total()
                })
                .sum::<f64>()
                / draws as f64;
            w.row(&[
                scheme.name().to_string(),
                format!("{bw_mhz}"),
                format!("{mean:.4}"),
            ])?;
            crate::info!("fig8 {} @ {bw_mhz} MHz: {mean:.3}s/round", scheme.name());
        }
    }
    Ok(())
}
