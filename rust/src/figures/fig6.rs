//! Figure 6 — accuracy vs latency across resource strategies:
//! Algorithm 1 (DDQN cut + optimal allocation) against fixed/random cut
//! selection under optimal/equal resource allocation.

use crate::ccc::{self, CccConfig, CutPolicy, DdqnCut, FixedCut, RandomCut};
use crate::coordinator::{AllocPolicy, RunMetrics, SchemeKind, TrainConfig, Trainer};
use crate::util::csvio::CsvWriter;

use super::FigCtx;

pub const EPSILON: f64 = 1e-4;

pub fn run(ctx: &FigCtx) -> anyhow::Result<()> {
    let rounds = if ctx.fast { 25 } else { 80 };
    let episodes = if ctx.fast { 60 } else { 300 };
    for ds in ctx.datasets() {
        let spec = ctx.manifest.for_dataset(ds)?.clone();
        // Train Algorithm 1's agent once per dataset.
        let ccc_cfg = CccConfig {
            episodes,
            steps_per_episode: 10,
            epsilon: EPSILON,
            alloc: AllocPolicy::Optimal,
            ..Default::default()
        };
        // The agent trains under the same scenario the evaluation runs in
        // (stragglers shift the allocator costs it optimizes against).
        let mut env = ccc::Env::with_scenario(
            spec.clone(),
            Default::default(),
            Default::default(),
            ccc_cfg,
            10,
            ctx.seed,
            ctx.scenario.clone(),
        );
        let trained = ccc::train(&mut env, ctx.seed ^ 0xA1);

        let mut strategies: Vec<(Box<dyn CutPolicy>, AllocPolicy)> = vec![
            (
                Box::new(DdqnCut::new(trained.agent, &spec, EPSILON)?),
                AllocPolicy::Optimal,
            ),
            (Box::new(FixedCut(2)), AllocPolicy::Optimal),
            (Box::new(FixedCut(2)), AllocPolicy::Equal),
            (
                Box::new(RandomCut::new(&spec, EPSILON, ctx.seed ^ 0x2A)?),
                AllocPolicy::Optimal,
            ),
            (
                Box::new(RandomCut::new(&spec, EPSILON, ctx.seed ^ 0x2B)?),
                AllocPolicy::Equal,
            ),
        ];

        let mut w = CsvWriter::create(
            ctx.out(&format!("fig6_{ds}.csv")),
            &["strategy", "round", "cut", "cum_latency_s", "test_acc"],
        )?;
        for (policy, alloc) in strategies.iter_mut() {
            let name = format!(
                "{}+{}",
                policy.name(),
                if *alloc == AllocPolicy::Optimal { "opt" } else { "eq" }
            );
            let cfg = TrainConfig {
                dataset: ds.to_string(),
                scheme: SchemeKind::SflGa,
                rounds,
                eval_every: 5,
                alloc: *alloc,
                seed: ctx.seed,
                threads: ctx.threads,
                scenario: ctx.scenario.clone(),
                ..Default::default()
            };
            let mut trainer = Trainer::native(&ctx.manifest, cfg)?;
            let mut metrics = RunMetrics::new(SchemeKind::SflGa, ds);
            // Build a throwaway env (same cfg) for feature extraction so
            // the trained policy sees Algorithm 1's state layout.
            let feat_env = ccc::Env::new(
                spec.clone(),
                Default::default(),
                Default::default(),
                CccConfig { epsilon: EPSILON, ..Default::default() },
                10,
                ctx.seed ^ 0xFE,
            );
            for r in 0..rounds {
                let state = trainer.draw_channel();
                let features = feat_env.features(&state);
                let cut = policy.select(r, &features);
                let stats = trainer.run_round(cut, &state)?;
                metrics.push(&stats);
                let row = metrics.rows.last().unwrap();
                if row.evaluated {
                    w.row(&[
                        name.clone(),
                        row.round.to_string(),
                        row.cut.to_string(),
                        format!("{:.4}", row.cum_latency_s),
                        format!("{:.4}", row.test_acc),
                    ])?;
                }
            }
            crate::info!(
                "fig6 {ds} {name}: acc {:.3} after {:.1}s simulated",
                metrics.final_accuracy(),
                metrics.total_latency_s()
            );
        }
    }
    Ok(())
}
