//! Figure harnesses: one module per evaluation figure of the paper.
//! Each writes `results/figN_*.csv` with the same series the paper plots.

pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;

use std::path::{Path, PathBuf};

use crate::model::Manifest;
use crate::scenario::ScenarioConfig;

/// Shared harness context.  Figures run on the built-in manifest and the
/// native backend, so regenerating them needs no artifacts.
pub struct FigCtx {
    pub results_dir: PathBuf,
    pub manifest: Manifest,
    /// Fast mode: fewer rounds/episodes for smoke runs (`--fast`).
    pub fast: bool,
    pub seed: u64,
    /// Round-engine worker threads (0 = auto); results are bitwise
    /// identical for every value, so figures stay reproducible.
    pub threads: usize,
    /// Scenario the training figures (3–6) run under; the default
    /// reproduces the paper's IID homogeneous always-on setup.
    pub scenario: ScenarioConfig,
}

impl FigCtx {
    pub fn new(results_dir: &Path, fast: bool, seed: u64) -> anyhow::Result<FigCtx> {
        std::fs::create_dir_all(results_dir)?;
        Ok(FigCtx {
            results_dir: results_dir.to_path_buf(),
            manifest: Manifest::builtin(),
            fast,
            seed,
            threads: 0,
            scenario: ScenarioConfig::default(),
        })
    }

    pub fn out(&self, name: &str) -> PathBuf {
        self.results_dir.join(name)
    }

    /// Datasets figures sweep: fast mode keeps mnist only.
    pub fn datasets(&self) -> Vec<&'static str> {
        if self.fast {
            vec!["mnist"]
        } else {
            vec!["mnist", "fmnist", "cifar10"]
        }
    }
}

/// Run one figure by number.
pub fn run(ctx: &FigCtx, fig: usize) -> anyhow::Result<()> {
    match fig {
        3 => fig3::run(ctx),
        4 => fig4::run(ctx),
        5 => fig5::run(ctx),
        6 => fig6::run(ctx),
        7 => fig7::run(ctx),
        8 => fig8::run(ctx),
        other => anyhow::bail!("no figure {other} (have 3..=8)"),
    }
}

/// Run every figure.
pub fn run_all(ctx: &FigCtx) -> anyhow::Result<()> {
    for fig in 3..=8 {
        crate::info!("=== figure {fig} ===");
        run(ctx, fig)?;
    }
    Ok(())
}
