//! Figure 5 — test accuracy vs cumulative wall latency for SFL-GA, SFL,
//! PSL and FL.  FL converges slowest (full model on 0.1 GHz clients); the
//! split schemes bunch together with SFL-GA cheapest per round.

use crate::coordinator::{RunMetrics, SchemeKind, TrainConfig, Trainer};
use crate::util::csvio::CsvWriter;

use super::FigCtx;

pub const CUT: usize = 2;

pub fn run(ctx: &FigCtx) -> anyhow::Result<()> {
    let rounds = if ctx.fast { 30 } else { 100 };
    for ds in ctx.datasets() {
        let mut w = CsvWriter::create(
            ctx.out(&format!("fig5_{ds}.csv")),
            &["scheme", "round", "cum_latency_s", "test_acc"],
        )?;
        for scheme in SchemeKind::all() {
            let cfg = TrainConfig {
                dataset: ds.to_string(),
                scheme,
                rounds,
                eval_every: if ctx.fast { 5 } else { 4 },
                seed: ctx.seed,
                threads: ctx.threads,
                scenario: ctx.scenario.clone(),
                ..Default::default()
            };
            let mut trainer = Trainer::native(&ctx.manifest, cfg)?;
            let mut metrics = RunMetrics::new(scheme, ds);
            for stats in trainer.run(CUT)? {
                metrics.push(&stats);
                let row = metrics.rows.last().unwrap();
                if row.evaluated {
                    w.row(&[
                        scheme.name().to_string(),
                        row.round.to_string(),
                        format!("{:.4}", row.cum_latency_s),
                        format!("{:.4}", row.test_acc),
                    ])?;
                }
            }
            crate::info!(
                "fig5 {ds} {}: acc {:.3} after {:.1}s simulated",
                scheme.name(),
                metrics.final_accuracy(),
                metrics.total_latency_s()
            );
        }
    }
    Ok(())
}
