//! Figure 3 — convergence (test accuracy vs communication round) of
//! SFL-GA at every cut of the model's menu, with traditional SFL as the
//! benchmark, per dataset.  Validates Theorem 2 / Remark 1: smaller φ(v)
//! converges better.

use crate::coordinator::{RunMetrics, SchemeKind, TrainConfig, Trainer};
use crate::util::csvio::CsvWriter;

use super::FigCtx;

pub fn run(ctx: &FigCtx) -> anyhow::Result<()> {
    let rounds = if ctx.fast { 30 } else { 100 };
    for ds in ctx.datasets() {
        let mut w = CsvWriter::create(
            ctx.out(&format!("fig3_{ds}.csv")),
            &["series", "round", "test_acc", "test_loss", "train_loss"],
        )?;
        let menu = ctx.manifest.for_dataset(ds)?.menu();
        // SFL benchmark at the middle cut.
        let mut runs: Vec<(String, SchemeKind, usize)> =
            vec![("sfl".into(), SchemeKind::Sfl, (menu.len() / 2).max(1))];
        for v in menu.ids() {
            runs.push((format!("sfl-ga-v{v}"), SchemeKind::SflGa, v));
        }
        for (series, scheme, cut) in runs {
            let cfg = TrainConfig {
                dataset: ds.to_string(),
                scheme,
                rounds,
                eval_every: if ctx.fast { 5 } else { 4 },
                seed: ctx.seed,
                threads: ctx.threads,
                scenario: ctx.scenario.clone(),
                ..Default::default()
            };
            let mut trainer = Trainer::native(&ctx.manifest, cfg)?;
            let mut metrics = RunMetrics::new(scheme, ds);
            for stats in trainer.run(cut)? {
                metrics.push(&stats);
                if let Some((tl, ta)) = stats.test {
                    w.row(&[
                        series.clone(),
                        stats.round.to_string(),
                        format!("{ta:.4}"),
                        format!("{tl:.4}"),
                        format!("{:.4}", stats.train_loss),
                    ])?;
                }
            }
            crate::info!(
                "fig3 {ds} {series}: final acc {:.3}",
                metrics.final_accuracy()
            );
        }
    }
    Ok(())
}
