//! Wireless system model (paper §II-C, §V-A2).
//!
//! Path loss 128.1 + 37.6·log10(d_km) dB, block Rayleigh fading (constant
//! within a round, redrawn across rounds), thermal noise −174 dBm/Hz.
//! Uplink: OFDMA subchannels, rate eq (10); downlink: full-band broadcast,
//! rate eq (11).  All quantities SI: Hz, W, bits/s.

use crate::util::rng::Pcg;

/// Static network configuration (defaults = the paper's §V-A numbers).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Total uplink bandwidth B in Hz (paper: 20 MHz).
    pub bandwidth: f64,
    /// Client max transmit power in W (paper: 25 dBm).
    pub p_max: f64,
    /// Server broadcast power in W (paper: 33 dBm).
    pub p_server: f64,
    /// Noise spectral density N0 in W/Hz (paper: −174 dBm/Hz).
    pub n0: f64,
    /// Client distance range in km (uniform draw per client).
    pub d_min_km: f64,
    pub d_max_km: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            bandwidth: 20e6,
            p_max: dbm_to_watt(25.0),
            p_server: dbm_to_watt(33.0),
            n0: dbm_to_watt(-174.0), // per Hz
            d_min_km: 0.05,
            d_max_km: 0.5,
        }
    }
}

pub fn dbm_to_watt(dbm: f64) -> f64 {
    10f64.powf((dbm - 30.0) / 10.0)
}

pub fn db_to_linear(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Path loss in dB at distance d (km): 128.1 + 37.6 log10(d).
pub fn path_loss_db(d_km: f64) -> f64 {
    128.1 + 37.6 * d_km.log10()
}

/// Average (large-scale) channel power gain at distance d.
pub fn avg_gain(d_km: f64) -> f64 {
    db_to_linear(-path_loss_db(d_km))
}

/// Shannon rate in bit/s over bandwidth `b` Hz with received power `p*g`.
/// r = B log2(1 + p g / (B N0))  — eqs (10)/(11).
pub fn rate(b: f64, p: f64, g: f64, n0: f64) -> f64 {
    if b <= 0.0 {
        return 0.0;
    }
    b * (1.0 + p * g / (b * n0)).log2()
}

/// Per-round channel state for all clients.
#[derive(Clone, Debug)]
pub struct ChannelState {
    /// Instantaneous power gains g_t^n (path loss × Rayleigh |h|²).
    pub gains: Vec<f64>,
}

/// Block-fading channel: fixed client placement, i.i.d. Rayleigh power
/// fading per round (|h|² ~ Exp(1)).
#[derive(Clone, Debug)]
pub struct Channel {
    cfg: NetConfig,
    avg_gains: Vec<f64>,
    rng: Pcg,
}

impl Channel {
    pub fn new(cfg: NetConfig, num_clients: usize, seed: u64) -> Channel {
        let mut rng = Pcg::new(seed, 0xC4A7);
        let avg_gains = (0..num_clients)
            .map(|_| avg_gain(rng.range(cfg.d_min_km, cfg.d_max_km)))
            .collect();
        Channel { cfg, avg_gains, rng }
    }

    pub fn cfg(&self) -> &NetConfig {
        &self.cfg
    }

    pub fn num_clients(&self) -> usize {
        self.avg_gains.len()
    }

    /// Draw round-t gains: g_t^n = ḡ_n · |h|²,  |h|² ~ Exp(1).
    pub fn draw_round(&mut self) -> ChannelState {
        let gains = self
            .avg_gains
            .iter()
            .map(|&g| g * self.rng.exponential(1.0))
            .collect();
        ChannelState { gains }
    }

    /// Uplink rate for client n given its bandwidth/power allocation.
    pub fn uplink_rate(&self, state: &ChannelState, n: usize, b: f64, p: f64) -> f64 {
        rate(b, p, state.gains[n], self.cfg.n0)
    }

    /// Downlink broadcast rate to client n (full band, server power),
    /// eq (11).
    pub fn downlink_rate(&self, state: &ChannelState, n: usize) -> f64 {
        rate(self.cfg.bandwidth, self.cfg.p_server, state.gains[n], self.cfg.n0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    #[test]
    fn dbm_conversions() {
        assert!((dbm_to_watt(30.0) - 1.0).abs() < 1e-12);
        assert!((dbm_to_watt(0.0) - 1e-3).abs() < 1e-15);
        assert!((dbm_to_watt(25.0) - 0.316227766).abs() < 1e-6);
    }

    #[test]
    fn path_loss_reference_point() {
        // At 1 km the law gives exactly 128.1 dB.
        assert!((path_loss_db(1.0) - 128.1).abs() < 1e-9);
        // Closer → less loss.
        assert!(path_loss_db(0.1) < path_loss_db(1.0));
    }

    #[test]
    fn rate_monotone_in_power_and_positive() {
        let g = avg_gain(0.2);
        let n0 = dbm_to_watt(-174.0);
        let r1 = rate(1e6, 0.1, g, n0);
        let r2 = rate(1e6, 0.3, g, n0);
        assert!(r2 > r1 && r1 > 0.0);
    }

    #[test]
    fn rate_subadditive_in_bandwidth() {
        // Fixed power split across more bandwidth still increases rate
        // (log concavity ⇒ diminishing, but monotone in B).
        let g = avg_gain(0.2);
        let n0 = dbm_to_watt(-174.0);
        let r1 = rate(1e6, 0.1, g, n0);
        let r2 = rate(2e6, 0.1, g, n0);
        assert!(r2 > r1);
        assert!(r2 < 2.0 * r1);
    }

    #[test]
    fn zero_bandwidth_zero_rate() {
        assert_eq!(rate(0.0, 1.0, 1.0, 1e-20), 0.0);
    }

    #[test]
    fn channel_is_deterministic_per_seed() {
        let cfg = NetConfig::default();
        let mut a = Channel::new(cfg.clone(), 5, 42);
        let mut b = Channel::new(cfg, 5, 42);
        for _ in 0..10 {
            assert_eq!(a.draw_round().gains, b.draw_round().gains);
        }
    }

    #[test]
    fn fading_preserves_mean_gain() {
        let cfg = NetConfig::default();
        let mut ch = Channel::new(cfg, 3, 7);
        let avg = ch.avg_gains.clone();
        let rounds = 20_000;
        let mut sums = vec![0.0; 3];
        for _ in 0..rounds {
            let st = ch.draw_round();
            for (s, g) in sums.iter_mut().zip(&st.gains) {
                *s += g;
            }
        }
        for (s, a) in sums.iter().zip(&avg) {
            let mean = s / rounds as f64;
            assert!((mean / a - 1.0).abs() < 0.05, "mean {mean} avg {a}");
        }
    }

    #[test]
    fn property_downlink_uses_full_band() {
        check("downlink-band", 32, |rng| {
            let cfg = NetConfig::default();
            let ch = Channel::new(cfg.clone(), 2, rng.next_u64());
            let st = ChannelState { gains: vec![rng.uniform() * 1e-10 + 1e-13; 2] };
            let r = ch.downlink_rate(&st, 0);
            let want = rate(cfg.bandwidth, cfg.p_server, st.gains[0], cfg.n0);
            prop_assert!((r - want).abs() < 1e-6, "downlink {r} != {want}");
            Ok(())
        });
    }

    #[test]
    fn realistic_rates_order_of_magnitude() {
        // 20 MHz, 25 dBm, 100–500 m: uplink SNR should yield Mb/s rates.
        let cfg = NetConfig::default();
        let g = avg_gain(0.3);
        let r = rate(2e6, cfg.p_max, g, cfg.n0);
        assert!(r > 1e5 && r < 1e9, "r = {r} bit/s");
    }
}
