//! Algorithm 1 — the joint CCC strategy (paper §IV-B).
//!
//! P2.2 (cutting-point selection) is cast as the MDP of §IV-B2:
//! * state  (eq 34): per-client channel gains + the episode's accumulated
//!   cost (both normalized for the Q-network);
//! * action: cut v ∈ {1..4};
//! * reward (eq 35): −(w·Γ(φ(v)) + χ_t + ψ_t) when the privacy constraint
//!   (30e) holds, else the penalty −C.  (χ, ψ) come from solving P2.1 with
//!   the convex allocator at every exploration step — exactly the
//!   interleaving Algorithm 1 prescribes.
//!
//! A trained agent doubles as a [`CutPolicy`] so the Trainer can run
//! Fig. 6's "Algorithm 1" strategy against fixed/random baselines.

use crate::allocator::build_problem;
use crate::coordinator::population::Population;
use crate::coordinator::timing::AllocPolicy;
use crate::ddqn::{DdqnAgent, DdqnConfig, Transition};
use crate::latency::ComputeConfig;
use crate::model::ShapeSpec;
use crate::privacy;
use crate::scenario::ScenarioConfig;
use crate::util::rng::Pcg;
use crate::wireless::{ChannelState, NetConfig};

/// Γ(φ): the convergence-penalty term of Assumption 4, modeled as the
/// monotone non-decreasing g0 · φ(v)/q.
pub fn gamma_of_phi(spec: &ShapeSpec, cut: usize, g0: f64) -> f64 {
    g0 * spec.phi_fraction(cut)
}

#[derive(Clone, Debug)]
pub struct CccConfig {
    /// Objective weight w in P1 (balances Γ vs latency).
    pub w: f64,
    /// Γ scale g0.
    pub g0: f64,
    /// Privacy threshold ε (30e).
    pub epsilon: f64,
    /// Penalty C for privacy-infeasible actions (reward = −C).
    pub penalty: f64,
    pub episodes: usize,
    /// Communication rounds per episode (T in Algorithm 1).
    pub steps_per_episode: usize,
    pub alloc: AllocPolicy,
    pub ddqn: DdqnConfig,
}

impl Default for CccConfig {
    fn default() -> Self {
        CccConfig {
            w: 1.0,
            g0: 10.0,
            epsilon: 1e-4,
            penalty: 50.0,
            episodes: 500,
            steps_per_episode: 20,
            alloc: AllocPolicy::Optimal,
            ddqn: DdqnConfig {
                state_dim: 0,   // filled by Env::agent_config
                num_actions: 0, // filled by Env::agent_config from the cut menu

                hidden: vec![64, 64],
                gamma: 0.9,
                lr: 1e-3,
                batch: 32,
                replay_capacity: 20_000,
                target_sync: 200,
                eps_start: 1.0,
                eps_end: 0.05,
                eps_decay: 0.999,
                warmup: 64,
            },
        }
    }
}

/// The MDP environment: wireless channel + P2.1 allocator + privacy gate,
/// under a [`ScenarioConfig`] (straggler compute profiles shift the
/// allocator's FP/BP terms; partial participation shrinks the per-round
/// cohort the allocation serves — Algorithm 1 then optimizes the cut for
/// the clients that actually show up).
pub struct Env {
    pub spec: ShapeSpec,
    pub net: NetConfig,
    pub comp: ComputeConfig,
    pub cfg: CccConfig,
    /// The virtual population the Trainer derives from — the SAME keyed
    /// pure functions, so the optimizer prices exactly the hardware,
    /// fading and cohorts the simulator replays
    /// (`tests/reproducibility.rs` pins the equality bitwise).
    pop: Population,
    /// Dense per-client capacity table, derived once from the population
    /// (the Env's cost model is an O(N) policy surface by construction —
    /// its feature vector is per-client — so caching the dense table
    /// costs nothing extra).
    caps: Vec<f64>,
    /// Channel draws consumed so far — the fading clock.  Deliberately
    /// NOT reset per episode: block fading continues across episodes.
    chan_draws: u64,
    /// Step index within the current episode — the cohort key, reset by
    /// [`Env::reset`] so every episode replays the same cohort sequence.
    episode_step: u64,
    cum_cost: f64,
    steps: usize,
}

impl Env {
    pub fn new(
        spec: ShapeSpec,
        net: NetConfig,
        comp: ComputeConfig,
        cfg: CccConfig,
        num_clients: usize,
        seed: u64,
    ) -> Env {
        Env::with_scenario(spec, net, comp, cfg, num_clients, seed, ScenarioConfig::default())
    }

    /// Environment whose per-step cost reflects a heterogeneity scenario.
    #[allow(clippy::too_many_arguments)]
    pub fn with_scenario(
        spec: ShapeSpec,
        net: NetConfig,
        comp: ComputeConfig,
        cfg: CccConfig,
        num_clients: usize,
        seed: u64,
        scenario: ScenarioConfig,
    ) -> Env {
        // One derivation for optimizer and simulator: the Env holds the
        // SAME virtual population `Trainer::new` constructs from the run
        // seed (DESIGN.md §Population), so capacities, straggler sets,
        // fading and cohort draws agree bitwise between the two.
        let pop = Population::new(seed, num_clients as u64, scenario, net.clone(), comp.clone())
            .expect("valid scenario/population configuration");
        let caps = pop.caps_dense();
        Env {
            spec,
            net,
            comp,
            cfg,
            pop,
            caps,
            chan_draws: 0,
            episode_step: 0,
            cum_cost: 0.0,
            steps: 0,
        }
    }

    pub fn num_clients(&self) -> usize {
        self.pop.num_clients() as usize
    }

    pub fn scenario(&self) -> &ScenarioConfig {
        self.pop.scenario()
    }

    /// The virtual population this environment prices.
    pub fn population(&self) -> &Population {
        &self.pop
    }

    /// DDQN dimensions for this environment.  The action space is the
    /// active model's cut menu, so a deeper architecture automatically
    /// widens the Q-network's output head.
    pub fn agent_config(&self) -> DdqnConfig {
        DdqnConfig {
            state_dim: self.num_clients() + 1,
            num_actions: self.spec.num_cuts(),
            ..self.cfg.ddqn.clone()
        }
    }

    /// Reset for a new episode; returns (channel state, feature vector).
    ///
    /// The episode's step counter — the cohort-draw key — rewinds to 0,
    /// so every episode replays the SAME cohort sequence: step t's cohort
    /// is the pure function [`Population::cohort`]`(t)`, independent of
    /// how many episodes already ran.  The fading clock (`chan_draws`) is
    /// deliberately NOT reset: block fading continues across episodes, so
    /// the agent explores fresh gain realizations each episode while the
    /// cohort stream stays pinned — the trajectory as a whole is still a
    /// deterministic function of the run seed and episode count.
    pub fn reset(&mut self) -> (ChannelState, Vec<f32>) {
        self.cum_cost = 0.0;
        self.steps = 0;
        self.episode_step = 0;
        let st = self.pop.gains_dense(self.chan_draws);
        self.chan_draws += 1;
        let f = self.features(&st);
        (st, f)
    }

    /// Feature vector (eq 34): normalized log-gains + normalized cum cost.
    pub fn features(&self, state: &ChannelState) -> Vec<f32> {
        let mut f: Vec<f32> = state
            .gains
            .iter()
            .map(|&g| ((g.max(1e-20).log10() + 14.0) / 6.0) as f32)
            .collect();
        let denom = (self.steps.max(1)) as f64;
        f.push((self.cum_cost / denom / 10.0) as f32);
        f
    }

    /// One MDP step: act with cut v on `state`; returns
    /// (reward, cost_components, next_state, next_features).  The round's
    /// cost is evaluated over the participant cohort drawn from the round
    /// RNG (everyone under full participation).
    pub fn step(&mut self, state: &ChannelState, cut: usize) -> StepOutcome {
        let feasible = privacy::cut_feasible(&self.spec, cut, self.cfg.epsilon);
        let n = self.num_clients();
        // Fast path under full participation: no cohort enumeration.
        let cohort = (!self.pop.scenario().full_participation())
            .then(|| self.pop.cohort(self.episode_step));
        self.episode_step += 1;
        let participants = cohort.as_ref().map_or(n, Vec::len);
        let (gamma, chi, psi) = self.cost_components_cohort(state, cut, cohort.as_deref());
        let cost = self.cfg.w * gamma + chi + psi;
        let reward = if feasible { -cost } else { -self.cfg.penalty };
        self.cum_cost += if feasible { cost } else { self.cfg.penalty };
        self.steps += 1;
        let next_state = self.pop.gains_dense(self.chan_draws);
        self.chan_draws += 1;
        let next_features = self.features(&next_state);
        StepOutcome {
            reward,
            gamma,
            chi,
            psi,
            feasible,
            participants,
            cohort,
            next_state,
            next_features,
        }
    }

    /// (Γ, χ*, ψ*) at cut v under the configured allocation policy, with
    /// every client participating.
    pub fn cost_components(&self, state: &ChannelState, cut: usize) -> (f64, f64, f64) {
        self.cost_components_cohort(state, cut, None)
    }

    /// (Γ, χ*, ψ*) with channel/compute restricted to a cohort (`None` =
    /// all clients — no per-call channel rebuild).
    fn cost_components_cohort(
        &self,
        state: &ChannelState,
        cut: usize,
        cohort: Option<&[usize]>,
    ) -> (f64, f64, f64) {
        let cut_spec = self.spec.cut(cut);
        let mut comp = self.comp.clone();
        let sub_state;
        let state_ref = match cohort {
            None => {
                comp.client_caps = self.caps.clone();
                state
            }
            Some(p) => {
                comp.client_caps = p.iter().map(|&i| self.caps[i]).collect();
                sub_state = ChannelState { gains: p.iter().map(|&i| state.gains[i]).collect() };
                &sub_state
            }
        };
        let problem = build_problem(&self.spec, cut_spec, &self.net, &comp, state_ref);
        let alloc = match self.cfg.alloc {
            AllocPolicy::Optimal => problem.solve(),
            AllocPolicy::Equal => problem.solve_equal(),
        };
        (gamma_of_phi(&self.spec, cut, self.cfg.g0), alloc.chi, alloc.psi)
    }
}

pub struct StepOutcome {
    pub reward: f64,
    pub gamma: f64,
    pub chi: f64,
    pub psi: f64,
    pub feasible: bool,
    /// Cohort size the cost was evaluated over.
    pub participants: usize,
    /// The drawn cohort (sorted client indices), `None` under full
    /// participation (implicitly `0..n` — the fast path draws nothing
    /// and allocates nothing).  Exposed so the episode-replay contract
    /// of [`Env::reset`] is observable: for a fixed run seed, every
    /// episode sees the same cohort sequence.
    pub cohort: Option<Vec<usize>>,
    pub next_state: ChannelState,
    pub next_features: Vec<f32>,
}

/// Algorithm 1 output: the trained agent + per-episode reward curve.
pub struct TrainedCcc {
    pub agent: DdqnAgent,
    pub episode_rewards: Vec<f64>,
}

/// Algorithm 1: joint CCC training loop.
pub fn train(env: &mut Env, seed: u64) -> TrainedCcc {
    let mut agent = DdqnAgent::new(env.agent_config(), seed);
    let mut episode_rewards = Vec::with_capacity(env.cfg.episodes);
    for _ep in 0..env.cfg.episodes {
        let (mut state, mut feat) = env.reset();
        let mut ep_reward = 0.0;
        for step in 0..env.cfg.steps_per_episode {
            let action = agent.act(&feat);
            let out = env.step(&state, action + 1);
            ep_reward += out.reward;
            let done = step + 1 == env.cfg.steps_per_episode;
            agent.remember(Transition {
                state: feat.clone(),
                action,
                reward: out.reward,
                next_state: out.next_features.clone(),
                done,
            });
            agent.train_step();
            state = out.next_state;
            feat = out.next_features;
        }
        episode_rewards.push(ep_reward);
    }
    TrainedCcc { agent, episode_rewards }
}

// ------------------------------------------------------------- policies

/// Round-by-round cut selection strategy (Fig. 6's x-axis of baselines).
pub trait CutPolicy {
    fn select(&mut self, round: usize, features: &[f32]) -> usize;
    fn name(&self) -> String;
}

/// Always the same cut.
pub struct FixedCut(pub usize);

impl CutPolicy for FixedCut {
    fn select(&mut self, _round: usize, _features: &[f32]) -> usize {
        self.0
    }
    fn name(&self) -> String {
        format!("fixed-v{}", self.0)
    }
}

/// Uniform over the privacy-feasible cuts.
pub struct RandomCut {
    pub feasible: Vec<usize>,
    pub rng: Pcg,
}

impl RandomCut {
    pub fn new(spec: &ShapeSpec, epsilon: f64, seed: u64) -> anyhow::Result<RandomCut> {
        let feasible = privacy::feasible_cuts(spec, epsilon);
        anyhow::ensure!(!feasible.is_empty(), "no privacy-feasible cut at eps {epsilon}");
        Ok(RandomCut { feasible, rng: Pcg::new(seed, 0x2A4D) })
    }
}

impl CutPolicy for RandomCut {
    fn select(&mut self, _round: usize, _features: &[f32]) -> usize {
        self.feasible[self.rng.below(self.feasible.len())]
    }
    fn name(&self) -> String {
        "random".into()
    }
}

/// Greedy policy from a trained Algorithm-1 agent, clamped to the
/// privacy-feasible set.
pub struct DdqnCut {
    pub agent: DdqnAgent,
    pub feasible: Vec<usize>,
}

impl DdqnCut {
    pub fn new(agent: DdqnAgent, spec: &ShapeSpec, epsilon: f64) -> anyhow::Result<DdqnCut> {
        let feasible = privacy::feasible_cuts(spec, epsilon);
        anyhow::ensure!(!feasible.is_empty(), "no privacy-feasible cut at eps {epsilon}");
        Ok(DdqnCut { agent, feasible })
    }
}

impl CutPolicy for DdqnCut {
    fn select(&mut self, _round: usize, features: &[f32]) -> usize {
        // Greedy over Q, restricted to feasible cuts.
        let q = self.agent.q_values(features);
        *self
            .feasible
            .iter()
            .max_by(|&&a, &&b| q[a - 1].partial_cmp(&q[b - 1]).unwrap())
            .unwrap()
    }
    fn name(&self) -> String {
        "algorithm1".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn env(epsilon: f64, episodes: usize) -> Env {
        let m = Manifest::builtin();
        let spec = m.for_dataset("mnist").unwrap().clone();
        let cfg = CccConfig {
            epsilon,
            episodes,
            steps_per_episode: 8,
            // Equal allocation keeps unit tests fast; Optimal exercised in
            // the figure harness and allocator tests.
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        Env::new(spec, NetConfig::default(), ComputeConfig::default(), cfg, 4, 3)
    }

    #[test]
    fn features_have_expected_dim_and_scale() {
        let mut env = env(1e-4, 1);
        let (_st, f) = env.reset();
        assert_eq!(f.len(), 5);
        assert!(f.iter().all(|&x| x.is_finite() && x.abs() < 20.0), "{f:?}");
    }

    #[test]
    fn infeasible_cut_gets_penalty() {
        // ε high enough that v=1 violates privacy on mnist:
        // φ(1)/q ≈ 4.8e-4 → margin ≈ 4.8e-4 < 1e-3.
        let mut env = env(1e-3, 1);
        let (st, _) = env.reset();
        let out = env.step(&st, 1);
        assert!(!out.feasible);
        assert_eq!(out.reward, -env.cfg.penalty);
        let out2 = env.step(&out.next_state, 2);
        assert!(out2.feasible);
        assert!(out2.reward > -env.cfg.penalty);
    }

    #[test]
    fn cost_components_monotone_gamma() {
        let mut env = env(0.0, 1);
        let (st, _) = env.reset();
        let g: Vec<f64> = (1..=4).map(|v| env.cost_components(&st, v).0).collect();
        assert!(g.windows(2).all(|w| w[0] <= w[1]), "{g:?}");
    }

    #[test]
    fn training_improves_rewards_and_avoids_penalties() {
        let mut env = env(1e-3, 60);
        let trained = train(&mut env, 5);
        assert_eq!(trained.episode_rewards.len(), 60);
        let early: f64 = trained.episode_rewards[..10].iter().sum::<f64>() / 10.0;
        let late: f64 = trained.episode_rewards[50..].iter().sum::<f64>() / 10.0;
        assert!(
            late > early,
            "no improvement: early {early:.2} late {late:.2}"
        );
        // Trained greedy policy should pick a feasible cut.
        let (_st, f) = env.reset();
        let mut pol = DdqnCut::new(trained.agent, &env.spec, 1e-3).unwrap();
        let v = pol.select(0, &f);
        assert!(crate::privacy::cut_feasible(&env.spec, v, 1e-3));
    }

    #[test]
    fn policies_report_names_and_respect_feasibility() {
        let env = env(1e-3, 1);
        let mut fixed = FixedCut(3);
        assert_eq!(fixed.select(0, &[]), 3);
        assert_eq!(fixed.name(), "fixed-v3");
        let mut rnd = RandomCut::new(&env.spec, 1e-3, 7).unwrap();
        for r in 0..50 {
            let v = rnd.select(r, &[]);
            assert!(crate::privacy::cut_feasible(&env.spec, v, 1e-3));
        }
        assert!(RandomCut::new(&env.spec, 10.0, 7).is_err());
    }
}
