//! Minimal benchmarking harness (criterion is not in the offline vendor
//! set).  Used by the `benches/*.rs` targets via `harness = false`:
//! warmup, timed iterations, mean/std/p50/p99 reporting, and a regression
//! guard helper for CI-style thresholds.
//!
//! Every bench target honors **quick mode** ([`quick`], set by the
//! `SFLGA_BENCH_QUICK` env var): iteration counts and problem sizes
//! shrink to smoke-test proportions so CI's `bench-smoke` lane can
//! execute every target end-to-end — exercising the real bench code paths
//! and emitting the real `BENCH_*.json` artifacts — in seconds rather
//! than minutes.  Quick-mode numbers are NOT comparable to full-mode
//! numbers; the JSON marks the mode so downstream tooling never mixes
//! them.

use std::sync::OnceLock;
use std::time::Instant;

use crate::util::stats::{percentile, Running};

/// True when the `SFLGA_BENCH_QUICK` environment variable is set to
/// anything but `0`: bench targets shrink to smoke proportions.  The env
/// var is read once and cached — bench loops call this per size decision,
/// and the mode cannot meaningfully change mid-process anyway.
pub fn quick() -> bool {
    static QUICK: OnceLock<bool> = OnceLock::new();
    *QUICK.get_or_init(|| {
        std::env::var_os("SFLGA_BENCH_QUICK").is_some_and(|v| v != "0" && !v.is_empty())
    })
}

/// Pick an iteration (or size) count by mode: `full` normally,
/// `quick_n` under [`quick`] mode.
pub fn iters(full: usize, quick_n: usize) -> usize {
    if quick() {
        quick_n
    } else {
        full
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` runs; prints + returns
/// the summary.  `f` should return something observable to keep the
/// optimizer honest; we black-box it via `std::hint::black_box`.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    let mut stats = Running::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_nanos() as f64;
        samples.push(dt);
        stats.push(dt);
    }
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats.mean(),
        std_ns: stats.std(),
        p50_ns: percentile(&samples, 50.0),
        p99_ns: percentile(&samples, 99.0),
        min_ns: stats.min(),
    };
    res.report();
    res
}

/// Run-once timing for expensive end-to-end cases.
pub fn bench_once<T, F: FnOnce() -> T>(name: &str, f: F) -> f64 {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let ns = t0.elapsed().as_nanos() as f64;
    println!("{:<44} {:>10}        once {:>12}", name, 1, fmt_ns(ns));
    ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 2, 50, || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(r.iters, 50);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn quick_mode_reads_env() {
        // Can't mutate the process env safely under parallel tests; just
        // pin the selection logic.
        if quick() {
            assert_eq!(iters(100, 2), 2);
        } else {
            assert_eq!(iters(100, 2), 100);
        }
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e10).ends_with(" s"));
    }
}
