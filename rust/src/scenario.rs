//! The scenario engine: who trains, on what data, on what hardware.
//!
//! The paper's CCC strategy only matters when clients differ — in
//! channels, compute and data — so every training run is parameterized by
//! a [`ScenarioConfig`] with three orthogonal axes:
//!
//! * **data distribution** — a [`Partition`] strategy (IID /
//!   Dirichlet(α) label skew / pathological shards) producing the
//!   per-client datasets and, through their sizes, the sample-count
//!   aggregation weights ρ^n = |D^n|/|D|;
//! * **client heterogeneity** — a [`StragglerConfig`] marking a fraction
//!   of clients as stragglers whose compute capacity is divided by a
//!   slowdown factor, flowing into [`crate::latency::ComputeConfig`] and
//!   from there into the timing model and the P2.1 resource allocator;
//! * **participation** — a per-round client sampling rate: each round the
//!   coordinator draws K = ⌈rate·N⌉ of the N clients from the round RNG,
//!   and only those clients compute, communicate and aggregate (with
//!   weights renormalized over the cohort).
//!
//! Defaults reproduce the paper's §V-A setup exactly: IID data,
//! homogeneous always-on clients.  Determinism: every draw is keyed on
//! the run seed and happens on the coordinator thread, so scenario runs
//! inherit the round engine's bitwise thread-count independence (see
//! `tests/determinism.rs` and DESIGN.md §Scenarios).

use crate::data::partition::Partition;
use crate::latency::ComputeConfig;
use crate::util::rng::Pcg;

/// Compute heterogeneity: a fraction of clients run `factor×` slower.
///
/// CLI syntax: `--straggler <frac>x<factor>`, e.g. `0.25x4` = a quarter
/// of the clients at a quarter speed.  Which clients straggle is drawn
/// once per deployment (fixed hardware), deterministically from the seed.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerConfig {
    /// Fraction of clients that are stragglers, in [0, 1].
    pub frac: f64,
    /// Slowdown factor (≥ 1): straggler capacity = f_client / factor.
    pub factor: f64,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        StragglerConfig { frac: 0.0, factor: 1.0 }
    }
}

impl StragglerConfig {
    /// Parse the CLI syntax `<frac>x<factor>` (e.g. `0.25x4`); `none`
    /// disables stragglers.
    pub fn parse(s: &str) -> anyhow::Result<StragglerConfig> {
        let lower = s.to_ascii_lowercase();
        if lower == "none" {
            return Ok(StragglerConfig::default());
        }
        let Some((frac, factor)) = lower.split_once('x') else {
            anyhow::bail!("bad straggler spec '{s}' (want <frac>x<factor>, e.g. 0.25x4)");
        };
        let cfg = StragglerConfig {
            frac: frac
                .parse()
                .map_err(|e| anyhow::anyhow!("--straggler frac '{frac}': {e}"))?,
            factor: factor
                .parse()
                .map_err(|e| anyhow::anyhow!("--straggler factor '{factor}': {e}"))?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.frac) && self.frac.is_finite(),
            "straggler fraction must be in [0, 1], got {}",
            self.frac
        );
        anyhow::ensure!(
            self.factor >= 1.0 && self.factor.is_finite(),
            "straggler factor must be >= 1, got {}",
            self.factor
        );
        Ok(())
    }

    /// Any straggling configured?
    pub fn enabled(&self) -> bool {
        self.frac > 0.0 && self.factor > 1.0
    }

    /// Per-client speed multipliers in (0, 1]: `1/factor` for the
    /// ⌈frac·n⌉ straggler clients (chosen by a seeded shuffle), `1.0`
    /// for the rest.  All-ones when disabled.
    pub fn multipliers(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut m = vec![1.0; n];
        if !self.enabled() || n == 0 {
            return m;
        }
        let k = ((self.frac * n as f64).ceil() as usize).clamp(1, n);
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Pcg::new(seed, 0x57A6);
        rng.shuffle(&mut idx);
        for &i in &idx[..k] {
            m[i] = 1.0 / self.factor;
        }
        m
    }
}

/// The full scenario: data partition × participation × stragglers.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioConfig {
    /// How training data splits across clients.
    pub partition: Partition,
    /// Per-round participation rate in (0, 1]: each round the coordinator
    /// samples ⌈rate·N⌉ clients.  `1.0` = everyone, every round.
    pub participation: f64,
    /// Compute heterogeneity profile.
    pub straggler: StragglerConfig,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            partition: Partition::Iid,
            participation: 1.0,
            straggler: StragglerConfig::default(),
        }
    }
}

impl ScenarioConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.participation > 0.0 && self.participation <= 1.0,
            "participation rate must be in (0, 1], got {}",
            self.participation
        );
        if let Partition::Dirichlet(a) = self.partition {
            anyhow::ensure!(a.is_finite() && a > 0.0, "dirichlet alpha must be > 0, got {a}");
        }
        if let Partition::Shards(s) = self.partition {
            anyhow::ensure!(s >= 1, "shards per client must be >= 1");
        }
        self.straggler.validate()
    }

    /// True when every client participates every round — the fast path
    /// that bypasses the cohort draw entirely (and therefore reproduces
    /// pre-scenario runs byte-for-byte).
    pub fn full_participation(&self) -> bool {
        self.participation >= 1.0
    }

    /// Cohort size K = ⌈rate·N⌉, clamped to [1, N].
    pub fn cohort_size(&self, n: usize) -> usize {
        ((self.participation * n as f64).ceil() as usize).clamp(1, n)
    }

    /// Draw this round's participant set: K distinct client indices,
    /// returned **sorted ascending** so reductions over the cohort keep
    /// the fixed client-index order the determinism guarantee needs.
    /// Full participation returns `0..n` without touching `rng`.
    pub fn draw_participants(&self, rng: &mut Pcg, n: usize) -> Vec<usize> {
        if self.full_participation() {
            return (0..n).collect();
        }
        let k = self.cohort_size(n);
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let mut cohort = idx[..k].to_vec();
        cohort.sort_unstable();
        cohort
    }

    /// Resolve the deployment's per-client compute capacities in FLOPS:
    /// the max/spread draw of [`ComputeConfig::client_flops`] (seeded by
    /// the client count, matching the timing model's convention) with the
    /// straggler multipliers folded in.  The trainer, the CCC environment
    /// and the figure harnesses all share this fold, so the optimizer
    /// prices exactly the hardware the simulator runs on.
    pub fn resolve_caps(&self, comp: &ComputeConfig, n: usize, seed: u64) -> Vec<f64> {
        let mut caps = comp.client_flops(n, n as u64);
        if self.straggler.enabled() {
            let mult = self.straggler.multipliers(n, seed ^ 0x57A6);
            for (c, m) in caps.iter_mut().zip(&mult) {
                *c *= m;
            }
        }
        caps
    }

    /// The participation RNG for a run: one cohort draw per round is
    /// consumed from this stream.  The contract (pinned by
    /// `tests/reproducibility.rs`): `Trainer` derives it once per
    /// run/reset and `ccc::Env` re-derives it on every episode reset, so
    /// for one run seed the trainer's run and EVERY optimizer episode
    /// replay the identical cohort sequence.
    pub fn part_rng(seed: u64) -> Pcg {
        Pcg::new(seed ^ 0x9AC7, 0x9AC7)
    }

    /// One-line description for logs ("dirichlet(0.3), participation 0.5,
    /// stragglers 0.25x4").
    pub fn describe(&self) -> String {
        let mut s = self.partition.name();
        if !self.full_participation() {
            s.push_str(&format!(", participation {}", self.participation));
        }
        if self.straggler.enabled() {
            s.push_str(&format!(
                ", stragglers {}x{}",
                self.straggler.frac, self.straggler.factor
            ));
        }
        s
    }
}

/// One churn event: a participant arriving or departing at a round
/// boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnEvent {
    /// `id` dials in before the round starts (a brand-new joiner, or a
    /// previously departed peer rejoining with a fresh process).
    Join(u64),
    /// `id` drops (process killed / link severed) before the round
    /// starts.  Departing a peer that is not live is a no-op — traces
    /// from fuzzers may be arbitrary.
    Leave(u64),
}

/// A scripted arrival/departure schedule, applied at round boundaries.
///
/// This is the churn-trace **oracle** the chaos wall compares real
/// SIGKILL-and-relaunch runs against: driving a loopback `NetTrainer`
/// with the trace that mirrors the real run's kills and rejoins must
/// produce bitwise-identical digests (DESIGN.md §Transport).  Events at
/// round `r` fire after round `r`'s entry admission poll would — i.e.
/// they shape the cohort that round `r` trains on — in insertion order,
/// so `Leave(3), Join(3)` at one round is a same-round rejoin (fresh
/// cold process) while `Join(3), Leave(3)` is join-then-immediately-die.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChurnTrace {
    events: Vec<(u64, ChurnEvent)>,
}

impl ChurnTrace {
    pub fn new() -> ChurnTrace {
        ChurnTrace::default()
    }

    /// Append an event at round `round` (0-based, round-entry time).
    pub fn push(&mut self, round: u64, ev: ChurnEvent) {
        self.events.push((round, ev));
    }

    /// Events scheduled for round `round`, preserving insertion order.
    pub fn events_at(&self, round: u64) -> impl Iterator<Item = ChurnEvent> + '_ {
        self.events.iter().filter(move |(r, _)| *r == round).map(|(_, ev)| ev).copied()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the CLI syntax: comma-separated `<round>:+<id>` (join) /
    /// `<round>:-<id>` (leave), e.g. `1:-2,3:+2` = client 2 leaves before
    /// round 1 and rejoins before round 3.  Empty string = no churn.
    pub fn parse(s: &str) -> anyhow::Result<ChurnTrace> {
        let mut trace = ChurnTrace::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((round, rest)) = part.split_once(':') else {
                anyhow::bail!("bad churn event '{part}' (want <round>:+<id> or <round>:-<id>)");
            };
            let round: u64 =
                round.parse().map_err(|e| anyhow::anyhow!("churn round '{round}': {e}"))?;
            let parse_id = |id: &str| -> anyhow::Result<u64> {
                id.parse().map_err(|e| anyhow::anyhow!("churn id '{id}': {e}"))
            };
            let ev = if let Some(id) = rest.strip_prefix('+') {
                ChurnEvent::Join(parse_id(id)?)
            } else if let Some(id) = rest.strip_prefix('-') {
                ChurnEvent::Leave(parse_id(id)?)
            } else {
                anyhow::bail!("bad churn event '{part}' (want +<id> or -<id> after ':')");
            };
            trace.push(round, ev);
        }
        Ok(trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_the_papers_setup() {
        let s = ScenarioConfig::default();
        assert_eq!(s.partition, Partition::Iid);
        assert!(s.full_participation());
        assert!(!s.straggler.enabled());
        s.validate().unwrap();
        assert_eq!(s.describe(), "iid");
    }

    #[test]
    fn straggler_parse_and_multipliers() {
        let s = StragglerConfig::parse("0.25x4").unwrap();
        assert_eq!(s, StragglerConfig { frac: 0.25, factor: 4.0 });
        assert!(StragglerConfig::parse("none").unwrap() == StragglerConfig::default());
        assert!(StragglerConfig::parse("1.5x4").is_err());
        assert!(StragglerConfig::parse("0.5x0.5").is_err());
        assert!(StragglerConfig::parse("fastx4").is_err());
        assert!(StragglerConfig::parse("0.5").is_err());

        let m = s.multipliers(8, 11);
        assert_eq!(m.len(), 8);
        assert_eq!(m.iter().filter(|&&x| x == 0.25).count(), 2, "{m:?}");
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 6);
        assert_eq!(m, s.multipliers(8, 11), "multipliers not deterministic");
        assert_ne!(m, s.multipliers(8, 12), "seed ignored");
        // Disabled profile is the identity.
        assert_eq!(StragglerConfig::default().multipliers(5, 1), vec![1.0; 5]);
    }

    #[test]
    fn cohort_draw_is_sorted_distinct_and_deterministic() {
        let sc = ScenarioConfig { participation: 0.5, ..Default::default() };
        sc.validate().unwrap();
        let mut rng = Pcg::new(3, 1);
        let a = sc.draw_participants(&mut rng, 10);
        assert_eq!(a.len(), 5);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "not sorted/distinct: {a:?}");
        assert!(a.iter().all(|&i| i < 10));
        // Re-draws differ across rounds but replay identically per seed.
        let b = sc.draw_participants(&mut rng, 10);
        let mut rng2 = Pcg::new(3, 1);
        assert_eq!(a, sc.draw_participants(&mut rng2, 10));
        assert_eq!(b, sc.draw_participants(&mut rng2, 10));
    }

    #[test]
    fn full_participation_is_identity_and_leaves_rng_untouched() {
        let sc = ScenarioConfig::default();
        let mut rng = Pcg::new(5, 7);
        assert_eq!(sc.draw_participants(&mut rng, 4), vec![0, 1, 2, 3]);
        let mut fresh = Pcg::new(5, 7);
        assert_eq!(rng.next_u64(), fresh.next_u64(), "full participation consumed RNG");
    }

    #[test]
    fn churn_trace_parses_and_preserves_order() {
        let t = ChurnTrace::parse("1:-2, 3:+2,1:+5").unwrap();
        assert_eq!(
            t.events_at(1).collect::<Vec<_>>(),
            vec![ChurnEvent::Leave(2), ChurnEvent::Join(5)]
        );
        assert_eq!(t.events_at(3).collect::<Vec<_>>(), vec![ChurnEvent::Join(2)]);
        assert_eq!(t.events_at(0).count(), 0);
        assert!(ChurnTrace::parse("").unwrap().is_empty());
        assert!(ChurnTrace::parse("1:+2").unwrap() == {
            let mut t = ChurnTrace::new();
            t.push(1, ChurnEvent::Join(2));
            t
        });
        // Same-round rejoin keeps leave-then-join ordering.
        let t = ChurnTrace::parse("2:-0,2:+0").unwrap();
        assert_eq!(
            t.events_at(2).collect::<Vec<_>>(),
            vec![ChurnEvent::Leave(0), ChurnEvent::Join(0)]
        );
        assert!(ChurnTrace::parse("x:+1").is_err());
        assert!(ChurnTrace::parse("1:").is_err());
        assert!(ChurnTrace::parse("1:*1").is_err());
        assert!(ChurnTrace::parse("1:+x").is_err());
        assert!(ChurnTrace::parse("1+2").is_err());
    }

    #[test]
    fn cohort_size_rounds_up_and_clamps() {
        let sc = |p| ScenarioConfig { participation: p, ..Default::default() };
        assert_eq!(sc(0.5).cohort_size(10), 5);
        assert_eq!(sc(0.55).cohort_size(10), 6);
        assert_eq!(sc(0.01).cohort_size(10), 1);
        assert_eq!(sc(1.0).cohort_size(10), 10);
        assert!(sc(0.0).validate().is_err());
        assert!(sc(1.5).validate().is_err());
    }
}
