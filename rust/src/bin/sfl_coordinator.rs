//! `sfl-coordinator` — the networked SFL-GA coordinator (DESIGN.md
//! §Transport).
//!
//! Binds a TCP listener, waits for `--clients` participants to Join,
//! then drives the configured scheme over them with per-phase
//! `--deadline-ms` fault handling (timeout/disconnect → drop →
//! renormalize → restart the round over the survivors).
//!
//! Machine-readable protocol on stdout (tests and scripts key on it):
//!
//! ```text
//! LISTENING 127.0.0.1:41234        # after bind, before accepting
//! JOINED 0 1 2                     # the federation, ascending ids
//! CHECKPOINT round=3               # after each snapshot hits disk
//! COMPLETE rounds=R dropped=1,3 stats=0x<fnv64> params=0x<fnv64>
//! ```
//!
//! The digests are FNV-1a over every stat float's bits and the final
//! global parameters — two coordinators print identical digests iff
//! their runs agreed bitwise.  Logs go to stderr.
//!
//! Churn controls: `--min-clients` sets the quorum floor (a round whose
//! live cohort falls below it pauses up to `--quorum-wait-ms` for
//! rejoins before erroring out); `--checkpoint <path>` +
//! `--checkpoint-every K` persist the round-entry state so a killed
//! coordinator relaunched with `--resume <path>` finishes the run with
//! digests bitwise identical to an uninterrupted one.

use std::net::TcpListener;
use std::path::{Path, PathBuf};

use sfl_ga::coordinator::{
    params_digest, stats_digest, AllocPolicy, Checkpoint, NetTrainer, RunMetrics, SchemeKind,
    TrainConfig,
};
use sfl_ga::info;
use sfl_ga::model::registry;
use sfl_ga::runtime::TcpTransport;
use sfl_ga::util::cli::Args;
use sfl_ga::util::logging;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    for (name, default, help) in [
        ("listen", "127.0.0.1:0", "bind address (port 0 = ephemeral)"),
        ("clients", "2", "participants to wait for"),
        ("join-deadline-ms", "30000", "rendezvous window"),
        ("deadline-ms", "10000", "per-phase response deadline (fault policy)"),
        ("scheme", "sfl-ga", "sfl-ga|sfl-ga-drift|sfl|psl|fl"),
        ("model", "builtin", "model architecture: builtin|vgg|txf"),
        ("cut", "2", "split layer v (validated against the model's cut menu)"),
        ("rounds", "2", "communication rounds"),
        ("tau", "1", "local epochs per round"),
        ("lr", "0.02", "learning rate"),
        ("dataset", "mnist", "dataset key"),
        ("seed", "17", "run seed"),
        ("partition", "iid", "iid|dirichlet:<a>|shards:<s>"),
        ("samples-per-client", "256", "client shard size"),
        ("test-samples", "2048", "test split size"),
        ("eval-every", "5", "rounds between evaluations"),
        ("threads", "0", "coordinator worker threads (0 = auto)"),
        ("min-clients", "1", "quorum floor: pause below this many live participants"),
        ("quorum-wait-ms", "0", "how long a paused round waits for rejoins"),
        ("checkpoint", "", "optional checkpoint path (round-entry snapshots)"),
        ("checkpoint-every", "5", "rounds between checkpoints"),
        ("resume", "", "resume a killed run from this checkpoint"),
        ("out", "", "optional metrics CSV path"),
    ] {
        args.declare(name, default, help);
    }
    if args.flag("help") {
        println!("{}", args.usage("sfl-coordinator", "networked SFL-GA coordinator"));
        return Ok(());
    }
    logging::set_level(logging::level_from_str(&args.str_or("log", "info")));

    let expected: usize = args.parse_or("clients", 2usize)?;
    anyhow::ensure!(expected > 0, "--clients must be positive");
    let join_deadline = args.duration_ms("join-deadline-ms", 30_000)?;
    let deadline = args.duration_ms("deadline-ms", 10_000)?;
    let scheme = SchemeKind::parse(&args.str_or("scheme", "sfl-ga"))?;
    let model = args.model()?;
    let dataset = args.str_or("dataset", "mnist");
    let manifest = registry::manifest(&model)?;
    let cut: usize = args.parse_or("cut", 2usize)?;
    // One shared validation path for the CLI, the round engine and the
    // wire protocol: the active model's menu.
    manifest
        .for_dataset(&dataset)?
        .menu()
        .validate(cut)
        .map_err(|e| anyhow::anyhow!("--cut: {e} (model '{model}')"))?;

    let resume_path = args.str_or("resume", "");
    let ckpt = if resume_path.is_empty() {
        None
    } else {
        let c = Checkpoint::load(Path::new(&resume_path))?;
        info!("resuming from {resume_path}: round {}, {} live", c.round, c.live.len());
        Some(c)
    };
    // A resumed run rendezvouses with exactly the peers that were live at
    // the snapshot — the restored round engine expects that cohort.
    let expected = ckpt.as_ref().map_or(expected, |c| c.live.len());
    anyhow::ensure!(expected > 0, "checkpoint has no live participants to resume with");

    let listener = TcpListener::bind(args.str_or("listen", "127.0.0.1:0"))?;
    emit(&format!("LISTENING {}", listener.local_addr()?));
    let transport = TcpTransport::accept(listener, expected, join_deadline)?;
    let joined = transport.joined();
    emit(&format!(
        "JOINED {}",
        joined.iter().map(|id| id.to_string()).collect::<Vec<_>>().join(" ")
    ));

    let cfg = TrainConfig {
        dataset: dataset.clone(),
        model: model.clone(),
        scheme,
        num_clients: joined.len(),
        rounds: args.parse_or("rounds", 2usize)?,
        tau: args.parse_or("tau", 1usize)?,
        lr: args.parse_or("lr", 0.02f32)?,
        samples_per_client: args.parse_or("samples-per-client", 256usize)?,
        test_samples: args.parse_or("test-samples", 2048usize)?,
        scenario: args.scenario()?,
        seed: args.parse_or("seed", 17u64)?,
        eval_every: args.parse_or("eval-every", 5usize)?,
        threads: args.threads()?,
        alloc: if args.flag("equal-alloc") { AllocPolicy::Equal } else { AllocPolicy::Optimal },
        ..Default::default()
    };
    let mut nt = match &ckpt {
        Some(c) => NetTrainer::resume(&manifest, cfg, deadline, transport, c)?,
        None => NetTrainer::new(&manifest, cfg, deadline, transport)?,
    };
    let min_clients: usize = args.parse_or("min-clients", 1usize)?;
    nt = nt.with_quorum(min_clients, args.duration_ms("quorum-wait-ms", 0)?);
    let ckpt_out = args.str_or("checkpoint", "");
    if !ckpt_out.is_empty() {
        let every: usize = args.parse_or("checkpoint-every", 5usize)?;
        nt = nt.with_checkpoint(PathBuf::from(&ckpt_out), every);
    }
    info!(
        "federation of {} at cut v={cut}, model {model}, scheme {}",
        joined.len(),
        scheme.name()
    );

    while let Some((s, saved)) = nt.step(cut)? {
        if saved {
            emit(&format!("CHECKPOINT round={}", s.round));
        }
    }
    let stats = nt.stats().to_vec();
    let mut metrics = RunMetrics::new(scheme, &dataset);
    for s in &stats {
        metrics.push(s);
        if let Some((tl, ta)) = s.test {
            info!(
                "round {:>4}  train_loss {:.4}  test_loss {tl:.4}  test_acc {ta:.3}",
                s.round, s.train_loss
            );
        }
    }
    let out = args.str_or("out", "");
    if !out.is_empty() {
        let path = PathBuf::from(out);
        metrics.write_csv(&path)?;
        info!("wrote {}", path.display());
    }
    let dropped = nt
        .dropped()
        .iter()
        .map(|id| id.to_string())
        .collect::<Vec<_>>()
        .join(",");
    emit(&format!(
        "COMPLETE rounds={} dropped={} stats=0x{:016x} params=0x{:016x}",
        stats.len(),
        if dropped.is_empty() { "-".into() } else { dropped },
        stats_digest(&stats),
        params_digest(&nt.global_params(cut)),
    ));
    nt.shutdown();
    Ok(())
}

/// Machine-readable stdout line, flushed so a spawning test sees it
/// immediately.
fn emit(line: &str) {
    use std::io::Write;
    let mut out = std::io::stdout().lock();
    let _ = writeln!(out, "{line}");
    let _ = out.flush();
}
