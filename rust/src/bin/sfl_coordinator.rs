//! `sfl-coordinator` — the networked SFL-GA coordinator (DESIGN.md
//! §Transport).
//!
//! Binds a TCP listener, waits for `--clients` participants to Join,
//! then drives the configured scheme over them with per-phase
//! `--deadline-ms` fault handling (timeout/disconnect → drop →
//! renormalize → restart the round over the survivors).
//!
//! Machine-readable protocol on stdout (tests and scripts key on it):
//!
//! ```text
//! LISTENING 127.0.0.1:41234        # after bind, before accepting
//! JOINED 0 1 2                     # the federation, ascending ids
//! COMPLETE rounds=R dropped=1,3 stats=0x<fnv64> params=0x<fnv64>
//! ```
//!
//! The digests are FNV-1a over every stat float's bits and the final
//! global parameters — two coordinators print identical digests iff
//! their runs agreed bitwise.  Logs go to stderr.

use std::net::TcpListener;
use std::path::PathBuf;

use sfl_ga::coordinator::{
    params_digest, stats_digest, AllocPolicy, NetTrainer, RunMetrics, SchemeKind, TrainConfig,
};
use sfl_ga::info;
use sfl_ga::model::{Manifest, NUM_CUTS};
use sfl_ga::runtime::TcpTransport;
use sfl_ga::util::cli::Args;
use sfl_ga::util::logging;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    for (name, default, help) in [
        ("listen", "127.0.0.1:0", "bind address (port 0 = ephemeral)"),
        ("clients", "2", "participants to wait for"),
        ("join-deadline-ms", "30000", "rendezvous window"),
        ("deadline-ms", "10000", "per-phase response deadline (fault policy)"),
        ("scheme", "sfl-ga", "sfl-ga|sfl-ga-drift|sfl|psl|fl"),
        ("cut", "2", "split layer v"),
        ("rounds", "2", "communication rounds"),
        ("tau", "1", "local epochs per round"),
        ("lr", "0.02", "learning rate"),
        ("dataset", "mnist", "dataset key"),
        ("seed", "17", "run seed"),
        ("partition", "iid", "iid|dirichlet:<a>|shards:<s>"),
        ("samples-per-client", "256", "client shard size"),
        ("test-samples", "2048", "test split size"),
        ("eval-every", "5", "rounds between evaluations"),
        ("threads", "0", "coordinator worker threads (0 = auto)"),
        ("out", "", "optional metrics CSV path"),
    ] {
        args.declare(name, default, help);
    }
    if args.flag("help") {
        println!("{}", args.usage("sfl-coordinator", "networked SFL-GA coordinator"));
        return Ok(());
    }
    logging::set_level(logging::level_from_str(&args.str_or("log", "info")));

    let expected: usize = args.parse_or("clients", 2usize)?;
    anyhow::ensure!(expected > 0, "--clients must be positive");
    let join_deadline = args.duration_ms("join-deadline-ms", 30_000)?;
    let deadline = args.duration_ms("deadline-ms", 10_000)?;
    let scheme = SchemeKind::parse(&args.str_or("scheme", "sfl-ga"))?;
    let cut: usize = args.parse_or("cut", 2usize)?;
    anyhow::ensure!(
        (1..=NUM_CUTS).contains(&cut),
        "--cut must be in 1..={NUM_CUTS}, got {cut}"
    );

    let listener = TcpListener::bind(args.str_or("listen", "127.0.0.1:0"))?;
    emit(&format!("LISTENING {}", listener.local_addr()?));
    let transport = TcpTransport::accept(&listener, expected, join_deadline)?;
    let joined = transport.joined();
    emit(&format!(
        "JOINED {}",
        joined.iter().map(|id| id.to_string()).collect::<Vec<_>>().join(" ")
    ));

    let dataset = args.str_or("dataset", "mnist");
    let cfg = TrainConfig {
        dataset: dataset.clone(),
        scheme,
        num_clients: joined.len(),
        rounds: args.parse_or("rounds", 2usize)?,
        tau: args.parse_or("tau", 1usize)?,
        lr: args.parse_or("lr", 0.02f32)?,
        samples_per_client: args.parse_or("samples-per-client", 256usize)?,
        test_samples: args.parse_or("test-samples", 2048usize)?,
        scenario: args.scenario()?,
        seed: args.parse_or("seed", 17u64)?,
        eval_every: args.parse_or("eval-every", 5usize)?,
        threads: args.threads()?,
        alloc: if args.flag("equal-alloc") { AllocPolicy::Equal } else { AllocPolicy::Optimal },
        ..Default::default()
    };
    let manifest = Manifest::builtin();
    let mut nt = NetTrainer::new(&manifest, cfg, deadline, transport)?;
    info!("federation of {} at cut v={cut}, scheme {}", joined.len(), scheme.name());

    let stats = nt.run(cut)?;
    let mut metrics = RunMetrics::new(scheme, &dataset);
    for s in &stats {
        metrics.push(s);
        if let Some((tl, ta)) = s.test {
            info!(
                "round {:>4}  train_loss {:.4}  test_loss {tl:.4}  test_acc {ta:.3}",
                s.round, s.train_loss
            );
        }
    }
    let out = args.str_or("out", "");
    if !out.is_empty() {
        let path = PathBuf::from(out);
        metrics.write_csv(&path)?;
        info!("wrote {}", path.display());
    }
    let dropped = nt
        .dropped()
        .iter()
        .map(|id| id.to_string())
        .collect::<Vec<_>>()
        .join(",");
    emit(&format!(
        "COMPLETE rounds={} dropped={} stats=0x{:016x} params=0x{:016x}",
        stats.len(),
        if dropped.is_empty() { "-".into() } else { dropped },
        stats_digest(&stats),
        params_digest(&nt.global_params(cut)),
    ));
    nt.shutdown();
    Ok(())
}

/// Machine-readable stdout line, flushed so a spawning test sees it
/// immediately.
fn emit(line: &str) {
    use std::io::Write;
    let mut out = std::io::stdout().lock();
    let _ = writeln!(out, "{line}");
    let _ = out.flush();
}
