//! `sfl-participant` — a stateless SFL-GA compute participant
//! (DESIGN.md §Transport).
//!
//! Connects to an `sfl-coordinator`, Joins with `--client-id`, then
//! services the protocol via the SAME [`ParticipantNode`] state machine
//! the in-process loopback transport runs — which is why TCP and
//! loopback federations train bitwise identically.
//!
//! Dialing uses exponential backoff with per-id jitter so a cohort of
//! participants launched in lockstep does not hammer the coordinator in
//! sync.  A coordinator EOF *during the handshake* (before any frame was
//! processed) is retried inside the same connect window — the
//! coordinator may be mid-restart or still draining a stale socket.
//!
//! Once a session is established the process exits on coordinator
//! Shutdown, on EOF (the coordinator closed the link — e.g. this
//! participant was dropped by the fault policy), or after
//! `--idle-timeout-ms` without coordinator traffic, so chaos runs and CI
//! never leak orphan processes.  With `--reconnect`, a mid-run EOF
//! instead re-arms the dialer for `--reconnect-window-ms` and the next
//! session opens with `Rejoin`; the coordinator admits it at the next
//! round boundary and re-`Sync`s the run configuration.  Prints
//! `JOINED <id>` to stdout once configured and `REJOINED <id>` when a
//! rejoin session processes its first frame.

use std::io::{ErrorKind, Read};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use sfl_ga::protocol::wire::{write_frame, MAX_FRAME};
use sfl_ga::protocol::{Msg, PROTO_VERSION};
use sfl_ga::runtime::ParticipantNode;
use sfl_ga::util::cli::Args;
use sfl_ga::util::logging;
use sfl_ga::util::rng::Pcg;
use sfl_ga::{info, warn_log};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// How a session over one TCP connection ended.
enum Exit {
    /// Coordinator sent `Shutdown` — the run is over, exit cleanly.
    Shutdown,
    /// The link went down.  `established` is true iff at least one
    /// coordinator frame was processed on this connection — false means
    /// the coordinator hung up during the handshake (retryable).
    Closed { established: bool },
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    for (name, default, help) in [
        ("connect", "", "coordinator address, e.g. 127.0.0.1:41234"),
        ("client-id", "", "this participant's client id"),
        ("connect-timeout-ms", "10000", "connection retry window"),
        ("reconnect-window-ms", "10000", "with --reconnect: redial window after a lost link"),
        ("idle-timeout-ms", "60000", "exit after this long without traffic"),
    ] {
        args.declare(name, default, help);
    }
    if args.flag("help") {
        println!("{}", args.usage("sfl-participant", "networked SFL-GA participant"));
        return Ok(());
    }
    logging::set_level(logging::level_from_str(&args.str_or("log", "info")));
    let addr = args.str_or("connect", "");
    anyhow::ensure!(!addr.is_empty(), "--connect <addr> is required");
    let id: u64 = args
        .get("client-id")
        .ok_or_else(|| anyhow::anyhow!("--client-id <n> is required"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("--client-id: {e}"))?;
    let connect_window = args.duration_ms("connect-timeout-ms", 10_000)?;
    let reconnect_window = args.duration_ms("reconnect-window-ms", 10_000)?;
    let idle = args.duration_ms("idle-timeout-ms", 60_000)?;
    let reconnect = args.flag("reconnect");

    let mut node = ParticipantNode::new(id);
    // Jitter stream keyed by client id: every participant walks a
    // different backoff schedule, so a lockstep cohort spreads out.
    let mut rng = Pcg::new(id, 0xB0FF);
    let mut attempt: u32 = 0;
    let mut hello = node.join_msg();
    let mut rejoining = false;
    let mut window = connect_window;
    let mut window_start = Instant::now();

    loop {
        let left = window.saturating_sub(window_start.elapsed());
        anyhow::ensure!(
            left > Duration::ZERO,
            "participant {id}: no session established within {window:?}"
        );
        let mut stream = connect_with_backoff(&addr, left, &mut rng, &mut attempt)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(idle))?;
        if let Err(e) = write_frame(&mut stream, &hello.encode()) {
            // The coordinator accepted then immediately closed — same as
            // a handshake EOF, retry inside the window.
            warn_log!("participant {id}: handshake send failed: {e:#}");
            continue;
        }
        info!("participant {id} connected to {addr}");
        match session(&mut stream, &mut node, id, rejoining, reconnect)? {
            Exit::Shutdown => {
                info!("participant {id}: shutdown");
                return Ok(());
            }
            Exit::Closed { established: false } => {
                // Handshake EOF: rendezvous refused or the coordinator is
                // mid-restart.  Retry inside the SAME window.
            }
            Exit::Closed { established: true } => {
                if !reconnect {
                    info!("participant {id}: coordinator closed the session");
                    return Ok(());
                }
                info!("participant {id}: link lost, re-arming reconnect");
                attempt = 0;
                hello = Msg::Rejoin { client: id, version: PROTO_VERSION };
                rejoining = true;
                window = reconnect_window;
                window_start = Instant::now();
            }
        }
    }
}

/// Service one established connection until Shutdown or the link drops.
/// IO failures map to [`Exit::Closed`] when `reconnect` is armed (the
/// caller redials); without it a mid-session transport error is fatal,
/// matching the original one-shot behaviour.
fn session(
    stream: &mut TcpStream,
    node: &mut ParticipantNode,
    id: u64,
    rejoining: bool,
    reconnect: bool,
) -> anyhow::Result<Exit> {
    let mut established = false;
    loop {
        let payload = match next_frame(stream) {
            Ok(Some(p)) => p,
            Ok(None) => {
                return Ok(Exit::Closed { established });
            }
            Err(e) if reconnect => {
                warn_log!("participant {id}: link error: {e:#}");
                return Ok(Exit::Closed { established });
            }
            Err(e) => {
                warn_log!("participant {id}: link error: {e:#}");
                return Err(e);
            }
        };
        let msg = Msg::decode(&payload)?;
        if matches!(msg, Msg::Shutdown) {
            return Ok(Exit::Shutdown);
        }
        let was_ready = node.ready();
        let replies = node.handle(&msg)?;
        if !established {
            established = true;
            if rejoining {
                emit(&format!("REJOINED {id}"));
            }
        }
        if !was_ready && node.ready() {
            // Machine-readable welcome acknowledgement for spawning tests.
            emit(&format!("JOINED {id}"));
        }
        for reply in replies {
            if let Err(e) = write_frame(stream, &reply.encode()) {
                if reconnect {
                    warn_log!("participant {id}: send failed: {e:#}");
                    return Ok(Exit::Closed { established });
                }
                return Err(e);
            }
        }
    }
}

/// Dial until the coordinator answers or the window closes (the
/// coordinator may bind after this process launches).  Sleeps between
/// attempts grow exponentially — base `25 << attempt` ms, capped at
/// 1.6 s — with the actual delay jittered into `[base/2, base)` so
/// retries desynchronize across the cohort.
fn connect_with_backoff(
    addr: &str,
    window: Duration,
    rng: &mut Pcg,
    attempt: &mut u32,
) -> anyhow::Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let base = 25u64 << (*attempt).min(6);
                *attempt += 1;
                let jittered = base / 2 + rng.below((base - base / 2) as usize) as u64;
                let delay = Duration::from_millis(jittered);
                if t0.elapsed() + delay >= window {
                    anyhow::bail!("could not connect to {addr} within {window:?}: {e}");
                }
                std::thread::sleep(delay);
            }
        }
    }
}

/// `protocol::wire::read_frame` with the socket's read timeout doubling
/// as the idle timeout: a timeout while *waiting for a frame to start*
/// is a quiet `Ok(None)` (exit path), a timeout mid-frame is a real
/// error.  The io-level error kinds must be inspected here — the
/// vendored anyhow does not downcast.
fn next_frame(stream: &mut TcpStream) -> anyhow::Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match stream.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
            warn_log!("idle timeout with no coordinator traffic");
            return Ok(None);
        }
        Err(e) => return Err(e.into()),
    }
    let n = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(n <= MAX_FRAME, "incoming frame of {n} bytes exceeds cap {MAX_FRAME}");
    let mut payload = vec![0u8; n];
    stream
        .read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("truncated frame ({n} byte payload): {e}"))?;
    Ok(Some(payload))
}

/// Machine-readable stdout line, flushed so a spawning test sees it
/// immediately.
fn emit(line: &str) {
    use std::io::Write;
    let mut out = std::io::stdout().lock();
    let _ = writeln!(out, "{line}");
    let _ = out.flush();
}
