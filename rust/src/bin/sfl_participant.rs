//! `sfl-participant` — a stateless SFL-GA compute participant
//! (DESIGN.md §Transport).
//!
//! Connects to an `sfl-coordinator`, Joins with `--client-id`, then
//! services the protocol via the SAME [`ParticipantNode`] state machine
//! the in-process loopback transport runs — which is why TCP and
//! loopback federations train bitwise identically.
//!
//! The process exits on coordinator Shutdown, on EOF (the coordinator
//! closed the link — e.g. this participant was dropped by the fault
//! policy), or after `--idle-timeout-ms` without coordinator traffic, so
//! chaos runs and CI never leak orphan processes.  Prints `JOINED <id>`
//! to stdout once configured.

use std::io::{ErrorKind, Read};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use sfl_ga::protocol::wire::{write_frame, MAX_FRAME};
use sfl_ga::protocol::Msg;
use sfl_ga::runtime::ParticipantNode;
use sfl_ga::util::cli::Args;
use sfl_ga::util::logging;
use sfl_ga::{info, warn_log};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    for (name, default, help) in [
        ("connect", "", "coordinator address, e.g. 127.0.0.1:41234"),
        ("client-id", "", "this participant's client id"),
        ("connect-timeout-ms", "10000", "connection retry window"),
        ("idle-timeout-ms", "60000", "exit after this long without traffic"),
    ] {
        args.declare(name, default, help);
    }
    if args.flag("help") {
        println!("{}", args.usage("sfl-participant", "networked SFL-GA participant"));
        return Ok(());
    }
    logging::set_level(logging::level_from_str(&args.str_or("log", "info")));
    let addr = args.str_or("connect", "");
    anyhow::ensure!(!addr.is_empty(), "--connect <addr> is required");
    let id: u64 = args
        .get("client-id")
        .ok_or_else(|| anyhow::anyhow!("--client-id <n> is required"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("--client-id: {e}"))?;
    let connect_window = args.duration_ms("connect-timeout-ms", 10_000)?;
    let idle = args.duration_ms("idle-timeout-ms", 60_000)?;

    let mut stream = connect_with_retry(&addr, connect_window)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(idle))?;
    let mut node = ParticipantNode::new(id);
    write_frame(&mut stream, &node.join_msg().encode())?;
    info!("participant {id} connected to {addr}");

    loop {
        let payload = match next_frame(&mut stream) {
            Ok(Some(p)) => p,
            Ok(None) => {
                info!("participant {id}: coordinator closed the session");
                return Ok(());
            }
            Err(e) => {
                warn_log!("participant {id}: link error: {e:#}");
                return Err(e);
            }
        };
        let msg = Msg::decode(&payload)?;
        if matches!(msg, Msg::Shutdown) {
            info!("participant {id}: shutdown");
            return Ok(());
        }
        let was_ready = node.ready();
        let replies = node.handle(&msg)?;
        if !was_ready && node.ready() {
            // Machine-readable welcome acknowledgement for spawning tests.
            use std::io::Write;
            let mut out = std::io::stdout().lock();
            let _ = writeln!(out, "JOINED {id}");
            let _ = out.flush();
        }
        for reply in replies {
            write_frame(&mut stream, &reply.encode())?;
        }
    }
}

/// Dial until the coordinator answers or the window closes (the
/// coordinator may bind after this process launches).
fn connect_with_retry(addr: &str, window: Duration) -> anyhow::Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if t0.elapsed() < window => {
                let _ = e;
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => anyhow::bail!("could not connect to {addr} within {window:?}: {e}"),
        }
    }
}

/// `protocol::wire::read_frame` with the socket's read timeout doubling
/// as the idle timeout: a timeout while *waiting for a frame to start*
/// is a quiet `Ok(None)` (exit path), a timeout mid-frame is a real
/// error.  The io-level error kinds must be inspected here — the
/// vendored anyhow does not downcast.
fn next_frame(stream: &mut TcpStream) -> anyhow::Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match stream.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
            warn_log!("idle timeout with no coordinator traffic");
            return Ok(None);
        }
        Err(e) => return Err(e.into()),
    }
    let n = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(n <= MAX_FRAME, "incoming frame of {n} bytes exceeds cap {MAX_FRAME}");
    let mut payload = vec![0u8; n];
    stream
        .read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("truncated frame ({n} byte payload): {e}"))?;
    Ok(Some(payload))
}
