//! # SFL-GA — Split Federated Learning with Gradient Aggregation
//!
//! Reproduction of "Communication-and-Computation Efficient Split Federated
//! Learning: Gradient Aggregation and Resource Management" (cs.DC 2025).
//!
//! Layer map (see DESIGN.md):
//! - [`runtime`] executes the split model behind the [`runtime::Backend`]
//!   trait: the pure-Rust native backend by default, or (feature `pjrt`)
//!   the JAX/Pallas AOT artifacts (HLO text) via a PJRT engine thread.
//! - [`coordinator`] implements the paper's training frameworks: SFL-GA and
//!   the SFL / PSL / FL baselines, with full communication accounting.
//! - [`wireless`], [`latency`], [`privacy`] are the paper's §II system
//!   models (eqs 10–17, 29).
//! - [`allocator`] solves the per-round convex resource-allocation
//!   subproblem P2.1; [`ddqn`] + [`ccc`] implement Algorithm 1 (joint CCC).
//! - [`figures`] regenerates Figures 3–8 of the paper's evaluation.

pub mod util;

pub mod tensor;

pub mod model;

pub mod wireless;

pub mod latency;

pub mod privacy;

pub mod allocator;

pub mod ddqn;

pub mod runtime;

pub mod data;

pub mod coordinator;

pub mod ccc;

pub mod figures;

pub mod benchlib;
