//! # SFL-GA — Split Federated Learning with Gradient Aggregation
//!
//! Reproduction of *"Communication-and-Computation Efficient Split
//! Federated Learning: Gradient Aggregation and Resource Management"*
//! (cs.DC 2025), grown into a pure-Rust simulator of split federated
//! training over wireless networks — schemes, system models, resource
//! optimization and figure harnesses, with no external dependencies.
//!
//! ## Quick start
//!
//! ```no_run
//! use sfl_ga::coordinator::{SchemeKind, TrainConfig, Trainer};
//! use sfl_ga::model::Manifest;
//!
//! let manifest = Manifest::builtin();
//! let cfg = TrainConfig { scheme: SchemeKind::SflGa, rounds: 20, ..Default::default() };
//! let mut trainer = Trainer::native(&manifest, cfg)?;
//! for stats in trainer.run(2)? {
//!     if let Some((loss, acc)) = stats.test {
//!         println!("round {}: loss {loss:.3} acc {acc:.3}", stats.round);
//!     }
//! }
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! ## Layer map (see DESIGN.md)
//!
//! - [`runtime`] executes the split model behind the [`runtime::Backend`]
//!   trait: the pure-Rust native backend by default, or (feature `pjrt`)
//!   the JAX/Pallas AOT artifacts (HLO text) via a PJRT engine pool; the
//!   [`runtime::ParallelExecutor`] fans per-client calls across worker
//!   threads with bitwise-deterministic results.
//! - [`coordinator`] implements the paper's training frameworks — SFL-GA
//!   and the SFL / PSL / FL baselines — as ONE phased round engine
//!   configured per scheme by a [`coordinator::RoundPlan`], with full
//!   communication accounting.
//! - [`data`] generates the synthetic datasets and, via
//!   [`data::partition`], splits them across clients (IID / Dirichlet
//!   label skew / pathological shards).
//! - [`scenario`] parameterizes runs by data distribution, partial
//!   participation and compute stragglers — the heterogeneity the CCC
//!   strategy exists to manage.
//! - [`wireless`], [`latency`], [`privacy`] are the paper's §II system
//!   models (eqs 10–17, 29).
//! - [`allocator`] solves the per-round convex resource-allocation
//!   subproblem P2.1; [`ddqn`] + [`ccc`] implement Algorithm 1 (joint
//!   cut/communication/computation management).
//! - [`figures`] regenerates Figures 3–8 of the paper's evaluation.
//!
//! Everything is deterministic in the run seed: figures, training curves
//! and benchmarks reproduce bit-for-bit across machines and thread
//! counts.

pub mod util;

pub mod tensor;

pub mod model;

pub mod wireless;

pub mod latency;

pub mod privacy;

pub mod allocator;

pub mod ddqn;

pub mod runtime;

pub mod protocol;

pub mod data;

pub mod scenario;

pub mod coordinator;

pub mod ccc;

pub mod figures;

pub mod benchlib;
