//! Flat f32 tensor math for the coordinator's hot paths: FedAvg-style
//! weighted aggregation (eqs 5, 7), SGD steps (eq 6), norms.
//!
//! Model state lives as `Vec<Vec<f32>>` — one flat buffer per parameter
//! array, in manifest order.  These loops are the only L3-side numeric
//! code touching model-sized data, so they are written allocation-free.

/// One model's parameters (or gradients): flat buffers in manifest order.
pub type Params = Vec<Vec<f32>>;

/// y += a * x (shape-checked).
pub fn saxpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "saxpy shape mismatch");
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// x *= a.
pub fn scale(x: &mut [f32], a: f32) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// SGD: w -= lr * g over a whole parameter set.
pub fn sgd_step(w: &mut [Vec<f32>], g: &[Vec<f32>], lr: f32) {
    assert_eq!(w.len(), g.len(), "sgd param-count mismatch");
    for (wi, gi) in w.iter_mut().zip(g) {
        saxpy(wi, -lr, gi);
    }
}

/// Zeroed accumulator set shaped like `like` (round-engine reductions
/// preallocate once and [`weighted_accumulate`] into it per client).
pub fn zeros_like(like: &[Vec<f32>]) -> Params {
    like.iter().map(|buf| vec![0.0f32; buf.len()]).collect()
}

/// Reset a preallocated accumulator set to zero (buffer reuse across
/// τ epochs — no per-epoch allocation).
pub fn zero(params: &mut [Vec<f32>]) {
    for buf in params.iter_mut() {
        buf.fill(0.0);
    }
}

/// Streaming reduction step: acc += w · part over a parameter set.
///
/// The round engine reduces per-client gradients by calling this in
/// FIXED client-index order on the coordinator thread, so the f32
/// addition order — and therefore every bit of the result — is
/// independent of how many worker threads computed the parts.
pub fn weighted_accumulate(acc: &mut [Vec<f32>], part: &[Vec<f32>], w: f64) {
    assert_eq!(acc.len(), part.len(), "aggregation param-count mismatch");
    for (a, p) in acc.iter_mut().zip(part) {
        saxpy(a, w as f32, p);
    }
}

/// Flat-buffer variant of [`weighted_accumulate`] (smashed-data grads).
pub fn weighted_accumulate_flat(acc: &mut [f32], part: &[f32], w: f64) {
    saxpy(acc, w as f32, part);
}

/// Weighted aggregation Σ ρ^n x^n into a fresh buffer set (eqs 5/7).
/// Weights need not sum to 1 (callers normalize per the paper's ρ^n = D^n/D).
pub fn weighted_sum(parts: &[&Params], weights: &[f64]) -> Params {
    assert!(!parts.is_empty());
    assert_eq!(parts.len(), weights.len());
    let mut out = zeros_like(parts[0]);
    for (part, &w) in parts.iter().zip(weights) {
        weighted_accumulate(&mut out, part, w);
    }
    out
}

/// Weighted aggregation of single flat buffers (smashed-data gradients).
pub fn weighted_sum_flat(parts: &[&[f32]], weights: &[f64]) -> Vec<f32> {
    assert!(!parts.is_empty());
    assert_eq!(parts.len(), weights.len());
    let mut out = vec![0.0f32; parts[0].len()];
    for (part, &w) in parts.iter().zip(weights) {
        weighted_accumulate_flat(&mut out, part, w);
    }
    out
}

/// L2 norm squared across a parameter set.
pub fn norm2(params: &[Vec<f32>]) -> f64 {
    params
        .iter()
        .flat_map(|buf| buf.iter())
        .map(|&x| (x as f64) * (x as f64))
        .sum()
}

/// Max |a - b| across two parameter sets (used by equivalence tests).
pub fn max_abs_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut m = 0.0f64;
    for (ai, bi) in a.iter().zip(b) {
        assert_eq!(ai.len(), bi.len());
        for (x, y) in ai.iter().zip(bi) {
            m = m.max((*x as f64 - *y as f64).abs());
        }
    }
    m
}

/// Total element count of a parameter set.
pub fn num_elems(params: &[Vec<f32>]) -> usize {
    params.iter().map(|b| b.len()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg;

    fn rand_params(rng: &mut Pcg, shapes: &[usize]) -> Params {
        shapes
            .iter()
            .map(|&n| (0..n).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn saxpy_basic() {
        let mut y = vec![1.0, 2.0];
        saxpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn saxpy_shape_checked() {
        saxpy(&mut [0.0f32; 2], 1.0, &[0.0f32; 3]);
    }

    #[test]
    fn sgd_reduces_toward_gradient_direction() {
        let mut w: Params = vec![vec![1.0, 1.0]];
        sgd_step(&mut w, &[vec![0.5, -0.5]], 0.1);
        assert_eq!(w[0], vec![0.95, 1.05]);
    }

    #[test]
    fn weighted_sum_is_convex_combination() {
        let a: Params = vec![vec![0.0, 10.0]];
        let b: Params = vec![vec![10.0, 0.0]];
        let out = weighted_sum(&[&a, &b], &[0.25, 0.75]);
        assert_eq!(out[0], vec![7.5, 2.5]);
    }

    #[test]
    fn property_aggregation_linearity() {
        // weighted_sum(w; x..) then sgd equals per-part saxpy accumulation.
        check("aggregation-linearity", 64, |rng| {
            let shapes = [3, 5];
            let n = 1 + rng.below(4);
            let parts: Vec<Params> = (0..n).map(|_| rand_params(rng, &shapes)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
            let refs: Vec<&Params> = parts.iter().collect();
            let agg = weighted_sum(&refs, &weights);
            // naive recompute
            for (pi, shape) in shapes.iter().enumerate() {
                for j in 0..*shape {
                    let want: f64 = parts
                        .iter()
                        .zip(&weights)
                        .map(|(p, &w)| p[pi][j] as f64 * w)
                        .sum();
                    prop_assert!(
                        (agg[pi][j] as f64 - want).abs() < 1e-4,
                        "agg[{pi}][{j}] = {} want {want}",
                        agg[pi][j]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_identity_weights() {
        check("identity-weight", 32, |rng| {
            let p = rand_params(rng, &[4, 2]);
            let out = weighted_sum(&[&p], &[1.0]);
            prop_assert!(max_abs_diff(&out, &p) < 1e-7, "identity aggregation changed values");
            Ok(())
        });
    }

    #[test]
    fn property_streaming_accumulate_is_bitwise_weighted_sum() {
        // The round engine's index-ordered streaming reduction must equal
        // the collect-then-sum path BITWISE — this is the determinism
        // contract parallel rounds rely on.
        check("streaming-accumulate-bitwise", 64, |rng| {
            let shapes = [7, 3];
            let n = 1 + rng.below(5);
            let parts: Vec<Params> = (0..n).map(|_| rand_params(rng, &shapes)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
            let refs: Vec<&Params> = parts.iter().collect();
            let want = weighted_sum(&refs, &weights);
            let mut acc = zeros_like(&parts[0]);
            zero(&mut acc); // idempotent on fresh buffers
            for (p, &w) in parts.iter().zip(&weights) {
                weighted_accumulate(&mut acc, p, w);
            }
            for (a, b) in acc.iter().flatten().zip(want.iter().flatten()) {
                prop_assert!(a.to_bits() == b.to_bits(), "streaming != batch: {a} vs {b}");
            }
            Ok(())
        });
    }

    #[test]
    fn zero_resets_in_place() {
        let mut p: Params = vec![vec![1.0, 2.0], vec![3.0]];
        zero(&mut p);
        assert_eq!(p, vec![vec![0.0, 0.0], vec![0.0]]);
    }

    #[test]
    fn norms_and_counts() {
        let p: Params = vec![vec![3.0], vec![4.0]];
        assert_eq!(norm2(&p), 25.0);
        assert_eq!(num_elems(&p), 2);
    }
}
