//! The paper's training frameworks: SFL-GA plus the SFL / PSL / FL
//! baselines, all executed by one phased round engine ([`trainer`])
//! configured per scheme by a [`plan::RoundPlan`] policy, with
//! communication accounting ([`comm`]), simulated wireless timing
//! ([`timing`]) and metrics collection ([`metrics`]).  Runs are
//! parameterized by a [`crate::scenario::ScenarioConfig`] — data
//! partition, partial participation, straggler compute profiles.
//!
//! The same round semantics also run *distributed*: [`net::NetTrainer`]
//! fans the client-side phases out over a
//! [`Transport`](crate::runtime::Transport) — in-process loopback or real
//! TCP participants — with per-phase deadlines and a drop/renormalize
//! fault policy (DESIGN.md §Transport).

pub mod checkpoint;
pub mod comm;
pub mod metrics;
pub mod net;
pub mod plan;
pub mod population;
pub mod timing;
pub mod trainer;

pub use checkpoint::{config_fingerprint, Checkpoint, ClientSideState};
pub use comm::RoundComm;
pub use metrics::RunMetrics;
pub use net::{params_digest, partition_str, stats_digest, NetTrainer};
pub use plan::{BwdDependency, ClientSync, CotangentRoute, RoundPlan};
pub use population::Population;
pub use timing::{AllocPolicy, RoundLatency};
pub use trainer::{RoundStats, TrainConfig, Trainer};

/// The four training schemes the paper evaluates, plus one ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// The paper's contribution: aggregated smashed-gradient broadcast,
    /// with the client-independent g^c of eq (19) (shared client model).
    SflGa,
    /// ABLATION — the *literal per-client* reading of §II-A: every client
    /// backprops the aggregated cotangent through its own data and keeps
    /// its own w^c with no aggregation.  Same communication volume as
    /// SflGa; diverges at large cuts (see DESIGN.md §SFL-GA gradient
    /// semantics).  Not part of the paper's evaluation.
    SflGaDrift,
    /// Traditional SplitFed [11]: unicast gradients + client-side FedAvg.
    Sfl,
    /// Parallel split learning: unicast gradients, no client aggregation.
    Psl,
    /// FedAvg on the full model.
    Fl,
}

impl SchemeKind {
    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::SflGa => "sfl-ga",
            SchemeKind::SflGaDrift => "sfl-ga-drift",
            SchemeKind::Sfl => "sfl",
            SchemeKind::Psl => "psl",
            SchemeKind::Fl => "fl",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<SchemeKind> {
        match s.to_ascii_lowercase().as_str() {
            "sfl-ga" | "sflga" | "ga" => Ok(SchemeKind::SflGa),
            "sfl-ga-drift" | "drift" => Ok(SchemeKind::SflGaDrift),
            "sfl" => Ok(SchemeKind::Sfl),
            "psl" => Ok(SchemeKind::Psl),
            "fl" | "fedavg" => Ok(SchemeKind::Fl),
            other => anyhow::bail!("unknown scheme '{other}' (sfl-ga|sfl-ga-drift|sfl|psl|fl)"),
        }
    }

    /// The paper's four evaluated schemes (the drift ablation excluded).
    pub fn all() -> [SchemeKind; 4] {
        [SchemeKind::SflGa, SchemeKind::Sfl, SchemeKind::Psl, SchemeKind::Fl]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_names_roundtrip() {
        for s in SchemeKind::all() {
            assert_eq!(SchemeKind::parse(s.name()).unwrap(), s);
        }
        assert!(SchemeKind::parse("bogus").is_err());
    }
}
