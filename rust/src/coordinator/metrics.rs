//! Round-stats collection and CSV export for the figure harnesses.

use std::path::Path;

use crate::util::csvio::CsvWriter;

use super::SchemeKind;
use super::trainer::RoundStats;

/// Accumulated series for one training run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub scheme: String,
    pub dataset: String,
    pub rows: Vec<Row>,
}

#[derive(Clone, Copy, Debug)]
pub struct Row {
    pub round: usize,
    pub cut: usize,
    /// Clients that participated this round (scenario engine; = N under
    /// full participation).
    pub participants: usize,
    pub train_loss: f64,
    pub cum_comm_mb: f64,
    pub cum_latency_s: f64,
    pub test_loss: f64,
    pub test_acc: f64,
    /// True when test_loss/test_acc were freshly measured this round.
    pub evaluated: bool,
}

impl RunMetrics {
    pub fn new(scheme: SchemeKind, dataset: &str) -> RunMetrics {
        RunMetrics {
            scheme: scheme.name().to_string(),
            dataset: dataset.to_string(),
            rows: Vec::new(),
        }
    }

    /// Fold a round's stats in, carrying forward the last test metrics.
    pub fn push(&mut self, stats: &RoundStats) {
        let (prev_comm, prev_lat, prev_tl, prev_ta) = self
            .rows
            .last()
            .map(|r| (r.cum_comm_mb, r.cum_latency_s, r.test_loss, r.test_acc))
            .unwrap_or((0.0, 0.0, f64::NAN, f64::NAN));
        let (test_loss, test_acc, evaluated) = match stats.test {
            Some((l, a)) => (l, a, true),
            None => (prev_tl, prev_ta, false),
        };
        self.rows.push(Row {
            round: stats.round,
            cut: stats.cut,
            participants: stats.participants,
            train_loss: stats.train_loss,
            cum_comm_mb: prev_comm + stats.comm.total_mbytes(),
            cum_latency_s: prev_lat + stats.latency.total(),
            test_loss,
            test_acc,
            evaluated,
        });
    }

    /// Latest accuracy (NaN before the first eval).
    pub fn final_accuracy(&self) -> f64 {
        self.rows.last().map(|r| r.test_acc).unwrap_or(f64::NAN)
    }

    pub fn total_comm_mb(&self) -> f64 {
        self.rows.last().map(|r| r.cum_comm_mb).unwrap_or(0.0)
    }

    pub fn total_latency_s(&self) -> f64 {
        self.rows.last().map(|r| r.cum_latency_s).unwrap_or(0.0)
    }

    /// Write the full series (one row per round).
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "scheme", "dataset", "round", "cut", "participants", "train_loss",
                "cum_comm_mb", "cum_latency_s", "test_loss", "test_acc", "evaluated",
            ],
        )?;
        for r in &self.rows {
            w.row(&[
                self.scheme.clone(),
                self.dataset.clone(),
                r.round.to_string(),
                r.cut.to_string(),
                r.participants.to_string(),
                format!("{:.6}", r.train_loss),
                format!("{:.6}", r.cum_comm_mb),
                format!("{:.6}", r.cum_latency_s),
                format!("{:.6}", r.test_loss),
                format!("{:.6}", r.test_acc),
                r.evaluated.to_string(),
            ])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::comm::RoundComm;
    use crate::coordinator::timing::RoundLatency;

    fn stats(round: usize, test: Option<(f64, f64)>) -> RoundStats {
        RoundStats {
            round,
            cut: 2,
            participants: 10,
            train_loss: 1.0,
            comm: RoundComm { uplink_bits: 8e6, downlink_bits: 8e6 },
            latency: RoundLatency { uplink_leg: 0.5, downlink_leg: 0.5 },
            test,
        }
    }

    #[test]
    fn accumulates_and_carries_forward() {
        let mut m = RunMetrics::new(SchemeKind::SflGa, "mnist");
        m.push(&stats(1, Some((2.0, 0.4))));
        m.push(&stats(2, None));
        m.push(&stats(3, Some((1.0, 0.6))));
        assert_eq!(m.rows.len(), 3);
        assert!((m.rows[1].cum_comm_mb - 4.0).abs() < 1e-9); // 2 * 16Mbit = 4 MB
        assert_eq!(m.rows[1].test_acc, 0.4); // carried forward
        assert!(!m.rows[1].evaluated);
        assert_eq!(m.final_accuracy(), 0.6);
        assert!((m.total_latency_s() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip_row_count() {
        let mut m = RunMetrics::new(SchemeKind::Psl, "cifar10");
        for r in 1..=5 {
            m.push(&stats(r, Some((1.0, 0.5))));
        }
        let dir = std::env::temp_dir().join(format!("sflga_metrics_{}", std::process::id()));
        let path = dir.join("run.csv");
        m.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 6); // header + 5 rows
        assert!(text.starts_with("scheme,dataset,round"));
        std::fs::remove_dir_all(dir).unwrap();
    }
}
