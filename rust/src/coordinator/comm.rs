//! Communication accounting — the quantity Fig. 4 plots and the reason
//! SFL-GA exists.
//!
//! Per communication round (τ local epochs), in bits:
//!
//! | scheme | uplink                                   | downlink                         |
//! |--------|------------------------------------------|----------------------------------|
//! | SFL-GA | τ·Σ_n (smashed + labels)                 | τ·smashed (ONE broadcast, eq 5)  |
//! | SFL    | τ·Σ_n (smashed + labels) + Σ_n |w^c|     | τ·Σ_n smashed + |w^c| broadcast  |
//! | PSL    | τ·Σ_n (smashed + labels)                 | τ·Σ_n smashed (unicast each)     |
//! | FL     | Σ_n |w|                                  | |w| broadcast                    |
//!
//! SFL's extra |w^c| terms are the synchronous client-side model
//! aggregation SFL-GA eliminates; the τ·(N−1)·smashed downlink gap between
//! PSL and SFL-GA is the gradient-aggregation saving itself.

use crate::latency::ComputeConfig;
use crate::model::{CutSpec, ShapeSpec};

use super::plan::{CotangentRoute, RoundPlan};
use super::SchemeKind;

/// One round's communication volume in bits.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundComm {
    pub uplink_bits: f64,
    pub downlink_bits: f64,
}

impl RoundComm {
    pub fn total_bits(&self) -> f64 {
        self.uplink_bits + self.downlink_bits
    }

    pub fn total_mbytes(&self) -> f64 {
        self.total_bits() / 8.0 / 1e6
    }
}

/// Bits for one round of `scheme` at cut v with `n` clients and τ epochs.
/// Volumes derive from the scheme's [`RoundPlan`]: the cotangent route
/// sets the downlink shape, the client-sync policy adds the w^c exchange.
pub fn round_comm(
    scheme: SchemeKind,
    spec: &ShapeSpec,
    cut: &CutSpec,
    cfg: &ComputeConfig,
    n_clients: usize,
    tau: usize,
) -> RoundComm {
    let n = n_clients as f64;
    let tau = tau as f64;
    let smashed = crate::latency::smashed_bits(cut, cfg);
    let labels = crate::latency::label_bits(spec, cfg);
    let wc_bits = crate::latency::model_bits(cut.phi, cfg);
    let w_bits = crate::latency::model_bits(spec.total_params, cfg);
    let plan = scheme.plan();
    match plan {
        RoundPlan::Split { route, .. } => {
            // Every split scheme uploads τ·Σ_n (smashed + labels).
            let mut up = tau * n * (smashed + labels);
            // Broadcast sends ONE aggregated cotangent (eq 5); unicast
            // repeats it per client — the gradient-aggregation saving.
            let mut down = match route {
                CotangentRoute::Broadcast => tau * smashed,
                CotangentRoute::Unicast => tau * n * smashed,
            };
            if plan.pays_client_fedavg() {
                // SFL's synchronous client-model exchange (removed by the
                // shared-step plan of eq 19).
                up += n * wc_bits;
                down += wc_bits;
            }
            RoundComm { uplink_bits: up, downlink_bits: down }
        }
        RoundPlan::Full => RoundComm {
            uplink_bits: n * w_bits,
            downlink_bits: w_bits,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn setup() -> (ShapeSpec, ComputeConfig) {
        let m = Manifest::builtin();
        (m.for_dataset("mnist").unwrap().clone(), ComputeConfig::default())
    }

    #[test]
    fn sfl_ga_strictly_cheaper_than_psl_and_sfl() {
        let (spec, cfg) = setup();
        for v in 1..=4 {
            let cut = spec.cut(v);
            for n in [2, 10, 50] {
                let ga = round_comm(SchemeKind::SflGa, &spec, cut, &cfg, n, 1);
                let psl = round_comm(SchemeKind::Psl, &spec, cut, &cfg, n, 1);
                let sfl = round_comm(SchemeKind::Sfl, &spec, cut, &cfg, n, 1);
                assert!(ga.total_bits() < psl.total_bits());
                assert!(psl.total_bits() < sfl.total_bits());
                // Uplink identical for GA and PSL; the saving is downlink.
                assert_eq!(ga.uplink_bits, psl.uplink_bits);
                assert_eq!(psl.downlink_bits, ga.downlink_bits * n as f64);
            }
        }
    }

    #[test]
    fn gradient_aggregation_saving_formula() {
        // PSL − SFL-GA downlink = (N−1)·τ·smashed bits exactly.
        let (spec, cfg) = setup();
        let cut = spec.cut(2);
        let n = 10;
        let tau = 3;
        let ga = round_comm(SchemeKind::SflGa, &spec, cut, &cfg, n, tau);
        let psl = round_comm(SchemeKind::Psl, &spec, cut, &cfg, n, tau);
        let smashed = crate::latency::smashed_bits(cut, &cfg);
        assert_eq!(
            psl.downlink_bits - ga.downlink_bits,
            (n - 1) as f64 * tau as f64 * smashed
        );
    }

    #[test]
    fn fl_scales_with_model_not_batch() {
        let (spec, cfg) = setup();
        let cut = spec.cut(1);
        let fl1 = round_comm(SchemeKind::Fl, &spec, cut, &cfg, 10, 1);
        let fl5 = round_comm(SchemeKind::Fl, &spec, cut, &cfg, 10, 5);
        assert_eq!(fl1, fl5, "FL comm is per-round, independent of tau");
        let w_bits = spec.total_params as f64 * 32.0;
        assert_eq!(fl1.uplink_bits, 10.0 * w_bits);
        assert_eq!(fl1.downlink_bits, w_bits);
    }

    #[test]
    fn sfl_carries_client_model_aggregation_traffic() {
        let (spec, cfg) = setup();
        let cut = spec.cut(3); // big client model
        let sfl = round_comm(SchemeKind::Sfl, &spec, cut, &cfg, 4, 1);
        let psl = round_comm(SchemeKind::Psl, &spec, cut, &cfg, 4, 1);
        let wc = cut.phi as f64 * 32.0;
        assert_eq!(sfl.uplink_bits - psl.uplink_bits, 4.0 * wc);
        assert_eq!(sfl.downlink_bits - psl.downlink_bits, wc);
    }
}
