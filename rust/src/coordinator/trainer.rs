//! The SFL-GA training coordinator: runs communication rounds of the
//! paper's framework (§II-A steps 1–5) and its three baselines over a
//! pluggable execution backend ([`ModelRuntime`]), with full
//! communication/latency accounting.  [`Trainer::native`] wires the
//! pure-Rust backend; the PJRT/AOT path sits behind the `pjrt` feature.
//!
//! Scheme semantics (see DESIGN.md for the discussion):
//! * **SflGa** — clients upload smashed data; the server updates per-client
//!   server-side models and aggregates them (eq 7), aggregates the
//!   smashed-data gradients (eq 5) and *broadcasts one tensor*; every
//!   client backprops that aggregated cotangent through its own data.
//!   Per the paper's eqs (6)/(18)/(19), the client-side gradient g_t^c is
//!   client-independent — all clients hold the same w^c and apply the same
//!   update, so no synchronous aggregation is needed.  We realize that
//!   semantics exactly: one shared w^c updated with the ρ-weighted VJP of
//!   the aggregated cotangent (∇_{w^c} F̃ of eq 19).  The *bias* of that
//!   gradient vs the true split gradient is the Γ(φ(v)) term of
//!   Assumption 4 — it grows with the client model, which is what Fig. 3
//!   measures.
//! * **Sfl** — per-client smashed-gradient unicast + synchronous client-
//!   side FedAvg each round (SplitFed [11]).
//! * **Psl** — per-client unicast, no client-side aggregation.
//! * **Fl** — FedAvg on the full model.
//!
//! Evaluation always scores the *global* model: ρ-weighted client-side
//! average joined with the server-side model (for FL, the global model).

use crate::data::init::{init_params, join_params, split_params};
use crate::data::{Batcher, Dataset, generate, partition};
use crate::latency::ComputeConfig;
use crate::model::Manifest;
use crate::runtime::{ModelRuntime, Tensor};
use crate::tensor::{self, Params};
use crate::wireless::{Channel, ChannelState, NetConfig};

use super::comm::{round_comm, RoundComm};
use super::SchemeKind;
use super::timing::{AllocPolicy, round_latency, RoundLatency};

/// Training configuration (defaults = the paper's §V-A setup).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: String,
    pub scheme: SchemeKind,
    pub num_clients: usize,
    pub rounds: usize,
    /// Local epochs τ per round (eq 6).
    pub tau: usize,
    pub lr: f32,
    /// Samples per client shard.
    pub samples_per_client: usize,
    /// Test-set size (multiple of the eval artifact batch).
    pub test_samples: usize,
    /// Dirichlet α for non-IID splits; None = IID.
    pub non_iid_alpha: Option<f64>,
    pub seed: u64,
    /// Rounds between evaluations.
    pub eval_every: usize,
    pub net: NetConfig,
    pub comp: ComputeConfig,
    pub alloc: AllocPolicy,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dataset: "mnist".into(),
            scheme: SchemeKind::SflGa,
            num_clients: 10,
            rounds: 100,
            tau: 1,
            lr: 0.02,
            samples_per_client: 256,
            test_samples: 2048,
            non_iid_alpha: None,
            seed: 17,
            eval_every: 5,
            net: NetConfig::default(),
            comp: ComputeConfig::default(),
            alloc: AllocPolicy::Optimal,
        }
    }
}

/// Per-round record (metrics.rs turns these into figure CSVs).
#[derive(Clone, Copy, Debug)]
pub struct RoundStats {
    pub round: usize,
    pub cut: usize,
    pub train_loss: f64,
    pub comm: RoundComm,
    pub latency: RoundLatency,
    /// Test metrics when this round evaluated (eval_every), else None.
    pub test: Option<(f64, f64)>, // (loss, accuracy)
}

/// The coordinator state machine.
pub struct Trainer {
    pub cfg: TrainConfig,
    rt: ModelRuntime,
    train: Dataset,
    test: Dataset,
    batchers: Vec<Batcher>,
    /// Aggregation weights ρ^n = D^n / D.
    rho: Vec<f64>,
    channel: Channel,
    /// Per-client client-side models (all schemes; identical where the
    /// scheme keeps them synchronized).
    wc: Vec<Params>,
    /// Server-side model (split schemes) — the aggregated w^s of eq (7).
    ws: Params,
    /// Full global model (FL).
    w_full: Params,
    round: usize,
    /// Cut used in the previous round (dynamic-cut runs resync on change).
    last_cut: Option<usize>,
}

impl Trainer {
    /// Trainer over the native pure-Rust backend — no artifacts needed.
    pub fn native(manifest: &Manifest, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::native(manifest, &cfg.dataset)?;
        Trainer::new(rt, cfg)
    }

    /// Trainer over the PJRT backend, compiled from the AOT artifacts.
    #[cfg(feature = "pjrt")]
    pub fn from_artifacts(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        cfg: TrainConfig,
    ) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::load(artifact_dir, manifest, &cfg.dataset)?;
        Trainer::new(rt, cfg)
    }

    /// Trainer over an already-constructed runtime (any backend).
    pub fn new(rt: ModelRuntime, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        anyhow::ensure!(cfg.num_clients > 0 && cfg.rounds > 0 && cfg.tau > 0);
        let spec = rt.spec().clone();
        anyhow::ensure!(
            cfg.test_samples % spec.eval_batch == 0,
            "test_samples must be a multiple of the eval batch {}",
            spec.eval_batch
        );

        let total = cfg.samples_per_client * cfg.num_clients;
        let train = generate(&spec, &cfg.dataset, total, cfg.seed);
        let test = generate(&spec, &cfg.dataset, cfg.test_samples, cfg.seed ^ 0x7E57);
        let shards = partition(&train, cfg.num_clients, cfg.non_iid_alpha, cfg.seed);
        let d_total: usize = shards.iter().map(Vec::len).sum();
        let rho: Vec<f64> = shards.iter().map(|s| s.len() as f64 / d_total as f64).collect();
        let batchers = shards
            .iter()
            .enumerate()
            .map(|(i, s)| Batcher::new(s.clone(), spec.train_batch, cfg.seed ^ (i as u64) << 8))
            .collect();

        let params = init_params(&spec, cfg.seed ^ 0x1417);
        // Initialize every cut's split from the same full model; the cut in
        // force selects which prefix the clients own.
        let wc = vec![params.clone(); cfg.num_clients];
        let channel = Channel::new(cfg.net.clone(), cfg.num_clients, cfg.seed ^ 0xC4A7);

        Ok(Trainer {
            rt,
            train,
            test,
            batchers,
            rho,
            channel,
            ws: params.clone(),
            w_full: params,
            wc,
            round: 0,
            last_cut: None,
            cfg,
        })
    }

    pub fn spec(&self) -> &crate::model::ShapeSpec {
        self.rt.spec()
    }

    /// Name of the execution backend in use ("native", "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.rt.backend_name()
    }

    pub fn rho(&self) -> &[f64] {
        &self.rho
    }

    pub fn round_index(&self) -> usize {
        self.round
    }

    /// Draw this round's channel (exposed for cut-selection policies that
    /// observe the state before choosing v — Algorithm 1's MDP state).
    pub fn draw_channel(&mut self) -> ChannelState {
        self.channel.draw_round()
    }

    /// Run one communication round at cut `v` with channel `state`.
    pub fn run_round(&mut self, cut: usize, state: &ChannelState) -> anyhow::Result<RoundStats> {
        // Dynamic cut selection (Algorithm 1) moves layer ownership between
        // the sides; on a cut change, re-anchor every replica to the global
        // model so the handed-over blocks carry the aggregated weights.
        if self.last_cut.is_some() && self.last_cut != Some(cut) {
            let global = self.global_params(self.last_cut.unwrap());
            for w in &mut self.wc {
                *w = global.clone();
            }
            self.ws = global;
        }
        self.last_cut = Some(cut);
        let loss = match self.cfg.scheme {
            SchemeKind::SflGa => self.round_sfl_ga(cut, /*shared_wc=*/ true)?,
            SchemeKind::SflGaDrift => self.round_sfl_ga(cut, /*shared_wc=*/ false)?,
            SchemeKind::Sfl => self.round_sfl(cut, /*aggregate_clients=*/ true)?,
            SchemeKind::Psl => self.round_sfl(cut, /*aggregate_clients=*/ false)?,
            SchemeKind::Fl => self.round_fl()?,
        };
        let spec = self.rt.spec().clone();
        let cut_spec = spec.cut(cut);
        let comm = round_comm(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &self.cfg.comp,
            self.cfg.num_clients,
            self.cfg.tau,
        );
        let latency = round_latency(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &self.cfg.net,
            &self.cfg.comp,
            state,
            self.cfg.alloc,
            self.cfg.tau,
        );
        self.round += 1;
        let test = if self.round % self.cfg.eval_every == 0 || self.round == self.cfg.rounds {
            Some(self.evaluate(cut)?)
        } else {
            None
        };
        Ok(RoundStats { round: self.round, cut, train_loss: loss, comm, latency, test })
    }

    /// Convenience: run a full fixed-cut training; returns all stats.
    pub fn run(&mut self, cut: usize) -> anyhow::Result<Vec<RoundStats>> {
        let mut out = Vec::with_capacity(self.cfg.rounds);
        for _ in 0..self.cfg.rounds {
            let state = self.draw_channel();
            out.push(self.run_round(cut, &state)?);
        }
        Ok(out)
    }

    // ----------------------------------------------------------- schemes

    /// SFL-GA round (§II-A steps 1–5), τ epochs.
    ///
    /// `shared_wc=true` is the paper's eq (19) semantics (one client-side
    /// gradient, shared model); `shared_wc=false` is the literal
    /// per-client ablation (own VJP of the aggregated cotangent, own
    /// model, no aggregation) — SchemeKind::SflGaDrift.
    fn round_sfl_ga(&mut self, cut: usize, shared_wc: bool) -> anyhow::Result<f64> {
        let spec = self.rt.spec().clone();
        let nc = spec.cut(cut).client_params;
        let mut mean_loss = 0.0;
        for _ in 0..self.cfg.tau {
            let n = self.cfg.num_clients;
            let mut batches = Vec::with_capacity(n);
            let mut smasheds = Vec::with_capacity(n);
            // (1) client-side FP in parallel (engine serializes execution;
            // the simulated latency model accounts the parallel timing).
            for i in 0..n {
                let idx = self.batchers[i].next_batch();
                let (x, y) = self.train.batch(&idx);
                let wc_i = self.wc[i][..nc].to_vec();
                let s = self.rt.client_fwd(cut, &wc_i, &x)?;
                batches.push((x, y));
                smasheds.push(s);
            }
            // (2)(3) server-side update + gradient aggregation.
            let ws_srv = self.ws[nc..].to_vec();
            let mut g_ws_parts: Vec<Params> = Vec::with_capacity(n);
            let mut g_s_parts: Vec<Tensor> = Vec::with_capacity(n);
            let mut loss_acc = 0.0;
            for i in 0..n {
                let (_, y) = &batches[i];
                let (loss, g_ws, g_s) = self.rt.server_grad(cut, &ws_srv, &smasheds[i], y)?;
                loss_acc += self.rho[i] * loss as f64;
                g_ws_parts.push(g_ws);
                g_s_parts.push(g_s);
            }
            // Aggregate server-side models (eq 7) — equivalent to one SGD
            // step with the ρ-weighted gradient (verified in tests).
            let g_ws_refs: Vec<&Params> = g_ws_parts.iter().collect();
            let g_ws = tensor::weighted_sum(&g_ws_refs, &self.rho);
            let mut ws_new = ws_srv.clone();
            tensor::sgd_step(&mut ws_new, &g_ws, self.cfg.lr);
            for (dst, src) in self.ws[nc..].iter_mut().zip(ws_new) {
                *dst = src;
            }
            // Aggregate smashed-data gradients (eq 5).
            let flat: Vec<&[f32]> = g_s_parts.iter().map(|t| t.data.as_slice()).collect();
            let g_s_agg = Tensor::new(
                tensor::weighted_sum_flat(&flat, &self.rho),
                g_s_parts[0].shape.clone(),
            );
            // (4)(5) broadcast + client-side BP with the SAME cotangent.
            if shared_wc {
                // g_t^c = Σ_n ρ^n VJP_n(s_agg) — the client-independent
                // client-side gradient of eq (19); every replica applies
                // the identical update, so the shared-w^c invariant holds
                // with NO aggregation traffic.
                let wc_shared = self.wc[0][..nc].to_vec();
                let mut g_c_parts: Vec<Params> = Vec::with_capacity(n);
                for (x, _) in &batches {
                    g_c_parts.push(self.rt.client_grad(cut, &wc_shared, x, &g_s_agg)?);
                }
                let g_c_refs: Vec<&Params> = g_c_parts.iter().collect();
                let g_c = tensor::weighted_sum(&g_c_refs, &self.rho);
                for wc_i in &mut self.wc {
                    for (w, g) in wc_i[..nc].iter_mut().zip(&g_c) {
                        tensor::saxpy(w, -self.cfg.lr, g);
                    }
                }
            } else {
                // Drift ablation: each client applies its OWN VJP of the
                // aggregated cotangent to its OWN w^c replica.
                for (i, (x, _)) in batches.iter().enumerate() {
                    let wc_i = self.wc[i][..nc].to_vec();
                    let g_c = self.rt.client_grad(cut, &wc_i, x, &g_s_agg)?;
                    for (w, g) in self.wc[i][..nc].iter_mut().zip(&g_c) {
                        tensor::saxpy(w, -self.cfg.lr, g);
                    }
                }
            }
            mean_loss += loss_acc / self.cfg.tau as f64;
        }
        Ok(mean_loss)
    }

    /// Traditional SFL [11] (aggregate_clients=true) / PSL (false).
    fn round_sfl(&mut self, cut: usize, aggregate_clients: bool) -> anyhow::Result<f64> {
        let spec = self.rt.spec().clone();
        let nc = spec.cut(cut).client_params;
        let mut mean_loss = 0.0;
        for _ in 0..self.cfg.tau {
            let n = self.cfg.num_clients;
            let ws_srv = self.ws[nc..].to_vec();
            let mut g_ws_parts: Vec<Params> = Vec::with_capacity(n);
            let mut loss_acc = 0.0;
            for i in 0..n {
                let idx = self.batchers[i].next_batch();
                let (x, y) = self.train.batch(&idx);
                let wc_i = self.wc[i][..nc].to_vec();
                let s = self.rt.client_fwd(cut, &wc_i, &x)?;
                let (loss, g_ws, g_s) = self.rt.server_grad(cut, &ws_srv, &s, &y)?;
                loss_acc += self.rho[i] * loss as f64;
                g_ws_parts.push(g_ws);
                // Per-client gradient unicast: own cotangent.
                let g_c = self.rt.client_grad(cut, &wc_i, &x, &g_s)?;
                for (w, g) in self.wc[i][..nc].iter_mut().zip(&g_c) {
                    tensor::saxpy(w, -self.cfg.lr, g);
                }
            }
            let g_ws_refs: Vec<&Params> = g_ws_parts.iter().collect();
            let g_ws = tensor::weighted_sum(&g_ws_refs, &self.rho);
            let mut ws_new = ws_srv.clone();
            tensor::sgd_step(&mut ws_new, &g_ws, self.cfg.lr);
            for (dst, src) in self.ws[nc..].iter_mut().zip(ws_new) {
                *dst = src;
            }
            mean_loss += loss_acc / self.cfg.tau as f64;
        }
        if aggregate_clients {
            // Synchronous client-side FedAvg (the traffic SFL-GA removes).
            let parts: Vec<Params> = self.wc.iter().map(|w| w[..nc].to_vec()).collect();
            let refs: Vec<&Params> = parts.iter().collect();
            let agg = tensor::weighted_sum(&refs, &self.rho);
            for w in &mut self.wc {
                for (dst, src) in w[..nc].iter_mut().zip(&agg) {
                    dst.copy_from_slice(src);
                }
            }
        }
        Ok(mean_loss)
    }

    /// FedAvg baseline: τ local full-model steps, then model aggregation.
    fn round_fl(&mut self) -> anyhow::Result<f64> {
        let n = self.cfg.num_clients;
        let mut locals: Vec<Params> = Vec::with_capacity(n);
        let mut loss_acc = 0.0;
        for i in 0..n {
            let mut w = self.w_full.clone();
            for e in 0..self.cfg.tau {
                let idx = self.batchers[i].next_batch();
                let (x, y) = self.train.batch(&idx);
                let (loss, g) = self.rt.full_grad(&w, &x, &y)?;
                if e == 0 {
                    loss_acc += self.rho[i] * loss as f64;
                }
                tensor::sgd_step(&mut w, &g, self.cfg.lr);
            }
            locals.push(w);
        }
        let refs: Vec<&Params> = locals.iter().collect();
        self.w_full = tensor::weighted_sum(&refs, &self.rho);
        Ok(loss_acc)
    }

    // ------------------------------------------------------------- eval

    /// Global model at cut v: ρ-weighted client-side average ++ server side.
    pub fn global_params(&self, cut: usize) -> Params {
        if self.cfg.scheme == SchemeKind::Fl {
            return self.w_full.clone();
        }
        let nc = self.rt.spec().cut(cut).client_params;
        let parts: Vec<Params> = self.wc.iter().map(|w| w[..nc].to_vec()).collect();
        let refs: Vec<&Params> = parts.iter().collect();
        let wc_avg = tensor::weighted_sum(&refs, &self.rho);
        join_params(&wc_avg, &self.ws[nc..])
    }

    /// Test-set (loss, accuracy) of the global model.
    pub fn evaluate(&self, cut: usize) -> anyhow::Result<(f64, f64)> {
        let w = self.global_params(cut);
        let spec = self.rt.spec();
        let eb = spec.eval_batch;
        let n_batches = self.test.len() / eb;
        let mut loss = 0.0;
        let mut correct = 0.0;
        for b in 0..n_batches {
            let idx: Vec<usize> = (b * eb..(b + 1) * eb).collect();
            let (x, y) = self.test.batch(&idx);
            let (l, c) = self.rt.eval(&w, &x, &y)?;
            loss += l as f64;
            correct += c as f64;
        }
        Ok((loss / n_batches as f64, correct / (n_batches * eb) as f64))
    }

    /// Max |Δ| between two clients' client-side models — the drift Γ(φ)
    /// bounds (diagnostics + tests).
    pub fn client_drift(&self, cut: usize) -> f64 {
        let nc = self.rt.spec().cut(cut).client_params;
        let mut m = 0.0f64;
        for i in 1..self.wc.len() {
            let a: Params = self.wc[0][..nc].to_vec();
            let b: Params = self.wc[i][..nc].to_vec();
            m = m.max(tensor::max_abs_diff(&a, &b));
        }
        m
    }

    /// Reset all model state (fresh init) without reloading artifacts.
    pub fn reset(&mut self, seed: u64) {
        let spec = self.rt.spec().clone();
        let params = init_params(&spec, seed);
        self.wc = vec![params.clone(); self.cfg.num_clients];
        self.ws = params.clone();
        self.w_full = params;
        self.round = 0;
        self.last_cut = None;
    }

    /// Access the split of the *current* global params (testing).
    pub fn split_of_global(&self, cut: usize) -> (Params, Params) {
        split_params(self.rt.spec(), cut, &self.global_params(cut))
    }
}
