//! The SFL-GA training coordinator: a single phased round engine that runs
//! communication rounds of the paper's framework (§II-A steps 1–5) and its
//! baselines over a pluggable execution backend ([`ModelRuntime`]), with
//! full communication/latency accounting.  [`Trainer::native`] wires the
//! pure-Rust backend; the PJRT/AOT path sits behind the `pjrt` feature.
//!
//! Every scheme executes the same five phases, configured per scheme by a
//! [`RoundPlan`] policy (see `plan.rs`):
//!
//! 1. **client-fwd fan-out** — per-client forward passes (eq 1),
//! 2. **server reduce** — per-client server FP+BP (eqs 2–4) and the
//!    fixed-order ρ-weighted server-gradient reduction (eq 7),
//! 3. **cotangent routing** — ONE aggregated broadcast (eq 5) or
//!    per-client unicast,
//! 4. **client-bwd fan-out** — per-client VJPs of the routed cotangent
//!    (eq 6),
//! 5. **aggregate** — the scheme's client-side synchronization policy.
//!
//! The phases are *pipelined*, not bulk-synchronous: the round engine
//! runs on the [`ParallelExecutor`]'s task-session API, submitting ONE
//! fused chain per participant — j's server FP+BP starts the moment j's
//! client-fwd lands, without waiting for any other participant, and when
//! the plan unicasts cotangents ([`RoundPlan::fuses_client_bwd`]) j's
//! client-bwd chains straight on.  Only the eq-5 broadcast aggregation is
//! a true barrier (it needs every participant's cotangent).  Under
//! [`Trainer::run`], a round's evaluation is additionally overlapped with
//! the NEXT round's fan-out: eval jobs score a snapshot of the
//! just-aggregated global model on the same worker queue.  Each worker
//! reuses its own kernel scratch arena across jobs (see
//! `runtime::scratch`).
//!
//! Determinism: every per-client job is a pure function of the
//! round-start state, batches are pure functions of `(client, step)`
//! keys, and ALL reductions/updates happen on the coordinator thread in
//! fixed client-index order over the buffered per-job results
//! (completion order never matters) — so training is bitwise identical
//! for every thread count (`tests/determinism.rs`), pipelining included.
//!
//! The federation is a *virtual population* ([`Population`] +
//! [`ClientSampler`], DESIGN.md §Population): no per-client vector of
//! datasets, batcher streams, capacities or weights exists.  Each round
//! derives ONLY the drawn cohort's state — ⌈r·N⌉ clients' batches, gains
//! and capacities — from `(run_seed, client_id)` keys, so resident
//! population state is O(cohort) while N scales to u64 range
//! ([`Trainer::peak_resident_population_bytes`] tracks the bound;
//! `benches/bench_population.rs` drives N = 10⁶).  Schemes that keep
//! per-client model replicas (SFL/PSL/the drift ablation) are inherently
//! O(N) in *model* state and are bounded to [`MAX_PER_CLIENT_REPLICAS`].
//!
//! Every run executes under a [`ScenarioConfig`] (see [`crate::scenario`]
//! and DESIGN.md §Scenarios): the partition strategy fixes per-client
//! label laws (every virtual client holds `samples_per_client` samples,
//! so the FedAvg weights ρ are uniformly 1/N); straggler profiles slow an
//! exact ⌈frac·N⌉ subset in the timing model; and under partial
//! participation each round runs over a cohort enumerated from a
//! round-keyed permutation, with communication/latency accounted for
//! exactly the clients that took part.
//!
//! Scheme semantics (see DESIGN.md for the discussion):
//! * **SflGa** — clients upload smashed data; the server updates per-client
//!   server-side models and aggregates them (eq 7), aggregates the
//!   smashed-data gradients (eq 5) and *broadcasts one tensor*.  Per the
//!   paper's eqs (6)/(18)/(19), the client-side gradient g_t^c is
//!   client-independent — ONE shared w^c (represented once, not N times)
//!   steps with the ρ-weighted VJP of the aggregated cotangent, no client
//!   aggregation traffic.  The *bias* of that gradient vs the true split
//!   gradient is the Γ(φ(v)) term of Assumption 4 — it grows with the
//!   client model (Fig. 3 measures it).
//! * **SflGaDrift** — ablation: own VJP of the aggregated cotangent, own
//!   replica, no sync.
//! * **Sfl** — per-client smashed-gradient unicast + synchronous client-
//!   side FedAvg each round (SplitFed [11]).
//! * **Psl** — per-client unicast, no client-side aggregation.
//! * **Fl** — FedAvg on the full model.
//!
//! Evaluation always scores the *global* model: ρ-weighted client-side
//! average joined with the server-side model (for FL, the global model).

use std::sync::Arc;

use crate::data::init::{init_params, join_params, split_params};
use crate::data::population::ClientSampler;
use crate::data::{Dataset, generate};
use crate::latency::ComputeConfig;
use crate::model::{Manifest, ShapeSpec};
use crate::runtime::{JobHandle, ModelRuntime, ParallelExecutor, TaskSession, Tensor};
use crate::scenario::ScenarioConfig;
use crate::tensor::{self, Params};
use crate::wireless::{ChannelState, NetConfig};

use super::comm::{round_comm, RoundComm};
use super::plan::{ClientSync, CotangentRoute, RoundPlan};
use super::population::Population;
use super::SchemeKind;
use super::timing::{AllocPolicy, round_latency, RoundLatency};

/// Upper bound on `num_clients` for schemes whose *model* state is
/// inherently per-client (SflGaDrift / Sfl / Psl keep one replica each).
/// The O(cohort) population refactor cannot help those — the replicas
/// themselves are O(N) — so they stay bounded; SflGa and Fl hold one
/// logical client-side model and scale to u64-range populations.
pub const MAX_PER_CLIENT_REPLICAS: usize = 65_536;

/// Training configuration (defaults = the paper's §V-A setup).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: String,
    /// Model-registry architecture id (`builtin`, `vgg`, `txf`).  Selects
    /// the layer graph and with it the cut menu every component — trainer,
    /// comm accounting, CCC action space, networked protocol — dispatches
    /// on.  Callers that construct their own `Manifest` must keep it
    /// consistent with this id (the binaries resolve both from one flag).
    pub model: String,
    pub scheme: SchemeKind,
    pub num_clients: usize,
    pub rounds: usize,
    /// Local epochs τ per round (eq 6).
    pub tau: usize,
    pub lr: f32,
    /// Samples per client shard.
    pub samples_per_client: usize,
    /// Test-set size (any size; the tail batch is handled).
    pub test_samples: usize,
    /// Scenario layer: data partition (IID / Dirichlet / shards), partial
    /// participation and compute stragglers.  Defaults = the paper's
    /// homogeneous always-on IID setup.
    pub scenario: ScenarioConfig,
    pub seed: u64,
    /// Rounds between evaluations.
    pub eval_every: usize,
    /// Round-engine worker threads: `0` = auto (the `SFLGA_TEST_THREADS`
    /// env override if set, else available parallelism), `1` = fully
    /// serial.  Training results are bitwise identical for every value.
    pub threads: usize,
    pub net: NetConfig,
    pub comp: ComputeConfig,
    pub alloc: AllocPolicy,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dataset: "mnist".into(),
            model: "builtin".into(),
            scheme: SchemeKind::SflGa,
            num_clients: 10,
            rounds: 100,
            tau: 1,
            lr: 0.02,
            samples_per_client: 256,
            test_samples: 2048,
            scenario: ScenarioConfig::default(),
            seed: 17,
            eval_every: 5,
            threads: 0,
            net: NetConfig::default(),
            comp: ComputeConfig::default(),
            alloc: AllocPolicy::Optimal,
        }
    }
}

/// Per-round record (metrics.rs turns these into figure CSVs).
#[derive(Clone, Copy, Debug)]
pub struct RoundStats {
    pub round: usize,
    pub cut: usize,
    /// Clients that actually participated this round (= N under full
    /// participation); comm/latency below account for exactly these.
    pub participants: usize,
    pub train_loss: f64,
    pub comm: RoundComm,
    pub latency: RoundLatency,
    /// Test metrics when this round evaluated (eval_every), else None.
    pub test: Option<(f64, f64)>, // (loss, accuracy)
}

/// The scheme's client-side model representation.  SFL-GA's eq-19
/// invariant (every replica identical) and FL (client state lives in
/// `w_full`) need ONE logical model; the per-replica schemes genuinely
/// hold N.
enum ClientSide {
    /// One shared logical client-side model — O(1) in N.
    Shared(Params),
    /// Per-client replicas (SflGaDrift / Sfl / Psl) — O(N), bounded by
    /// [`MAX_PER_CLIENT_REPLICAS`].
    PerClient(Vec<Params>),
}

impl ClientSide {
    fn for_scheme(scheme: SchemeKind, n: usize, w0: &Params) -> anyhow::Result<ClientSide> {
        let shared = match scheme.plan() {
            RoundPlan::Full => true,
            RoundPlan::Split { sync, .. } => sync == ClientSync::SharedStep,
        };
        if shared {
            Ok(ClientSide::Shared(w0.clone()))
        } else {
            anyhow::ensure!(
                n <= MAX_PER_CLIENT_REPLICAS,
                "{} keeps a model replica per client; {n} clients exceeds the {} bound \
                 (use sfl-ga or fl for virtual-population scale)",
                scheme.name(),
                MAX_PER_CLIENT_REPLICAS
            );
            Ok(ClientSide::PerClient(vec![w0.clone(); n]))
        }
    }

    /// Client `i`'s parameters (the shared model for every `i` under
    /// [`ClientSide::Shared`]).
    fn params_of(&self, i: usize) -> &Params {
        match self {
            ClientSide::Shared(w) => w,
            ClientSide::PerClient(reps) => &reps[i],
        }
    }
}

/// Where a round's cohort gains come from: a caller-provided dense state
/// ([`Trainer::run_round`]'s policy API) or a lazy per-cohort derivation
/// at a channel-draw index ([`Trainer::run`]'s O(cohort) path).  Both
/// evaluate the same pure function [`Population::gain_at`], so the two
/// paths are bitwise identical (`tests/reproducibility.rs`).
enum GainSource<'a> {
    Dense(&'a ChannelState),
    Lazy(u64),
}

/// The coordinator state machine.
pub struct Trainer {
    pub cfg: TrainConfig,
    rt: ModelRuntime,
    pool: ParallelExecutor,
    /// The virtual population: per-client capacities, weights, channel and
    /// cohort draws as keyed pure functions (O(1) state however large N).
    pop: Population,
    /// Lazy per-client training data (same keyed-derivation contract).
    sampler: ClientSampler,
    /// The test split stays eagerly materialized — it is O(test_samples),
    /// independent of N.
    test: Dataset,
    /// Client-side model(s); see [`ClientSide`].
    client_side: ClientSide,
    /// Server-side model (split schemes) — the aggregated w^s of eq (7).
    ws: Params,
    /// Full global model (FL).
    w_full: Params,
    /// Channel draws consumed so far — the fading clock.  Draw d of
    /// client i is `Population::gain_at(d, i)` whether it was observed
    /// via [`Trainer::draw_channel`] (dense) or lazily per cohort.
    chan_draws: u64,
    round: usize,
    /// Cut used in the previous round (dynamic-cut runs resync on change).
    last_cut: Option<usize>,
    /// High-water mark of per-round materialized population state in
    /// bytes; see [`Trainer::peak_resident_population_bytes`].
    peak_resident_bytes: usize,
}

impl Trainer {
    /// Trainer over the native pure-Rust backend — no artifacts needed.
    pub fn native(manifest: &Manifest, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::native(manifest, &cfg.dataset)?;
        Trainer::new(rt, cfg)
    }

    /// Trainer over the PJRT backend, compiled from the AOT artifacts.
    #[cfg(feature = "pjrt")]
    pub fn from_artifacts(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        cfg: TrainConfig,
    ) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::load(artifact_dir, manifest, &cfg.dataset)?;
        Trainer::new(rt, cfg)
    }

    /// Every seed-dependent component, derived from `cfg.seed` alone:
    /// the virtual population, the per-client sample source, the test
    /// split and the initial model.  [`Trainer::new`] and
    /// [`Trainer::reset`] both call this — reset ≡ fresh is structural
    /// (`tests/reproducibility.rs`).
    fn derive_seeded(
        cfg: &TrainConfig,
        spec: &ShapeSpec,
    ) -> anyhow::Result<(Population, ClientSampler, Dataset, Params)> {
        let pop = Population::new(
            cfg.seed,
            cfg.num_clients as u64,
            cfg.scenario.clone(),
            cfg.net.clone(),
            cfg.comp.clone(),
        )?;
        let sampler = ClientSampler::new(
            spec,
            &cfg.dataset,
            cfg.scenario.partition.clone(),
            cfg.samples_per_client,
            cfg.seed,
        );
        // Test-split seed convention unchanged from the eager substrate.
        let test = generate(spec, &cfg.dataset, cfg.test_samples, cfg.seed ^ 0x7E57);
        let params = init_params(spec, cfg.seed ^ 0x1417);
        Ok((pop, sampler, test, params))
    }

    /// Trainer over an already-constructed runtime (any backend).
    pub fn new(rt: ModelRuntime, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        anyhow::ensure!(cfg.num_clients > 0 && cfg.rounds > 0 && cfg.tau > 0);
        anyhow::ensure!(cfg.eval_every > 0, "eval_every must be positive");
        anyhow::ensure!(cfg.test_samples > 0, "test_samples must be positive");
        anyhow::ensure!(cfg.samples_per_client > 0, "samples_per_client must be positive");
        cfg.scenario.validate()?;
        let spec = rt.spec().clone();
        // Dynamic-batch backends (native) score the remainder tail batch;
        // fixed-shape AOT backends (pjrt) cannot take one.
        anyhow::ensure!(
            rt.dynamic_batch() || cfg.test_samples % spec.eval_batch == 0,
            "backend '{}' is compiled for fixed shapes: test_samples must be a multiple of the \
             eval batch {}",
            rt.backend_name(),
            spec.eval_batch
        );

        let (pop, sampler, test, params) = Trainer::derive_seeded(&cfg, &spec)?;
        let client_side = ClientSide::for_scheme(cfg.scheme, cfg.num_clients, &params)?;
        let pool = ParallelExecutor::new(cfg.threads);
        // Grant eval calls the pool capacity its batch fan-out cannot fill:
        // with fewer eval batches than workers, each eval job may split its
        // dense GEMMs across the idle share.  Bitwise-neutral by the
        // Backend contract, so the threads=N ≡ threads=1 guarantee and
        // every recorded metric are unaffected.
        let eval_jobs = cfg.test_samples.div_ceil(spec.eval_batch).max(1);
        rt.set_eval_parallelism((pool.threads() / eval_jobs).max(1));
        Ok(Trainer {
            rt,
            pool,
            pop,
            sampler,
            test,
            client_side,
            // Initialize every cut's split from the same full model; the
            // cut in force selects which prefix the clients own.
            ws: params.clone(),
            w_full: params,
            chan_draws: 0,
            round: 0,
            last_cut: None,
            peak_resident_bytes: 0,
            cfg,
        })
    }

    pub fn spec(&self) -> &crate::model::ShapeSpec {
        self.rt.spec()
    }

    /// Name of the execution backend in use ("native", "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.rt.backend_name()
    }

    /// Resolved round-engine worker count (1 = serial).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The virtual population this run derives from.
    pub fn population(&self) -> &Population {
        &self.pop
    }

    /// Aggregation weights ρ^n = |D^n|/|D| — uniformly 1/N (every virtual
    /// client holds `samples_per_client` samples).  Materialized O(N)
    /// vector for diagnostics; prefer [`Population::weight`].
    pub fn rho(&self) -> Vec<f64> {
        vec![self.pop.weight(); self.cfg.num_clients]
    }

    pub fn round_index(&self) -> usize {
        self.round
    }

    /// Peak bytes of per-round *population-derived* state materialized so
    /// far: cohort indices, gains, capacities, weights and the cohort's
    /// batch tensors.  Bounded by O(cohort · batch) independent of
    /// `num_clients` — the contract `benches/bench_population.rs` asserts
    /// at N = 10⁴ vs 10⁶.  Model state is excluded: it is O(1) in N for
    /// SflGa/Fl ([`ClientSide::Shared`]) and inherently O(N) for the
    /// per-replica schemes.
    pub fn peak_resident_population_bytes(&self) -> usize {
        self.peak_resident_bytes
    }

    /// Draw this round's channel (exposed for cut-selection policies that
    /// observe the state before choosing v — Algorithm 1's MDP state).
    /// This is the O(N) dense *policy* surface; [`Trainer::run`] derives
    /// the same draws lazily per cohort without materializing it.
    pub fn draw_channel(&mut self) -> ChannelState {
        let st = self.pop.gains_dense(self.chan_draws);
        self.chan_draws += 1;
        st
    }

    /// Run one communication round at cut `v` with channel `state`.
    ///
    /// The round runs the scheme's [`RoundPlan`] over this round's
    /// participant cohort (enumerated from the round-keyed population
    /// permutation — everyone under full participation), then accounts
    /// communication and latency for exactly the clients that took part.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use sfl_ga::coordinator::{TrainConfig, Trainer};
    /// use sfl_ga::model::Manifest;
    ///
    /// let manifest = Manifest::builtin();
    /// let mut trainer = Trainer::native(&manifest, TrainConfig::default())?;
    /// // Cut selection policies observe the channel before choosing v.
    /// let state = trainer.draw_channel();
    /// let stats = trainer.run_round(2, &state)?;
    /// println!("{} clients participated", stats.participants);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn run_round(&mut self, cut: usize, state: &ChannelState) -> anyhow::Result<RoundStats> {
        let (mut stats, _no_pending) = self.run_round_inner(cut, GainSource::Dense(state), None)?;
        if self.eval_due() {
            stats.test = Some(self.evaluate(cut)?);
        }
        Ok(stats)
    }

    /// Whether the round that just finished (`self.round`, 1-based after
    /// the increment) is an evaluation round.
    fn eval_due(&self) -> bool {
        self.round % self.cfg.eval_every == 0 || self.round == self.cfg.rounds
    }

    /// One round WITHOUT its own evaluation: executes the scheme's plan
    /// over the cohort and, when `pending` carries the previous round's
    /// deferred evaluation, scores that snapshot on the same worker queue
    /// as this round's first fan-out — returning the completed result so
    /// the caller can attach it to the earlier round's stats.
    fn run_round_inner(
        &mut self,
        cut: usize,
        gains: GainSource,
        pending: Option<&PendingEval>,
    ) -> anyhow::Result<(RoundStats, Option<(f64, f64)>)> {
        self.rt.spec().menu().validate(cut)?;
        // Dynamic cut selection (Algorithm 1) moves layer ownership between
        // the sides; on a cut change, re-anchor every replica to the global
        // model so the handed-over blocks carry the aggregated weights.
        if self.last_cut.is_some() && self.last_cut != Some(cut) {
            let global = self.global_params(self.last_cut.unwrap());
            match &mut self.client_side {
                ClientSide::Shared(w) => *w = global.clone(),
                ClientSide::PerClient(reps) => {
                    for w in reps.iter_mut() {
                        *w = global.clone();
                    }
                }
            }
            self.ws = global;
        }
        self.last_cut = Some(cut);
        // Scenario axis 3 — participation: the cohort enumerates from the
        // round-keyed permutation on the coordinator thread (identical
        // for every thread count, independent of any other round).
        let participants = self.pop.cohort(self.round as u64);
        let k = participants.len();
        // This round's gains, for exactly the cohort: restrict the dense
        // policy state, or derive the cohort's entries of the same draw.
        let gains_cohort: Vec<f64> = match gains {
            GainSource::Dense(st) => participants.iter().map(|&i| st.gains[i]).collect(),
            GainSource::Lazy(draw) => self.pop.gains_for(draw, &participants),
        };
        // Cohort aggregation weights: ρ is uniform (equal shards), so the
        // renormalized cohort weights are exactly 1/K.
        let weights = vec![1.0 / k as f64; k];
        // O(cohort) residency: ids + gains + caps + weights + the epoch's
        // materialized batch tensors (the only per-client state alive).
        let resident = k * (std::mem::size_of::<usize>() + 3 * std::mem::size_of::<f64>())
            + k * self.sampler.batch_bytes();
        self.peak_resident_bytes = self.peak_resident_bytes.max(resident);
        let (loss, prior_eval) = match self.cfg.scheme.plan() {
            RoundPlan::Split { route, sync } => {
                self.round_split(cut, route, sync, &participants, &weights, pending)?
            }
            RoundPlan::Full => self.round_full(&participants, &weights, pending)?,
        };
        // Communication and latency account for the cohort only: the
        // channel state and compute table restricted to participants.
        let state_round = ChannelState { gains: gains_cohort };
        let mut comp_round = self.cfg.comp.clone();
        comp_round.client_caps = self.pop.caps_for(&participants);
        let spec = self.rt.spec().clone();
        let cut_spec = spec.cut(cut);
        let comm = round_comm(self.cfg.scheme, &spec, cut_spec, &comp_round, k, self.cfg.tau);
        let latency = round_latency(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &self.cfg.net,
            &comp_round,
            &state_round,
            self.cfg.alloc,
            self.cfg.tau,
        );
        self.round += 1;
        let stats = RoundStats {
            round: self.round,
            cut,
            participants: k,
            train_loss: loss,
            comm,
            latency,
            test: None,
        };
        Ok((stats, prior_eval))
    }

    /// Convenience: run a full fixed-cut training; returns all stats.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use sfl_ga::coordinator::{SchemeKind, TrainConfig, Trainer};
    /// use sfl_ga::model::Manifest;
    /// use sfl_ga::scenario::ScenarioConfig;
    /// use sfl_ga::data::partition::Partition;
    ///
    /// let manifest = Manifest::builtin();
    /// let cfg = TrainConfig {
    ///     scheme: SchemeKind::SflGa,
    ///     rounds: 10,
    ///     scenario: ScenarioConfig {
    ///         partition: Partition::Dirichlet(0.3),
    ///         participation: 0.5,
    ///         ..Default::default()
    ///     },
    ///     ..Default::default()
    /// };
    /// let mut trainer = Trainer::native(&manifest, cfg)?;
    /// let stats = trainer.run(2)?; // fixed cut v=2
    /// assert_eq!(stats.len(), 10);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    /// Rounds are pipelined across the eval boundary: when round t
    /// evaluates, its eval jobs score a SNAPSHOT of the just-aggregated
    /// global model on the same worker queue as round t+1's first
    /// fan-out, and the result is attached to round t's stats once it
    /// lands.  Values are bitwise identical to evaluating synchronously
    /// (the snapshot is immutable and eval consumes no RNG); only
    /// wall-clock moves.  The last round's eval has no successor to
    /// overlap with and runs synchronously.
    ///
    /// Unlike the [`Trainer::draw_channel`] + [`Trainer::run_round`]
    /// policy loop, `run` never materializes a dense channel state: each
    /// round consumes one draw index and derives gains for the cohort
    /// only — bitwise the same values, O(cohort) memory.
    pub fn run(&mut self, cut: usize) -> anyhow::Result<Vec<RoundStats>> {
        let mut out: Vec<RoundStats> = Vec::with_capacity(self.cfg.rounds);
        let mut pending: Option<PendingEval> = None;
        for _ in 0..self.cfg.rounds {
            let draw = self.chan_draws;
            self.chan_draws += 1;
            let (stats, prior_eval) =
                self.run_round_inner(cut, GainSource::Lazy(draw), pending.as_ref())?;
            if let Some(p) = pending.take() {
                let result = prior_eval.expect("round engine completes any pending eval");
                out[p.stats_idx].test = Some(result);
            }
            out.push(stats);
            if self.eval_due() {
                pending = Some(PendingEval {
                    stats_idx: out.len() - 1,
                    w: Arc::new(self.global_params(cut)),
                });
            }
        }
        if let Some(p) = pending.take() {
            out[p.stats_idx].test = Some(self.evaluate_snapshot(&p.w)?);
        }
        Ok(out)
    }

    // ------------------------------------------------- the round engine

    /// One split round (§II-A steps 1–5) of τ epochs over the cohort
    /// `participants` (sorted ascending), phases configured by
    /// `route`/`sync`.  `weights[j]` is participant j's aggregation
    /// weight (1/K — ρ renormalized over the cohort).
    ///
    /// Pipelined execution: each participant is ONE fused task chain —
    /// client-fwd (eq 1) feeds the server FP+BP (eqs 2–4) the moment it
    /// lands, and when the plan unicasts cotangents the client-bwd
    /// (eq 6) chains straight on; only the eq-5 broadcast aggregation is
    /// a barrier.  The previous round's deferred evaluation (when
    /// `pending` is set) rides the first epoch's worker queue.  All
    /// reductions run on the coordinator thread in fixed client-index
    /// order over the buffered results (bitwise thread-count
    /// independence).
    fn round_split(
        &mut self,
        cut: usize,
        route: CotangentRoute,
        sync: ClientSync,
        participants: &[usize],
        weights: &[f64],
        pending: Option<&PendingEval>,
    ) -> anyhow::Result<(f64, Option<(f64, f64)>)> {
        let nc = self.rt.spec().cut(cut).client_params;
        let eb = self.rt.spec().eval_batch;
        let k = participants.len();
        let lr = self.cfg.lr;
        let tau = self.cfg.tau;
        let base_step = self.round * tau;
        let shared = sync == ClientSync::SharedStep;
        let fuse_bwd = RoundPlan::Split { route, sync }.fuses_client_bwd();
        // Preallocated reduction accumulators, reused across the τ epochs.
        let mut g_ws_acc = tensor::zeros_like(&self.ws[nc..]);
        let mut g_c_acc = if shared {
            tensor::zeros_like(&self.client_side.params_of(participants[0])[..nc])
        } else {
            Params::new()
        };
        let mut mean_loss = 0.0;
        let mut eval_handles: Option<Vec<JobHandle<(f64, f64)>>> = None;
        for epoch in 0..tau {
            // Phase 0: the cohort's batches materialize on the
            // coordinator thread in ascending cohort order — each a pure
            // function of (client, global step = round·τ + epoch), so the
            // stream is identical for every thread count and every
            // population size.
            let step = (base_step + epoch) as u64;
            let batches: Vec<(Tensor, Tensor)> =
                participants.iter().map(|&i| self.sampler.batch(i as u64, step)).collect();
            let rt = &self.rt;
            let test = &self.test;
            let client_side = &self.client_side;
            // Per-participant client-model views, ascending cohort order
            // (all the same shared model under SharedStep).
            let views: Vec<&Params> =
                participants.iter().map(|&i| client_side.params_of(i)).collect();
            let ws_srv = &self.ws[nc..];
            // (1)+(2) fused fan-out — eq (1) chaining into eqs (2–4) per
            // participant with no cross-client barrier (and, unicast,
            // eq (6) too); zero-copy parameter views, each worker drawing
            // kernel scratch from its own arena.  Returns per chain:
            // (loss, g_ws, cotangent to aggregate, fused g_c).
            let chains = self.pool.session(|sess| {
                let handles: Vec<_> = (0..k)
                    .map(|j| {
                        let wv: &Params = views[j];
                        let (x, y) = (&batches[j].0, &batches[j].1);
                        sess.submit(move |scratch| {
                            let smashed = rt.client_fwd_with(scratch, cut, &wv[..nc], x)?;
                            let (loss, g_ws, g_s) =
                                rt.server_grad_with(scratch, cut, ws_srv, &smashed, y)?;
                            if fuse_bwd {
                                let g_c =
                                    rt.client_grad_with(scratch, cut, &wv[..nc], x, &g_s)?;
                                Ok((loss, g_ws, None, Some(g_c)))
                            } else {
                                Ok((loss, g_ws, Some(g_s), None))
                            }
                        })
                    })
                    .collect();
                // The deferred eval of round t−1 overlaps this round's
                // phase-0/1 work: same queue, snapshot model, no RNG.
                if epoch == 0 {
                    if let Some(p) = pending {
                        eval_handles = Some(submit_eval(sess, rt, test, eb, &p.w));
                    }
                }
                // In-order collection over out-of-order completions: the
                // buffered handles restore ascending cohort order for
                // every reduction below.
                handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
            })?;
            // (2b) the weighted server-gradient reduction (eq 7) streams
            // into the accumulator in cohort (= ascending client index)
            // order on the coordinator thread.
            tensor::zero(&mut g_ws_acc);
            let mut loss_acc = 0.0;
            for (j, (loss, g_ws, _, _)) in chains.iter().enumerate() {
                loss_acc += weights[j] * *loss as f64;
                tensor::weighted_accumulate(&mut g_ws_acc, g_ws, weights[j]);
            }
            // (3)+(4) cotangent routing and client-bwd.  Unicast plans
            // already carried eq (6) inside each chain; broadcast plans
            // hit the irreducible eq-5 barrier — aggregate ONE tensor in
            // cohort order, then fan the VJPs out against it.
            let g_c_parts: Vec<Params> = if fuse_bwd {
                chains
                    .into_iter()
                    .map(|(_, _, _, g_c)| g_c.expect("fused chain carries g_c"))
                    .collect()
            } else {
                let mut agg = {
                    let g0 = chains[0].2.as_ref().expect("barrier chain carries cotangent");
                    Tensor::zeros(&g0.shape)
                };
                for (j, (_, _, g_s, _)) in chains.iter().enumerate() {
                    let g_s = g_s.as_ref().expect("barrier chain carries cotangent");
                    tensor::weighted_accumulate_flat(&mut agg.data, &g_s.data, weights[j]);
                }
                let agg = &agg;
                // The shared plan runs every VJP against the one shared
                // w^c; per-client plans against the client's own replica.
                self.pool.session(|sess| {
                    let handles: Vec<_> = (0..k)
                        .map(|j| {
                            let wv: &Params = views[j];
                            let x = &batches[j].0;
                            sess.submit(move |scratch| {
                                rt.client_grad_with(scratch, cut, &wv[..nc], x, agg)
                            })
                        })
                        .collect();
                    handles.into_iter().map(JobHandle::wait).collect()
                })?
            };
            // Apply this epoch's updates on the coordinator thread:
            // server-side SGD step on the aggregated gradient (eq 7)…
            tensor::sgd_step(&mut self.ws[nc..], &g_ws_acc, lr);
            match &mut self.client_side {
                ClientSide::Shared(w) => {
                    // …and the client-independent g_t^c of eq (19): the
                    // weighted VJP reduction steps the ONE logical w^c —
                    // no aggregation traffic, no replica vector.  Under
                    // partial participation the shared w^c is server-held:
                    // clients that sat the round out pick the stepped
                    // model up when they next join.
                    tensor::zero(&mut g_c_acc);
                    for (j, g_c) in g_c_parts.iter().enumerate() {
                        tensor::weighted_accumulate(&mut g_c_acc, g_c, weights[j]);
                    }
                    tensor::sgd_step(&mut w[..nc], &g_c_acc, lr);
                }
                ClientSide::PerClient(reps) => {
                    // …or each participant's own step on its own replica
                    // (absent clients keep their stale replicas).
                    for (j, g_c) in g_c_parts.iter().enumerate() {
                        tensor::sgd_step(&mut reps[participants[j]][..nc], g_c, lr);
                    }
                }
            }
            mean_loss += loss_acc / tau as f64;
        }
        // (5) aggregate: synchronous client-side FedAvg — SFL only, the
        // traffic SFL-GA removes.  Only the round's participants exchange
        // and receive the aggregate; absentees stay stale until they next
        // participate.
        if sync == ClientSync::FedAvg {
            if let ClientSide::PerClient(reps) = &mut self.client_side {
                let mut agg = tensor::zeros_like(&reps[participants[0]][..nc]);
                for (j, &i) in participants.iter().enumerate() {
                    tensor::weighted_accumulate(&mut agg, &reps[i][..nc], weights[j]);
                }
                for &i in participants {
                    for (dst, src) in reps[i][..nc].iter_mut().zip(&agg) {
                        dst.copy_from_slice(src);
                    }
                }
            }
        }
        // Collect the deferred eval (already complete — its session
        // closed with epoch 0) in fixed batch order.
        let prior_eval = match eval_handles {
            Some(handles) => Some(collect_eval(handles, self.test.len())?),
            None => None,
        };
        Ok((mean_loss, prior_eval))
    }

    /// FedAvg round ([`RoundPlan::Full`]) over the cohort: per-participant
    /// τ full-model local steps fan out as ONE fused chain each (a worker
    /// owns a private model clone for the whole local run), then the
    /// weighted model aggregation streams in cohort order.  The previous
    /// round's deferred eval (when `pending` is set) rides the same
    /// worker queue.
    fn round_full(
        &mut self,
        participants: &[usize],
        weights: &[f64],
        pending: Option<&PendingEval>,
    ) -> anyhow::Result<(f64, Option<(f64, f64)>)> {
        let k = participants.len();
        let lr = self.cfg.lr;
        let tau = self.cfg.tau;
        let eb = self.rt.spec().eval_batch;
        let base_step = (self.round * tau) as u64;
        let rt = &self.rt;
        let sampler = &self.sampler;
        let test = &self.test;
        let w0 = &self.w_full;
        let mut eval_handles: Option<Vec<JobHandle<(f64, f64)>>> = None;
        let locals = self.pool.session(|sess| {
            let handles: Vec<_> = (0..k)
                .map(|j| {
                    let client = participants[j] as u64;
                    sess.submit(move |scratch| {
                        let mut w = w0.clone();
                        // Train loss averaged over the τ local epochs —
                        // the same Σ_e/τ accounting the split rounds
                        // report, so fig-3-style loss curves compare like
                        // quantities at τ > 1 (a reported FL loss is no
                        // longer just the FIRST local epoch's).  Each
                        // worker synthesizes its own client's batches on
                        // demand (a pure function of client + global
                        // step): one batch resident per worker, bitwise
                        // the stream the coordinator would draw.
                        let mut loss_sum = 0.0f64;
                        for e in 0..tau {
                            let (x, y) = sampler.batch(client, base_step + e as u64);
                            let (loss, g) = rt.full_grad_with(scratch, &w, &x, &y)?;
                            loss_sum += loss as f64;
                            tensor::sgd_step(&mut w, &g, lr);
                        }
                        Ok((loss_sum / tau as f64, w))
                    })
                })
                .collect();
            if let Some(p) = pending {
                eval_handles = Some(submit_eval(sess, rt, test, eb, &p.w));
            }
            handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
        })?;
        let mut agg = tensor::zeros_like(&self.w_full);
        let mut loss_acc = 0.0;
        for (j, (loss, w)) in locals.iter().enumerate() {
            loss_acc += weights[j] * *loss;
            tensor::weighted_accumulate(&mut agg, w, weights[j]);
        }
        self.w_full = agg;
        let prior_eval = match eval_handles {
            Some(handles) => Some(collect_eval(handles, self.test.len())?),
            None => None,
        };
        Ok((loss_acc, prior_eval))
    }

    // ------------------------------------------------------------- eval

    /// Global model at cut v: ρ-weighted client-side average ++ server
    /// side.  Under [`ClientSide::Shared`] the average of N identical
    /// replicas IS the shared model — joined directly, no O(N) pass.
    pub fn global_params(&self, cut: usize) -> Params {
        if self.cfg.scheme == SchemeKind::Fl {
            return self.w_full.clone();
        }
        let nc = self.rt.spec().cut(cut).client_params;
        match &self.client_side {
            ClientSide::Shared(w) => join_params(&w[..nc], &self.ws[nc..]),
            ClientSide::PerClient(reps) => {
                let rho = self.pop.weight();
                let mut wc_avg = tensor::zeros_like(&reps[0][..nc]);
                for w in reps {
                    tensor::weighted_accumulate(&mut wc_avg, &w[..nc], rho);
                }
                join_params(&wc_avg, &self.ws[nc..])
            }
        }
    }

    /// Test-set (loss, accuracy) of the global model.  Batches fan out on
    /// the executor; the remainder tail batch (when `test_samples` is not
    /// a multiple of the eval batch) is scored too, with the mean loss
    /// weighted by true batch sizes.
    pub fn evaluate(&self, cut: usize) -> anyhow::Result<(f64, f64)> {
        self.evaluate_snapshot(&Arc::new(self.global_params(cut)))
    }

    /// [`Trainer::evaluate`] over an explicit parameter snapshot — the
    /// synchronous twin of the deferred eval `run` pipelines into the
    /// next round.  ONE implementation serves both paths: the same
    /// [`submit_eval`] jobs and [`collect_eval`] reduction run here in a
    /// dedicated session, so deferred and synchronous evaluation cannot
    /// drift apart (the bitwise-equality contract of
    /// `tests/reproducibility.rs`).
    fn evaluate_snapshot(&self, w: &Arc<Params>) -> anyhow::Result<(f64, f64)> {
        let total = self.test.len();
        anyhow::ensure!(total > 0, "empty test set");
        let eb = self.rt.spec().eval_batch;
        let rt = &self.rt;
        let test = &self.test;
        let handles = self.pool.session(|sess| Ok(submit_eval(sess, rt, test, eb, w)))?;
        collect_eval(handles, total)
    }

    /// Max |Δ| between two clients' client-side models — the drift Γ(φ)
    /// bounds (diagnostics + tests).  Structurally zero under
    /// [`ClientSide::Shared`] (one logical model).
    pub fn client_drift(&self, cut: usize) -> f64 {
        match &self.client_side {
            ClientSide::Shared(_) => 0.0,
            ClientSide::PerClient(reps) => {
                let nc = self.rt.spec().cut(cut).client_params;
                let mut m = 0.0f64;
                for w in &reps[1..] {
                    m = m.max(tensor::max_abs_diff(&reps[0][..nc], &w[..nc]));
                }
                m
            }
        }
    }

    /// Reset to a freshly-constructed trainer for `seed` without
    /// reloading the backend.  EVERY seed-dependent stream — the virtual
    /// population (capacities, straggler set, channel, cohorts), the
    /// per-client sample streams, the test split and the model init — is
    /// re-derived from the new seed through the same
    /// [`Trainer::derive_seeded`] as construction, so `reset(s)` followed
    /// by `run` is bitwise identical to a fresh `Trainer` with seed `s`
    /// (`tests/reproducibility.rs`).
    pub fn reset(&mut self, seed: u64) {
        self.cfg.seed = seed;
        let spec = self.rt.spec().clone();
        let (pop, sampler, test, params) = Trainer::derive_seeded(&self.cfg, &spec)
            .expect("config validated at construction");
        self.pop = pop;
        self.sampler = sampler;
        self.test = test;
        self.client_side = ClientSide::for_scheme(self.cfg.scheme, self.cfg.num_clients, &params)
            .expect("scheme/population bound validated at construction");
        self.ws = params.clone();
        self.w_full = params;
        self.chan_draws = 0;
        self.round = 0;
        self.last_cut = None;
        self.peak_resident_bytes = 0;
    }

    /// Access the split of the *current* global params (testing).
    pub fn split_of_global(&self, cut: usize) -> (Params, Params) {
        split_params(self.rt.spec(), cut, &self.global_params(cut))
    }
}

// ------------------------------------------------------- deferred eval

/// A deferred evaluation: the snapshot of the just-aggregated global
/// model for the round at `stats_idx`, scored while the NEXT round's
/// fan-out runs (see [`Trainer::run`]).  The snapshot is immutable and
/// evaluation consumes no RNG, so the result is bitwise what a
/// synchronous [`Trainer::evaluate`] at the end of that round returns.
struct PendingEval {
    /// Index into the run's stats vec whose `test` field this eval fills.
    stats_idx: usize,
    /// Owned snapshot shared across the per-batch eval jobs.
    w: Arc<Params>,
}

/// Submit the deferred evaluation of snapshot `w` into `sess`, one job
/// per eval batch (the tail batch included).  Jobs interleave with the
/// round's fan-out on the same workers; collect with [`collect_eval`].
fn submit_eval<'env>(
    sess: &TaskSession<'env>,
    rt: &'env ModelRuntime,
    test: &'env Dataset,
    eval_batch: usize,
    w: &Arc<Params>,
) -> Vec<JobHandle<(f64, f64)>> {
    let total = test.len();
    (0..total)
        .step_by(eval_batch)
        .map(|lo| {
            let hi = (lo + eval_batch).min(total);
            let w = Arc::clone(w);
            sess.submit(move |scratch| {
                let idx: Vec<usize> = (lo..hi).collect();
                let (x, y) = test.batch(&idx);
                let (l, c) = rt.eval_with(scratch, &w, &x, &y)?;
                Ok((l as f64 * (hi - lo) as f64, c as f64))
            })
        })
        .collect()
}

/// Reduce the per-batch eval scores in fixed batch order — the same
/// reduction [`Trainer::evaluate`] performs, so deferred and synchronous
/// evaluation agree bitwise.
fn collect_eval(handles: Vec<JobHandle<(f64, f64)>>, total: usize) -> anyhow::Result<(f64, f64)> {
    let mut loss = 0.0;
    let mut correct = 0.0;
    for h in handles {
        let (l, c) = h.wait()?;
        loss += l;
        correct += c;
    }
    Ok((loss / total as f64, correct / total as f64))
}
