//! The SFL-GA training coordinator: a single phased round engine that runs
//! communication rounds of the paper's framework (§II-A steps 1–5) and its
//! baselines over a pluggable execution backend ([`ModelRuntime`]), with
//! full communication/latency accounting.  [`Trainer::native`] wires the
//! pure-Rust backend; the PJRT/AOT path sits behind the `pjrt` feature.
//!
//! Every scheme executes the same five phases, configured per scheme by a
//! [`RoundPlan`] policy (see `plan.rs`):
//!
//! 1. **client-fwd fan-out** — per-client forward passes (eq 1),
//! 2. **server reduce** — per-client server FP+BP (eqs 2–4) and the
//!    fixed-order ρ-weighted server-gradient reduction (eq 7),
//! 3. **cotangent routing** — ONE aggregated broadcast (eq 5) or
//!    per-client unicast,
//! 4. **client-bwd fan-out** — per-client VJPs of the routed cotangent
//!    (eq 6),
//! 5. **aggregate** — the scheme's client-side synchronization policy.
//!
//! Fan-out phases run on the [`ParallelExecutor`] — the paper's framework
//! is parallel by construction (N clients compute simultaneously), and the
//! engine executes it that way.  Determinism: every per-client job is a
//! pure function of the round-start state, batches are drawn on the
//! coordinator thread in client order, and ALL reductions/updates happen
//! on the coordinator thread in fixed client-index order — so training is
//! bitwise identical for every thread count (`tests/determinism.rs`).
//!
//! Scheme semantics (see DESIGN.md for the discussion):
//! * **SflGa** — clients upload smashed data; the server updates per-client
//!   server-side models and aggregates them (eq 7), aggregates the
//!   smashed-data gradients (eq 5) and *broadcasts one tensor*.  Per the
//!   paper's eqs (6)/(18)/(19), the client-side gradient g_t^c is
//!   client-independent — one shared w^c steps with the ρ-weighted VJP of
//!   the aggregated cotangent, no client aggregation traffic.  The *bias*
//!   of that gradient vs the true split gradient is the Γ(φ(v)) term of
//!   Assumption 4 — it grows with the client model (Fig. 3 measures it).
//! * **SflGaDrift** — ablation: own VJP of the aggregated cotangent, own
//!   replica, no sync.
//! * **Sfl** — per-client smashed-gradient unicast + synchronous client-
//!   side FedAvg each round (SplitFed [11]).
//! * **Psl** — per-client unicast, no client-side aggregation.
//! * **Fl** — FedAvg on the full model.
//!
//! Evaluation always scores the *global* model: ρ-weighted client-side
//! average joined with the server-side model (for FL, the global model).

use crate::data::init::{init_params, join_params, split_params};
use crate::data::{Batcher, Dataset, generate, partition};
use crate::latency::ComputeConfig;
use crate::model::Manifest;
use crate::runtime::{ModelRuntime, ParallelExecutor, Tensor};
use crate::tensor::{self, Params};
use crate::wireless::{Channel, ChannelState, NetConfig};

use super::comm::{round_comm, RoundComm};
use super::plan::{ClientSync, CotangentRoute, RoundPlan};
use super::SchemeKind;
use super::timing::{AllocPolicy, round_latency, RoundLatency};

/// Training configuration (defaults = the paper's §V-A setup).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: String,
    pub scheme: SchemeKind,
    pub num_clients: usize,
    pub rounds: usize,
    /// Local epochs τ per round (eq 6).
    pub tau: usize,
    pub lr: f32,
    /// Samples per client shard.
    pub samples_per_client: usize,
    /// Test-set size (any size; the tail batch is handled).
    pub test_samples: usize,
    /// Dirichlet α for non-IID splits; None = IID.
    pub non_iid_alpha: Option<f64>,
    pub seed: u64,
    /// Rounds between evaluations.
    pub eval_every: usize,
    /// Round-engine worker threads: `0` = auto (the `SFLGA_TEST_THREADS`
    /// env override if set, else available parallelism), `1` = fully
    /// serial.  Training results are bitwise identical for every value.
    pub threads: usize,
    pub net: NetConfig,
    pub comp: ComputeConfig,
    pub alloc: AllocPolicy,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dataset: "mnist".into(),
            scheme: SchemeKind::SflGa,
            num_clients: 10,
            rounds: 100,
            tau: 1,
            lr: 0.02,
            samples_per_client: 256,
            test_samples: 2048,
            non_iid_alpha: None,
            seed: 17,
            eval_every: 5,
            threads: 0,
            net: NetConfig::default(),
            comp: ComputeConfig::default(),
            alloc: AllocPolicy::Optimal,
        }
    }
}

/// Per-round record (metrics.rs turns these into figure CSVs).
#[derive(Clone, Copy, Debug)]
pub struct RoundStats {
    pub round: usize,
    pub cut: usize,
    pub train_loss: f64,
    pub comm: RoundComm,
    pub latency: RoundLatency,
    /// Test metrics when this round evaluated (eval_every), else None.
    pub test: Option<(f64, f64)>, // (loss, accuracy)
}

/// The coordinator state machine.
pub struct Trainer {
    pub cfg: TrainConfig,
    rt: ModelRuntime,
    pool: ParallelExecutor,
    train: Dataset,
    test: Dataset,
    batchers: Vec<Batcher>,
    /// Aggregation weights ρ^n = D^n / D.
    rho: Vec<f64>,
    channel: Channel,
    /// Per-client client-side models (all schemes; identical where the
    /// scheme keeps them synchronized).
    wc: Vec<Params>,
    /// Server-side model (split schemes) — the aggregated w^s of eq (7).
    ws: Params,
    /// Full global model (FL).
    w_full: Params,
    round: usize,
    /// Cut used in the previous round (dynamic-cut runs resync on change).
    last_cut: Option<usize>,
}

impl Trainer {
    /// Trainer over the native pure-Rust backend — no artifacts needed.
    pub fn native(manifest: &Manifest, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::native(manifest, &cfg.dataset)?;
        Trainer::new(rt, cfg)
    }

    /// Trainer over the PJRT backend, compiled from the AOT artifacts.
    #[cfg(feature = "pjrt")]
    pub fn from_artifacts(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        cfg: TrainConfig,
    ) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::load(artifact_dir, manifest, &cfg.dataset)?;
        Trainer::new(rt, cfg)
    }

    /// Trainer over an already-constructed runtime (any backend).
    pub fn new(rt: ModelRuntime, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        anyhow::ensure!(cfg.num_clients > 0 && cfg.rounds > 0 && cfg.tau > 0);
        anyhow::ensure!(cfg.eval_every > 0, "eval_every must be positive");
        anyhow::ensure!(cfg.test_samples > 0, "test_samples must be positive");
        let spec = rt.spec().clone();
        // Dynamic-batch backends (native) score the remainder tail batch;
        // fixed-shape AOT backends (pjrt) cannot take one.
        anyhow::ensure!(
            rt.dynamic_batch() || cfg.test_samples % spec.eval_batch == 0,
            "backend '{}' is compiled for fixed shapes: test_samples must be a multiple of the \
             eval batch {}",
            rt.backend_name(),
            spec.eval_batch
        );

        let total = cfg.samples_per_client * cfg.num_clients;
        let train = generate(&spec, &cfg.dataset, total, cfg.seed);
        let test = generate(&spec, &cfg.dataset, cfg.test_samples, cfg.seed ^ 0x7E57);
        let shards = partition(&train, cfg.num_clients, cfg.non_iid_alpha, cfg.seed);
        let d_total: usize = shards.iter().map(Vec::len).sum();
        let rho: Vec<f64> = shards.iter().map(|s| s.len() as f64 / d_total as f64).collect();
        let batchers = shards
            .iter()
            .enumerate()
            .map(|(i, s)| Batcher::new(s.clone(), spec.train_batch, cfg.seed ^ (i as u64) << 8))
            .collect();

        let params = init_params(&spec, cfg.seed ^ 0x1417);
        // Initialize every cut's split from the same full model; the cut in
        // force selects which prefix the clients own.
        let wc = vec![params.clone(); cfg.num_clients];
        let channel = Channel::new(cfg.net.clone(), cfg.num_clients, cfg.seed ^ 0xC4A7);
        let pool = ParallelExecutor::new(cfg.threads);

        Ok(Trainer {
            rt,
            pool,
            train,
            test,
            batchers,
            rho,
            channel,
            ws: params.clone(),
            w_full: params,
            wc,
            round: 0,
            last_cut: None,
            cfg,
        })
    }

    pub fn spec(&self) -> &crate::model::ShapeSpec {
        self.rt.spec()
    }

    /// Name of the execution backend in use ("native", "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.rt.backend_name()
    }

    /// Resolved round-engine worker count (1 = serial).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn rho(&self) -> &[f64] {
        &self.rho
    }

    pub fn round_index(&self) -> usize {
        self.round
    }

    /// Draw this round's channel (exposed for cut-selection policies that
    /// observe the state before choosing v — Algorithm 1's MDP state).
    pub fn draw_channel(&mut self) -> ChannelState {
        self.channel.draw_round()
    }

    /// Run one communication round at cut `v` with channel `state`.
    pub fn run_round(&mut self, cut: usize, state: &ChannelState) -> anyhow::Result<RoundStats> {
        // Dynamic cut selection (Algorithm 1) moves layer ownership between
        // the sides; on a cut change, re-anchor every replica to the global
        // model so the handed-over blocks carry the aggregated weights.
        if self.last_cut.is_some() && self.last_cut != Some(cut) {
            let global = self.global_params(self.last_cut.unwrap());
            for w in &mut self.wc {
                *w = global.clone();
            }
            self.ws = global;
        }
        self.last_cut = Some(cut);
        let loss = match self.cfg.scheme.plan() {
            RoundPlan::Split { route, sync } => self.round_split(cut, route, sync)?,
            RoundPlan::Full => self.round_full()?,
        };
        let spec = self.rt.spec().clone();
        let cut_spec = spec.cut(cut);
        let comm = round_comm(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &self.cfg.comp,
            self.cfg.num_clients,
            self.cfg.tau,
        );
        let latency = round_latency(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &self.cfg.net,
            &self.cfg.comp,
            state,
            self.cfg.alloc,
            self.cfg.tau,
        );
        self.round += 1;
        let test = if self.round % self.cfg.eval_every == 0 || self.round == self.cfg.rounds {
            Some(self.evaluate(cut)?)
        } else {
            None
        };
        Ok(RoundStats { round: self.round, cut, train_loss: loss, comm, latency, test })
    }

    /// Convenience: run a full fixed-cut training; returns all stats.
    pub fn run(&mut self, cut: usize) -> anyhow::Result<Vec<RoundStats>> {
        let mut out = Vec::with_capacity(self.cfg.rounds);
        for _ in 0..self.cfg.rounds {
            let state = self.draw_channel();
            out.push(self.run_round(cut, &state)?);
        }
        Ok(out)
    }

    // ------------------------------------------------- the round engine

    /// Draw every client's next batch, on the coordinator thread in client
    /// order (phase 0) — the Batcher RNG sequence is therefore identical
    /// for every thread count.
    fn draw_batches(&mut self) -> Vec<(Tensor, Tensor)> {
        (0..self.cfg.num_clients)
            .map(|i| {
                let idx = self.batchers[i].next_batch();
                self.train.batch(&idx)
            })
            .collect()
    }

    /// One split round (§II-A steps 1–5) of τ epochs, phases configured by
    /// `route`/`sync`.  All per-client backend calls fan out on the
    /// executor; all reductions run on the coordinator thread in fixed
    /// client-index order (bitwise thread-count independence).
    fn round_split(
        &mut self,
        cut: usize,
        route: CotangentRoute,
        sync: ClientSync,
    ) -> anyhow::Result<f64> {
        let nc = self.rt.spec().cut(cut).client_params;
        let n = self.cfg.num_clients;
        let lr = self.cfg.lr;
        let shared = sync == ClientSync::SharedStep;
        // Preallocated reduction accumulators, reused across the τ epochs.
        let mut g_ws_acc = tensor::zeros_like(&self.ws[nc..]);
        let mut g_c_acc = if shared {
            tensor::zeros_like(&self.wc[0][..nc])
        } else {
            Params::new()
        };
        let mut mean_loss = 0.0;
        for _ in 0..self.cfg.tau {
            let batches = self.draw_batches();
            let rt = &self.rt;
            let wc = &self.wc;
            // (1) client-fwd fan-out — eq (1), zero-copy parameter views.
            let smashed = self.pool.map(n, |i| rt.client_fwd(cut, &wc[i][..nc], &batches[i].0))?;
            // (2) server reduce: per-client server FP+BP (eqs 2–4) fan
            // out; the ρ-weighted server-gradient reduction (eq 7) then
            // streams into the accumulator in client-index order.
            let ws_srv = &self.ws[nc..];
            let server =
                self.pool.map(n, |i| rt.server_grad(cut, ws_srv, &smashed[i], &batches[i].1))?;
            tensor::zero(&mut g_ws_acc);
            let mut loss_acc = 0.0;
            for (i, (loss, g_ws, _)) in server.iter().enumerate() {
                loss_acc += self.rho[i] * *loss as f64;
                tensor::weighted_accumulate(&mut g_ws_acc, g_ws, self.rho[i]);
            }
            // (3) cotangent routing: aggregate per eq (5) and broadcast
            // ONE tensor, or unicast each client its own cotangent.
            let broadcast = match route {
                CotangentRoute::Broadcast => {
                    let mut agg = Tensor::zeros(&server[0].2.shape);
                    for (i, (_, _, g_s)) in server.iter().enumerate() {
                        tensor::weighted_accumulate_flat(&mut agg.data, &g_s.data, self.rho[i]);
                    }
                    Some(agg)
                }
                CotangentRoute::Unicast => None,
            };
            // (4) client-bwd fan-out — eq (6).  The shared plan runs every
            // VJP against the one shared w^c; per-client plans against the
            // client's own replica and (unicast) own cotangent.
            let g_c_parts = self.pool.map(n, |i| {
                let wc_i = if shared { &wc[0][..nc] } else { &wc[i][..nc] };
                let cot = broadcast.as_ref().unwrap_or(&server[i].2);
                rt.client_grad(cut, wc_i, &batches[i].0, cot)
            })?;
            // Apply this epoch's updates on the coordinator thread:
            // server-side SGD step on the aggregated gradient (eq 7)…
            tensor::sgd_step(&mut self.ws[nc..], &g_ws_acc, lr);
            if shared {
                // …and the client-independent g_t^c of eq (19): the
                // ρ-weighted VJP reduction, applied identically to every
                // replica, keeps the shared-w^c invariant with NO
                // aggregation traffic.
                tensor::zero(&mut g_c_acc);
                for (i, g_c) in g_c_parts.iter().enumerate() {
                    tensor::weighted_accumulate(&mut g_c_acc, g_c, self.rho[i]);
                }
                for wc_i in &mut self.wc {
                    tensor::sgd_step(&mut wc_i[..nc], &g_c_acc, lr);
                }
            } else {
                // …or each client's own step on its own replica.
                for (wc_i, g_c) in self.wc.iter_mut().zip(&g_c_parts) {
                    tensor::sgd_step(&mut wc_i[..nc], g_c, lr);
                }
            }
            mean_loss += loss_acc / self.cfg.tau as f64;
        }
        // (5) aggregate: synchronous client-side FedAvg — SFL only, the
        // traffic SFL-GA removes.
        if sync == ClientSync::FedAvg {
            let mut agg = tensor::zeros_like(&self.wc[0][..nc]);
            for (i, w) in self.wc.iter().enumerate() {
                tensor::weighted_accumulate(&mut agg, &w[..nc], self.rho[i]);
            }
            for w in &mut self.wc {
                for (dst, src) in w[..nc].iter_mut().zip(&agg) {
                    dst.copy_from_slice(src);
                }
            }
        }
        Ok(mean_loss)
    }

    /// FedAvg round ([`RoundPlan::Full`]): per-client τ full-model local
    /// steps fan out (each worker owns a private model clone), then the
    /// ρ-weighted model aggregation streams in client-index order.
    fn round_full(&mut self) -> anyhow::Result<f64> {
        let n = self.cfg.num_clients;
        let lr = self.cfg.lr;
        let tau = self.cfg.tau;
        // Phase 0: τ batch-index draws per client, in client order on the
        // coordinator thread (per-client Batcher RNG order is identical to
        // serial).  Workers materialize their own client's tensors from
        // the shared read-only dataset, so only one batch per worker is
        // resident at a time.
        let draws: Vec<Vec<Vec<usize>>> = (0..n)
            .map(|i| (0..tau).map(|_| self.batchers[i].next_batch()).collect())
            .collect();
        let rt = &self.rt;
        let train = &self.train;
        let w0 = &self.w_full;
        let locals = self.pool.map(n, |i| {
            let mut w = w0.clone();
            let mut first_loss = 0.0f32;
            for (e, idx) in draws[i].iter().enumerate() {
                let (x, y) = train.batch(idx);
                let (loss, g) = rt.full_grad(&w, &x, &y)?;
                if e == 0 {
                    first_loss = loss;
                }
                tensor::sgd_step(&mut w, &g, lr);
            }
            Ok((first_loss, w))
        })?;
        let mut agg = tensor::zeros_like(&self.w_full);
        let mut loss_acc = 0.0;
        for (i, (loss, w)) in locals.iter().enumerate() {
            loss_acc += self.rho[i] * *loss as f64;
            tensor::weighted_accumulate(&mut agg, w, self.rho[i]);
        }
        self.w_full = agg;
        Ok(loss_acc)
    }

    // ------------------------------------------------------------- eval

    /// Global model at cut v: ρ-weighted client-side average ++ server side.
    pub fn global_params(&self, cut: usize) -> Params {
        if self.cfg.scheme == SchemeKind::Fl {
            return self.w_full.clone();
        }
        let nc = self.rt.spec().cut(cut).client_params;
        let mut wc_avg = tensor::zeros_like(&self.wc[0][..nc]);
        for (i, w) in self.wc.iter().enumerate() {
            tensor::weighted_accumulate(&mut wc_avg, &w[..nc], self.rho[i]);
        }
        join_params(&wc_avg, &self.ws[nc..])
    }

    /// Test-set (loss, accuracy) of the global model.  Batches fan out on
    /// the executor; the remainder tail batch (when `test_samples` is not
    /// a multiple of the eval batch) is scored too, with the mean loss
    /// weighted by true batch sizes.
    pub fn evaluate(&self, cut: usize) -> anyhow::Result<(f64, f64)> {
        let w = self.global_params(cut);
        let eb = self.rt.spec().eval_batch;
        let total = self.test.len();
        anyhow::ensure!(total > 0, "empty test set");
        let starts: Vec<usize> = (0..total).step_by(eb).collect();
        let rt = &self.rt;
        let test = &self.test;
        let scores = self.pool.map(starts.len(), |b| {
            let lo = starts[b];
            let hi = (lo + eb).min(total);
            let idx: Vec<usize> = (lo..hi).collect();
            let (x, y) = test.batch(&idx);
            let (l, c) = rt.eval(&w, &x, &y)?;
            Ok((l as f64 * (hi - lo) as f64, c as f64))
        })?;
        let mut loss = 0.0;
        let mut correct = 0.0;
        for (l, c) in scores {
            loss += l;
            correct += c;
        }
        Ok((loss / total as f64, correct / total as f64))
    }

    /// Max |Δ| between two clients' client-side models — the drift Γ(φ)
    /// bounds (diagnostics + tests).
    pub fn client_drift(&self, cut: usize) -> f64 {
        let nc = self.rt.spec().cut(cut).client_params;
        let mut m = 0.0f64;
        for i in 1..self.wc.len() {
            m = m.max(tensor::max_abs_diff(&self.wc[0][..nc], &self.wc[i][..nc]));
        }
        m
    }

    /// Reset all model state (fresh init) without reloading artifacts.
    pub fn reset(&mut self, seed: u64) {
        let spec = self.rt.spec().clone();
        let params = init_params(&spec, seed);
        self.wc = vec![params.clone(); self.cfg.num_clients];
        self.ws = params.clone();
        self.w_full = params;
        self.round = 0;
        self.last_cut = None;
    }

    /// Access the split of the *current* global params (testing).
    pub fn split_of_global(&self, cut: usize) -> (Params, Params) {
        split_params(self.rt.spec(), cut, &self.global_params(cut))
    }
}
