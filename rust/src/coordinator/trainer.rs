//! The SFL-GA training coordinator: a single phased round engine that runs
//! communication rounds of the paper's framework (§II-A steps 1–5) and its
//! baselines over a pluggable execution backend ([`ModelRuntime`]), with
//! full communication/latency accounting.  [`Trainer::native`] wires the
//! pure-Rust backend; the PJRT/AOT path sits behind the `pjrt` feature.
//!
//! Every scheme executes the same five phases, configured per scheme by a
//! [`RoundPlan`] policy (see `plan.rs`):
//!
//! 1. **client-fwd fan-out** — per-client forward passes (eq 1),
//! 2. **server reduce** — per-client server FP+BP (eqs 2–4) and the
//!    fixed-order ρ-weighted server-gradient reduction (eq 7),
//! 3. **cotangent routing** — ONE aggregated broadcast (eq 5) or
//!    per-client unicast,
//! 4. **client-bwd fan-out** — per-client VJPs of the routed cotangent
//!    (eq 6),
//! 5. **aggregate** — the scheme's client-side synchronization policy.
//!
//! Fan-out phases run on the [`ParallelExecutor`] — the paper's framework
//! is parallel by construction (N clients compute simultaneously), and the
//! engine executes it that way; each worker reuses its own kernel scratch
//! arena across jobs (see `runtime::scratch`).  Determinism: every
//! per-client job is a
//! pure function of the round-start state, batches are drawn on the
//! coordinator thread in client order, and ALL reductions/updates happen
//! on the coordinator thread in fixed client-index order — so training is
//! bitwise identical for every thread count (`tests/determinism.rs`).
//!
//! Every run executes under a [`ScenarioConfig`] (see [`crate::scenario`]
//! and DESIGN.md §Scenarios): the partition strategy fixes per-client
//! shards and the sample-count aggregation weights ρ^n = |D^n|/|D|;
//! straggler profiles slow a subset of clients in the timing model; and
//! under partial participation each round runs over a cohort drawn
//! coordinator-side, with weights renormalized over the cohort and
//! communication/latency accounted for exactly the clients that took
//! part.  The default scenario reproduces the paper's IID, homogeneous,
//! always-on setup byte-for-byte.
//!
//! Scheme semantics (see DESIGN.md for the discussion):
//! * **SflGa** — clients upload smashed data; the server updates per-client
//!   server-side models and aggregates them (eq 7), aggregates the
//!   smashed-data gradients (eq 5) and *broadcasts one tensor*.  Per the
//!   paper's eqs (6)/(18)/(19), the client-side gradient g_t^c is
//!   client-independent — one shared w^c steps with the ρ-weighted VJP of
//!   the aggregated cotangent, no client aggregation traffic.  The *bias*
//!   of that gradient vs the true split gradient is the Γ(φ(v)) term of
//!   Assumption 4 — it grows with the client model (Fig. 3 measures it).
//! * **SflGaDrift** — ablation: own VJP of the aggregated cotangent, own
//!   replica, no sync.
//! * **Sfl** — per-client smashed-gradient unicast + synchronous client-
//!   side FedAvg each round (SplitFed [11]).
//! * **Psl** — per-client unicast, no client-side aggregation.
//! * **Fl** — FedAvg on the full model.
//!
//! Evaluation always scores the *global* model: ρ-weighted client-side
//! average joined with the server-side model (for FL, the global model).

use crate::data::init::{init_params, join_params, split_params};
use crate::data::{Batcher, Dataset, generate};
use crate::latency::ComputeConfig;
use crate::model::Manifest;
use crate::runtime::{ModelRuntime, ParallelExecutor, Tensor};
use crate::scenario::ScenarioConfig;
use crate::tensor::{self, Params};
use crate::util::rng::Pcg;
use crate::wireless::{Channel, ChannelState, NetConfig};

use super::comm::{round_comm, RoundComm};
use super::plan::{ClientSync, CotangentRoute, RoundPlan};
use super::SchemeKind;
use super::timing::{AllocPolicy, round_latency, RoundLatency};

/// Training configuration (defaults = the paper's §V-A setup).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: String,
    pub scheme: SchemeKind,
    pub num_clients: usize,
    pub rounds: usize,
    /// Local epochs τ per round (eq 6).
    pub tau: usize,
    pub lr: f32,
    /// Samples per client shard.
    pub samples_per_client: usize,
    /// Test-set size (any size; the tail batch is handled).
    pub test_samples: usize,
    /// Scenario layer: data partition (IID / Dirichlet / shards), partial
    /// participation and compute stragglers.  Defaults = the paper's
    /// homogeneous always-on IID setup.
    pub scenario: ScenarioConfig,
    pub seed: u64,
    /// Rounds between evaluations.
    pub eval_every: usize,
    /// Round-engine worker threads: `0` = auto (the `SFLGA_TEST_THREADS`
    /// env override if set, else available parallelism), `1` = fully
    /// serial.  Training results are bitwise identical for every value.
    pub threads: usize,
    pub net: NetConfig,
    pub comp: ComputeConfig,
    pub alloc: AllocPolicy,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dataset: "mnist".into(),
            scheme: SchemeKind::SflGa,
            num_clients: 10,
            rounds: 100,
            tau: 1,
            lr: 0.02,
            samples_per_client: 256,
            test_samples: 2048,
            scenario: ScenarioConfig::default(),
            seed: 17,
            eval_every: 5,
            threads: 0,
            net: NetConfig::default(),
            comp: ComputeConfig::default(),
            alloc: AllocPolicy::Optimal,
        }
    }
}

/// Per-round record (metrics.rs turns these into figure CSVs).
#[derive(Clone, Copy, Debug)]
pub struct RoundStats {
    pub round: usize,
    pub cut: usize,
    /// Clients that actually participated this round (= N under full
    /// participation); comm/latency below account for exactly these.
    pub participants: usize,
    pub train_loss: f64,
    pub comm: RoundComm,
    pub latency: RoundLatency,
    /// Test metrics when this round evaluated (eval_every), else None.
    pub test: Option<(f64, f64)>, // (loss, accuracy)
}

/// The coordinator state machine.
pub struct Trainer {
    pub cfg: TrainConfig,
    rt: ModelRuntime,
    pool: ParallelExecutor,
    train: Dataset,
    test: Dataset,
    batchers: Vec<Batcher>,
    /// Aggregation weights ρ^n = D^n / D.
    rho: Vec<f64>,
    channel: Channel,
    /// Per-client client-side models (all schemes; identical where the
    /// scheme keeps them synchronized).
    wc: Vec<Params>,
    /// Server-side model (split schemes) — the aggregated w^s of eq (7).
    ws: Params,
    /// Full global model (FL).
    w_full: Params,
    /// Per-client compute capacities in FLOPS — the max/spread draw with
    /// the scenario's straggler multipliers folded in, resolved once per
    /// deployment (fixed hardware).
    caps: Vec<f64>,
    /// Participation RNG: the cohort draw consumes this on the
    /// coordinator thread, one draw per round (untouched under full
    /// participation).
    part_rng: Pcg,
    round: usize,
    /// Cut used in the previous round (dynamic-cut runs resync on change).
    last_cut: Option<usize>,
}

impl Trainer {
    /// Trainer over the native pure-Rust backend — no artifacts needed.
    pub fn native(manifest: &Manifest, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::native(manifest, &cfg.dataset)?;
        Trainer::new(rt, cfg)
    }

    /// Trainer over the PJRT backend, compiled from the AOT artifacts.
    #[cfg(feature = "pjrt")]
    pub fn from_artifacts(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        cfg: TrainConfig,
    ) -> anyhow::Result<Trainer> {
        let rt = ModelRuntime::load(artifact_dir, manifest, &cfg.dataset)?;
        Trainer::new(rt, cfg)
    }

    /// Trainer over an already-constructed runtime (any backend).
    pub fn new(rt: ModelRuntime, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        anyhow::ensure!(cfg.num_clients > 0 && cfg.rounds > 0 && cfg.tau > 0);
        anyhow::ensure!(cfg.eval_every > 0, "eval_every must be positive");
        anyhow::ensure!(cfg.test_samples > 0, "test_samples must be positive");
        anyhow::ensure!(cfg.samples_per_client > 0, "samples_per_client must be positive");
        cfg.scenario.validate()?;
        let spec = rt.spec().clone();
        // Dynamic-batch backends (native) score the remainder tail batch;
        // fixed-shape AOT backends (pjrt) cannot take one.
        anyhow::ensure!(
            rt.dynamic_batch() || cfg.test_samples % spec.eval_batch == 0,
            "backend '{}' is compiled for fixed shapes: test_samples must be a multiple of the \
             eval batch {}",
            rt.backend_name(),
            spec.eval_batch
        );

        let total = cfg.samples_per_client * cfg.num_clients;
        let train = generate(&spec, &cfg.dataset, total, cfg.seed);
        let test = generate(&spec, &cfg.dataset, cfg.test_samples, cfg.seed ^ 0x7E57);
        // Scenario axis 1 — data distribution: the partition strategy
        // fixes each client's shard and, via |D^n|, the sample-count
        // aggregation weights ρ^n = |D^n| / |D| (FedAvg weighting).
        let shards =
            cfg.scenario.partition.indices(&train.labels, train.classes, cfg.num_clients, cfg.seed);
        let d_total: usize = shards.iter().map(Vec::len).sum();
        let rho: Vec<f64> = shards.iter().map(|s| s.len() as f64 / d_total as f64).collect();
        let batchers = shards
            .iter()
            .enumerate()
            .map(|(i, s)| Batcher::new(s.clone(), spec.train_batch, cfg.seed ^ (i as u64) << 8))
            .collect();

        // Scenario axis 2 — compute heterogeneity: resolve the max/spread
        // draw and the straggler multipliers into one per-client capacity
        // table (fixed hardware; participant subsets index into it).
        let caps = cfg.scenario.resolve_caps(&cfg.comp, cfg.num_clients, cfg.seed);

        let params = init_params(&spec, cfg.seed ^ 0x1417);
        // Initialize every cut's split from the same full model; the cut in
        // force selects which prefix the clients own.
        let wc = vec![params.clone(); cfg.num_clients];
        let channel = Channel::new(cfg.net.clone(), cfg.num_clients, cfg.seed ^ 0xC4A7);
        let part_rng = ScenarioConfig::part_rng(cfg.seed);
        let pool = ParallelExecutor::new(cfg.threads);

        Ok(Trainer {
            rt,
            pool,
            train,
            test,
            batchers,
            rho,
            channel,
            ws: params.clone(),
            w_full: params,
            wc,
            caps,
            part_rng,
            round: 0,
            last_cut: None,
            cfg,
        })
    }

    pub fn spec(&self) -> &crate::model::ShapeSpec {
        self.rt.spec()
    }

    /// Name of the execution backend in use ("native", "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.rt.backend_name()
    }

    /// Resolved round-engine worker count (1 = serial).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn rho(&self) -> &[f64] {
        &self.rho
    }

    pub fn round_index(&self) -> usize {
        self.round
    }

    /// Draw this round's channel (exposed for cut-selection policies that
    /// observe the state before choosing v — Algorithm 1's MDP state).
    pub fn draw_channel(&mut self) -> ChannelState {
        self.channel.draw_round()
    }

    /// Run one communication round at cut `v` with channel `state`.
    ///
    /// The round runs the scheme's [`RoundPlan`] over this round's
    /// participant cohort (drawn coordinator-side from the round RNG —
    /// everyone under full participation), then accounts communication
    /// and latency for exactly the clients that took part.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use sfl_ga::coordinator::{TrainConfig, Trainer};
    /// use sfl_ga::model::Manifest;
    ///
    /// let manifest = Manifest::builtin();
    /// let mut trainer = Trainer::native(&manifest, TrainConfig::default())?;
    /// // Cut selection policies observe the channel before choosing v.
    /// let state = trainer.draw_channel();
    /// let stats = trainer.run_round(2, &state)?;
    /// println!("{} clients participated", stats.participants);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn run_round(&mut self, cut: usize, state: &ChannelState) -> anyhow::Result<RoundStats> {
        // Dynamic cut selection (Algorithm 1) moves layer ownership between
        // the sides; on a cut change, re-anchor every replica to the global
        // model so the handed-over blocks carry the aggregated weights.
        if self.last_cut.is_some() && self.last_cut != Some(cut) {
            let global = self.global_params(self.last_cut.unwrap());
            for w in &mut self.wc {
                *w = global.clone();
            }
            self.ws = global;
        }
        self.last_cut = Some(cut);
        // Scenario axis 3 — participation: the cohort draw happens on the
        // coordinator thread, so it is identical for every thread count.
        let n = self.cfg.num_clients;
        let participants = self.cfg.scenario.draw_participants(&mut self.part_rng, n);
        // Aggregation weights over the cohort: ρ renormalized to sum to 1
        // across the participants (exactly ρ itself under full
        // participation — no renormalization bit-noise on the fast path).
        let weights: Vec<f64> = if participants.len() == n {
            self.rho.clone()
        } else {
            let total: f64 = participants.iter().map(|&i| self.rho[i]).sum();
            participants.iter().map(|&i| self.rho[i] / total).collect()
        };
        let loss = match self.cfg.scheme.plan() {
            RoundPlan::Split { route, sync } => {
                self.round_split(cut, route, sync, &participants, &weights)?
            }
            RoundPlan::Full => self.round_full(&participants, &weights)?,
        };
        // Communication and latency account for the cohort only: the
        // channel state and compute table restricted to participants.
        let state_round = if participants.len() == n {
            state.clone()
        } else {
            ChannelState { gains: participants.iter().map(|&i| state.gains[i]).collect() }
        };
        let mut comp_round = self.cfg.comp.clone();
        comp_round.client_caps = participants.iter().map(|&i| self.caps[i]).collect();
        let spec = self.rt.spec().clone();
        let cut_spec = spec.cut(cut);
        let comm = round_comm(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &comp_round,
            participants.len(),
            self.cfg.tau,
        );
        let latency = round_latency(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &self.cfg.net,
            &comp_round,
            &state_round,
            self.cfg.alloc,
            self.cfg.tau,
        );
        self.round += 1;
        let test = if self.round % self.cfg.eval_every == 0 || self.round == self.cfg.rounds {
            Some(self.evaluate(cut)?)
        } else {
            None
        };
        Ok(RoundStats {
            round: self.round,
            cut,
            participants: participants.len(),
            train_loss: loss,
            comm,
            latency,
            test,
        })
    }

    /// Convenience: run a full fixed-cut training; returns all stats.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use sfl_ga::coordinator::{SchemeKind, TrainConfig, Trainer};
    /// use sfl_ga::model::Manifest;
    /// use sfl_ga::scenario::ScenarioConfig;
    /// use sfl_ga::data::partition::Partition;
    ///
    /// let manifest = Manifest::builtin();
    /// let cfg = TrainConfig {
    ///     scheme: SchemeKind::SflGa,
    ///     rounds: 10,
    ///     scenario: ScenarioConfig {
    ///         partition: Partition::Dirichlet(0.3),
    ///         participation: 0.5,
    ///         ..Default::default()
    ///     },
    ///     ..Default::default()
    /// };
    /// let mut trainer = Trainer::native(&manifest, cfg)?;
    /// let stats = trainer.run(2)?; // fixed cut v=2
    /// assert_eq!(stats.len(), 10);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn run(&mut self, cut: usize) -> anyhow::Result<Vec<RoundStats>> {
        let mut out = Vec::with_capacity(self.cfg.rounds);
        for _ in 0..self.cfg.rounds {
            let state = self.draw_channel();
            out.push(self.run_round(cut, &state)?);
        }
        Ok(out)
    }

    // ------------------------------------------------- the round engine

    /// Draw each participant's next batch, on the coordinator thread in
    /// ascending client order (phase 0) — the Batcher RNG sequences are
    /// therefore identical for every thread count, and a client's batch
    /// stream only advances on rounds it participates in.
    fn draw_batches(&mut self, participants: &[usize]) -> Vec<(Tensor, Tensor)> {
        participants
            .iter()
            .map(|&i| {
                let idx = self.batchers[i].next_batch();
                self.train.batch(&idx)
            })
            .collect()
    }

    /// One split round (§II-A steps 1–5) of τ epochs over the cohort
    /// `participants` (sorted ascending), phases configured by
    /// `route`/`sync`.  `weights[j]` is participant j's aggregation
    /// weight (ρ renormalized over the cohort).  All per-client backend
    /// calls fan out on the executor; all reductions run on the
    /// coordinator thread in fixed client-index order (bitwise
    /// thread-count independence).
    fn round_split(
        &mut self,
        cut: usize,
        route: CotangentRoute,
        sync: ClientSync,
        participants: &[usize],
        weights: &[f64],
    ) -> anyhow::Result<f64> {
        let nc = self.rt.spec().cut(cut).client_params;
        let k = participants.len();
        let lr = self.cfg.lr;
        let shared = sync == ClientSync::SharedStep;
        // Preallocated reduction accumulators, reused across the τ epochs.
        let mut g_ws_acc = tensor::zeros_like(&self.ws[nc..]);
        let mut g_c_acc = if shared {
            tensor::zeros_like(&self.wc[0][..nc])
        } else {
            Params::new()
        };
        let mut mean_loss = 0.0;
        for _ in 0..self.cfg.tau {
            let batches = self.draw_batches(participants);
            let rt = &self.rt;
            let wc = &self.wc;
            // (1) client-fwd fan-out — eq (1), zero-copy parameter views;
            // each worker draws kernel scratch from its own arena.
            let smashed = self.pool.map_with_scratch(k, |scratch, j| {
                rt.client_fwd_with(scratch, cut, &wc[participants[j]][..nc], &batches[j].0)
            })?;
            // (2) server reduce: per-participant server FP+BP (eqs 2–4)
            // fan out; the weighted server-gradient reduction (eq 7) then
            // streams into the accumulator in cohort (= ascending client
            // index) order.
            let ws_srv = &self.ws[nc..];
            let server = self.pool.map_with_scratch(k, |scratch, j| {
                rt.server_grad_with(scratch, cut, ws_srv, &smashed[j], &batches[j].1)
            })?;
            tensor::zero(&mut g_ws_acc);
            let mut loss_acc = 0.0;
            for (j, (loss, g_ws, _)) in server.iter().enumerate() {
                loss_acc += weights[j] * *loss as f64;
                tensor::weighted_accumulate(&mut g_ws_acc, g_ws, weights[j]);
            }
            // (3) cotangent routing: aggregate per eq (5) and broadcast
            // ONE tensor, or unicast each participant its own cotangent.
            let broadcast = match route {
                CotangentRoute::Broadcast => {
                    let mut agg = Tensor::zeros(&server[0].2.shape);
                    for (j, (_, _, g_s)) in server.iter().enumerate() {
                        tensor::weighted_accumulate_flat(&mut agg.data, &g_s.data, weights[j]);
                    }
                    Some(agg)
                }
                CotangentRoute::Unicast => None,
            };
            // (4) client-bwd fan-out — eq (6).  The shared plan runs every
            // VJP against the one shared w^c; per-client plans against the
            // client's own replica and (unicast) own cotangent.
            let g_c_parts = self.pool.map_with_scratch(k, |scratch, j| {
                let wc_j = if shared { &wc[0][..nc] } else { &wc[participants[j]][..nc] };
                let cot = broadcast.as_ref().unwrap_or(&server[j].2);
                rt.client_grad_with(scratch, cut, wc_j, &batches[j].0, cot)
            })?;
            // Apply this epoch's updates on the coordinator thread:
            // server-side SGD step on the aggregated gradient (eq 7)…
            tensor::sgd_step(&mut self.ws[nc..], &g_ws_acc, lr);
            if shared {
                // …and the client-independent g_t^c of eq (19): the
                // weighted VJP reduction, applied identically to every
                // replica, keeps the shared-w^c invariant with NO
                // aggregation traffic.  Under partial participation the
                // shared w^c is ONE logical server-held model — clients
                // that sat the round out pick the stepped model up when
                // they next join, so every replica steps here too.
                tensor::zero(&mut g_c_acc);
                for (j, g_c) in g_c_parts.iter().enumerate() {
                    tensor::weighted_accumulate(&mut g_c_acc, g_c, weights[j]);
                }
                for wc_i in &mut self.wc {
                    tensor::sgd_step(&mut wc_i[..nc], &g_c_acc, lr);
                }
            } else {
                // …or each participant's own step on its own replica
                // (absent clients keep their stale replicas).
                for (j, g_c) in g_c_parts.iter().enumerate() {
                    tensor::sgd_step(&mut self.wc[participants[j]][..nc], g_c, lr);
                }
            }
            mean_loss += loss_acc / self.cfg.tau as f64;
        }
        // (5) aggregate: synchronous client-side FedAvg — SFL only, the
        // traffic SFL-GA removes.  Only the round's participants exchange
        // and receive the aggregate; absentees stay stale until they next
        // participate.
        if sync == ClientSync::FedAvg {
            let mut agg = tensor::zeros_like(&self.wc[0][..nc]);
            for (j, &i) in participants.iter().enumerate() {
                tensor::weighted_accumulate(&mut agg, &self.wc[i][..nc], weights[j]);
            }
            for &i in participants {
                for (dst, src) in self.wc[i][..nc].iter_mut().zip(&agg) {
                    dst.copy_from_slice(src);
                }
            }
        }
        Ok(mean_loss)
    }

    /// FedAvg round ([`RoundPlan::Full`]) over the cohort: per-participant
    /// τ full-model local steps fan out (each worker owns a private model
    /// clone), then the weighted model aggregation streams in cohort
    /// order.
    fn round_full(&mut self, participants: &[usize], weights: &[f64]) -> anyhow::Result<f64> {
        let k = participants.len();
        let lr = self.cfg.lr;
        let tau = self.cfg.tau;
        // Phase 0: τ batch-index draws per participant, in ascending
        // client order on the coordinator thread (per-client Batcher RNG
        // order is identical to serial).  Workers materialize their own
        // client's tensors from the shared read-only dataset, so only one
        // batch per worker is resident at a time.
        let draws: Vec<Vec<Vec<usize>>> = participants
            .iter()
            .map(|&i| (0..tau).map(|_| self.batchers[i].next_batch()).collect())
            .collect();
        let rt = &self.rt;
        let train = &self.train;
        let w0 = &self.w_full;
        let locals = self.pool.map_with_scratch(k, |scratch, j| {
            let mut w = w0.clone();
            let mut first_loss = 0.0f32;
            for (e, idx) in draws[j].iter().enumerate() {
                let (x, y) = train.batch(idx);
                let (loss, g) = rt.full_grad_with(scratch, &w, &x, &y)?;
                if e == 0 {
                    first_loss = loss;
                }
                tensor::sgd_step(&mut w, &g, lr);
            }
            Ok((first_loss, w))
        })?;
        let mut agg = tensor::zeros_like(&self.w_full);
        let mut loss_acc = 0.0;
        for (j, (loss, w)) in locals.iter().enumerate() {
            loss_acc += weights[j] * *loss as f64;
            tensor::weighted_accumulate(&mut agg, w, weights[j]);
        }
        self.w_full = agg;
        Ok(loss_acc)
    }

    // ------------------------------------------------------------- eval

    /// Global model at cut v: ρ-weighted client-side average ++ server side.
    pub fn global_params(&self, cut: usize) -> Params {
        if self.cfg.scheme == SchemeKind::Fl {
            return self.w_full.clone();
        }
        let nc = self.rt.spec().cut(cut).client_params;
        let mut wc_avg = tensor::zeros_like(&self.wc[0][..nc]);
        for (i, w) in self.wc.iter().enumerate() {
            tensor::weighted_accumulate(&mut wc_avg, &w[..nc], self.rho[i]);
        }
        join_params(&wc_avg, &self.ws[nc..])
    }

    /// Test-set (loss, accuracy) of the global model.  Batches fan out on
    /// the executor; the remainder tail batch (when `test_samples` is not
    /// a multiple of the eval batch) is scored too, with the mean loss
    /// weighted by true batch sizes.
    pub fn evaluate(&self, cut: usize) -> anyhow::Result<(f64, f64)> {
        let w = self.global_params(cut);
        let eb = self.rt.spec().eval_batch;
        let total = self.test.len();
        anyhow::ensure!(total > 0, "empty test set");
        let starts: Vec<usize> = (0..total).step_by(eb).collect();
        let rt = &self.rt;
        let test = &self.test;
        let scores = self.pool.map_with_scratch(starts.len(), |scratch, b| {
            let lo = starts[b];
            let hi = (lo + eb).min(total);
            let idx: Vec<usize> = (lo..hi).collect();
            let (x, y) = test.batch(&idx);
            let (l, c) = rt.eval_with(scratch, &w, &x, &y)?;
            Ok((l as f64 * (hi - lo) as f64, c as f64))
        })?;
        let mut loss = 0.0;
        let mut correct = 0.0;
        for (l, c) in scores {
            loss += l;
            correct += c;
        }
        Ok((loss / total as f64, correct / total as f64))
    }

    /// Max |Δ| between two clients' client-side models — the drift Γ(φ)
    /// bounds (diagnostics + tests).
    pub fn client_drift(&self, cut: usize) -> f64 {
        let nc = self.rt.spec().cut(cut).client_params;
        let mut m = 0.0f64;
        for i in 1..self.wc.len() {
            m = m.max(tensor::max_abs_diff(&self.wc[0][..nc], &self.wc[i][..nc]));
        }
        m
    }

    /// Reset all model state (fresh init) without reloading artifacts.
    pub fn reset(&mut self, seed: u64) {
        let spec = self.rt.spec().clone();
        let params = init_params(&spec, seed);
        self.wc = vec![params.clone(); self.cfg.num_clients];
        self.ws = params.clone();
        self.w_full = params;
        self.round = 0;
        self.last_cut = None;
    }

    /// Access the split of the *current* global params (testing).
    pub fn split_of_global(&self, cut: usize) -> (Params, Params) {
        split_params(self.rt.spec(), cut, &self.global_params(cut))
    }
}
