//! The round *plan*: a small per-scheme policy object that configures the
//! single phased round executor in [`super::trainer`].
//!
//! Every scheme the paper evaluates is the same five-phase round —
//! *client-fwd fan-out → server reduce → cotangent routing → client-bwd
//! fan-out → aggregate* — differing only in (a) how the server routes the
//! smashed-data cotangents back (§II-A step 4) and (b) what happens to the
//! client-side models afterwards.  `RoundPlan` captures exactly those two
//! choices, so SflGa / SflGaDrift / Sfl / Psl are configurations of one
//! executor rather than hand-rolled loops, and FL is the degenerate plan
//! with no split at all.  The communication ([`super::comm`]) and latency
//! ([`super::timing`]) models dispatch on the same plan, keeping the
//! scheme semantics defined in ONE place.

use super::SchemeKind;

/// How the server returns smashed-data cotangents to the clients
/// (§II-A step 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CotangentRoute {
    /// Aggregate per eq (5) and broadcast ONE tensor to every client —
    /// the paper's gradient-aggregation saving.
    Broadcast,
    /// Unicast each client its own cotangent (SFL / PSL).
    Unicast,
}

/// What happens to the client-side models at the end of the round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClientSync {
    /// eq (19): the client-side gradient is client-independent, so ONE
    /// ρ-weighted gradient steps the shared w^c — no aggregation traffic.
    SharedStep,
    /// Per-replica step + synchronous client-side FedAvg exchange
    /// (SplitFed [11]) — the w^c traffic SFL-GA eliminates.
    FedAvg,
    /// Per-replica step, no synchronization (PSL, the drift ablation).
    None,
}

/// The per-scheme configuration of the phased round executor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundPlan {
    /// Split execution: client-fwd fan-out → server reduce → cotangent
    /// routing → client-bwd fan-out → client aggregate.
    Split { route: CotangentRoute, sync: ClientSync },
    /// FedAvg on the full model: local-step fan-out → model aggregate.
    Full,
}

/// When a participant's client-side BP (eq 6) may start, relative to its
/// own server FP+BP — the plan's pipeline dependency description, which
/// the round engine turns into an executor schedule (DESIGN.md §Perf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BwdDependency {
    /// Unicast: participant j backprops its OWN cotangent s^j, which is
    /// ready the moment j's server FP+BP lands — client-bwd fuses onto
    /// the same per-participant task chain, no cross-client barrier.
    OwnServerGrad,
    /// Broadcast: eq (5) aggregates the cotangents of ALL participants
    /// before anyone can backprop — an irreducible barrier; client-bwd
    /// fans out only after the coordinator's fixed-order reduction.
    BroadcastBarrier,
}

impl RoundPlan {
    /// The split-phase routing, if this plan splits the model.
    pub fn route(&self) -> Option<CotangentRoute> {
        match self {
            RoundPlan::Split { route, .. } => Some(*route),
            RoundPlan::Full => None,
        }
    }

    /// Whether the round pays synchronous client-model FedAvg traffic.
    pub fn pays_client_fedavg(&self) -> bool {
        matches!(self, RoundPlan::Split { sync: ClientSync::FedAvg, .. })
    }

    /// The client-bwd dependency of this plan's pipeline, `None` for the
    /// full-model plan (FL has no split phases at all — each participant
    /// is already ONE fused τ-epoch local-training task).
    pub fn bwd_dependency(&self) -> Option<BwdDependency> {
        self.route().map(|r| match r {
            CotangentRoute::Unicast => BwdDependency::OwnServerGrad,
            CotangentRoute::Broadcast => BwdDependency::BroadcastBarrier,
        })
    }

    /// True when the executor may fuse client-bwd onto each participant's
    /// fwd→server chain (no barrier between eqs 2–4 and eq 6).
    pub fn fuses_client_bwd(&self) -> bool {
        self.bwd_dependency() == Some(BwdDependency::OwnServerGrad)
    }
}

impl SchemeKind {
    /// The policy object the round executor, comm and timing models run.
    pub fn plan(self) -> RoundPlan {
        match self {
            SchemeKind::SflGa => RoundPlan::Split {
                route: CotangentRoute::Broadcast,
                sync: ClientSync::SharedStep,
            },
            SchemeKind::SflGaDrift => RoundPlan::Split {
                route: CotangentRoute::Broadcast,
                sync: ClientSync::None,
            },
            SchemeKind::Sfl => RoundPlan::Split {
                route: CotangentRoute::Unicast,
                sync: ClientSync::FedAvg,
            },
            SchemeKind::Psl => RoundPlan::Split {
                route: CotangentRoute::Unicast,
                sync: ClientSync::None,
            },
            SchemeKind::Fl => RoundPlan::Full,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_encode_the_papers_scheme_table() {
        // SFL-GA = broadcast + shared step (eq 19), no FedAvg traffic.
        let ga = SchemeKind::SflGa.plan();
        assert_eq!(ga.route(), Some(CotangentRoute::Broadcast));
        assert!(!ga.pays_client_fedavg());
        // The drift ablation shares SFL-GA's communication pattern.
        assert_eq!(SchemeKind::SflGaDrift.plan().route(), ga.route());
        // SFL = unicast + the client FedAvg exchange SFL-GA removes.
        let sfl = SchemeKind::Sfl.plan();
        assert_eq!(sfl.route(), Some(CotangentRoute::Unicast));
        assert!(sfl.pays_client_fedavg());
        // PSL = unicast, no sync.
        assert_eq!(
            SchemeKind::Psl.plan(),
            RoundPlan::Split { route: CotangentRoute::Unicast, sync: ClientSync::None }
        );
        // FL never splits.
        assert_eq!(SchemeKind::Fl.plan().route(), None);
        assert!(!SchemeKind::Fl.plan().pays_client_fedavg());
    }

    #[test]
    fn bwd_dependency_encodes_the_pipeline_shape() {
        // Unicast schemes fuse client-bwd onto the per-participant chain;
        // broadcast schemes barrier on the eq-5 aggregation; FL has no
        // split phases.
        for s in [SchemeKind::Sfl, SchemeKind::Psl] {
            assert_eq!(s.plan().bwd_dependency(), Some(BwdDependency::OwnServerGrad));
            assert!(s.plan().fuses_client_bwd());
        }
        for s in [SchemeKind::SflGa, SchemeKind::SflGaDrift] {
            assert_eq!(s.plan().bwd_dependency(), Some(BwdDependency::BroadcastBarrier));
            assert!(!s.plan().fuses_client_bwd());
        }
        assert_eq!(SchemeKind::Fl.plan().bwd_dependency(), None);
        assert!(!SchemeKind::Fl.plan().fuses_client_bwd());
    }
}
