//! Coordinator checkpoint/resume (DESIGN.md §Transport): the round-entry
//! snapshot serialized to disk every K rounds so a SIGKILLed coordinator
//! can resume a run instead of losing it.
//!
//! The checkpoint IS the fault policy's round-entry snapshot plus the
//! bookkeeping the engine threads through rounds: global parameters as
//! raw LE f32 bits (reusing the wire codec, so checkpointed params
//! roundtrip bit-exactly), the round index, the seq counter, the dropped
//! set, the live id set, and the full per-round stats history.  Nothing
//! else is state: per-client derivations (channel gains, capacities,
//! batches) are pure functions of `(seed, id[, draw])`, so they replay
//! identically from the config — which is why a resumed run is bitwise
//! the uninterrupted run (`tests/chaos.rs` pins this across a real
//! SIGKILL).
//!
//! File format: an 8-byte magic, the payload over [`wire`]'s LE
//! primitives, and a trailing FNV-1a digest of the payload — a torn or
//! corrupted file (e.g. a crash mid-write, though [`Checkpoint::save`]
//! writes via tmp+rename to keep the published path atomic) fails the
//! digest check instead of resuming silently wrong.

use std::collections::BTreeMap;
use std::path::Path;

use crate::protocol::wire::{ByteReader, ByteWriter};
use crate::protocol::{decode_params, encode_params};
use crate::tensor::Params;

use super::net::{partition_str, Digest};
use super::trainer::{RoundStats, TrainConfig};

/// `b"SFLGACK1"` as a little-endian u64.
const MAGIC: u64 = u64::from_le_bytes(*b"SFLGACK1");

/// Client-side model state in checkpoint form — the serializable twin of
/// the engine's private representation.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientSideState {
    /// One shared logical client model (SFL-GA's eq 19, and FL).
    Shared(Params),
    /// Per-participant replicas, keyed by id (SFL / PSL / drift).
    PerClient(BTreeMap<u64, Params>),
}

/// A serialized round-entry snapshot; see the module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Fingerprint of the [`TrainConfig`] that produced this snapshot;
    /// resuming under a different config is refused (the derivation keys
    /// would not replay).
    pub fingerprint: u64,
    /// Rounds completed (the next round to run).
    pub round: u64,
    /// The engine's seq counter (monotone across the whole run, so
    /// post-resume requests can never collide with pre-kill stale ones).
    pub seq: u64,
    /// Participants removed by the fault policy, in drop order.
    pub dropped: Vec<u64>,
    /// Participants live at the snapshot, ascending — the resumed
    /// rendezvous expects exactly these to dial back in.
    pub live: Vec<u64>,
    pub client_side: ClientSideState,
    /// Server-side (split) parameter vector.
    pub ws: Params,
    /// Full-model (FL) parameter vector.
    pub w_full: Params,
    /// Per-round stats so far: a resumed run's COMPLETE history digests
    /// equal to the uninterrupted run's.
    pub stats: Vec<RoundStats>,
}

/// The config fields that shape training results — everything a resumed
/// process must agree on.  `num_clients` (unused by the networked
/// engine) and `threads` (bitwise-irrelevant by the determinism
/// guarantee) are deliberately excluded.
pub fn config_fingerprint(cfg: &TrainConfig) -> u64 {
    let mut d = Digest::new();
    d.bytes(cfg.dataset.as_bytes());
    d.bytes(cfg.model.as_bytes());
    d.bytes(cfg.scheme.name().as_bytes());
    d.bytes(&(cfg.rounds as u64).to_le_bytes());
    d.bytes(&(cfg.tau as u64).to_le_bytes());
    d.bytes(&cfg.lr.to_bits().to_le_bytes());
    d.bytes(&(cfg.samples_per_client as u64).to_le_bytes());
    d.bytes(&(cfg.test_samples as u64).to_le_bytes());
    d.bytes(&cfg.seed.to_le_bytes());
    d.bytes(&(cfg.eval_every as u64).to_le_bytes());
    d.bytes(partition_str(&cfg.scenario.partition).as_bytes());
    d.bytes(&[cfg.alloc as u8]);
    for x in [
        cfg.net.bandwidth,
        cfg.net.p_max,
        cfg.net.p_server,
        cfg.net.n0,
        cfg.net.d_min_km,
        cfg.net.d_max_km,
        cfg.comp.f_client_max,
        cfg.comp.f_client_spread,
        cfg.comp.f_server_total,
        cfg.comp.samples_per_round as f64,
        cfg.comp.bits_per_scalar,
    ] {
        d.f64(x);
    }
    d.bytes(&(cfg.comp.client_caps.len() as u64).to_le_bytes());
    for &c in &cfg.comp.client_caps {
        d.f64(c);
    }
    d.value()
}

fn encode_ids(w: &mut ByteWriter, ids: &[u64]) {
    w.u32(ids.len() as u32);
    for &id in ids {
        w.u64(id);
    }
}

fn decode_ids(r: &mut ByteReader) -> anyhow::Result<Vec<u64>> {
    let n = r.u32()? as usize;
    anyhow::ensure!(
        n * 8 <= r.remaining(),
        "implausible id count {n} for {} remaining bytes",
        r.remaining()
    );
    (0..n).map(|_| r.u64()).collect()
}

fn encode_stats(w: &mut ByteWriter, stats: &[RoundStats]) {
    w.u32(stats.len() as u32);
    for s in stats {
        w.u64(s.round as u64);
        w.u64(s.cut as u64);
        w.u64(s.participants as u64);
        w.f64(s.train_loss);
        w.f64(s.comm.uplink_bits);
        w.f64(s.comm.downlink_bits);
        w.f64(s.latency.uplink_leg);
        w.f64(s.latency.downlink_leg);
        match s.test {
            Some((l, a)) => {
                w.u8(1);
                w.f64(l);
                w.f64(a);
            }
            None => w.u8(0),
        }
    }
}

fn decode_stats(r: &mut ByteReader) -> anyhow::Result<Vec<RoundStats>> {
    let n = r.u32()? as usize;
    // Each record is at least 65 bytes; cheap bound against a corrupt
    // count allocating wild.
    anyhow::ensure!(
        n * 65 <= r.remaining() + 65,
        "implausible stats count {n} for {} remaining bytes",
        r.remaining()
    );
    (0..n)
        .map(|_| {
            let round = r.u64()? as usize;
            let cut = r.u64()? as usize;
            let participants = r.u64()? as usize;
            let train_loss = r.f64()?;
            let comm = crate::coordinator::RoundComm {
                uplink_bits: r.f64()?,
                downlink_bits: r.f64()?,
            };
            let latency = crate::coordinator::RoundLatency {
                uplink_leg: r.f64()?,
                downlink_leg: r.f64()?,
            };
            let test = match r.u8()? {
                0 => None,
                1 => Some((r.f64()?, r.f64()?)),
                other => anyhow::bail!("bad test-presence byte {other}"),
            };
            Ok(RoundStats { round, cut, participants, train_loss, comm, latency, test })
        })
        .collect()
}

const TAG_SHARED: u8 = 1;
const TAG_PER_CLIENT: u8 = 2;

impl Checkpoint {
    /// Serialize: magic + payload + FNV digest of the payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u64(self.fingerprint);
        w.u64(self.round);
        w.u64(self.seq);
        encode_ids(&mut w, &self.dropped);
        encode_ids(&mut w, &self.live);
        match &self.client_side {
            ClientSideState::Shared(p) => {
                w.u8(TAG_SHARED);
                encode_params(&mut w, p);
            }
            ClientSideState::PerClient(reps) => {
                w.u8(TAG_PER_CLIENT);
                w.u32(reps.len() as u32);
                for (id, p) in reps {
                    w.u64(*id);
                    encode_params(&mut w, p);
                }
            }
        }
        encode_params(&mut w, &self.ws);
        encode_params(&mut w, &self.w_full);
        encode_stats(&mut w, &self.stats);
        let payload = w.into_bytes();
        let digest = Digest::new().bytes(&payload).value();
        let mut out = Vec::with_capacity(payload.len() + 16);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&payload);
        out.extend_from_slice(&digest.to_le_bytes());
        out
    }

    /// Decode + integrity-check; never panics on corrupt input.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
        anyhow::ensure!(bytes.len() >= 16, "checkpoint too short ({} bytes)", bytes.len());
        let magic = u64::from_le_bytes(bytes[..8].try_into().expect("8 bytes"));
        anyhow::ensure!(magic == MAGIC, "not a checkpoint file (bad magic {magic:#x})");
        let payload = &bytes[8..bytes.len() - 8];
        let stored =
            u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().expect("8 bytes"));
        let actual = Digest::new().bytes(payload).value();
        anyhow::ensure!(
            stored == actual,
            "checkpoint digest mismatch (stored {stored:#x}, payload hashes to {actual:#x})"
        );
        let mut r = ByteReader::new(payload);
        let fingerprint = r.u64()?;
        let round = r.u64()?;
        let seq = r.u64()?;
        let dropped = decode_ids(&mut r)?;
        let live = decode_ids(&mut r)?;
        let client_side = match r.u8()? {
            TAG_SHARED => ClientSideState::Shared(decode_params(&mut r)?),
            TAG_PER_CLIENT => {
                let n = r.u32()? as usize;
                anyhow::ensure!(
                    n * 12 <= r.remaining() + 12,
                    "implausible replica count {n} for {} remaining bytes",
                    r.remaining()
                );
                let mut reps = BTreeMap::new();
                for _ in 0..n {
                    let id = r.u64()?;
                    reps.insert(id, decode_params(&mut r)?);
                }
                ClientSideState::PerClient(reps)
            }
            other => anyhow::bail!("bad client-side tag {other}"),
        };
        let ws = decode_params(&mut r)?;
        let w_full = decode_params(&mut r)?;
        let stats = decode_stats(&mut r)?;
        r.finish()?;
        Ok(Checkpoint {
            fingerprint,
            round,
            seq,
            dropped,
            live,
            client_side,
            ws,
            w_full,
            stats,
        })
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path`, so a crash mid-save leaves either the previous checkpoint
    /// or the new one — never a torn file at the published path.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.encode())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("publishing {}: {e}", path.display()))?;
        Ok(())
    }

    /// Read + decode a checkpoint file.
    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Checkpoint::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{RoundComm, RoundLatency};

    fn sample() -> Checkpoint {
        let params: Params = vec![vec![1.0, -0.5, 0.0], vec![f32::MIN_POSITIVE]];
        let mut reps = BTreeMap::new();
        reps.insert(0u64, params.clone());
        reps.insert(3u64, vec![vec![2.5f32]]);
        Checkpoint {
            fingerprint: config_fingerprint(&TrainConfig::default()),
            round: 4,
            seq: 99,
            dropped: vec![1, 2],
            live: vec![0, 3],
            client_side: ClientSideState::PerClient(reps),
            ws: params.clone(),
            w_full: params,
            stats: vec![
                RoundStats {
                    round: 1,
                    cut: 2,
                    participants: 3,
                    train_loss: 1.5,
                    comm: RoundComm { uplink_bits: 8.0, downlink_bits: 4.0 },
                    latency: RoundLatency { uplink_leg: 0.5, downlink_leg: 0.25 },
                    test: Some((1.25, 0.5)),
                },
                RoundStats {
                    round: 2,
                    cut: 2,
                    participants: 2,
                    train_loss: 1.25,
                    comm: RoundComm::default(),
                    latency: RoundLatency::default(),
                    test: None,
                },
            ],
        }
    }

    #[test]
    fn roundtrip_is_structural() {
        let ck = sample();
        assert_eq!(Checkpoint::decode(&ck.encode()).unwrap(), ck);
        let shared = Checkpoint {
            client_side: ClientSideState::Shared(vec![vec![0.25f32, -0.0]]),
            ..sample()
        };
        let back = Checkpoint::decode(&shared.encode()).unwrap();
        assert_eq!(back, shared);
        // ±0.0 survive as distinct bit patterns (params travel as bits).
        match back.client_side {
            ClientSideState::Shared(p) => assert_eq!(p[0][1].to_bits(), (-0.0f32).to_bits()),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn corruption_and_truncation_are_rejected() {
        let bytes = sample().encode();
        assert!(Checkpoint::decode(&[]).is_err());
        assert!(Checkpoint::decode(&bytes[..bytes.len() - 1]).is_err());
        for at in [0usize, 8, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x40;
            assert!(Checkpoint::decode(&bad).is_err(), "corruption at {at} accepted");
        }
    }

    #[test]
    fn fingerprint_tracks_training_relevant_config() {
        let base = TrainConfig::default();
        let f0 = config_fingerprint(&base);
        assert_eq!(f0, config_fingerprint(&base.clone()));
        let mut c = base.clone();
        c.seed ^= 1;
        assert_ne!(f0, config_fingerprint(&c));
        let mut c = base.clone();
        c.tau += 1;
        assert_ne!(f0, config_fingerprint(&c));
        // threads and num_clients are bitwise-irrelevant — excluded.
        let mut c = base.clone();
        c.threads = 7;
        c.num_clients = 123;
        assert_eq!(f0, config_fingerprint(&c));
    }

    #[test]
    fn save_is_atomic_and_loads_back() {
        let dir = std::env::temp_dir()
            .join(format!("sfl-ga-ckpt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        let ck = sample();
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        // Overwrite with a later snapshot; the tmp file is gone.
        let later = Checkpoint { round: 5, ..ck };
        later.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), later);
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
