//! The virtual client population (DESIGN.md §Population).
//!
//! [`Population`] is the ONE place the per-client seeded state of a run
//! lives — capacities, straggler assignment, aggregation weights, channel
//! distances/fading, cohort draws — as pure functions of
//! `(run_seed, client_id)` (plus a round or draw index for the
//! time-varying streams) instead of eagerly materialized vectors.  The
//! [`crate::coordinator::Trainer`] and the [`crate::ccc::Env`] both
//! derive from it, so the channel-seed and cohort-draw conventions pinned
//! by `tests/reproducibility.rs` cannot drift apart, and `reset ≡ fresh`
//! is structural (a reset rebuilds a value-identical `Population`).
//!
//! Derivation tree (every edge a [`mix2`]/[`mix3`] sub-seed, every leaf
//! an independent Pcg stream):
//!
//! ```text
//! run_seed
//! ├── (seed, client) ── 0xD157  distance → ḡ_i (path-loss avg gain)
//! │                 └── 0xF10C  capacity spread draw
//! ├── (seed, draw, client) ── 0xFADE  per-round Rayleigh |h|² ~ Exp(1)
//! ├── (seed, 0x57A6)  straggler rank permutation (rank < ⌈frac·N⌉)
//! └── (seed, 0x9AC7, round)  cohort rank permutation (rank < ⌈r·N⌉)
//! ```
//!
//! Because each leaf is keyed, deriving client 999_999's state never
//! touches clients 0..999_998 — resident memory is O(queried set), and
//! any interleaving of queries yields identical bits
//! (`tests/population.rs`).  The cohort/straggler memberships go through
//! a [`SeededPermutation`]: membership is an O(1) forward rank check with
//! the member COUNT exact (bijectivity), and a K-member cohort enumerates
//! in O(K log K) by inverting ranks 0..K and sorting — preserving the
//! fixed-ascending-client-index reduction order the bitwise determinism
//! contract requires (`tests/determinism.rs`).

use crate::latency::ComputeConfig;
use crate::scenario::ScenarioConfig;
use crate::util::perm::SeededPermutation;
use crate::util::rng::{mix2, mix3, Pcg};
use crate::wireless::{avg_gain, ChannelState, NetConfig};

/// Pcg stream tag for a client's distance (→ average channel gain).
const STREAM_DISTANCE: u64 = 0xD157;
/// Pcg stream tag for a (draw, client) Rayleigh fading realization.
const STREAM_FADING: u64 = 0xFADE;
/// Pcg stream tag for a client's capacity-spread draw.
const STREAM_CAPACITY: u64 = 0xF10C;
/// Sub-seed salt for the straggler rank permutation.
const SALT_STRAGGLER: u64 = 0x57A6;
/// Sub-seed salt for the per-round cohort rank permutations.
const SALT_COHORT: u64 = 0x9AC7;

/// Seeded generator of per-client state for an N-client federation; see
/// the module docs.  Cheap to construct and to clone — it holds O(1)
/// state regardless of N.
#[derive(Clone, Debug)]
pub struct Population {
    seed: u64,
    n: u64,
    scenario: ScenarioConfig,
    net: NetConfig,
    comp: ComputeConfig,
    /// Straggler rank permutation (`None` ⇔ no straggling configured).
    strag_perm: Option<SeededPermutation>,
    strag_count: u64,
}

impl Population {
    pub fn new(
        seed: u64,
        n: u64,
        scenario: ScenarioConfig,
        net: NetConfig,
        comp: ComputeConfig,
    ) -> anyhow::Result<Population> {
        anyhow::ensure!(n > 0, "population needs at least one client");
        scenario.validate()?;
        if !comp.client_caps.is_empty() {
            anyhow::ensure!(
                comp.client_caps.len() as u64 >= n,
                "client_caps has {} entries for {n} clients",
                comp.client_caps.len()
            );
        }
        let strag = &scenario.straggler;
        let (strag_perm, strag_count) = if strag.enabled() {
            let k = ((strag.frac * n as f64).ceil() as u64).clamp(1, n);
            (Some(SeededPermutation::new(n, mix2(seed, SALT_STRAGGLER))), k)
        } else {
            (None, 0)
        };
        Ok(Population { seed, n, scenario, net, comp, strag_perm, strag_count })
    }

    pub fn num_clients(&self) -> u64 {
        self.n
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn scenario(&self) -> &ScenarioConfig {
        &self.scenario
    }

    pub fn net(&self) -> &NetConfig {
        &self.net
    }

    pub fn comp(&self) -> &ComputeConfig {
        &self.comp
    }

    // ---------------------------------------------------------- cohorts

    /// Cohort size K = ⌈participation·N⌉, clamped to [1, N].
    pub fn cohort_size(&self) -> u64 {
        ((self.scenario.participation * self.n as f64).ceil() as u64).clamp(1, self.n)
    }

    /// The round's participant set: K distinct client indices, sorted
    /// ascending (the fixed reduction order).  Full participation returns
    /// `0..n`; otherwise ranks 0..K of the round-keyed permutation invert
    /// in O(K log K) — independent of N and of any other round's draw.
    pub fn cohort(&self, round: u64) -> Vec<usize> {
        if self.scenario.full_participation() {
            return (0..self.n as usize).collect();
        }
        let k = self.cohort_size();
        let perm = SeededPermutation::new(self.n, mix3(self.seed, SALT_COHORT, round));
        let mut cohort: Vec<usize> = (0..k).map(|p| perm.invert(p) as usize).collect();
        cohort.sort_unstable();
        cohort
    }

    // ---------------------------------------------------------- compute

    /// Whether client `i` is one of the ⌈frac·N⌉ stragglers (exact count
    /// by permutation-rank membership).
    pub fn is_straggler(&self, i: u64) -> bool {
        self.strag_perm.as_ref().is_some_and(|p| p.apply(i) < self.strag_count)
    }

    /// Client `i`'s compute capacity in FLOPS: an explicit
    /// `comp.client_caps` table wins (bounded-N deployments); otherwise
    /// the max/spread draw keyed per client — with the straggler slowdown
    /// folded in either way (fixed hardware, identical on every query).
    pub fn capacity(&self, i: u64) -> f64 {
        debug_assert!(i < self.n);
        let base = if !self.comp.client_caps.is_empty() {
            self.comp.client_caps[i as usize]
        } else if self.comp.f_client_spread <= 0.0 {
            self.comp.f_client_max
        } else {
            let mut rng = Pcg::new(mix2(self.seed, i), STREAM_CAPACITY);
            self.comp.f_client_max * rng.range(1.0 - self.comp.f_client_spread, 1.0)
        };
        if self.is_straggler(i) {
            base * (1.0 / self.scenario.straggler.factor)
        } else {
            base
        }
    }

    /// Capacities of a cohort, in the cohort's order.
    pub fn caps_for(&self, cohort: &[usize]) -> Vec<f64> {
        cohort.iter().map(|&i| self.capacity(i as u64)).collect()
    }

    /// The full capacity table (policy/diagnostic surface — O(N), only
    /// for bounded-N uses like the CCC feature vector).
    pub fn caps_dense(&self) -> Vec<f64> {
        (0..self.n).map(|i| self.capacity(i)).collect()
    }

    /// Aggregation weight ρ^i: every virtual client holds the same
    /// `samples_per_client`, so ρ is uniformly 1/N — no O(N) vector.
    pub fn weight(&self) -> f64 {
        1.0 / self.n as f64
    }

    // ---------------------------------------------------------- channel

    /// Client `i`'s average (large-scale) channel gain: path loss at its
    /// keyed uniform distance draw — fixed placement.
    pub fn avg_gain_of(&self, i: u64) -> f64 {
        debug_assert!(i < self.n);
        let mut rng = Pcg::new(mix2(self.seed, i), STREAM_DISTANCE);
        avg_gain(rng.range(self.net.d_min_km, self.net.d_max_km))
    }

    /// Instantaneous gain of client `i` at channel draw `draw`:
    /// g = ḡ_i · |h|², |h|² ~ Exp(1) keyed by `(seed, draw, client)` —
    /// block fading, redrawn per round, identical whether computed dense
    /// or for a single cohort member.
    pub fn gain_at(&self, draw: u64, i: u64) -> f64 {
        let mut rng = Pcg::new(mix3(self.seed, draw, i), STREAM_FADING);
        self.avg_gain_of(i) * rng.exponential(1.0)
    }

    /// Gains of a cohort at draw `draw`, in the cohort's order.
    pub fn gains_for(&self, draw: u64, cohort: &[usize]) -> Vec<f64> {
        cohort.iter().map(|&i| self.gain_at(draw, i as u64)).collect()
    }

    /// The full channel state at draw `draw` (policy surface — O(N), for
    /// bounded-N uses: cut-selection features, `Trainer::draw_channel`).
    pub fn gains_dense(&self, draw: u64) -> ChannelState {
        ChannelState { gains: (0..self.n).map(|i| self.gain_at(draw, i)).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::StragglerConfig;

    fn pop(n: u64, seed: u64, scenario: ScenarioConfig) -> Population {
        Population::new(seed, n, scenario, NetConfig::default(), ComputeConfig::default())
            .unwrap()
    }

    #[test]
    fn straggler_count_is_exact() {
        let scenario = ScenarioConfig {
            straggler: StragglerConfig { frac: 0.25, factor: 4.0 },
            ..Default::default()
        };
        let p = pop(100, 5, scenario);
        let stragglers = (0..100).filter(|&i| p.is_straggler(i)).count();
        assert_eq!(stragglers, 25, "⌈0.25·100⌉ must be exact, not statistical");
        for i in 0..100 {
            let want = if p.is_straggler(i) { 0.025e9 } else { 0.1e9 };
            assert_eq!(p.capacity(i), want);
        }
    }

    #[test]
    fn cohorts_are_sorted_distinct_and_keyed_by_round() {
        let scenario = ScenarioConfig { participation: 0.5, ..Default::default() };
        let p = pop(10, 3, scenario);
        let a = p.cohort(0);
        assert_eq!(a.len(), 5);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "not sorted/distinct: {a:?}");
        assert!(a.iter().all(|&i| i < 10));
        // Same round replays; rounds vary; another seed differs.
        assert_eq!(a, p.cohort(0));
        assert!((1..20).any(|r| p.cohort(r) != a), "cohort never varies across rounds");
        let q = pop(10, 4, ScenarioConfig { participation: 0.5, ..Default::default() });
        assert!((0..20).any(|r| p.cohort(r) != q.cohort(r)), "seed ignored");
    }

    #[test]
    fn full_participation_is_identity() {
        let p = pop(6, 9, ScenarioConfig::default());
        assert_eq!(p.cohort(0), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(p.cohort(7), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(p.cohort_size(), 6);
    }

    #[test]
    fn derivation_is_order_independent() {
        let scenario = ScenarioConfig {
            participation: 0.3,
            straggler: StragglerConfig { frac: 0.5, factor: 8.0 },
            ..Default::default()
        };
        let p = pop(1000, 21, scenario.clone());
        // Query a scattered subset first, then the dense table: bits match.
        let scattered: Vec<f64> =
            [999u64, 0, 500, 3].iter().map(|&i| p.capacity(i)).collect();
        let fresh = pop(1000, 21, scenario);
        let dense = fresh.caps_dense();
        assert_eq!(scattered, vec![dense[999], dense[0], dense[500], dense[3]]);
        let g_one = p.gain_at(4, 777);
        assert_eq!(g_one, fresh.gains_dense(4).gains[777]);
        assert_eq!(p.gains_for(4, &[777]), vec![g_one]);
    }

    #[test]
    fn channel_statistics_are_sane() {
        let p = pop(4, 7, ScenarioConfig::default());
        for i in 0..4 {
            let avg = p.avg_gain_of(i);
            assert!(avg > 0.0 && avg < 1e-9, "implausible path-loss gain {avg}");
            // Fading preserves the mean gain (Exp(1) has mean 1).
            let rounds = 20_000;
            let mean: f64 =
                (0..rounds).map(|d| p.gain_at(d, i)).sum::<f64>() / rounds as f64;
            assert!((mean / avg - 1.0).abs() < 0.05, "client {i}: mean {mean} avg {avg}");
        }
    }

    #[test]
    fn explicit_cap_table_wins_and_is_length_checked() {
        let comp =
            ComputeConfig { client_caps: vec![1.0, 2.0, 3.0], ..Default::default() };
        let p = Population::new(
            1,
            3,
            ScenarioConfig::default(),
            NetConfig::default(),
            comp.clone(),
        )
        .unwrap();
        assert_eq!(p.caps_dense(), vec![1.0, 2.0, 3.0]);
        assert!(
            Population::new(1, 5, ScenarioConfig::default(), NetConfig::default(), comp)
                .is_err(),
            "short cap table must be rejected"
        );
    }

    #[test]
    fn million_client_population_holds_o_cohort_state() {
        let scenario = ScenarioConfig { participation: 1e-4, ..Default::default() };
        let p = pop(1_000_000, 42, scenario);
        assert_eq!(p.cohort_size(), 100);
        let cohort = p.cohort(0);
        assert_eq!(cohort.len(), 100);
        assert!(cohort.windows(2).all(|w| w[0] < w[1]));
        // Deriving the cohort's full state touches 100 clients, not 1M.
        assert_eq!(p.caps_for(&cohort).len(), 100);
        assert_eq!(p.gains_for(0, &cohort).len(), 100);
        assert!((p.weight() - 1e-6).abs() < 1e-18);
    }
}
