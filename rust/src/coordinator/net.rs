//! The networked coordinator: [`Trainer`](super::Trainer)'s round
//! semantics fanned out over a [`Transport`] instead of in-process
//! closures, plus the fault policy the paper's ρ-weighting implies
//! (DESIGN.md §Transport).
//!
//! [`NetTrainer`] owns EVERY piece of model state and every reduction —
//! participants are stateless compute peers (`runtime::node`).  Each
//! split-round epoch is the same five phases as the in-process engine:
//! fwd fan-out ([`Msg::FwdReq`] shipping the client-side weights), the
//! coordinator-side server FP+BP (eqs 2–4) over the returned smashed
//! batches, cotangent routing ([`Msg::BwdReq`] — ONE aggregated
//! broadcast under eq 5 or per-client unicast), the client-VJP
//! collection, and the fixed-ascending-order weighted reductions.  FL
//! rides [`Msg::FullReq`] (τ local steps participant-side).  Because
//! responses are slotted by participant id and every reduction runs in
//! ascending id order over the buffered results, arrival order — and
//! hence transport choice, thread count, or any delay below the deadline
//! — never changes a bit of the result: a loopback run, a TCP run and an
//! in-process [`Trainer`](super::Trainer) run of the same config agree
//! bitwise (`tests/net_equivalence.rs`).
//!
//! **Fault policy** (chaos-tested in `tests/chaos.rs`): each collection
//! phase has a deadline.  A participant that misses it — or whose
//! connection drops — is removed from the federation, the round
//! *restarts from its entry snapshot* over the survivors, and the
//! aggregation weights renormalize to 1/|survivors| (ρ is uniform, eq 7).
//! Restarting rather than patching the half-collected round is what
//! makes the policy exact: a run that loses client c during round r is
//! bitwise the run that excluded c before round r began.  A round
//! consumes one channel draw keyed by its index, so a restart replays
//! the same fading state.  When every participant is gone the run fails
//! cleanly (no panic, no hang).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::init::{init_params, join_params};
use crate::data::partition::Partition;
use crate::data::{generate, Dataset};
use crate::model::Manifest;
use crate::protocol::{Msg, RunSetup};
use crate::runtime::transport::{Incoming, Transport};
use crate::runtime::{LoopbackTransport, ModelRuntime, ParallelExecutor, Tensor};
use crate::scenario::{ChurnEvent, ChurnTrace};
use crate::tensor::{self, Params};
use crate::wireless::ChannelState;
use crate::{info, warn_log};

use super::checkpoint::{config_fingerprint, Checkpoint, ClientSideState};
use super::comm::round_comm;
use super::plan::{ClientSync, CotangentRoute, RoundPlan};
use super::population::Population;
use super::timing::round_latency;
use super::trainer::{RoundStats, TrainConfig};
use super::SchemeKind;

/// Client-side model state, coordinator-held (participants are
/// stateless).  Mirrors the in-process trainer's representation with
/// replicas keyed by participant id, so dropping a client drops its
/// replica — the "excluded up front" equality needs exactly that.
#[derive(Clone)]
enum NetClientSide {
    /// One shared logical client model (SFL-GA's eq 19, and FL).
    Shared(Params),
    /// Per-participant replicas (SFL / PSL / the drift ablation).
    PerClient(BTreeMap<u64, Params>),
}

impl NetClientSide {
    /// Checkpoint form (the engine's representation stays private).
    fn to_state(&self) -> ClientSideState {
        match self {
            NetClientSide::Shared(p) => ClientSideState::Shared(p.clone()),
            NetClientSide::PerClient(reps) => ClientSideState::PerClient(reps.clone()),
        }
    }

    fn from_state(s: &ClientSideState) -> NetClientSide {
        match s {
            ClientSideState::Shared(p) => NetClientSide::Shared(p.clone()),
            ClientSideState::PerClient(reps) => NetClientSide::PerClient(reps.clone()),
        }
    }
}

/// A collection phase's outcome: every expected response (slotted in
/// cohort order), or the peers to drop.
enum Phase {
    Complete(Vec<Msg>),
    Fault { dead: Vec<u64>, reason: String },
}

/// The networked round engine; see the module docs.
pub struct NetTrainer<T: Transport> {
    pub cfg: TrainConfig,
    /// Per-phase collection deadline (timeout ⇒ drop ⇒ renormalize).
    deadline: Duration,
    transport: T,
    rt: ModelRuntime,
    pool: ParallelExecutor,
    pop: Population,
    test: Dataset,
    client_side: NetClientSide,
    ws: Params,
    w_full: Params,
    /// The run's initial parameter vector `init_params(spec, seed^0x1417)`
    /// — also every participant's COLD client-side state, so a rejoiner
    /// (or brand-new joiner) gets exactly the replica it would have held
    /// had it been present from round 0 and never stepped.
    w_init: Params,
    round: usize,
    seq: u64,
    /// Participants dropped by the fault policy (or departed via churn),
    /// in drop order.
    dropped: Vec<u64>,
    /// Per-round stats so far — the checkpointable run history; `run`
    /// returns a clone of the COMPLETE history so a resumed run digests
    /// identically to an uninterrupted one.
    stats: Vec<RoundStats>,
    /// Quorum floor: below `min_clients` live peers the engine pauses
    /// (bounded by `quorum_wait`) for rejoins instead of renormalizing
    /// toward an empty cohort.  Defaults: floor 1, zero wait — which
    /// makes "everyone dropped" an immediate clean error.
    min_clients: usize,
    quorum_wait: Duration,
    /// Checkpoint sink: every `ckpt_every` completed rounds (and at the
    /// final round) the round-entry snapshot is saved to `ckpt_path`.
    ckpt_path: Option<PathBuf>,
    ckpt_every: usize,
}

impl NetTrainer<LoopbackTransport> {
    /// In-process federation of `n` loopback participants with ids
    /// `0..n` — the transport-layer twin of an `n`-client
    /// [`Trainer`](super::Trainer).
    pub fn loopback(
        manifest: &Manifest,
        cfg: TrainConfig,
        n: usize,
    ) -> anyhow::Result<NetTrainer<LoopbackTransport>> {
        let ids: Vec<u64> = (0..n as u64).collect();
        let transport = LoopbackTransport::new(&ids, cfg.threads)?;
        NetTrainer::new(manifest, cfg, Duration::from_secs(60), transport)
    }

    /// Drive a full run under a scripted [`ChurnTrace`] — the **oracle**
    /// the chaos wall compares real kill/relaunch TCP runs against.
    /// Events fire at round-entry time in trace order: a `Leave` departs
    /// the peer, a `Join` admits a FRESH unconfigured participant (so
    /// `Leave(i), Join(i)` in one round is a same-round cold rejoin and
    /// `Join(i), Leave(i)` is join-then-immediately-die, which nets out
    /// to never having joined).  Returns the complete stats history.
    pub fn run_churn(
        &mut self,
        cut: usize,
        trace: &ChurnTrace,
    ) -> anyhow::Result<Vec<RoundStats>> {
        while self.round < self.cfg.rounds {
            for ev in trace.events_at(self.round as u64) {
                match ev {
                    ChurnEvent::Join(id) => {
                        self.transport.schedule_admit(id);
                        self.admit_new()?;
                    }
                    ChurnEvent::Leave(id) => self.depart(id),
                }
            }
            if self.step(cut)?.is_none() {
                break;
            }
        }
        Ok(self.stats.clone())
    }
}

impl<T: Transport> NetTrainer<T> {
    /// Coordinator over an already-joined transport.  Sends every
    /// participant its [`Msg::Welcome`] configuration.
    pub fn new(
        manifest: &Manifest,
        cfg: TrainConfig,
        deadline: Duration,
        mut transport: T,
    ) -> anyhow::Result<NetTrainer<T>> {
        anyhow::ensure!(cfg.rounds > 0 && cfg.tau > 0, "rounds and tau must be positive");
        anyhow::ensure!(deadline > Duration::ZERO, "deadline must be positive");
        anyhow::ensure!(cfg.eval_every > 0, "eval_every must be positive");
        anyhow::ensure!(cfg.test_samples > 0, "test_samples must be positive");
        anyhow::ensure!(cfg.samples_per_client > 0, "samples_per_client must be positive");
        cfg.scenario.validate()?;
        // The networked cohort IS the live participant set: the scenario
        // engine's virtual sampling and straggler profiles stay with the
        // in-process simulator (real stragglers are the chaos harness's
        // job here).
        anyhow::ensure!(
            cfg.scenario.full_participation() && !cfg.scenario.straggler.enabled(),
            "the networked runtime runs full participation over joined clients; \
             partial participation / simulated stragglers are in-process features"
        );
        let ids = transport.clients();
        anyhow::ensure!(!ids.is_empty(), "no participants joined the federation");

        let rt = ModelRuntime::native(manifest, &cfg.dataset)?;
        let spec = rt.spec().clone();
        anyhow::ensure!(
            rt.dynamic_batch() || cfg.test_samples % spec.eval_batch == 0,
            "test_samples must be a multiple of the eval batch {}",
            spec.eval_batch
        );
        // Per-client state (gains, capacities) is keyed by (seed, id), so
        // the population only needs to span the joined id range.
        let n_pop = ids.iter().copied().max().unwrap_or(0) + 1;
        let pop = Population::new(
            cfg.seed,
            n_pop,
            cfg.scenario.clone(),
            cfg.net.clone(),
            cfg.comp.clone(),
        )?;
        let test = generate(&spec, &cfg.dataset, cfg.test_samples, cfg.seed ^ 0x7E57);
        let params = init_params(&spec, cfg.seed ^ 0x1417);
        let shared = match cfg.scheme.plan() {
            RoundPlan::Full => true,
            RoundPlan::Split { sync, .. } => sync == ClientSync::SharedStep,
        };
        let client_side = if shared {
            NetClientSide::Shared(params.clone())
        } else {
            NetClientSide::PerClient(ids.iter().map(|&id| (id, params.clone())).collect())
        };
        let pool = ParallelExecutor::new(cfg.threads);
        let eval_jobs = cfg.test_samples.div_ceil(spec.eval_batch).max(1);
        rt.set_eval_parallelism((pool.threads() / eval_jobs).max(1));

        let setup = RunSetup {
            dataset: cfg.dataset.clone(),
            seed: cfg.seed,
            partition: partition_str(&cfg.scenario.partition),
            samples_per_client: cfg.samples_per_client,
            model: cfg.model.clone(),
            num_cuts: spec.num_cuts() as u32,
        };
        // Writes must respect the same deadline as collections: a peer
        // that stops reading would otherwise block `send` forever and
        // the fault policy could never fire.
        transport.set_io_deadline(deadline);
        for &id in &ids {
            transport.send(id, &Msg::Welcome { setup: setup.clone() });
        }
        Ok(NetTrainer {
            cfg,
            deadline,
            transport,
            rt,
            pool,
            pop,
            test,
            client_side,
            ws: params.clone(),
            w_full: params.clone(),
            w_init: params,
            round: 0,
            seq: 0,
            dropped: Vec::new(),
            stats: Vec::new(),
            min_clients: 1,
            quorum_wait: Duration::ZERO,
            ckpt_path: None,
            ckpt_every: 0,
        })
    }

    /// Resume from a checkpoint: the same constructor path, then the
    /// serialized round-entry snapshot replaces the fresh state.  The
    /// config must fingerprint-match the checkpointing run and the
    /// transport's joined set must be exactly the snapshot's live set —
    /// anything else could not replay the uninterrupted run bitwise.
    pub fn resume(
        manifest: &Manifest,
        cfg: TrainConfig,
        deadline: Duration,
        transport: T,
        ckpt: &Checkpoint,
    ) -> anyhow::Result<NetTrainer<T>> {
        anyhow::ensure!(
            ckpt.fingerprint == config_fingerprint(&cfg),
            "checkpoint was written under a different training config \
             (fingerprint {:#x}, this config {:#x})",
            ckpt.fingerprint,
            config_fingerprint(&cfg)
        );
        let mut nt = NetTrainer::new(manifest, cfg, deadline, transport)?;
        anyhow::ensure!(
            nt.transport.clients() == ckpt.live,
            "resume requires the checkpoint's live participants {:?} to rejoin, got {:?}",
            ckpt.live,
            nt.transport.clients()
        );
        nt.round = ckpt.round as usize;
        nt.seq = ckpt.seq;
        nt.dropped = ckpt.dropped.clone();
        nt.client_side = NetClientSide::from_state(&ckpt.client_side);
        nt.ws = ckpt.ws.clone();
        nt.w_full = ckpt.w_full.clone();
        nt.stats = ckpt.stats.clone();
        Ok(nt)
    }

    /// Set the quorum floor and how long a below-floor round pauses for
    /// rejoins before erroring out.
    pub fn with_quorum(mut self, min_clients: usize, wait: Duration) -> Self {
        self.min_clients = min_clients;
        self.quorum_wait = wait;
        self
    }

    /// Checkpoint the round-entry snapshot to `path` every `every`
    /// completed rounds (and at the final round).
    pub fn with_checkpoint(mut self, path: PathBuf, every: usize) -> Self {
        self.ckpt_path = Some(path);
        self.ckpt_every = every.max(1);
        self
    }

    /// Live participant ids, ascending.
    pub fn live(&self) -> Vec<u64> {
        self.transport.clients()
    }

    /// Participants removed by the fault policy so far, in drop order.
    pub fn dropped(&self) -> &[u64] {
        &self.dropped
    }

    pub fn round_index(&self) -> usize {
        self.round
    }

    /// Per-round stats completed so far (includes any checkpoint-restored
    /// history).
    pub fn stats(&self) -> &[RoundStats] {
        &self.stats
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// The [`RunSetup`] every participant (initial or rejoining) is
    /// configured with.
    fn run_setup(&self) -> RunSetup {
        RunSetup {
            dataset: self.cfg.dataset.clone(),
            seed: self.cfg.seed,
            partition: partition_str(&self.cfg.scenario.partition),
            samples_per_client: self.cfg.samples_per_client,
            model: self.cfg.model.clone(),
            num_cuts: self.rt.spec().num_cuts() as u32,
        }
    }

    /// Run the full fixed-cut training; mirrors
    /// [`Trainer::run`](super::Trainer::run) stats-for-stats (evaluation
    /// is synchronous here — the in-process engine's deferred eval is
    /// documented bitwise-equal to it).  Returns the COMPLETE history —
    /// on a resumed run that includes the checkpoint-restored rounds, so
    /// digesting the return value compares whole runs.
    pub fn run(&mut self, cut: usize) -> anyhow::Result<Vec<RoundStats>> {
        while self.step(cut)?.is_some() {}
        Ok(self.stats.clone())
    }

    /// Advance the run by one round: admit any peers dialing in at the
    /// round boundary (each configured by [`Msg::Sync`]), run the
    /// fault-tolerant round, evaluate if due, record the stats, and
    /// checkpoint if due.  Returns `None` once all rounds are done;
    /// otherwise the round's stats and whether a checkpoint was written.
    pub fn step(&mut self, cut: usize) -> anyhow::Result<Option<(RoundStats, bool)>> {
        if self.round >= self.cfg.rounds {
            return Ok(None);
        }
        self.admit_new()?;
        let mut stats = self.run_round(cut)?;
        if self.round % self.cfg.eval_every == 0 || self.round == self.cfg.rounds {
            stats.test = Some(self.evaluate(cut)?);
        }
        self.stats.push(stats);
        let saved = self.maybe_checkpoint()?;
        Ok(Some((stats, saved)))
    }

    /// Poll the transport for mid-run joiners and configure each with a
    /// [`Msg::Sync`] (+ a cold replica where the scheme keeps per-client
    /// state).  Round-boundary only — admission timing inside a round
    /// would be nondeterministic.
    fn admit_new(&mut self) -> anyhow::Result<Vec<u64>> {
        let admitted = self.transport.accept_new();
        for &id in &admitted {
            self.sync_peer(id)?;
        }
        Ok(admitted)
    }

    /// Configure a just-admitted peer: grow the population span if the id
    /// is brand-new (per-id derivations are pure in `(seed, id)`, so
    /// regrowing changes nothing for existing ids), ship the
    /// [`Msg::Sync`], and install the cold replica.
    fn sync_peer(&mut self, id: u64) -> anyhow::Result<()> {
        if id >= self.pop.num_clients() {
            self.pop = Population::new(
                self.cfg.seed,
                id + 1,
                self.cfg.scenario.clone(),
                self.cfg.net.clone(),
                self.cfg.comp.clone(),
            )?;
        }
        let setup = self.run_setup();
        self.transport.send(id, &Msg::Sync { round: self.round as u64, setup });
        if let NetClientSide::PerClient(reps) = &mut self.client_side {
            if !reps.contains_key(&id) {
                reps.insert(id, self.w_init.clone());
            }
        }
        Ok(())
    }

    /// Remove `id` from the federation at a round boundary (the churn
    /// trace's departure event — process killed, link severed).  No-op
    /// for a peer that is not live.
    pub fn depart(&mut self, id: u64) {
        if !self.transport.clients().contains(&id) {
            return;
        }
        self.transport.drop_client(id);
        self.dropped.push(id);
        if let NetClientSide::PerClient(reps) = &mut self.client_side {
            reps.remove(&id);
        }
    }

    /// Block (bounded by `quorum_wait`) until at least
    /// `max(min_clients, 1)` peers are live, admitting rejoiners as they
    /// dial in.  Mid-round admissions also install the cold replica into
    /// the round-entry `snapshot`: cold state is deterministic, so this
    /// equals the rejoiner having been live-and-cold at round entry —
    /// which is exactly what the churn-trace oracle computes.
    fn await_quorum(
        &mut self,
        snapshot: &mut (NetClientSide, Params, Params),
    ) -> anyhow::Result<()> {
        let floor = self.min_clients.max(1);
        let t_end = Instant::now() + self.quorum_wait;
        loop {
            let admitted = self.admit_new()?;
            for &id in &admitted {
                if let NetClientSide::PerClient(reps) = &mut snapshot.0 {
                    if !reps.contains_key(&id) {
                        reps.insert(id, self.w_init.clone());
                    }
                }
            }
            let live = self.transport.clients().len();
            if live >= floor {
                return Ok(());
            }
            if Instant::now() >= t_end {
                anyhow::bail!(
                    "round {}: federation below quorum ({live} live < {floor} required) \
                     after waiting {:?} (dropped in order: {:?})",
                    self.round,
                    self.quorum_wait,
                    self.dropped
                );
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Serialize the current round-entry snapshot.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            fingerprint: config_fingerprint(&self.cfg),
            round: self.round as u64,
            seq: self.seq,
            dropped: self.dropped.clone(),
            live: self.transport.clients(),
            client_side: self.client_side.to_state(),
            ws: self.ws.clone(),
            w_full: self.w_full.clone(),
            stats: self.stats.clone(),
        }
    }

    /// Save a checkpoint if one is due (every `ckpt_every` rounds, plus
    /// the final round); returns whether a file was written.
    fn maybe_checkpoint(&mut self) -> anyhow::Result<bool> {
        let Some(path) = self.ckpt_path.clone() else { return Ok(false) };
        let due =
            self.round % self.ckpt_every == 0 || self.round == self.cfg.rounds;
        if !due {
            return Ok(false);
        }
        self.checkpoint().save(&path)?;
        Ok(true)
    }

    /// One fault-tolerant round at cut `v`: execute over the live set;
    /// on a drop, restore the entry snapshot, renormalize to the
    /// survivors and restart (same channel draw — see the module docs).
    pub fn run_round(&mut self, cut: usize) -> anyhow::Result<RoundStats> {
        self.rt.spec().menu().validate(cut)?;
        let mut snapshot = (self.client_side.clone(), self.ws.clone(), self.w_full.clone());
        let draw = self.round as u64;
        loop {
            if self.transport.clients().len() < self.min_clients.max(1) {
                // Quorum degradation: pause (bounded) for rejoins instead
                // of renormalizing toward an empty cohort; a clean error
                // with the drop history if the wait expires.
                self.await_quorum(&mut snapshot)?;
            }
            let ids = self.transport.clients();
            let k = ids.len();
            // ρ is uniform, so the cohort weights renormalize to 1/K over
            // whoever is still standing.
            let weights = vec![1.0 / k as f64; k];
            let attempt = match self.cfg.scheme.plan() {
                RoundPlan::Split { route, sync } => {
                    self.round_split(cut, route, sync, &ids, &weights)?
                }
                RoundPlan::Full => self.round_full(&ids, &weights)?,
            };
            match attempt {
                Ok(train_loss) => {
                    let stats = self.finish_round(cut, draw, &ids, train_loss);
                    for &id in &ids {
                        self.transport.send(id, &Msg::RoundDone { round: stats.round as u64 });
                    }
                    return Ok(stats);
                }
                Err((dead, reason)) => {
                    warn_log!(
                        "round {}: dropping {dead:?} ({reason}); restarting over survivors",
                        self.round
                    );
                    let (cs, ws, wf) = snapshot.clone();
                    self.client_side = cs;
                    self.ws = ws;
                    self.w_full = wf;
                    for &id in &dead {
                        self.transport.drop_client(id);
                        self.dropped.push(id);
                        if let NetClientSide::PerClient(reps) = &mut self.client_side {
                            reps.remove(&id);
                        }
                        // Scrub the snapshot as well: if this peer later
                        // rejoins mid-round (quorum wait), it must come
                        // back COLD — a second fault restoring the entry
                        // snapshot must not resurrect its old replica.
                        if let NetClientSide::PerClient(reps) = &mut snapshot.0 {
                            reps.remove(&id);
                        }
                    }
                }
            }
        }
    }

    /// Account the completed round (comm + latency over exactly the
    /// cohort, as the in-process engine does) and advance the clock.
    fn finish_round(&mut self, cut: usize, draw: u64, ids: &[u64], train_loss: f64) -> RoundStats {
        let k = ids.len();
        let cohort: Vec<usize> = ids.iter().map(|&id| id as usize).collect();
        let state_round = ChannelState { gains: self.pop.gains_for(draw, &cohort) };
        let mut comp_round = self.cfg.comp.clone();
        comp_round.client_caps = self.pop.caps_for(&cohort);
        let spec = self.rt.spec().clone();
        let cut_spec = spec.cut(cut);
        let comm = round_comm(self.cfg.scheme, &spec, cut_spec, &comp_round, k, self.cfg.tau);
        let latency = round_latency(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &self.cfg.net,
            &comp_round,
            &state_round,
            self.cfg.alloc,
            self.cfg.tau,
        );
        self.round += 1;
        RoundStats {
            round: self.round,
            cut,
            participants: k,
            train_loss,
            comm,
            latency,
            test: None,
        }
    }

    /// One split-round attempt over `ids`; `Ok(Err(..))` names the peers
    /// to drop.  The math is phase-for-phase the in-process engine's
    /// `round_split`, with the client kernels remote.
    #[allow(clippy::type_complexity)]
    fn round_split(
        &mut self,
        cut: usize,
        route: CotangentRoute,
        sync: ClientSync,
        ids: &[u64],
        weights: &[f64],
    ) -> anyhow::Result<Result<f64, (Vec<u64>, String)>> {
        let nc = self.rt.spec().cut(cut).client_params;
        let k = ids.len();
        let lr = self.cfg.lr;
        let tau = self.cfg.tau;
        let base_step = self.round * tau;
        let mut g_ws_acc = tensor::zeros_like(&self.ws[nc..]);
        let mut g_c_acc = match &self.client_side {
            NetClientSide::Shared(w) => tensor::zeros_like(&w[..nc]),
            NetClientSide::PerClient(_) => Params::new(),
        };
        let mut mean_loss = 0.0;
        for epoch in 0..tau {
            let step = (base_step + epoch) as u64;
            // Phase 1 — client-fwd fan-out (eq 1): ship each participant
            // its current client-side weights and the batch key.
            let mut seq2slot = BTreeMap::new();
            let mut seqs = Vec::with_capacity(k);
            for (j, &id) in ids.iter().enumerate() {
                let wc = match &self.client_side {
                    NetClientSide::Shared(w) => w[..nc].to_vec(),
                    NetClientSide::PerClient(reps) => reps[&id][..nc].to_vec(),
                };
                let seq = self.next_seq();
                seq2slot.insert(seq, j);
                seqs.push(seq);
                self.transport.send(id, &Msg::FwdReq { seq, cut: cut as u32, step, wc });
            }
            let fwds = match self.collect(&seq2slot, ids) {
                Phase::Complete(msgs) => msgs,
                Phase::Fault { dead, reason } => return Ok(Err((dead, reason))),
            };
            let mut smashed = Vec::with_capacity(k);
            let mut labels = Vec::with_capacity(k);
            for (j, msg) in fwds.into_iter().enumerate() {
                match msg {
                    Msg::FwdOk { smashed: s, labels: y, .. } => {
                        smashed.push(s);
                        labels.push(y);
                    }
                    // A wrong-typed reply is that peer's protocol
                    // violation, not the federation's: fault it.
                    other => {
                        return Ok(Err((
                            vec![ids[j]],
                            format!("expected fwd-ok, got {}", other.name()),
                        )))
                    }
                }
            }
            // Phase 2 — server FP+BP (eqs 2–4) on the coordinator's own
            // pool, results in ascending cohort order.
            let rt = &self.rt;
            let ws_srv = &self.ws[nc..];
            let smashed_ref = &smashed;
            let labels_ref = &labels;
            let servers: Vec<(f32, Params, Tensor)> = self.pool.map_with_scratch(k, |scratch, j| {
                rt.server_grad_with(scratch, cut, ws_srv, &smashed_ref[j], &labels_ref[j])
            })?;
            // Phase 2b — the ρ-weighted server reduction (eq 7), fixed
            // ascending order.
            tensor::zero(&mut g_ws_acc);
            let mut loss_acc = 0.0;
            for (j, (loss, g_ws, _)) in servers.iter().enumerate() {
                loss_acc += weights[j] * *loss as f64;
                tensor::weighted_accumulate(&mut g_ws_acc, g_ws, weights[j]);
            }
            // Phase 3 — cotangent routing: eq-5 aggregated broadcast
            // (ONE tensor for everyone) or per-client unicast.
            let mut seq2slot_bwd = BTreeMap::new();
            match route {
                CotangentRoute::Broadcast => {
                    let mut agg = Tensor::zeros(&servers[0].2.shape);
                    for (j, (_, _, g_s)) in servers.iter().enumerate() {
                        tensor::weighted_accumulate_flat(&mut agg.data, &g_s.data, weights[j]);
                    }
                    for (j, &id) in ids.iter().enumerate() {
                        seq2slot_bwd.insert(seqs[j], j);
                        self.transport
                            .send(id, &Msg::BwdReq { seq: seqs[j], cotangent: agg.clone() });
                    }
                }
                CotangentRoute::Unicast => {
                    for (j, &id) in ids.iter().enumerate() {
                        seq2slot_bwd.insert(seqs[j], j);
                        self.transport.send(
                            id,
                            &Msg::BwdReq { seq: seqs[j], cotangent: servers[j].2.clone() },
                        );
                    }
                }
            }
            // Phase 4 — client-bwd collection (eq 6).
            let bwds = match self.collect(&seq2slot_bwd, ids) {
                Phase::Complete(msgs) => msgs,
                Phase::Fault { dead, reason } => return Ok(Err((dead, reason))),
            };
            let mut g_c_parts = Vec::with_capacity(k);
            for (j, msg) in bwds.into_iter().enumerate() {
                match msg {
                    Msg::BwdOk { grad, .. } => g_c_parts.push(grad),
                    other => {
                        return Ok(Err((
                            vec![ids[j]],
                            format!("expected bwd-ok, got {}", other.name()),
                        )))
                    }
                }
            }
            // Apply this epoch's updates on the coordinator: server step
            // on the aggregated gradient, then the scheme's client step.
            tensor::sgd_step(&mut self.ws[nc..], &g_ws_acc, lr);
            match &mut self.client_side {
                NetClientSide::Shared(w) => {
                    tensor::zero(&mut g_c_acc);
                    for (j, g_c) in g_c_parts.iter().enumerate() {
                        tensor::weighted_accumulate(&mut g_c_acc, g_c, weights[j]);
                    }
                    tensor::sgd_step(&mut w[..nc], &g_c_acc, lr);
                }
                NetClientSide::PerClient(reps) => {
                    for (j, g_c) in g_c_parts.iter().enumerate() {
                        let rep = reps.get_mut(&ids[j]).expect("live participant has a replica");
                        tensor::sgd_step(&mut rep[..nc], g_c, lr);
                    }
                }
            }
            mean_loss += loss_acc / tau as f64;
        }
        // Phase 5 — client-side FedAvg (SFL only): aggregate the cohort's
        // replicas and write the average back.
        if sync == ClientSync::FedAvg {
            if let NetClientSide::PerClient(reps) = &mut self.client_side {
                let mut agg = tensor::zeros_like(&reps[&ids[0]][..nc]);
                for (j, id) in ids.iter().enumerate() {
                    tensor::weighted_accumulate(&mut agg, &reps[id][..nc], weights[j]);
                }
                for id in ids {
                    let rep = reps.get_mut(id).expect("live participant has a replica");
                    for (dst, src) in rep[..nc].iter_mut().zip(&agg) {
                        dst.copy_from_slice(src);
                    }
                }
            }
        }
        Ok(Ok(mean_loss))
    }

    /// One FL-round attempt: τ local steps participant-side, weighted
    /// model aggregation coordinator-side (ascending order).
    #[allow(clippy::type_complexity)]
    fn round_full(
        &mut self,
        ids: &[u64],
        weights: &[f64],
    ) -> anyhow::Result<Result<f64, (Vec<u64>, String)>> {
        let k = ids.len();
        let base_step = (self.round * self.cfg.tau) as u64;
        let mut seq2slot = BTreeMap::new();
        for (j, &id) in ids.iter().enumerate() {
            let seq = self.next_seq();
            seq2slot.insert(seq, j);
            let req = Msg::FullReq {
                seq,
                step0: base_step,
                tau: self.cfg.tau as u32,
                lr: self.cfg.lr,
                w: self.w_full.clone(),
            };
            self.transport.send(id, &req);
        }
        let fulls = match self.collect(&seq2slot, ids) {
            Phase::Complete(msgs) => msgs,
            Phase::Fault { dead, reason } => return Ok(Err((dead, reason))),
        };
        let mut agg = tensor::zeros_like(&self.w_full);
        let mut loss_acc = 0.0;
        for (j, msg) in fulls.iter().enumerate() {
            match msg {
                Msg::FullOk { loss, w, .. } => {
                    loss_acc += weights[j] * *loss;
                    tensor::weighted_accumulate(&mut agg, w, weights[j]);
                }
                other => {
                    return Ok(Err((
                        vec![ids[j]],
                        format!("expected full-ok, got {}", other.name()),
                    )))
                }
            }
        }
        self.w_full = agg;
        Ok(Ok(loss_acc))
    }

    /// Await one response per expected `seq` (any arrival order; results
    /// slotted into cohort order), up to the phase deadline.  Stale seqs
    /// from an aborted attempt are ignored; a gone peer or the deadline
    /// yields the drop set.
    fn collect(&mut self, seq2slot: &BTreeMap<u64, usize>, ids: &[u64]) -> Phase {
        let k = ids.len();
        let mut slots: Vec<Option<Msg>> = vec![None; k];
        let mut got = 0usize;
        let t_end = Instant::now() + self.deadline;
        while got < k {
            let left = t_end.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Phase::Fault {
                    dead: missing_ids(&slots, ids),
                    reason: format!("deadline {:?} exceeded", self.deadline),
                };
            }
            match self.transport.recv(left) {
                // Events from outside the cohort are stale: dropping a
                // TCP peer shuts its socket, which wakes its reader
                // thread and queues one last Gone for an id the fault
                // policy already removed — acting on it would re-fault
                // the restarted attempt and double-count the drop.
                Some((id, ev)) if !ids.contains(&id) => {
                    let what = match &ev {
                        Incoming::Msg(m) => m.name(),
                        Incoming::Gone(_) => "gone",
                    };
                    info!("ignoring stale {what} from dropped {id}");
                }
                Some((id, Incoming::Msg(msg))) => {
                    let seq = match &msg {
                        Msg::FwdOk { seq, .. } | Msg::BwdOk { seq, .. }
                        | Msg::FullOk { seq, .. } => Some(*seq),
                        _ => None,
                    };
                    match seq.and_then(|s| seq2slot.get(&s)) {
                        Some(&j) if slots[j].is_none() => {
                            slots[j] = Some(msg);
                            got += 1;
                        }
                        // Stale (pre-restart) or duplicate response.
                        _ => info!("ignoring stale {} from {id}", msg.name()),
                    }
                }
                Some((id, Incoming::Gone(reason))) => {
                    return Phase::Fault { dead: vec![id], reason };
                }
                None => {
                    // recv timed out before the phase deadline only for
                    // the loopback (which is synchronous): whoever has no
                    // response now never answers.
                    return Phase::Fault {
                        dead: missing_ids(&slots, ids),
                        reason: "no response".into(),
                    };
                }
            }
        }
        Phase::Complete(slots.into_iter().map(|s| s.expect("all slots filled")).collect())
    }

    // ------------------------------------------------------------- eval

    /// Global model at cut v — same composition as the in-process
    /// engine's.
    pub fn global_params(&self, cut: usize) -> Params {
        if self.cfg.scheme == SchemeKind::Fl {
            return self.w_full.clone();
        }
        let nc = self.rt.spec().cut(cut).client_params;
        match &self.client_side {
            NetClientSide::Shared(w) => join_params(&w[..nc], &self.ws[nc..]),
            NetClientSide::PerClient(reps) => {
                let rho = 1.0 / reps.len() as f64;
                let first = reps.values().next().expect("at least one replica");
                let mut wc_avg = tensor::zeros_like(&first[..nc]);
                for w in reps.values() {
                    tensor::weighted_accumulate(&mut wc_avg, &w[..nc], rho);
                }
                join_params(&wc_avg, &self.ws[nc..])
            }
        }
    }

    /// Test-set (loss, accuracy) of the global model — the same
    /// per-batch fan-out and fixed-order reduction as the in-process
    /// engine (eval is always coordinator-side; participants never see
    /// the test split).
    pub fn evaluate(&self, cut: usize) -> anyhow::Result<(f64, f64)> {
        let total = self.test.len();
        anyhow::ensure!(total > 0, "empty test set");
        let eb = self.rt.spec().eval_batch;
        let w = Arc::new(self.global_params(cut));
        let rt = &self.rt;
        let test = &self.test;
        let bounds: Vec<(usize, usize)> =
            (0..total).step_by(eb).map(|lo| (lo, (lo + eb).min(total))).collect();
        let bounds_ref = &bounds;
        let scores = self.pool.map_with_scratch(bounds.len(), |scratch, b| {
            let (lo, hi) = bounds_ref[b];
            let idx: Vec<usize> = (lo..hi).collect();
            let (x, y) = test.batch(&idx);
            let (l, c) = rt.eval_with(scratch, &w, &x, &y)?;
            Ok((l as f64 * (hi - lo) as f64, c as f64))
        })?;
        let mut loss = 0.0;
        let mut correct = 0.0;
        for (l, c) in scores {
            loss += l;
            correct += c;
        }
        Ok((loss / total as f64, correct / total as f64))
    }

    /// Block (up to `timeout`) until participant `id` has dialed in and
    /// been admitted + synced.  A driver affordance for deterministic
    /// churn scripts: a relaunched process needs real time to reconnect,
    /// and WHICH round admits it decides the churn trace — callers that
    /// compare against an oracle pin the boundary with this.
    pub fn await_peer(&mut self, id: u64, timeout: Duration) -> anyhow::Result<()> {
        let t_end = Instant::now() + timeout;
        loop {
            if self.admit_new()?.contains(&id) {
                return Ok(());
            }
            anyhow::ensure!(
                Instant::now() < t_end,
                "peer {id} did not (re)join within {timeout:?}"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Restart the run from scratch under `seed`: fresh parameters, fresh
    /// population/test derivations, the transport's INITIAL peer set with
    /// fresh unconfigured participants (re-Welcomed), empty history.
    /// Errors on transports that cannot recreate peers (TCP: the remote
    /// processes are not ours to respawn).
    pub fn reset(&mut self, seed: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.transport.reset_peers(),
            "this transport cannot reset its peers"
        );
        self.cfg.seed = seed;
        let ids = self.transport.clients();
        anyhow::ensure!(!ids.is_empty(), "no participants after reset");
        let n_pop = ids.iter().copied().max().unwrap_or(0) + 1;
        self.pop = Population::new(
            seed,
            n_pop,
            self.cfg.scenario.clone(),
            self.cfg.net.clone(),
            self.cfg.comp.clone(),
        )?;
        let spec = self.rt.spec().clone();
        self.test = generate(&spec, &self.cfg.dataset, self.cfg.test_samples, seed ^ 0x7E57);
        let params = init_params(&spec, seed ^ 0x1417);
        let shared = match self.cfg.scheme.plan() {
            RoundPlan::Full => true,
            RoundPlan::Split { sync, .. } => sync == ClientSync::SharedStep,
        };
        self.client_side = if shared {
            NetClientSide::Shared(params.clone())
        } else {
            NetClientSide::PerClient(ids.iter().map(|&id| (id, params.clone())).collect())
        };
        self.ws = params.clone();
        self.w_full = params.clone();
        self.w_init = params;
        self.round = 0;
        self.seq = 0;
        self.dropped.clear();
        self.stats.clear();
        let setup = self.run_setup();
        for &id in &ids {
            self.transport.send(id, &Msg::Welcome { setup: setup.clone() });
        }
        Ok(())
    }

    /// End the run: every live participant gets a [`Msg::Shutdown`].
    pub fn shutdown(&mut self) {
        for id in self.transport.clients() {
            self.transport.send(id, &Msg::Shutdown);
        }
    }
}

/// Cohort slots still waiting on a response.
fn missing_ids(slots: &[Option<Msg>], ids: &[u64]) -> Vec<u64> {
    slots
        .iter()
        .enumerate()
        .filter(|(_, s)| s.is_none())
        .map(|(j, _)| ids[j])
        .collect()
}

/// CLI/wire spelling of a partition (the inverse of
/// [`Partition::parse`]).
pub fn partition_str(p: &Partition) -> String {
    match p {
        Partition::Iid => "iid".into(),
        Partition::Dirichlet(a) => format!("dirichlet:{a}"),
        Partition::Shards(s) => format!("shards:{s}"),
    }
}

// ----------------------------------------------------------- digesting

/// FNV-1a over a byte stream — a tiny content digest for bitwise
/// comparisons across processes (stats files, final parameters).
#[derive(Clone, Copy)]
pub struct Digest(u64);

impl Digest {
    pub fn new() -> Digest {
        Digest(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x1000_0000_01b3);
        }
        self
    }

    pub fn f32s(&mut self, xs: &[f32]) -> &mut Self {
        for &x in xs {
            self.bytes(&x.to_bits().to_le_bytes());
        }
        self
    }

    pub fn f64(&mut self, x: f64) -> &mut Self {
        self.bytes(&x.to_bits().to_le_bytes())
    }

    pub fn value(&self) -> u64 {
        self.0
    }
}

impl Default for Digest {
    fn default() -> Self {
        Digest::new()
    }
}

/// Bitwise digest of a parameter set.
pub fn params_digest(params: &Params) -> u64 {
    let mut d = Digest::new();
    for layer in params {
        d.f32s(layer);
    }
    d.value()
}

/// Bitwise digest of a run's stats (every float hashed at full
/// precision) — two runs agree iff their digests do, within FNV odds.
///
/// `tests/net_equivalence.rs` and the `sfl-coordinator` binary compare
/// runs across processes through this digest.
pub fn stats_digest(stats: &[RoundStats]) -> u64 {
    let mut d = Digest::new();
    for s in stats {
        d.bytes(&(s.round as u64).to_le_bytes());
        d.bytes(&(s.cut as u64).to_le_bytes());
        d.bytes(&(s.participants as u64).to_le_bytes());
        d.f64(s.train_loss);
        d.f64(s.comm.uplink_bits);
        d.f64(s.comm.downlink_bits);
        d.f64(s.latency.uplink_leg);
        d.f64(s.latency.downlink_leg);
        match s.test {
            Some((l, a)) => {
                d.bytes(&[1]);
                d.f64(l);
                d.f64(a);
            }
            None => {
                d.bytes(&[0]);
            }
        }
    }
    d.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ScenarioConfig;

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            rounds: 1,
            tau: 1,
            samples_per_client: 16,
            test_samples: 64,
            eval_every: 1,
            threads: 1,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn partition_str_is_parse_inverse() {
        for p in [Partition::Iid, Partition::Dirichlet(0.3), Partition::Shards(2)] {
            assert_eq!(Partition::parse(&partition_str(&p)).unwrap(), p);
        }
    }

    #[test]
    fn digests_are_bit_sensitive() {
        let a = vec![vec![1.0f32, 2.0], vec![3.0]];
        let mut b = a.clone();
        assert_eq!(params_digest(&a), params_digest(&b));
        // Flip one mantissa bit: the digest must move.
        b[1][0] = f32::from_bits(b[1][0].to_bits() ^ 1);
        assert_ne!(params_digest(&a), params_digest(&b));
        // ±0.0 compare equal as floats but are distinct bit patterns.
        assert_ne!(
            params_digest(&vec![vec![0.0f32]]),
            params_digest(&vec![vec![-0.0f32]])
        );
    }

    #[test]
    fn net_trainer_rejects_simulator_only_scenarios() {
        let manifest = Manifest::builtin();
        // Partial participation is an in-process simulator feature.
        let mut cfg = tiny_cfg();
        cfg.scenario = ScenarioConfig { participation: 0.5, ..Default::default() };
        assert!(NetTrainer::loopback(&manifest, cfg, 2).is_err());
        // Zero participants cannot form a federation.
        assert!(NetTrainer::loopback(&manifest, tiny_cfg(), 0).is_err());
    }

    /// Loopback wrapper reproducing the TCP drop race: the peer's first
    /// fwd-ok is lost (deadline fault), and — as shutting a dropped
    /// peer's socket does — a terminal Gone for it arrives AFTER the
    /// fault policy removed it.  The stale Gone must be discarded, not
    /// double-drop the peer and re-restart the round.
    struct StaleGoneTransport {
        inner: LoopbackTransport,
        swallowed: bool,
        stale_gone: Option<u64>,
    }

    impl Transport for StaleGoneTransport {
        fn clients(&self) -> Vec<u64> {
            self.inner.clients()
        }

        fn send(&mut self, id: u64, msg: &Msg) {
            self.inner.send(id, msg)
        }

        fn recv(&mut self, timeout: Duration) -> Option<(u64, Incoming)> {
            if let Some(id) = self.stale_gone.take() {
                return Some((id, Incoming::Gone("connection closed".into())));
            }
            loop {
                let (id, ev) = self.inner.recv(timeout)?;
                if !self.swallowed && id == 1 {
                    if let Incoming::Msg(Msg::FwdOk { .. }) = ev {
                        self.swallowed = true;
                        continue; // lost on the wire
                    }
                }
                return Some((id, ev));
            }
        }

        fn drop_client(&mut self, id: u64) {
            self.inner.drop_client(id);
            self.stale_gone = Some(id);
        }
    }

    #[test]
    fn stale_gone_after_drop_is_discarded() {
        let manifest = Manifest::builtin();
        let transport = StaleGoneTransport {
            inner: LoopbackTransport::new(&[0, 1], 1).unwrap(),
            swallowed: false,
            stale_gone: None,
        };
        let mut nt =
            NetTrainer::new(&manifest, tiny_cfg(), Duration::from_secs(60), transport).unwrap();
        let stats = nt.run(2).unwrap();
        // Exactly one drop of exactly peer 1, and the restarted round
        // completes over the survivor.
        assert_eq!(nt.dropped(), &[1]);
        assert_eq!(nt.live(), vec![0]);
        assert_eq!(stats[0].participants, 1);
    }

    /// Loopback wrapper whose peer 1 answers its first fwd-req with a
    /// well-formed but wrong-typed message carrying the matching seq.
    struct WrongTypeTransport {
        inner: LoopbackTransport,
        tampered: bool,
    }

    impl Transport for WrongTypeTransport {
        fn clients(&self) -> Vec<u64> {
            self.inner.clients()
        }

        fn send(&mut self, id: u64, msg: &Msg) {
            self.inner.send(id, msg)
        }

        fn recv(&mut self, timeout: Duration) -> Option<(u64, Incoming)> {
            let (id, ev) = self.inner.recv(timeout)?;
            if !self.tampered && id == 1 {
                if let Incoming::Msg(Msg::FwdOk { seq, .. }) = &ev {
                    self.tampered = true;
                    let wrong = Msg::BwdOk { seq: *seq, grad: Params::new() };
                    return Some((id, Incoming::Msg(wrong)));
                }
            }
            Some((id, ev))
        }

        fn drop_client(&mut self, id: u64) {
            self.inner.drop_client(id)
        }
    }

    #[test]
    fn wrong_typed_reply_drops_only_the_offender() {
        let manifest = Manifest::builtin();
        let transport = WrongTypeTransport {
            inner: LoopbackTransport::new(&[0, 1], 1).unwrap(),
            tampered: false,
        };
        let mut nt =
            NetTrainer::new(&manifest, tiny_cfg(), Duration::from_secs(60), transport).unwrap();
        // One buggy participant must not kill the federation: peer 1 is
        // dropped via the fault policy and the run completes over peer 0.
        let stats = nt.run(2).unwrap();
        assert_eq!(nt.dropped(), &[1]);
        assert_eq!(nt.live(), vec![0]);
        assert_eq!(stats[0].participants, 1);
    }

    /// Loopback wrapper that loses EVERY participant response: each phase
    /// times out, the fault policy drops the whole cohort, and the run
    /// must end in a clean quorum error carrying the drop history — not a
    /// panic from renormalizing ρ over zero survivors.
    struct BlackHoleTransport(LoopbackTransport);

    impl Transport for BlackHoleTransport {
        fn clients(&self) -> Vec<u64> {
            self.0.clients()
        }

        fn send(&mut self, id: u64, msg: &Msg) {
            self.0.send(id, msg)
        }

        fn recv(&mut self, timeout: Duration) -> Option<(u64, Incoming)> {
            while self.0.recv(timeout).is_some() {}
            None
        }

        fn drop_client(&mut self, id: u64) {
            self.0.drop_client(id)
        }
    }

    #[test]
    fn cohort_empties_to_zero_is_a_clean_error() {
        let manifest = Manifest::builtin();
        let transport = BlackHoleTransport(LoopbackTransport::new(&[0, 1], 1).unwrap());
        let mut nt =
            NetTrainer::new(&manifest, tiny_cfg(), Duration::from_millis(50), transport)
                .unwrap();
        let err = nt.run(2).unwrap_err().to_string();
        assert!(err.contains("below quorum"), "unexpected error: {err}");
        assert!(err.contains("dropped in order"), "missing drop history: {err}");
        assert!(err.contains('0') && err.contains('1'), "history incomplete: {err}");
        assert_eq!(nt.dropped(), &[0, 1]);
    }

    #[test]
    fn quorum_wait_admits_rejoiner_and_matches_cold_oracle() {
        let manifest = Manifest::builtin();
        let mut cfg = tiny_cfg();
        cfg.scheme = SchemeKind::Sfl; // exercise the per-client replica path
        // Peer 1 departs before round 0 and is scheduled to dial back in;
        // the quorum floor of 2 forces the engine to pause and admit it.
        let mut nt = NetTrainer::loopback(&manifest, cfg.clone(), 2)
            .unwrap()
            .with_quorum(2, Duration::from_secs(30));
        nt.depart(1);
        nt.transport.schedule_admit(1);
        let stats = nt.run(2).unwrap();
        assert_eq!(stats[0].participants, 2);
        assert_eq!(nt.dropped(), &[1]);
        // A round-0 rejoin lands with COLD state = the initial replica,
        // so the run is bitwise one where peer 1 never left.
        let mut plain = NetTrainer::loopback(&manifest, cfg, 2).unwrap();
        let plain_stats = plain.run(2).unwrap();
        assert_eq!(stats_digest(&stats), stats_digest(&plain_stats));
        assert_eq!(
            params_digest(&nt.global_params(2)),
            params_digest(&plain.global_params(2))
        );
    }

    /// Loopback wrapper staging a mid-round drop-below-quorum: peer 1's
    /// first fwd-ok is lost (fault → drop), and the dropped peer
    /// immediately re-dials (its drop schedules a loopback admit), so the
    /// quorum wait must admit it cold and restart the round over both.
    struct DropThenRejoinTransport {
        inner: LoopbackTransport,
        swallowed: bool,
    }

    impl Transport for DropThenRejoinTransport {
        fn clients(&self) -> Vec<u64> {
            self.inner.clients()
        }

        fn send(&mut self, id: u64, msg: &Msg) {
            self.inner.send(id, msg)
        }

        fn recv(&mut self, timeout: Duration) -> Option<(u64, Incoming)> {
            loop {
                let (id, ev) = self.inner.recv(timeout)?;
                if !self.swallowed && id == 1 {
                    if let Incoming::Msg(Msg::FwdOk { .. }) = ev {
                        self.swallowed = true;
                        continue; // lost on the wire
                    }
                }
                return Some((id, ev));
            }
        }

        fn drop_client(&mut self, id: u64) {
            self.inner.drop_client(id);
            if id == 1 {
                self.inner.schedule_admit(1); // the killed process relaunches
            }
        }

        fn accept_new(&mut self) -> Vec<u64> {
            self.inner.accept_new()
        }
    }

    #[test]
    fn mid_round_quorum_admission_rejoins_cold_and_restarts() {
        let manifest = Manifest::builtin();
        let mut cfg = tiny_cfg();
        cfg.scheme = SchemeKind::Sfl;
        let transport = DropThenRejoinTransport {
            inner: LoopbackTransport::new(&[0, 1], 1).unwrap(),
            swallowed: false,
        };
        let mut nt = NetTrainer::new(&manifest, cfg.clone(), Duration::from_millis(100), transport)
            .unwrap()
            .with_quorum(2, Duration::from_secs(30));
        let stats = nt.run(2).unwrap();
        // The drop happened, and the rejoiner made it back into round 0.
        assert_eq!(nt.dropped(), &[1]);
        assert_eq!(stats[0].participants, 2);
        // Round-0 cold state IS the initial replica, so the churned run is
        // bitwise a run where peer 1 never faulted.
        let mut plain = NetTrainer::loopback(&manifest, cfg, 2).unwrap();
        let plain_stats = plain.run(2).unwrap();
        assert_eq!(stats_digest(&stats), stats_digest(&plain_stats));
        assert_eq!(
            params_digest(&nt.global_params(2)),
            params_digest(&plain.global_params(2))
        );
    }

    #[test]
    fn quorum_wait_expiry_is_a_clean_error() {
        let manifest = Manifest::builtin();
        let mut nt = NetTrainer::loopback(&manifest, tiny_cfg(), 2)
            .unwrap()
            .with_quorum(2, Duration::from_millis(20));
        nt.depart(0);
        let err = nt.run(2).unwrap_err().to_string();
        assert!(err.contains("below quorum"), "unexpected error: {err}");
        assert!(err.contains("1 live < 2 required"), "unexpected error: {err}");
    }

    #[test]
    fn churn_trace_departure_and_rejoin_runs_cleanly() {
        let manifest = Manifest::builtin();
        let mut cfg = tiny_cfg();
        cfg.rounds = 3;
        let trace = ChurnTrace::parse("1:-1,2:+1").unwrap();
        let mut nt = NetTrainer::loopback(&manifest, cfg, 2).unwrap();
        let stats = nt.run_churn(2, &trace).unwrap();
        let participants: Vec<usize> = stats.iter().map(|s| s.participants).collect();
        assert_eq!(participants, vec![2, 1, 2]);
        assert!(stats.iter().all(|s| s.train_loss.is_finite()));
        assert_eq!(nt.dropped(), &[1]);
        assert_eq!(nt.live(), vec![0, 1]);
    }

    #[test]
    fn checkpoint_resume_matches_uninterrupted_loopback() {
        let manifest = Manifest::builtin();
        let mut cfg = tiny_cfg();
        cfg.rounds = 4;
        let dir = std::env::temp_dir()
            .join(format!("sfl-ga-net-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("net.ckpt");

        let mut a = NetTrainer::loopback(&manifest, cfg.clone(), 2).unwrap();
        let full = a.run(2).unwrap();

        // Run B checkpoints every 2 rounds and "dies" after round 2.
        let mut b = NetTrainer::loopback(&manifest, cfg.clone(), 2)
            .unwrap()
            .with_checkpoint(path.clone(), 2);
        b.step(2).unwrap().unwrap();
        let (_, saved) = b.step(2).unwrap().unwrap();
        assert!(saved, "checkpoint due at round 2 was not written");
        drop(b);

        let ckpt = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt.round, 2);
        let transport = LoopbackTransport::new(&[0, 1], 1).unwrap();
        let mut c =
            NetTrainer::resume(&manifest, cfg.clone(), Duration::from_secs(60), transport, &ckpt)
                .unwrap();
        let resumed = c.run(2).unwrap();
        assert_eq!(resumed.len(), full.len());
        assert_eq!(stats_digest(&full), stats_digest(&resumed));
        assert_eq!(
            params_digest(&a.global_params(2)),
            params_digest(&c.global_params(2))
        );

        // A config drift is refused instead of replaying wrong.
        let mut other = cfg;
        other.seed ^= 1;
        let transport = LoopbackTransport::new(&[0, 1], 1).unwrap();
        assert!(NetTrainer::resume(
            &manifest,
            other,
            Duration::from_secs(60),
            transport,
            &ckpt
        )
        .is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reset_after_churn_equals_fresh() {
        let manifest = Manifest::builtin();
        let mut cfg = tiny_cfg();
        cfg.rounds = 2;
        let trace = ChurnTrace::parse("1:-0").unwrap();
        let mut churned = NetTrainer::loopback(&manifest, cfg.clone(), 2).unwrap();
        churned.run_churn(2, &trace).unwrap();
        churned.reset(cfg.seed).unwrap();
        let after_reset = churned.run(2).unwrap();
        let mut fresh = NetTrainer::loopback(&manifest, cfg, 2).unwrap();
        let fresh_stats = fresh.run(2).unwrap();
        assert_eq!(stats_digest(&after_reset), stats_digest(&fresh_stats));
        assert_eq!(
            params_digest(&churned.global_params(2)),
            params_digest(&fresh.global_params(2))
        );
    }

    #[test]
    fn run_round_rejects_out_of_range_cuts() {
        let manifest = Manifest::builtin();
        let mut nt = NetTrainer::loopback(&manifest, tiny_cfg(), 1).unwrap();
        assert!(nt.run_round(0).is_err());
        assert!(nt.run_round(nt.rt.spec().num_cuts() + 1).is_err());
    }

    #[test]
    fn loopback_round_runs_and_reports() {
        let manifest = Manifest::builtin();
        let mut nt = NetTrainer::loopback(&manifest, tiny_cfg(), 2).unwrap();
        let stats = nt.run(2).unwrap();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].participants, 2);
        assert!(stats[0].train_loss.is_finite());
        let (loss, acc) = stats[0].test.unwrap();
        assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
        assert!(nt.dropped().is_empty());
        nt.shutdown();
    }
}
