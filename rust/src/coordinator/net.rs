//! The networked coordinator: [`Trainer`](super::Trainer)'s round
//! semantics fanned out over a [`Transport`] instead of in-process
//! closures, plus the fault policy the paper's ρ-weighting implies
//! (DESIGN.md §Transport).
//!
//! [`NetTrainer`] owns EVERY piece of model state and every reduction —
//! participants are stateless compute peers (`runtime::node`).  Each
//! split-round epoch is the same five phases as the in-process engine:
//! fwd fan-out ([`Msg::FwdReq`] shipping the client-side weights), the
//! coordinator-side server FP+BP (eqs 2–4) over the returned smashed
//! batches, cotangent routing ([`Msg::BwdReq`] — ONE aggregated
//! broadcast under eq 5 or per-client unicast), the client-VJP
//! collection, and the fixed-ascending-order weighted reductions.  FL
//! rides [`Msg::FullReq`] (τ local steps participant-side).  Because
//! responses are slotted by participant id and every reduction runs in
//! ascending id order over the buffered results, arrival order — and
//! hence transport choice, thread count, or any delay below the deadline
//! — never changes a bit of the result: a loopback run, a TCP run and an
//! in-process [`Trainer`](super::Trainer) run of the same config agree
//! bitwise (`tests/net_equivalence.rs`).
//!
//! **Fault policy** (chaos-tested in `tests/chaos.rs`): each collection
//! phase has a deadline.  A participant that misses it — or whose
//! connection drops — is removed from the federation, the round
//! *restarts from its entry snapshot* over the survivors, and the
//! aggregation weights renormalize to 1/|survivors| (ρ is uniform, eq 7).
//! Restarting rather than patching the half-collected round is what
//! makes the policy exact: a run that loses client c during round r is
//! bitwise the run that excluded c before round r began.  A round
//! consumes one channel draw keyed by its index, so a restart replays
//! the same fading state.  When every participant is gone the run fails
//! cleanly (no panic, no hang).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::init::{init_params, join_params};
use crate::data::partition::Partition;
use crate::data::{generate, Dataset};
use crate::model::{Manifest, NUM_CUTS};
use crate::protocol::{Msg, RunSetup};
use crate::runtime::transport::{Incoming, Transport};
use crate::runtime::{LoopbackTransport, ModelRuntime, ParallelExecutor, Tensor};
use crate::tensor::{self, Params};
use crate::wireless::ChannelState;
use crate::{info, warn_log};

use super::comm::round_comm;
use super::plan::{ClientSync, CotangentRoute, RoundPlan};
use super::population::Population;
use super::timing::round_latency;
use super::trainer::{RoundStats, TrainConfig};
use super::SchemeKind;

/// Client-side model state, coordinator-held (participants are
/// stateless).  Mirrors the in-process trainer's representation with
/// replicas keyed by participant id, so dropping a client drops its
/// replica — the "excluded up front" equality needs exactly that.
#[derive(Clone)]
enum NetClientSide {
    /// One shared logical client model (SFL-GA's eq 19, and FL).
    Shared(Params),
    /// Per-participant replicas (SFL / PSL / the drift ablation).
    PerClient(BTreeMap<u64, Params>),
}

/// A collection phase's outcome: every expected response (slotted in
/// cohort order), or the peers to drop.
enum Phase {
    Complete(Vec<Msg>),
    Fault { dead: Vec<u64>, reason: String },
}

/// The networked round engine; see the module docs.
pub struct NetTrainer<T: Transport> {
    pub cfg: TrainConfig,
    /// Per-phase collection deadline (timeout ⇒ drop ⇒ renormalize).
    deadline: Duration,
    transport: T,
    rt: ModelRuntime,
    pool: ParallelExecutor,
    pop: Population,
    test: Dataset,
    client_side: NetClientSide,
    ws: Params,
    w_full: Params,
    round: usize,
    seq: u64,
    /// Participants dropped by the fault policy, in drop order.
    dropped: Vec<u64>,
}

impl NetTrainer<LoopbackTransport> {
    /// In-process federation of `n` loopback participants with ids
    /// `0..n` — the transport-layer twin of an `n`-client
    /// [`Trainer`](super::Trainer).
    pub fn loopback(
        manifest: &Manifest,
        cfg: TrainConfig,
        n: usize,
    ) -> anyhow::Result<NetTrainer<LoopbackTransport>> {
        let ids: Vec<u64> = (0..n as u64).collect();
        let transport = LoopbackTransport::new(&ids, cfg.threads)?;
        NetTrainer::new(manifest, cfg, Duration::from_secs(60), transport)
    }
}

impl<T: Transport> NetTrainer<T> {
    /// Coordinator over an already-joined transport.  Sends every
    /// participant its [`Msg::Welcome`] configuration.
    pub fn new(
        manifest: &Manifest,
        cfg: TrainConfig,
        deadline: Duration,
        mut transport: T,
    ) -> anyhow::Result<NetTrainer<T>> {
        anyhow::ensure!(cfg.rounds > 0 && cfg.tau > 0, "rounds and tau must be positive");
        anyhow::ensure!(deadline > Duration::ZERO, "deadline must be positive");
        anyhow::ensure!(cfg.eval_every > 0, "eval_every must be positive");
        anyhow::ensure!(cfg.test_samples > 0, "test_samples must be positive");
        anyhow::ensure!(cfg.samples_per_client > 0, "samples_per_client must be positive");
        cfg.scenario.validate()?;
        // The networked cohort IS the live participant set: the scenario
        // engine's virtual sampling and straggler profiles stay with the
        // in-process simulator (real stragglers are the chaos harness's
        // job here).
        anyhow::ensure!(
            cfg.scenario.full_participation() && !cfg.scenario.straggler.enabled(),
            "the networked runtime runs full participation over joined clients; \
             partial participation / simulated stragglers are in-process features"
        );
        let ids = transport.clients();
        anyhow::ensure!(!ids.is_empty(), "no participants joined the federation");

        let rt = ModelRuntime::native(manifest, &cfg.dataset)?;
        let spec = rt.spec().clone();
        anyhow::ensure!(
            rt.dynamic_batch() || cfg.test_samples % spec.eval_batch == 0,
            "test_samples must be a multiple of the eval batch {}",
            spec.eval_batch
        );
        // Per-client state (gains, capacities) is keyed by (seed, id), so
        // the population only needs to span the joined id range.
        let n_pop = ids.iter().copied().max().unwrap_or(0) + 1;
        let pop = Population::new(
            cfg.seed,
            n_pop,
            cfg.scenario.clone(),
            cfg.net.clone(),
            cfg.comp.clone(),
        )?;
        let test = generate(&spec, &cfg.dataset, cfg.test_samples, cfg.seed ^ 0x7E57);
        let params = init_params(&spec, cfg.seed ^ 0x1417);
        let shared = match cfg.scheme.plan() {
            RoundPlan::Full => true,
            RoundPlan::Split { sync, .. } => sync == ClientSync::SharedStep,
        };
        let client_side = if shared {
            NetClientSide::Shared(params.clone())
        } else {
            NetClientSide::PerClient(ids.iter().map(|&id| (id, params.clone())).collect())
        };
        let pool = ParallelExecutor::new(cfg.threads);
        let eval_jobs = cfg.test_samples.div_ceil(spec.eval_batch).max(1);
        rt.set_eval_parallelism((pool.threads() / eval_jobs).max(1));

        let setup = RunSetup {
            dataset: cfg.dataset.clone(),
            seed: cfg.seed,
            partition: partition_str(&cfg.scenario.partition),
            samples_per_client: cfg.samples_per_client,
        };
        // Writes must respect the same deadline as collections: a peer
        // that stops reading would otherwise block `send` forever and
        // the fault policy could never fire.
        transport.set_io_deadline(deadline);
        for &id in &ids {
            transport.send(id, &Msg::Welcome { setup: setup.clone() });
        }
        Ok(NetTrainer {
            cfg,
            deadline,
            transport,
            rt,
            pool,
            pop,
            test,
            client_side,
            ws: params.clone(),
            w_full: params,
            round: 0,
            seq: 0,
            dropped: Vec::new(),
        })
    }

    /// Live participant ids, ascending.
    pub fn live(&self) -> Vec<u64> {
        self.transport.clients()
    }

    /// Participants removed by the fault policy so far, in drop order.
    pub fn dropped(&self) -> &[u64] {
        &self.dropped
    }

    pub fn round_index(&self) -> usize {
        self.round
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Run the full fixed-cut training; mirrors
    /// [`Trainer::run`](super::Trainer::run) stats-for-stats (evaluation
    /// is synchronous here — the in-process engine's deferred eval is
    /// documented bitwise-equal to it).
    pub fn run(&mut self, cut: usize) -> anyhow::Result<Vec<RoundStats>> {
        let mut out = Vec::with_capacity(self.cfg.rounds);
        for _ in 0..self.cfg.rounds {
            let mut stats = self.run_round(cut)?;
            if self.round % self.cfg.eval_every == 0 || self.round == self.cfg.rounds {
                stats.test = Some(self.evaluate(cut)?);
            }
            out.push(stats);
        }
        Ok(out)
    }

    /// One fault-tolerant round at cut `v`: execute over the live set;
    /// on a drop, restore the entry snapshot, renormalize to the
    /// survivors and restart (same channel draw — see the module docs).
    pub fn run_round(&mut self, cut: usize) -> anyhow::Result<RoundStats> {
        anyhow::ensure!(
            (1..=NUM_CUTS).contains(&cut),
            "cut {cut} outside 1..={NUM_CUTS}"
        );
        let snapshot = (self.client_side.clone(), self.ws.clone(), self.w_full.clone());
        let draw = self.round as u64;
        loop {
            let ids = self.transport.clients();
            anyhow::ensure!(
                !ids.is_empty(),
                "round {}: every participant dropped out",
                self.round
            );
            let k = ids.len();
            // ρ is uniform, so the cohort weights renormalize to 1/K over
            // whoever is still standing.
            let weights = vec![1.0 / k as f64; k];
            let attempt = match self.cfg.scheme.plan() {
                RoundPlan::Split { route, sync } => {
                    self.round_split(cut, route, sync, &ids, &weights)?
                }
                RoundPlan::Full => self.round_full(&ids, &weights)?,
            };
            match attempt {
                Ok(train_loss) => {
                    let stats = self.finish_round(cut, draw, &ids, train_loss);
                    for &id in &ids {
                        self.transport.send(id, &Msg::RoundDone { round: stats.round as u64 });
                    }
                    return Ok(stats);
                }
                Err((dead, reason)) => {
                    warn_log!(
                        "round {}: dropping {dead:?} ({reason}); restarting over survivors",
                        self.round
                    );
                    let (cs, ws, wf) = snapshot.clone();
                    self.client_side = cs;
                    self.ws = ws;
                    self.w_full = wf;
                    for &id in &dead {
                        self.transport.drop_client(id);
                        self.dropped.push(id);
                        if let NetClientSide::PerClient(reps) = &mut self.client_side {
                            reps.remove(&id);
                        }
                    }
                }
            }
        }
    }

    /// Account the completed round (comm + latency over exactly the
    /// cohort, as the in-process engine does) and advance the clock.
    fn finish_round(&mut self, cut: usize, draw: u64, ids: &[u64], train_loss: f64) -> RoundStats {
        let k = ids.len();
        let cohort: Vec<usize> = ids.iter().map(|&id| id as usize).collect();
        let state_round = ChannelState { gains: self.pop.gains_for(draw, &cohort) };
        let mut comp_round = self.cfg.comp.clone();
        comp_round.client_caps = self.pop.caps_for(&cohort);
        let spec = self.rt.spec().clone();
        let cut_spec = spec.cut(cut);
        let comm = round_comm(self.cfg.scheme, &spec, cut_spec, &comp_round, k, self.cfg.tau);
        let latency = round_latency(
            self.cfg.scheme,
            &spec,
            cut_spec,
            &self.cfg.net,
            &comp_round,
            &state_round,
            self.cfg.alloc,
            self.cfg.tau,
        );
        self.round += 1;
        RoundStats {
            round: self.round,
            cut,
            participants: k,
            train_loss,
            comm,
            latency,
            test: None,
        }
    }

    /// One split-round attempt over `ids`; `Ok(Err(..))` names the peers
    /// to drop.  The math is phase-for-phase the in-process engine's
    /// `round_split`, with the client kernels remote.
    #[allow(clippy::type_complexity)]
    fn round_split(
        &mut self,
        cut: usize,
        route: CotangentRoute,
        sync: ClientSync,
        ids: &[u64],
        weights: &[f64],
    ) -> anyhow::Result<Result<f64, (Vec<u64>, String)>> {
        let nc = self.rt.spec().cut(cut).client_params;
        let k = ids.len();
        let lr = self.cfg.lr;
        let tau = self.cfg.tau;
        let base_step = self.round * tau;
        let mut g_ws_acc = tensor::zeros_like(&self.ws[nc..]);
        let mut g_c_acc = match &self.client_side {
            NetClientSide::Shared(w) => tensor::zeros_like(&w[..nc]),
            NetClientSide::PerClient(_) => Params::new(),
        };
        let mut mean_loss = 0.0;
        for epoch in 0..tau {
            let step = (base_step + epoch) as u64;
            // Phase 1 — client-fwd fan-out (eq 1): ship each participant
            // its current client-side weights and the batch key.
            let mut seq2slot = BTreeMap::new();
            let mut seqs = Vec::with_capacity(k);
            for (j, &id) in ids.iter().enumerate() {
                let wc = match &self.client_side {
                    NetClientSide::Shared(w) => w[..nc].to_vec(),
                    NetClientSide::PerClient(reps) => reps[&id][..nc].to_vec(),
                };
                let seq = self.next_seq();
                seq2slot.insert(seq, j);
                seqs.push(seq);
                self.transport.send(id, &Msg::FwdReq { seq, cut: cut as u32, step, wc });
            }
            let fwds = match self.collect(&seq2slot, ids) {
                Phase::Complete(msgs) => msgs,
                Phase::Fault { dead, reason } => return Ok(Err((dead, reason))),
            };
            let mut smashed = Vec::with_capacity(k);
            let mut labels = Vec::with_capacity(k);
            for (j, msg) in fwds.into_iter().enumerate() {
                match msg {
                    Msg::FwdOk { smashed: s, labels: y, .. } => {
                        smashed.push(s);
                        labels.push(y);
                    }
                    // A wrong-typed reply is that peer's protocol
                    // violation, not the federation's: fault it.
                    other => {
                        return Ok(Err((
                            vec![ids[j]],
                            format!("expected fwd-ok, got {}", other.name()),
                        )))
                    }
                }
            }
            // Phase 2 — server FP+BP (eqs 2–4) on the coordinator's own
            // pool, results in ascending cohort order.
            let rt = &self.rt;
            let ws_srv = &self.ws[nc..];
            let smashed_ref = &smashed;
            let labels_ref = &labels;
            let servers: Vec<(f32, Params, Tensor)> = self.pool.map_with_scratch(k, |scratch, j| {
                rt.server_grad_with(scratch, cut, ws_srv, &smashed_ref[j], &labels_ref[j])
            })?;
            // Phase 2b — the ρ-weighted server reduction (eq 7), fixed
            // ascending order.
            tensor::zero(&mut g_ws_acc);
            let mut loss_acc = 0.0;
            for (j, (loss, g_ws, _)) in servers.iter().enumerate() {
                loss_acc += weights[j] * *loss as f64;
                tensor::weighted_accumulate(&mut g_ws_acc, g_ws, weights[j]);
            }
            // Phase 3 — cotangent routing: eq-5 aggregated broadcast
            // (ONE tensor for everyone) or per-client unicast.
            let mut seq2slot_bwd = BTreeMap::new();
            match route {
                CotangentRoute::Broadcast => {
                    let mut agg = Tensor::zeros(&servers[0].2.shape);
                    for (j, (_, _, g_s)) in servers.iter().enumerate() {
                        tensor::weighted_accumulate_flat(&mut agg.data, &g_s.data, weights[j]);
                    }
                    for (j, &id) in ids.iter().enumerate() {
                        seq2slot_bwd.insert(seqs[j], j);
                        self.transport
                            .send(id, &Msg::BwdReq { seq: seqs[j], cotangent: agg.clone() });
                    }
                }
                CotangentRoute::Unicast => {
                    for (j, &id) in ids.iter().enumerate() {
                        seq2slot_bwd.insert(seqs[j], j);
                        self.transport.send(
                            id,
                            &Msg::BwdReq { seq: seqs[j], cotangent: servers[j].2.clone() },
                        );
                    }
                }
            }
            // Phase 4 — client-bwd collection (eq 6).
            let bwds = match self.collect(&seq2slot_bwd, ids) {
                Phase::Complete(msgs) => msgs,
                Phase::Fault { dead, reason } => return Ok(Err((dead, reason))),
            };
            let mut g_c_parts = Vec::with_capacity(k);
            for (j, msg) in bwds.into_iter().enumerate() {
                match msg {
                    Msg::BwdOk { grad, .. } => g_c_parts.push(grad),
                    other => {
                        return Ok(Err((
                            vec![ids[j]],
                            format!("expected bwd-ok, got {}", other.name()),
                        )))
                    }
                }
            }
            // Apply this epoch's updates on the coordinator: server step
            // on the aggregated gradient, then the scheme's client step.
            tensor::sgd_step(&mut self.ws[nc..], &g_ws_acc, lr);
            match &mut self.client_side {
                NetClientSide::Shared(w) => {
                    tensor::zero(&mut g_c_acc);
                    for (j, g_c) in g_c_parts.iter().enumerate() {
                        tensor::weighted_accumulate(&mut g_c_acc, g_c, weights[j]);
                    }
                    tensor::sgd_step(&mut w[..nc], &g_c_acc, lr);
                }
                NetClientSide::PerClient(reps) => {
                    for (j, g_c) in g_c_parts.iter().enumerate() {
                        let rep = reps.get_mut(&ids[j]).expect("live participant has a replica");
                        tensor::sgd_step(&mut rep[..nc], g_c, lr);
                    }
                }
            }
            mean_loss += loss_acc / tau as f64;
        }
        // Phase 5 — client-side FedAvg (SFL only): aggregate the cohort's
        // replicas and write the average back.
        if sync == ClientSync::FedAvg {
            if let NetClientSide::PerClient(reps) = &mut self.client_side {
                let mut agg = tensor::zeros_like(&reps[&ids[0]][..nc]);
                for (j, id) in ids.iter().enumerate() {
                    tensor::weighted_accumulate(&mut agg, &reps[id][..nc], weights[j]);
                }
                for id in ids {
                    let rep = reps.get_mut(id).expect("live participant has a replica");
                    for (dst, src) in rep[..nc].iter_mut().zip(&agg) {
                        dst.copy_from_slice(src);
                    }
                }
            }
        }
        Ok(Ok(mean_loss))
    }

    /// One FL-round attempt: τ local steps participant-side, weighted
    /// model aggregation coordinator-side (ascending order).
    #[allow(clippy::type_complexity)]
    fn round_full(
        &mut self,
        ids: &[u64],
        weights: &[f64],
    ) -> anyhow::Result<Result<f64, (Vec<u64>, String)>> {
        let k = ids.len();
        let base_step = (self.round * self.cfg.tau) as u64;
        let mut seq2slot = BTreeMap::new();
        for (j, &id) in ids.iter().enumerate() {
            let seq = self.next_seq();
            seq2slot.insert(seq, j);
            let req = Msg::FullReq {
                seq,
                step0: base_step,
                tau: self.cfg.tau as u32,
                lr: self.cfg.lr,
                w: self.w_full.clone(),
            };
            self.transport.send(id, &req);
        }
        let fulls = match self.collect(&seq2slot, ids) {
            Phase::Complete(msgs) => msgs,
            Phase::Fault { dead, reason } => return Ok(Err((dead, reason))),
        };
        let mut agg = tensor::zeros_like(&self.w_full);
        let mut loss_acc = 0.0;
        for (j, msg) in fulls.iter().enumerate() {
            match msg {
                Msg::FullOk { loss, w, .. } => {
                    loss_acc += weights[j] * *loss;
                    tensor::weighted_accumulate(&mut agg, w, weights[j]);
                }
                other => {
                    return Ok(Err((
                        vec![ids[j]],
                        format!("expected full-ok, got {}", other.name()),
                    )))
                }
            }
        }
        self.w_full = agg;
        Ok(Ok(loss_acc))
    }

    /// Await one response per expected `seq` (any arrival order; results
    /// slotted into cohort order), up to the phase deadline.  Stale seqs
    /// from an aborted attempt are ignored; a gone peer or the deadline
    /// yields the drop set.
    fn collect(&mut self, seq2slot: &BTreeMap<u64, usize>, ids: &[u64]) -> Phase {
        let k = ids.len();
        let mut slots: Vec<Option<Msg>> = vec![None; k];
        let mut got = 0usize;
        let t_end = Instant::now() + self.deadline;
        while got < k {
            let left = t_end.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Phase::Fault {
                    dead: missing_ids(&slots, ids),
                    reason: format!("deadline {:?} exceeded", self.deadline),
                };
            }
            match self.transport.recv(left) {
                // Events from outside the cohort are stale: dropping a
                // TCP peer shuts its socket, which wakes its reader
                // thread and queues one last Gone for an id the fault
                // policy already removed — acting on it would re-fault
                // the restarted attempt and double-count the drop.
                Some((id, ev)) if !ids.contains(&id) => {
                    let what = match &ev {
                        Incoming::Msg(m) => m.name(),
                        Incoming::Gone(_) => "gone",
                    };
                    info!("ignoring stale {what} from dropped {id}");
                }
                Some((id, Incoming::Msg(msg))) => {
                    let seq = match &msg {
                        Msg::FwdOk { seq, .. } | Msg::BwdOk { seq, .. }
                        | Msg::FullOk { seq, .. } => Some(*seq),
                        _ => None,
                    };
                    match seq.and_then(|s| seq2slot.get(&s)) {
                        Some(&j) if slots[j].is_none() => {
                            slots[j] = Some(msg);
                            got += 1;
                        }
                        // Stale (pre-restart) or duplicate response.
                        _ => info!("ignoring stale {} from {id}", msg.name()),
                    }
                }
                Some((id, Incoming::Gone(reason))) => {
                    return Phase::Fault { dead: vec![id], reason };
                }
                None => {
                    // recv timed out before the phase deadline only for
                    // the loopback (which is synchronous): whoever has no
                    // response now never answers.
                    return Phase::Fault {
                        dead: missing_ids(&slots, ids),
                        reason: "no response".into(),
                    };
                }
            }
        }
        Phase::Complete(slots.into_iter().map(|s| s.expect("all slots filled")).collect())
    }

    // ------------------------------------------------------------- eval

    /// Global model at cut v — same composition as the in-process
    /// engine's.
    pub fn global_params(&self, cut: usize) -> Params {
        if self.cfg.scheme == SchemeKind::Fl {
            return self.w_full.clone();
        }
        let nc = self.rt.spec().cut(cut).client_params;
        match &self.client_side {
            NetClientSide::Shared(w) => join_params(&w[..nc], &self.ws[nc..]),
            NetClientSide::PerClient(reps) => {
                let rho = 1.0 / reps.len() as f64;
                let first = reps.values().next().expect("at least one replica");
                let mut wc_avg = tensor::zeros_like(&first[..nc]);
                for w in reps.values() {
                    tensor::weighted_accumulate(&mut wc_avg, &w[..nc], rho);
                }
                join_params(&wc_avg, &self.ws[nc..])
            }
        }
    }

    /// Test-set (loss, accuracy) of the global model — the same
    /// per-batch fan-out and fixed-order reduction as the in-process
    /// engine (eval is always coordinator-side; participants never see
    /// the test split).
    pub fn evaluate(&self, cut: usize) -> anyhow::Result<(f64, f64)> {
        let total = self.test.len();
        anyhow::ensure!(total > 0, "empty test set");
        let eb = self.rt.spec().eval_batch;
        let w = Arc::new(self.global_params(cut));
        let rt = &self.rt;
        let test = &self.test;
        let bounds: Vec<(usize, usize)> =
            (0..total).step_by(eb).map(|lo| (lo, (lo + eb).min(total))).collect();
        let bounds_ref = &bounds;
        let scores = self.pool.map_with_scratch(bounds.len(), |scratch, b| {
            let (lo, hi) = bounds_ref[b];
            let idx: Vec<usize> = (lo..hi).collect();
            let (x, y) = test.batch(&idx);
            let (l, c) = rt.eval_with(scratch, &w, &x, &y)?;
            Ok((l as f64 * (hi - lo) as f64, c as f64))
        })?;
        let mut loss = 0.0;
        let mut correct = 0.0;
        for (l, c) in scores {
            loss += l;
            correct += c;
        }
        Ok((loss / total as f64, correct / total as f64))
    }

    /// End the run: every live participant gets a [`Msg::Shutdown`].
    pub fn shutdown(&mut self) {
        for id in self.transport.clients() {
            self.transport.send(id, &Msg::Shutdown);
        }
    }
}

/// Cohort slots still waiting on a response.
fn missing_ids(slots: &[Option<Msg>], ids: &[u64]) -> Vec<u64> {
    slots
        .iter()
        .enumerate()
        .filter(|(_, s)| s.is_none())
        .map(|(j, _)| ids[j])
        .collect()
}

/// CLI/wire spelling of a partition (the inverse of
/// [`Partition::parse`]).
pub fn partition_str(p: &Partition) -> String {
    match p {
        Partition::Iid => "iid".into(),
        Partition::Dirichlet(a) => format!("dirichlet:{a}"),
        Partition::Shards(s) => format!("shards:{s}"),
    }
}

// ----------------------------------------------------------- digesting

/// FNV-1a over a byte stream — a tiny content digest for bitwise
/// comparisons across processes (stats files, final parameters).
#[derive(Clone, Copy)]
pub struct Digest(u64);

impl Digest {
    pub fn new() -> Digest {
        Digest(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x1000_0000_01b3);
        }
        self
    }

    pub fn f32s(&mut self, xs: &[f32]) -> &mut Self {
        for &x in xs {
            self.bytes(&x.to_bits().to_le_bytes());
        }
        self
    }

    pub fn f64(&mut self, x: f64) -> &mut Self {
        self.bytes(&x.to_bits().to_le_bytes())
    }

    pub fn value(&self) -> u64 {
        self.0
    }
}

impl Default for Digest {
    fn default() -> Self {
        Digest::new()
    }
}

/// Bitwise digest of a parameter set.
pub fn params_digest(params: &Params) -> u64 {
    let mut d = Digest::new();
    for layer in params {
        d.f32s(layer);
    }
    d.value()
}

/// Bitwise digest of a run's stats (every float hashed at full
/// precision) — two runs agree iff their digests do, within FNV odds.
///
/// `tests/net_equivalence.rs` and the `sfl-coordinator` binary compare
/// runs across processes through this digest.
pub fn stats_digest(stats: &[RoundStats]) -> u64 {
    let mut d = Digest::new();
    for s in stats {
        d.bytes(&(s.round as u64).to_le_bytes());
        d.bytes(&(s.cut as u64).to_le_bytes());
        d.bytes(&(s.participants as u64).to_le_bytes());
        d.f64(s.train_loss);
        d.f64(s.comm.uplink_bits);
        d.f64(s.comm.downlink_bits);
        d.f64(s.latency.uplink_leg);
        d.f64(s.latency.downlink_leg);
        match s.test {
            Some((l, a)) => {
                d.bytes(&[1]);
                d.f64(l);
                d.f64(a);
            }
            None => {
                d.bytes(&[0]);
            }
        }
    }
    d.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ScenarioConfig;

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            rounds: 1,
            tau: 1,
            samples_per_client: 16,
            test_samples: 64,
            eval_every: 1,
            threads: 1,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn partition_str_is_parse_inverse() {
        for p in [Partition::Iid, Partition::Dirichlet(0.3), Partition::Shards(2)] {
            assert_eq!(Partition::parse(&partition_str(&p)).unwrap(), p);
        }
    }

    #[test]
    fn digests_are_bit_sensitive() {
        let a = vec![vec![1.0f32, 2.0], vec![3.0]];
        let mut b = a.clone();
        assert_eq!(params_digest(&a), params_digest(&b));
        // Flip one mantissa bit: the digest must move.
        b[1][0] = f32::from_bits(b[1][0].to_bits() ^ 1);
        assert_ne!(params_digest(&a), params_digest(&b));
        // ±0.0 compare equal as floats but are distinct bit patterns.
        assert_ne!(
            params_digest(&vec![vec![0.0f32]]),
            params_digest(&vec![vec![-0.0f32]])
        );
    }

    #[test]
    fn net_trainer_rejects_simulator_only_scenarios() {
        let manifest = Manifest::builtin();
        // Partial participation is an in-process simulator feature.
        let mut cfg = tiny_cfg();
        cfg.scenario = ScenarioConfig { participation: 0.5, ..Default::default() };
        assert!(NetTrainer::loopback(&manifest, cfg, 2).is_err());
        // Zero participants cannot form a federation.
        assert!(NetTrainer::loopback(&manifest, tiny_cfg(), 0).is_err());
    }

    /// Loopback wrapper reproducing the TCP drop race: the peer's first
    /// fwd-ok is lost (deadline fault), and — as shutting a dropped
    /// peer's socket does — a terminal Gone for it arrives AFTER the
    /// fault policy removed it.  The stale Gone must be discarded, not
    /// double-drop the peer and re-restart the round.
    struct StaleGoneTransport {
        inner: LoopbackTransport,
        swallowed: bool,
        stale_gone: Option<u64>,
    }

    impl Transport for StaleGoneTransport {
        fn clients(&self) -> Vec<u64> {
            self.inner.clients()
        }

        fn send(&mut self, id: u64, msg: &Msg) {
            self.inner.send(id, msg)
        }

        fn recv(&mut self, timeout: Duration) -> Option<(u64, Incoming)> {
            if let Some(id) = self.stale_gone.take() {
                return Some((id, Incoming::Gone("connection closed".into())));
            }
            loop {
                let (id, ev) = self.inner.recv(timeout)?;
                if !self.swallowed && id == 1 {
                    if let Incoming::Msg(Msg::FwdOk { .. }) = ev {
                        self.swallowed = true;
                        continue; // lost on the wire
                    }
                }
                return Some((id, ev));
            }
        }

        fn drop_client(&mut self, id: u64) {
            self.inner.drop_client(id);
            self.stale_gone = Some(id);
        }
    }

    #[test]
    fn stale_gone_after_drop_is_discarded() {
        let manifest = Manifest::builtin();
        let transport = StaleGoneTransport {
            inner: LoopbackTransport::new(&[0, 1], 1).unwrap(),
            swallowed: false,
            stale_gone: None,
        };
        let mut nt =
            NetTrainer::new(&manifest, tiny_cfg(), Duration::from_secs(60), transport).unwrap();
        let stats = nt.run(2).unwrap();
        // Exactly one drop of exactly peer 1, and the restarted round
        // completes over the survivor.
        assert_eq!(nt.dropped(), &[1]);
        assert_eq!(nt.live(), vec![0]);
        assert_eq!(stats[0].participants, 1);
    }

    /// Loopback wrapper whose peer 1 answers its first fwd-req with a
    /// well-formed but wrong-typed message carrying the matching seq.
    struct WrongTypeTransport {
        inner: LoopbackTransport,
        tampered: bool,
    }

    impl Transport for WrongTypeTransport {
        fn clients(&self) -> Vec<u64> {
            self.inner.clients()
        }

        fn send(&mut self, id: u64, msg: &Msg) {
            self.inner.send(id, msg)
        }

        fn recv(&mut self, timeout: Duration) -> Option<(u64, Incoming)> {
            let (id, ev) = self.inner.recv(timeout)?;
            if !self.tampered && id == 1 {
                if let Incoming::Msg(Msg::FwdOk { seq, .. }) = &ev {
                    self.tampered = true;
                    let wrong = Msg::BwdOk { seq: *seq, grad: Params::new() };
                    return Some((id, Incoming::Msg(wrong)));
                }
            }
            Some((id, ev))
        }

        fn drop_client(&mut self, id: u64) {
            self.inner.drop_client(id)
        }
    }

    #[test]
    fn wrong_typed_reply_drops_only_the_offender() {
        let manifest = Manifest::builtin();
        let transport = WrongTypeTransport {
            inner: LoopbackTransport::new(&[0, 1], 1).unwrap(),
            tampered: false,
        };
        let mut nt =
            NetTrainer::new(&manifest, tiny_cfg(), Duration::from_secs(60), transport).unwrap();
        // One buggy participant must not kill the federation: peer 1 is
        // dropped via the fault policy and the run completes over peer 0.
        let stats = nt.run(2).unwrap();
        assert_eq!(nt.dropped(), &[1]);
        assert_eq!(nt.live(), vec![0]);
        assert_eq!(stats[0].participants, 1);
    }

    #[test]
    fn run_round_rejects_out_of_range_cuts() {
        let manifest = Manifest::builtin();
        let mut nt = NetTrainer::loopback(&manifest, tiny_cfg(), 1).unwrap();
        assert!(nt.run_round(0).is_err());
        assert!(nt.run_round(crate::model::NUM_CUTS + 1).is_err());
    }

    #[test]
    fn loopback_round_runs_and_reports() {
        let manifest = Manifest::builtin();
        let mut nt = NetTrainer::loopback(&manifest, tiny_cfg(), 2).unwrap();
        let stats = nt.run(2).unwrap();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].participants, 2);
        assert!(stats[0].train_loss.is_finite());
        let (loss, acc) = stats[0].test.unwrap();
        assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
        assert!(nt.dropped().is_empty());
        nt.shutdown();
    }
}
