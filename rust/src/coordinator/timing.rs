//! Per-scheme simulated round latency, combining the wireless/compute
//! models (eqs 12–16, 29) with an allocation policy.
//!
//! Downlink differences per scheme:
//! * SFL-GA broadcasts ONE aggregated gradient — every client receives the
//!   same transmission concurrently, so the downlink time is the slowest
//!   client's broadcast reception (eq 13 with the full band).
//! * SFL / PSL unicast per-client gradients sequentially on the full band
//!   (TDM), so downlink times add.
//! * SFL additionally pays client-model upload (uplink, with the round's
//!   bandwidth allocation) and aggregated-client-model broadcast.
//! * FL uploads the whole model and receives one model broadcast.

use crate::allocator::Allocation;
use crate::latency::{self, ComputeConfig};
use crate::model::{CutSpec, ShapeSpec};
use crate::wireless::{ChannelState, NetConfig, rate};

use super::plan::{CotangentRoute, RoundPlan};
use super::SchemeKind;

/// How the round's bandwidth / server-CPU are allocated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Solve P2.1 (the paper's Algorithm 1 inner step).
    Optimal,
    /// Equal split (the "fixed resource" baseline of Fig. 6).
    Equal,
}

/// Latency breakdown for one round.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundLatency {
    pub uplink_leg: f64,
    pub downlink_leg: f64,
}

impl RoundLatency {
    pub fn total(&self) -> f64 {
        self.uplink_leg + self.downlink_leg
    }
}

/// Simulated latency of one round of `scheme` at cut v (τ epochs).
///
/// Split schemes pay τ× the smashed-data exchange; model-aggregation
/// traffic (SFL's w^c, FL's w) is once per round.
#[allow(clippy::too_many_arguments)]
pub fn round_latency(
    scheme: SchemeKind,
    spec: &ShapeSpec,
    cut: &CutSpec,
    net: &NetConfig,
    comp: &ComputeConfig,
    state: &ChannelState,
    policy: AllocPolicy,
    tau: usize,
) -> RoundLatency {
    match scheme.plan() {
        RoundPlan::Full => fl_latency(spec, net, comp, state),
        plan => split_latency(plan, spec, cut, net, comp, state, policy, tau),
    }
}

/// Allocate resources for the split-scheme uplink leg.
pub fn allocate(
    spec: &ShapeSpec,
    cut: &CutSpec,
    net: &NetConfig,
    comp: &ComputeConfig,
    state: &ChannelState,
    policy: AllocPolicy,
) -> Allocation {
    let problem = crate::allocator::build_problem(spec, cut, net, comp, state);
    match policy {
        AllocPolicy::Optimal => problem.solve(),
        AllocPolicy::Equal => problem.solve_equal(),
    }
}

#[allow(clippy::too_many_arguments)]
fn split_latency(
    plan: RoundPlan,
    spec: &ShapeSpec,
    cut: &CutSpec,
    net: &NetConfig,
    comp: &ComputeConfig,
    state: &ChannelState,
    policy: AllocPolicy,
    tau: usize,
) -> RoundLatency {
    let alloc = allocate(spec, cut, net, comp, state, policy);
    let n = state.gains.len();
    let smashed = latency::smashed_bits(cut, comp);
    let tau_f = tau as f64;

    // Uplink leg: χ from the allocation covers smashed upload + client FP
    // + server compute (eq 31b), once per epoch.
    let mut uplink_leg = tau_f * alloc.chi;
    // Downlink gradients.
    let down_rates: Vec<f64> = (0..n)
        .map(|i| rate(net.bandwidth, net.p_server, state.gains[i], net.n0))
        .collect();
    // Downlink leg takes the max over clients: the slowest deployment
    // member gates the BP time under heterogeneity.
    let f_min = comp
        .client_flops(n, n as u64)
        .into_iter()
        .fold(f64::INFINITY, f64::min);
    let bwd = latency::client_bwd_latency(cut, comp, f_min);
    let mut downlink_leg = match plan.route() {
        Some(CotangentRoute::Broadcast) => {
            // One broadcast: all clients listen; slowest receiver gates.
            let t_bc = down_rates
                .iter()
                .map(|&r| latency::comm_latency(smashed, r))
                .fold(0.0, f64::max);
            tau_f * (t_bc + bwd)
        }
        _ => {
            // Sequential unicasts: transmissions add; every client then
            // runs BP (overlapped except the last, so add one bwd).
            let t_uni: f64 = down_rates
                .iter()
                .map(|&r| latency::comm_latency(smashed, r))
                .sum();
            tau_f * (t_uni + bwd)
        }
    };

    if plan.pays_client_fedavg() {
        // Client-side model aggregation: upload w^c over the allocated
        // uplink bandwidth, broadcast the aggregate.
        let wc_bits = latency::model_bits(cut.phi, comp);
        let up_extra = (0..n)
            .map(|i| {
                let r = rate(alloc.bandwidth[i], alloc.power[i], state.gains[i], net.n0);
                latency::comm_latency(wc_bits, r)
            })
            .fold(0.0, f64::max);
        uplink_leg += up_extra;
        let bc_extra = down_rates
            .iter()
            .map(|&r| latency::comm_latency(wc_bits, r))
            .fold(0.0, f64::max);
        downlink_leg += bc_extra;
    }

    RoundLatency { uplink_leg, downlink_leg }
}

fn fl_latency(
    spec: &ShapeSpec,
    net: &NetConfig,
    comp: &ComputeConfig,
    state: &ChannelState,
) -> RoundLatency {
    let n = state.gains.len();
    let w_bits = latency::model_bits(spec.total_params, comp);
    // Full fwd+bwd locally on the weakest hardware (entire model).
    let total_fwd: f64 = spec.cuts.last().map(|c| c.flops_client_fwd + c.flops_server_fwd).unwrap();
    let total_bwd: f64 = spec.cuts.last().map(|c| c.flops_client_bwd + c.flops_server_bwd).unwrap();
    let f_min = comp
        .client_flops(n, n as u64)
        .into_iter()
        .fold(f64::INFINITY, f64::min);
    let local = comp.samples_per_round as f64 * (total_fwd + total_bwd) / f_min;
    // Equal uplink bandwidth split for the model upload.
    let b_each = net.bandwidth / n as f64;
    let uplink_leg = (0..n)
        .map(|i| {
            let r = rate(b_each, net.p_max, state.gains[i], net.n0);
            local + latency::comm_latency(w_bits, r)
        })
        .fold(0.0, f64::max);
    let downlink_leg = (0..n)
        .map(|i| {
            let r = rate(net.bandwidth, net.p_server, state.gains[i], net.n0);
            latency::comm_latency(w_bits, r)
        })
        .fold(0.0, f64::max);
    RoundLatency { uplink_leg, downlink_leg }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;
    use crate::wireless::Channel;

    type Ctx = (ShapeSpec, NetConfig, ComputeConfig, ChannelState);

    fn setup() -> Ctx {
        let m = Manifest::builtin();
        let spec = m.for_dataset("mnist").unwrap().clone();
        let net = NetConfig::default();
        let mut ch = Channel::new(net.clone(), 10, 11);
        let state = ch.draw_round();
        (spec, net, ComputeConfig::default(), state)
    }

    fn lat(ctx: &Ctx, sk: SchemeKind, v: usize, policy: AllocPolicy, tau: usize) -> RoundLatency {
        round_latency(sk, &ctx.0, ctx.0.cut(v), &ctx.1, &ctx.2, &ctx.3, policy, tau)
    }

    #[test]
    fn broadcast_beats_unicast_downlink() {
        let ctx = setup();
        let ga = lat(&ctx, SchemeKind::SflGa, 2, AllocPolicy::Equal, 1);
        let psl = lat(&ctx, SchemeKind::Psl, 2, AllocPolicy::Equal, 1);
        assert!(ga.downlink_leg < psl.downlink_leg, "{} vs {}", ga.downlink_leg, psl.downlink_leg);
        assert_eq!(ga.uplink_leg, psl.uplink_leg);
    }

    #[test]
    fn sfl_pays_model_aggregation_latency() {
        let ctx = setup();
        let sfl = lat(&ctx, SchemeKind::Sfl, 2, AllocPolicy::Equal, 1);
        let psl = lat(&ctx, SchemeKind::Psl, 2, AllocPolicy::Equal, 1);
        assert!(sfl.total() > psl.total());
    }

    #[test]
    fn optimal_allocation_no_worse_than_equal() {
        let ctx = setup();
        for v in 1..=4 {
            let opt = lat(&ctx, SchemeKind::SflGa, v, AllocPolicy::Optimal, 1);
            let eq = lat(&ctx, SchemeKind::SflGa, v, AllocPolicy::Equal, 1);
            assert!(
                opt.uplink_leg <= eq.uplink_leg * (1.0 + 1e-6),
                "v={v}: {} > {}",
                opt.uplink_leg,
                eq.uplink_leg
            );
        }
    }

    #[test]
    fn fl_slowest_on_weak_clients() {
        // With 0.1 GHz clients and a 1.7M-param model, FL's local compute
        // dominates every split scheme (the paper's Fig. 5 ordering).
        let ctx = setup();
        let fl = lat(&ctx, SchemeKind::Fl, 2, AllocPolicy::Equal, 1);
        let ga = lat(&ctx, SchemeKind::SflGa, 2, AllocPolicy::Optimal, 1);
        assert!(fl.total() > ga.total(), "fl {} vs ga {}", fl.total(), ga.total());
    }

    #[test]
    fn tau_scales_exchange_but_not_aggregation() {
        let ctx = setup();
        let l1 = lat(&ctx, SchemeKind::Sfl, 1, AllocPolicy::Equal, 1);
        let l3 = lat(&ctx, SchemeKind::Sfl, 1, AllocPolicy::Equal, 3);
        // τ=3 costs less than 3× τ=1 because the model-aggregation part
        // is per-round.
        assert!(l3.total() > 2.0 * l1.total() * 0.9);
        assert!(l3.total() < 3.0 * l1.total());
    }
}
