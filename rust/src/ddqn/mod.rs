//! Pure-Rust Double-DQN: MLP Q-network + Adam + replay + double-Q targets.
//! Drives the cutting-point selection subproblem P2.2 (see [`crate::ccc`]).

pub mod adam;
pub mod agent;
pub mod nn;
pub mod replay;

pub use agent::{DdqnAgent, DdqnConfig};
pub use replay::Transition;
