//! Pure-Rust Double-DQN: MLP Q-network + Adam + replay + double-Q targets.
//! Drives the cutting-point selection subproblem P2.2 (see [`crate::ccc`]).
//!
//! Layout: [`nn`] is a minimal dense MLP with manual backprop, [`adam`]
//! its optimizer, [`replay`] the ring-buffer experience store, and
//! [`agent`] ties them into the ε-greedy Double-DQN of Algorithm 1
//! (online net selects the argmax action, target net evaluates it —
//! the van Hasselt 2016 decoupling).  Everything is deterministic in the
//! seed; no external crates.

pub mod adam;
pub mod agent;
pub mod nn;
pub mod replay;

pub use agent::{DdqnAgent, DdqnConfig};
pub use replay::Transition;
