//! Experience replay buffer (fixed-capacity ring + uniform sampling).

use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f32>,
    pub action: usize,
    pub reward: f64,
    pub next_state: Vec<f32>,
    pub done: bool,
}

#[derive(Debug)]
pub struct Replay {
    buf: Vec<Transition>,
    capacity: usize,
    head: usize,
}

impl Replay {
    pub fn new(capacity: usize) -> Replay {
        assert!(capacity > 0);
        Replay { buf: Vec::with_capacity(capacity), capacity, head: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Uniform sample with replacement (standard DQN practice).
    pub fn sample<'a>(&'a self, batch: usize, rng: &mut Pcg) -> Vec<&'a Transition> {
        assert!(!self.buf.is_empty());
        (0..batch).map(|_| &self.buf[rng.below(self.buf.len())]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: f64) -> Transition {
        Transition { state: vec![r as f32], action: 0, reward: r, next_state: vec![], done: false }
    }

    #[test]
    fn fills_then_wraps() {
        let mut rp = Replay::new(3);
        for i in 0..5 {
            rp.push(t(i as f64));
        }
        assert_eq!(rp.len(), 3);
        // Entries 0 and 1 overwritten by 3 and 4.
        let rewards: Vec<f64> = rp.buf.iter().map(|x| x.reward).collect();
        let mut sorted = rewards.clone();
        sorted.sort_by(f64::total_cmp);
        assert_eq!(sorted, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn sampling_covers_buffer() {
        let mut rp = Replay::new(10);
        for i in 0..10 {
            rp.push(t(i as f64));
        }
        let mut rng = Pcg::new(3, 0);
        let sample = rp.sample(1000, &mut rng);
        let mut seen = [false; 10];
        for s in sample {
            seen[s.reward as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    #[should_panic]
    fn sample_empty_panics() {
        let rp = Replay::new(4);
        let mut rng = Pcg::new(1, 1);
        let _ = rp.sample(1, &mut rng);
    }
}
