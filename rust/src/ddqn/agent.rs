//! Double-DQN agent (paper §IV-B2, eqs 38–40).
//!
//! Online net selects the argmax action at s'; the target net evaluates it
//! (eq 40's decoupling), which removes vanilla-DQN's max-operator
//! overestimation.  ε-greedy exploration with exponential decay; hard
//! target sync every `target_sync` learner steps.

use super::adam::{Adam, AdamConfig};
use super::nn::Mlp;
use super::replay::{Replay, Transition};
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct DdqnConfig {
    pub state_dim: usize,
    pub num_actions: usize,
    pub hidden: Vec<usize>,
    pub gamma: f64,
    pub lr: f64,
    pub batch: usize,
    pub replay_capacity: usize,
    /// Learner steps between hard target-network syncs.
    pub target_sync: usize,
    pub eps_start: f64,
    pub eps_end: f64,
    /// Multiplicative ε decay per act() call.
    pub eps_decay: f64,
    /// Minimum buffered transitions before learning starts.
    pub warmup: usize,
}

impl Default for DdqnConfig {
    fn default() -> Self {
        DdqnConfig {
            state_dim: 1,
            num_actions: 2,
            hidden: vec![64, 64],
            gamma: 0.9,
            lr: 1e-3,
            batch: 32,
            replay_capacity: 10_000,
            target_sync: 100,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay: 0.995,
            warmup: 64,
        }
    }
}

pub struct DdqnAgent {
    pub cfg: DdqnConfig,
    online: Mlp,
    target: Mlp,
    opt: Adam,
    replay: Replay,
    rng: Pcg,
    eps: f64,
    steps: usize,
}

impl DdqnAgent {
    pub fn new(cfg: DdqnConfig, seed: u64) -> DdqnAgent {
        let mut rng = Pcg::new(seed, 0xDD01);
        let mut dims = vec![cfg.state_dim];
        dims.extend_from_slice(&cfg.hidden);
        dims.push(cfg.num_actions);
        let online = Mlp::new(&dims, &mut rng);
        let mut target = Mlp::new(&dims, &mut rng);
        target.copy_from(&online);
        let opt = Adam::new(&online, AdamConfig { lr: cfg.lr, ..Default::default() });
        let replay = Replay::new(cfg.replay_capacity);
        let eps = cfg.eps_start;
        DdqnAgent { cfg, online, target, opt, replay, rng, eps, steps: 0 }
    }

    pub fn epsilon(&self) -> f64 {
        self.eps
    }

    /// Greedy Q-values for diagnostics.
    pub fn q_values(&self, state: &[f32]) -> Vec<f32> {
        self.online.forward(state)
    }

    /// ε-greedy action; decays ε.
    pub fn act(&mut self, state: &[f32]) -> usize {
        let a = if self.rng.uniform() < self.eps {
            self.rng.below(self.cfg.num_actions)
        } else {
            self.greedy(state)
        };
        self.eps = (self.eps * self.cfg.eps_decay).max(self.cfg.eps_end);
        a
    }

    /// Greedy action (no exploration, no decay) — evaluation mode.
    pub fn greedy(&self, state: &[f32]) -> usize {
        argmax(&self.online.forward(state))
    }

    pub fn remember(&mut self, t: Transition) {
        self.replay.push(t);
    }

    /// One learner step; returns the minibatch TD loss when training ran.
    pub fn train_step(&mut self) -> Option<f64> {
        if self.replay.len() < self.cfg.warmup.max(self.cfg.batch) {
            return None;
        }
        let batch = self.replay.sample(self.cfg.batch, &mut self.rng);
        let mut grads = self.online.zero_grads();
        let mut loss = 0.0;
        let scale = 1.0 / self.cfg.batch as f32;
        for tr in batch {
            // Double-Q target (eq 40): a* from online, value from target.
            let y = if tr.done {
                tr.reward
            } else {
                let a_star = argmax(&self.online.forward(&tr.next_state));
                let q_next = self.target.forward(&tr.next_state)[a_star] as f64;
                tr.reward + self.cfg.gamma * q_next
            };
            let cache = self.online.forward_cached(&tr.state);
            let q_sa = cache.output[tr.action] as f64;
            let err = (q_sa - y) as f32;
            loss += 0.5 * (err as f64) * (err as f64);
            let mut dout = vec![0.0f32; self.cfg.num_actions];
            dout[tr.action] = err * scale;
            self.online.backward(&cache, &dout, &mut grads);
        }
        self.opt.step(&mut self.online, &grads);
        self.steps += 1;
        if self.steps % self.cfg.target_sync == 0 {
            self.target.copy_from(&self.online);
        }
        Some(loss / self.cfg.batch as f64)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 1.0, 0.5]), 0);
        assert_eq!(argmax(&[0.0, 2.0, 2.0]), 1);
    }

    #[test]
    fn epsilon_decays_to_floor() {
        let mut agent = DdqnAgent::new(
            DdqnConfig { eps_decay: 0.5, eps_end: 0.1, ..Default::default() },
            1,
        );
        for _ in 0..100 {
            agent.act(&[0.0]);
        }
        assert!((agent.epsilon() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn no_training_before_warmup() {
        let mut agent = DdqnAgent::new(DdqnConfig { warmup: 10, ..Default::default() }, 2);
        for _ in 0..5 {
            agent.remember(Transition {
                state: vec![0.0],
                action: 0,
                reward: 0.0,
                next_state: vec![0.0],
                done: false,
            });
        }
        assert!(agent.train_step().is_none());
    }

    #[test]
    fn learns_two_armed_bandit() {
        // Single state, two actions, deterministic rewards 0 / 1.
        let cfg = DdqnConfig {
            state_dim: 1,
            num_actions: 2,
            hidden: vec![16],
            gamma: 0.0, // bandit: no bootstrapping
            lr: 5e-3,
            batch: 16,
            warmup: 16,
            eps_decay: 0.98,
            ..Default::default()
        };
        let mut agent = DdqnAgent::new(cfg, 3);
        for _ in 0..400 {
            let a = agent.act(&[1.0]);
            let r = if a == 1 { 1.0 } else { 0.0 };
            agent.remember(Transition {
                state: vec![1.0],
                action: a,
                reward: r,
                next_state: vec![1.0],
                done: true,
            });
            agent.train_step();
        }
        assert_eq!(agent.greedy(&[1.0]), 1);
        let q = agent.q_values(&[1.0]);
        assert!((q[1] as f64 - 1.0).abs() < 0.2, "Q(good) = {}", q[1]);
        assert!((q[0] as f64).abs() < 0.3, "Q(bad) = {}", q[0]);
    }

    #[test]
    fn learns_chain_mdp_with_bootstrapping() {
        // Two-state chain: s0 --a1--> s1 (r=0), s1 --a1--> terminal (r=1);
        // a0 anywhere terminates with r=0.  Optimal: pick a1 twice.
        // Q*(s0, a1) = γ·1, Q*(s1, a1) = 1.
        let cfg = DdqnConfig {
            state_dim: 2,
            num_actions: 2,
            hidden: vec![24],
            gamma: 0.9,
            lr: 5e-3,
            batch: 32,
            warmup: 32,
            target_sync: 50,
            eps_decay: 0.995,
            ..Default::default()
        };
        let mut agent = DdqnAgent::new(cfg, 7);
        let s0 = [1.0f32, 0.0];
        let s1 = [0.0f32, 1.0];
        for _ in 0..1500 {
            // episode
            let a0 = agent.act(&s0);
            if a0 == 0 {
                agent.remember(Transition {
                    state: s0.to_vec(),
                    action: 0,
                    reward: 0.0,
                    next_state: s0.to_vec(),
                    done: true,
                });
            } else {
                agent.remember(Transition {
                    state: s0.to_vec(),
                    action: 1,
                    reward: 0.0,
                    next_state: s1.to_vec(),
                    done: false,
                });
                let a1 = agent.act(&s1);
                let r = if a1 == 1 { 1.0 } else { 0.0 };
                agent.remember(Transition {
                    state: s1.to_vec(),
                    action: a1,
                    reward: r,
                    next_state: s1.to_vec(),
                    done: true,
                });
            }
            agent.train_step();
        }
        assert_eq!(agent.greedy(&s0), 1, "should walk the chain");
        assert_eq!(agent.greedy(&s1), 1, "should collect the reward");
        let q1 = agent.q_values(&s1)[1] as f64;
        assert!((q1 - 1.0).abs() < 0.25, "Q(s1, a1) = {q1}");
        let q0 = agent.q_values(&s0)[1] as f64;
        assert!((q0 - 0.9).abs() < 0.3, "Q(s0, a1) = {q0}");
    }
}
