//! Fully-connected Q-network: f32 MLP with ReLU hidden layers and a
//! linear head, plus exact manual backprop (verified by finite-difference
//! gradcheck in the tests).  This is the FCNN the paper's complexity
//! analysis assumes (§IV-C).

use crate::util::rng::Pcg;

/// One dense layer: row-major weights `[out][in]` + bias.
#[derive(Clone, Debug)]
pub struct Layer {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub n_in: usize,
    pub n_out: usize,
}

impl Layer {
    fn new(n_in: usize, n_out: usize, rng: &mut Pcg) -> Layer {
        // He-normal for ReLU nets.
        let std = (2.0 / n_in as f64).sqrt();
        let w = (0..n_in * n_out)
            .map(|_| (rng.normal() * std) as f32)
            .collect();
        Layer { w, b: vec![0.0; n_out], n_in, n_out }
    }

    fn forward(&self, x: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), self.n_in);
        out.clear();
        out.reserve(self.n_out);
        for o in 0..self.n_out {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let mut acc = self.b[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out.push(acc);
        }
    }
}

/// Gradients mirroring a network's layers.
#[derive(Clone, Debug)]
pub struct Grads {
    pub layers: Vec<(Vec<f32>, Vec<f32>)>, // (dW, db) per layer
}

/// Forward cache for one input: pre-activations per layer + the input.
pub struct Cache {
    input: Vec<f32>,
    /// Post-activation outputs of each hidden layer (ReLU applied).
    hidden: Vec<Vec<f32>>,
    /// Final linear output (Q-values).
    pub output: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Layer>,
}

impl Mlp {
    /// `dims` = [input, hidden..., output].
    pub fn new(dims: &[usize], rng: &mut Pcg) -> Mlp {
        assert!(dims.len() >= 2, "need at least input+output dims");
        let layers = dims
            .windows(2)
            .map(|w| Layer::new(w[0], w[1], rng))
            .collect();
        Mlp { layers }
    }

    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Q-values for one state.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut cur = x.to_vec();
        let mut next = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            layer.forward(&cur, &mut next);
            if i + 1 < self.layers.len() {
                for v in next.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            std::mem::swap(&mut cur, &mut next);
        }
        cur
    }

    /// Forward keeping activations for backprop.
    pub fn forward_cached(&self, x: &[f32]) -> Cache {
        let mut hidden = Vec::with_capacity(self.layers.len() - 1);
        let mut cur = x.to_vec();
        let mut next = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            layer.forward(&cur, &mut next);
            if i + 1 < self.layers.len() {
                for v in next.iter_mut() {
                    *v = v.max(0.0);
                }
                hidden.push(next.clone());
            }
            std::mem::swap(&mut cur, &mut next);
        }
        Cache { input: x.to_vec(), hidden, output: cur }
    }

    pub fn zero_grads(&self) -> Grads {
        Grads {
            layers: self
                .layers
                .iter()
                .map(|l| (vec![0.0; l.w.len()], vec![0.0; l.b.len()]))
                .collect(),
        }
    }

    /// Accumulate gradients for one sample given dL/d(output).
    /// ReLU masks are recovered from the cached post-activations.
    pub fn backward(&self, cache: &Cache, dout: &[f32], grads: &mut Grads) {
        let nl = self.layers.len();
        let mut delta = dout.to_vec();
        for li in (0..nl).rev() {
            let layer = &self.layers[li];
            let input: &[f32] = if li == 0 { &cache.input } else { &cache.hidden[li - 1] };
            let (dw, db) = &mut grads.layers[li];
            for o in 0..layer.n_out {
                let d = delta[o];
                if d != 0.0 {
                    let row = &mut dw[o * layer.n_in..(o + 1) * layer.n_in];
                    for (g, xi) in row.iter_mut().zip(input) {
                        *g += d * xi;
                    }
                    db[o] += d;
                }
            }
            if li > 0 {
                // Propagate: delta_in = W^T delta, masked by ReLU'(hidden).
                let mut din = vec![0.0f32; layer.n_in];
                for o in 0..layer.n_out {
                    let d = delta[o];
                    if d != 0.0 {
                        let row = &layer.w[o * layer.n_in..(o + 1) * layer.n_in];
                        for (di, wi) in din.iter_mut().zip(row) {
                            *di += d * wi;
                        }
                    }
                }
                let act = &cache.hidden[li - 1];
                for (di, &a) in din.iter_mut().zip(act) {
                    if a <= 0.0 {
                        *di = 0.0;
                    }
                }
                delta = din;
            }
        }
    }

    /// Hard-copy weights (target-network sync).
    pub fn copy_from(&mut self, other: &Mlp) {
        for (dst, src) in self.layers.iter_mut().zip(&other.layers) {
            dst.w.copy_from_slice(&src.w);
            dst.b.copy_from_slice(&src.b);
        }
    }

    /// Flat views for the optimizer: (&mut w, &mut b) per layer.
    pub fn params_mut(&mut self) -> Vec<(&mut Vec<f32>, &mut Vec<f32>)> {
        self.layers.iter_mut().map(|l| (&mut l.w, &mut l.b)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_net() -> Mlp {
        let mut rng = Pcg::new(1, 1);
        Mlp::new(&[3, 8, 5, 2], &mut rng)
    }

    #[test]
    fn forward_shapes() {
        let net = toy_net();
        let q = net.forward(&[0.1, -0.2, 0.3]);
        assert_eq!(q.len(), 2);
        assert_eq!(net.num_params(), 3 * 8 + 8 + 8 * 5 + 5 + 5 * 2 + 2);
    }

    #[test]
    fn cached_forward_matches_plain() {
        let net = toy_net();
        let x = [0.5, -1.0, 2.0];
        let plain = net.forward(&x);
        let cache = net.forward_cached(&x);
        assert_eq!(plain, cache.output);
    }

    #[test]
    fn gradcheck_against_finite_differences() {
        let mut net = toy_net();
        let x = [0.7f32, -0.3, 0.9];
        // Loss: 0.5 * sum(q^2) → dout = q.
        let cache = net.forward_cached(&x);
        let mut grads = net.zero_grads();
        net.backward(&cache, &cache.output.clone(), &mut grads);

        let loss = |net: &Mlp| -> f64 {
            net.forward(&x).iter().map(|&q| 0.5 * (q as f64) * (q as f64)).sum()
        };
        let eps = 1e-3f32;
        for li in 0..net.layers.len() {
            // Spot-check a handful of weights per layer.
            for &wi in &[0usize, 1, net.layers[li].w.len() - 1] {
                let orig = net.layers[li].w[wi];
                net.layers[li].w[wi] = orig + eps;
                let lp = loss(&net);
                net.layers[li].w[wi] = orig - eps;
                let lm = loss(&net);
                net.layers[li].w[wi] = orig;
                let numeric = (lp - lm) / (2.0 * eps as f64);
                let analytic = grads.layers[li].0[wi] as f64;
                assert!(
                    (numeric - analytic).abs() < 2e-2 * (1.0 + numeric.abs()),
                    "layer {li} w[{wi}]: numeric {numeric} vs analytic {analytic}"
                );
            }
            let orig = net.layers[li].b[0];
            net.layers[li].b[0] = orig + eps;
            let lp = loss(&net);
            net.layers[li].b[0] = orig - eps;
            let lm = loss(&net);
            net.layers[li].b[0] = orig;
            let numeric = (lp - lm) / (2.0 * eps as f64);
            let analytic = grads.layers[li].1[0] as f64;
            assert!(
                (numeric - analytic).abs() < 2e-2 * (1.0 + numeric.abs()),
                "layer {li} b[0]: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn copy_from_syncs_outputs() {
        let net = toy_net();
        let mut rng = Pcg::new(9, 9);
        let mut other = Mlp::new(&[3, 8, 5, 2], &mut rng);
        let x = [0.2, 0.4, -0.6];
        assert_ne!(net.forward(&x), other.forward(&x));
        other.copy_from(&net);
        assert_eq!(net.forward(&x), other.forward(&x));
    }

    #[test]
    fn relu_kills_negative_paths() {
        // Single hidden unit forced negative: gradient through it is zero.
        let mut rng = Pcg::new(3, 3);
        let mut net = Mlp::new(&[1, 1, 1], &mut rng);
        net.layers[0].w[0] = 1.0;
        net.layers[0].b[0] = -10.0; // hidden pre-act always << 0 for small x
        net.layers[1].w[0] = 1.0;
        let cache = net.forward_cached(&[0.5]);
        let mut grads = net.zero_grads();
        net.backward(&cache, &[1.0], &mut grads);
        assert_eq!(grads.layers[0].0[0], 0.0);
        assert_eq!(grads.layers[1].0[0], 0.0); // input to layer 1 is 0
        assert_eq!(grads.layers[1].1[0], 1.0); // bias still learns
    }
}
