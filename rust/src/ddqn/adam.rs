//! Adam optimizer over the Q-network's per-layer (w, b) buffers.

use super::nn::{Grads, Mlp};

#[derive(Clone, Debug)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

#[derive(Clone, Debug)]
pub struct Adam {
    cfg: AdamConfig,
    /// (m, v) moments per layer for (w, b).
    moments: Vec<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>)>,
    t: u64,
}

impl Adam {
    pub fn new(net: &Mlp, cfg: AdamConfig) -> Adam {
        let moments = net
            .layers
            .iter()
            .map(|l| {
                (
                    vec![0.0; l.w.len()],
                    vec![0.0; l.w.len()],
                    vec![0.0; l.b.len()],
                    vec![0.0; l.b.len()],
                )
            })
            .collect();
        Adam { cfg, moments, t: 0 }
    }

    pub fn step(&mut self, net: &mut Mlp, grads: &Grads) {
        self.t += 1;
        let b1t = 1.0 - self.cfg.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.cfg.beta2.powi(self.t as i32);
        for (layer, ((dw, db), (mw, vw, mb, vb))) in net
            .layers
            .iter_mut()
            .zip(grads.layers.iter().map(|(a, b)| (a, b)).zip(&mut self.moments))
        {
            update(&mut layer.w, dw, mw, vw, &self.cfg, b1t, b2t);
            update(&mut layer.b, db, mb, vb, &self.cfg, b1t, b2t);
        }
    }
}

fn update(
    params: &mut [f32],
    grads: &[f32],
    m: &mut [f64],
    v: &mut [f64],
    cfg: &AdamConfig,
    b1t: f64,
    b2t: f64,
) {
    for i in 0..params.len() {
        let g = grads[i] as f64;
        m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * g;
        v[i] = cfg.beta2 * v[i] + (1.0 - cfg.beta2) * g * g;
        let mhat = m[i] / b1t;
        let vhat = v[i] / b2t;
        params[i] -= (cfg.lr * mhat / (vhat.sqrt() + cfg.eps)) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn adam_minimizes_quadratic() {
        // Fit a 1-layer net y = w*x to minimize (w*x - 3x)^2 → w → 3.
        let mut rng = Pcg::new(2, 2);
        let mut net = Mlp::new(&[1, 1], &mut rng);
        let mut opt = Adam::new(&net, AdamConfig { lr: 0.05, ..Default::default() });
        for _ in 0..600 {
            let x = [1.0f32];
            let cache = net.forward_cached(&x);
            let err = cache.output[0] - 3.0;
            let mut grads = net.zero_grads();
            net.backward(&cache, &[err], &mut grads);
            opt.step(&mut net, &grads);
        }
        let out = net.forward(&[1.0])[0];
        assert!((out - 3.0).abs() < 1e-2, "converged to {out}");
    }

    #[test]
    fn step_count_bias_correction() {
        // First step with grad g moves param by ~lr regardless of g scale.
        let mut rng = Pcg::new(4, 4);
        let mut net = Mlp::new(&[1, 1], &mut rng);
        let w0 = net.layers[0].w[0];
        let mut opt = Adam::new(&net, AdamConfig { lr: 0.1, ..Default::default() });
        let mut grads = net.zero_grads();
        grads.layers[0].0[0] = 1e-4; // tiny gradient
        opt.step(&mut net, &grads);
        let dw = (net.layers[0].w[0] - w0).abs();
        assert!((dw - 0.1).abs() < 0.01, "first-step size {dw}");
    }
}
