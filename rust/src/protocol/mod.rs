//! The coordinator ⇄ participant message protocol (DESIGN.md §Transport).
//!
//! One round trip of the paper's §II-A loop maps onto four messages:
//! [`Msg::FwdReq`] ships the client-side weights and the batch key down,
//! [`Msg::FwdOk`] returns the smashed activations (eq 1) with the batch's
//! labels, [`Msg::BwdReq`] routes the cotangent back — ONE aggregated
//! tensor under SFL-GA's eq-5 broadcast, a per-client tensor under the
//! SFL/PSL unicast — and [`Msg::BwdOk`] returns the client-side VJP
//! (eq 6).  FL rides [`Msg::FullReq`]/[`Msg::FullOk`] (τ local steps on a
//! shipped full model).  [`Msg::Join`]/[`Msg::Welcome`] are the
//! rendezvous, [`Msg::RoundDone`] marks round boundaries and
//! [`Msg::Shutdown`] ends a run.
//!
//! Participants are **stateless between rounds**: all model state, every
//! reduction and every scheme policy live on the coordinator (the
//! Psyche/xaynet role split) — a participant only derives its own batches
//! (a pure function of `(seed, client, step)`, configured once by
//! [`RunSetup`]) and runs the client-side forward/backward kernels.  The
//! only cross-message state is the in-flight forward context a
//! [`Msg::BwdReq`] resolves by `seq`.
//!
//! Encoding: tag byte + fields over [`wire`]'s LE primitives, one message
//! per length-prefixed frame.  [`Msg::decode`] never panics on arbitrary
//! or truncated input, and encode→decode is bit-exact (f32 bits travel
//! raw) — both properties are fuzzed in `tests/protocol.rs`.

pub mod wire;

use crate::runtime::Tensor;
use crate::tensor::Params;
use wire::{ByteReader, ByteWriter};

/// Bumped on any wire-format change; [`Msg::Join`] carries it and the
/// coordinator rejects mismatches at rendezvous.  v2 added the churn
/// handshake ([`Msg::Rejoin`] / [`Msg::Sync`]); v3 made [`RunSetup`]
/// carry the model-registry id and its cut-menu length, so both sides
/// validate cuts against the SAME peer-agreed menu instead of a
/// hard-coded constant.
pub const PROTO_VERSION: u32 = 3;

/// Per-run configuration a participant needs to derive its own batch
/// stream and run FL local steps — shipped once in [`Msg::Welcome`].
#[derive(Clone, Debug, PartialEq)]
pub struct RunSetup {
    /// Dataset name (selects the manifest entry of `model`).
    pub dataset: String,
    /// Run seed: the participant's `ClientSampler` derives from it, so
    /// its batches are bitwise the ones the in-process trainer would draw.
    pub seed: u64,
    /// Data partition in CLI syntax (`iid`, `dirichlet:0.3`, `shards:2`).
    pub partition: String,
    /// Samples per client shard.
    pub samples_per_client: usize,
    /// Model-registry architecture id (`builtin`, `vgg`, `txf`): both
    /// sides resolve it through `model::registry`, so the whole cut menu
    /// is pinned by one string.
    pub model: String,
    /// Length of the coordinator's cut menu, cross-checked against the
    /// participant's own resolution of `model` at configure time — a
    /// registry drift between binaries fails loudly at rendezvous, not
    /// as a shape error mid-round.
    pub num_cuts: u32,
}

impl RunSetup {
    fn encode(&self, w: &mut ByteWriter) {
        w.str(&self.dataset);
        w.u64(self.seed);
        w.str(&self.partition);
        w.usize(self.samples_per_client);
        w.str(&self.model);
        w.u32(self.num_cuts);
    }

    fn decode(r: &mut ByteReader) -> anyhow::Result<RunSetup> {
        Ok(RunSetup {
            dataset: r.str()?,
            seed: r.u64()?,
            partition: r.str()?,
            samples_per_client: r.usize()?,
            model: r.str()?,
            num_cuts: r.u32()?,
        })
    }
}

/// The protocol messages.  `seq` ties a response to its request and is
/// globally unique per coordinator run (round restarts after a fault
/// re-issue work under fresh seqs, so stale replies are recognizably
/// stale).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// participant → coordinator: rendezvous claim of `client` id.
    Join { client: u64, version: u32 },
    /// coordinator → participant: rendezvous accept + run configuration.
    Welcome { setup: RunSetup },
    /// coordinator → participant: run the eq-1 client forward at `cut`
    /// with weights `wc` on the participant's own batch for `step`.
    FwdReq { seq: u64, cut: u32, step: u64, wc: Params },
    /// participant → coordinator: the smashed activations plus the
    /// batch's one-hot labels (labels travel with the smashed data, as in
    /// SplitFed — the coordinator never touches client data directly).
    FwdOk { seq: u64, smashed: Tensor, labels: Tensor },
    /// coordinator → participant: the routed cotangent for `seq` — the
    /// eq-5 aggregated broadcast (same tensor to everyone) or the
    /// per-client unicast, depending on the scheme's `RoundPlan`.
    BwdReq { seq: u64, cotangent: Tensor },
    /// participant → coordinator: the eq-6 client-side VJP.
    BwdOk { seq: u64, grad: Params },
    /// coordinator → participant (FL): run `tau` local SGD steps from
    /// `w`, batches keyed from `step0`.
    FullReq { seq: u64, step0: u64, tau: u32, lr: f32, w: Params },
    /// participant → coordinator (FL): τ-averaged train loss + the
    /// locally-updated model.
    FullOk { seq: u64, loss: f64, w: Params },
    /// coordinator → participant: round boundary (any in-flight forward
    /// context is dropped).
    RoundDone { round: u64 },
    /// coordinator → participant: end of run.
    Shutdown,
    /// participant → coordinator: a previously-seen participant dialing
    /// back in mid-run (after a drop or a coordinator blip).  Valid any
    /// time the coordinator polls for admissions between rounds; answered
    /// with [`Msg::Sync`].  A brand-new late joiner may open with a plain
    /// [`Msg::Join`] instead — participants are stateless, so the
    /// coordinator treats both identically.
    Rejoin { client: u64, version: u32 },
    /// coordinator → participant: mid-run admission accept — the run
    /// configuration plus the round index the participant will first
    /// compute in.  All client-side model state stays coordinator-held
    /// (the rejoiner gets the scheme-appropriate state there: the shared
    /// model, or a cold `(seed, id)`-keyed replica), so nothing else
    /// needs to travel.
    Sync { round: u64, setup: RunSetup },
}

const TAG_JOIN: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_FWD_REQ: u8 = 3;
const TAG_FWD_OK: u8 = 4;
const TAG_BWD_REQ: u8 = 5;
const TAG_BWD_OK: u8 = 6;
const TAG_FULL_REQ: u8 = 7;
const TAG_FULL_OK: u8 = 8;
const TAG_ROUND_DONE: u8 = 9;
const TAG_SHUTDOWN: u8 = 10;
const TAG_REJOIN: u8 = 11;
const TAG_SYNC: u8 = 12;

/// Length-prefixed [`Params`] encoding (layer count, then each layer's
/// raw-bit f32s).  Public within the crate: the coordinator's checkpoint
/// format reuses it so checkpointed parameters roundtrip bit-exactly.
pub(crate) fn encode_params(w: &mut ByteWriter, p: &Params) {
    w.u32(p.len() as u32);
    for layer in p {
        w.f32s(layer);
    }
}

/// Inverse of [`encode_params`]; bounds-checked, never panics.
pub(crate) fn decode_params(r: &mut ByteReader) -> anyhow::Result<Params> {
    let n = r.u32()? as usize;
    // A layer costs at least a 4-byte length on the wire; the per-layer
    // f32s reads enforce the real bounds.
    anyhow::ensure!(
        n <= 1024 && n * 4 <= r.remaining() + 4,
        "implausible layer count {n} for {} remaining bytes",
        r.remaining()
    );
    (0..n).map(|_| r.f32s()).collect()
}

fn encode_tensor(w: &mut ByteWriter, t: &Tensor) {
    w.usizes(&t.shape);
    w.f32s(&t.data);
}

fn decode_tensor(r: &mut ByteReader) -> anyhow::Result<Tensor> {
    let shape = r.usizes()?;
    let data = r.f32s()?;
    // Tensor::new panics on a shape/len mismatch; validate first so a
    // corrupt frame errors instead (checked: the product may overflow).
    let elems = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow::anyhow!("tensor shape {shape:?} overflows"))?;
    anyhow::ensure!(
        elems == data.len(),
        "tensor shape {shape:?} wants {elems} elements, payload has {}",
        data.len()
    );
    Ok(Tensor::new(data, shape))
}

impl Msg {
    /// Short name for logs and drop diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Join { .. } => "join",
            Msg::Welcome { .. } => "welcome",
            Msg::FwdReq { .. } => "fwd-req",
            Msg::FwdOk { .. } => "fwd-ok",
            Msg::BwdReq { .. } => "bwd-req",
            Msg::BwdOk { .. } => "bwd-ok",
            Msg::FullReq { .. } => "full-req",
            Msg::FullOk { .. } => "full-ok",
            Msg::RoundDone { .. } => "round-done",
            Msg::Shutdown => "shutdown",
            Msg::Rejoin { .. } => "rejoin",
            Msg::Sync { .. } => "sync",
        }
    }

    /// Encode into one frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            Msg::Join { client, version } => {
                w.u8(TAG_JOIN);
                w.u64(*client);
                w.u32(*version);
            }
            Msg::Welcome { setup } => {
                w.u8(TAG_WELCOME);
                setup.encode(&mut w);
            }
            Msg::FwdReq { seq, cut, step, wc } => {
                w.u8(TAG_FWD_REQ);
                w.u64(*seq);
                w.u32(*cut);
                w.u64(*step);
                encode_params(&mut w, wc);
            }
            Msg::FwdOk { seq, smashed, labels } => {
                w.u8(TAG_FWD_OK);
                w.u64(*seq);
                encode_tensor(&mut w, smashed);
                encode_tensor(&mut w, labels);
            }
            Msg::BwdReq { seq, cotangent } => {
                w.u8(TAG_BWD_REQ);
                w.u64(*seq);
                encode_tensor(&mut w, cotangent);
            }
            Msg::BwdOk { seq, grad } => {
                w.u8(TAG_BWD_OK);
                w.u64(*seq);
                encode_params(&mut w, grad);
            }
            Msg::FullReq { seq, step0, tau, lr, w: params } => {
                w.u8(TAG_FULL_REQ);
                w.u64(*seq);
                w.u64(*step0);
                w.u32(*tau);
                w.f32(*lr);
                encode_params(&mut w, params);
            }
            Msg::FullOk { seq, loss, w: params } => {
                w.u8(TAG_FULL_OK);
                w.u64(*seq);
                w.f64(*loss);
                encode_params(&mut w, params);
            }
            Msg::RoundDone { round } => {
                w.u8(TAG_ROUND_DONE);
                w.u64(*round);
            }
            Msg::Shutdown => {
                w.u8(TAG_SHUTDOWN);
            }
            Msg::Rejoin { client, version } => {
                w.u8(TAG_REJOIN);
                w.u64(*client);
                w.u32(*version);
            }
            Msg::Sync { round, setup } => {
                w.u8(TAG_SYNC);
                w.u64(*round);
                setup.encode(&mut w);
            }
        }
        w.into_bytes()
    }

    /// Decode one frame payload.  Never panics; every malformed input is
    /// an `Err` (fuzzed in `tests/protocol.rs`).
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Msg> {
        let mut r = ByteReader::new(bytes);
        let tag = r.u8()?;
        let msg = match tag {
            TAG_JOIN => Msg::Join { client: r.u64()?, version: r.u32()? },
            TAG_WELCOME => Msg::Welcome { setup: RunSetup::decode(&mut r)? },
            TAG_FWD_REQ => {
                let seq = r.u64()?;
                let cut = r.u32()?;
                // Structural check only: cut ids are 1-based.  Whether the
                // cut is on the active model's menu is the receiver's call
                // (`CutMenu::validate` against the RunSetup-agreed model) —
                // the decoder cannot know which architecture is running.
                anyhow::ensure!(cut >= 1, "cut ids are 1-based, got {cut}");
                let step = r.u64()?;
                Msg::FwdReq { seq, cut, step, wc: decode_params(&mut r)? }
            }
            TAG_FWD_OK => Msg::FwdOk {
                seq: r.u64()?,
                smashed: decode_tensor(&mut r)?,
                labels: decode_tensor(&mut r)?,
            },
            TAG_BWD_REQ => Msg::BwdReq { seq: r.u64()?, cotangent: decode_tensor(&mut r)? },
            TAG_BWD_OK => Msg::BwdOk { seq: r.u64()?, grad: decode_params(&mut r)? },
            TAG_FULL_REQ => {
                let seq = r.u64()?;
                let step0 = r.u64()?;
                let tau = r.u32()?;
                anyhow::ensure!(tau > 0, "full-req with tau = 0");
                let lr = r.f32()?;
                Msg::FullReq { seq, step0, tau, lr, w: decode_params(&mut r)? }
            }
            TAG_FULL_OK => {
                Msg::FullOk { seq: r.u64()?, loss: r.f64()?, w: decode_params(&mut r)? }
            }
            TAG_ROUND_DONE => Msg::RoundDone { round: r.u64()? },
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_REJOIN => Msg::Rejoin { client: r.u64()?, version: r.u32()? },
            TAG_SYNC => Msg::Sync { round: r.u64()?, setup: RunSetup::decode(&mut r)? },
            other => anyhow::bail!("unknown message tag {other}"),
        };
        r.finish()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) {
        let bytes = msg.encode();
        let back = Msg::decode(&bytes).expect("well-formed message decodes");
        assert_eq!(&back, msg);
    }

    #[test]
    fn every_message_roundtrips() {
        let params: Params = vec![vec![1.0, -2.5, 0.0], vec![f32::MIN_POSITIVE]];
        let t = Tensor::new(vec![0.5; 6], vec![2, 3]);
        roundtrip(&Msg::Join { client: 7, version: PROTO_VERSION });
        roundtrip(&Msg::Welcome {
            setup: RunSetup {
                dataset: "mnist".into(),
                seed: 17,
                partition: "dirichlet:0.3".into(),
                samples_per_client: 256,
                model: "vgg".into(),
                num_cuts: 11,
            },
        });
        roundtrip(&Msg::FwdReq { seq: 1, cut: 2, step: 9, wc: params.clone() });
        roundtrip(&Msg::FwdOk { seq: 1, smashed: t.clone(), labels: t.clone() });
        roundtrip(&Msg::BwdReq { seq: 1, cotangent: t.clone() });
        roundtrip(&Msg::BwdOk { seq: 1, grad: params.clone() });
        roundtrip(&Msg::FullReq { seq: 2, step0: 4, tau: 3, lr: 0.02, w: params.clone() });
        roundtrip(&Msg::FullOk { seq: 2, loss: 1.25, w: params });
        roundtrip(&Msg::RoundDone { round: 3 });
        roundtrip(&Msg::Shutdown);
        roundtrip(&Msg::Rejoin { client: 7, version: PROTO_VERSION });
        roundtrip(&Msg::Sync {
            round: 4,
            setup: RunSetup {
                dataset: "mnist".into(),
                seed: 17,
                partition: "shards:2".into(),
                samples_per_client: 64,
                model: "builtin".into(),
                num_cuts: 4,
            },
        });
    }

    #[test]
    fn bad_cut_and_bad_tensor_are_errors() {
        let msg = Msg::FwdReq { seq: 1, cut: 2, step: 0, wc: vec![vec![1.0]] };
        let mut bytes = msg.encode();
        // Corrupt the cut field (offset: tag 1 + seq 8).  Zero is
        // structurally invalid; a large id decodes fine — whether it is on
        // the active menu is the receiving node's check, not the decoder's.
        bytes[9] = 0;
        assert!(Msg::decode(&bytes).is_err());
        bytes[9] = 200;
        assert!(matches!(Msg::decode(&bytes), Ok(Msg::FwdReq { cut: 200, .. })));

        // Tensor whose shape does not match its payload length.
        let mut w = ByteWriter::new();
        w.u8(TAG_BWD_REQ);
        w.u64(1);
        w.usizes(&[2, 3]);
        w.f32s(&[0.0; 5]);
        assert!(Msg::decode(&w.into_bytes()).is_err());
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut bytes = Msg::Shutdown.encode();
        bytes.push(0);
        assert!(Msg::decode(&bytes).is_err());
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[99]).is_err());
    }
}
