//! Binary wire primitives for the coordinator/participant protocol: a
//! bounds-checked little-endian byte writer/reader pair plus
//! length-prefixed frame I/O (serde is not in the offline vendor set, so
//! the encoding is hand-rolled — see DESIGN.md §Transport for the
//! grammar).
//!
//! Contract: **decoding never panics**.  Every read is bounds-checked
//! against the buffer, every length field is capped before allocation
//! ([`MAX_ELEMS`] / [`MAX_FRAME`]) and every multiplication is `checked_`
//! — arbitrary or truncated byte streams produce `Err`, not UB or OOM
//! (`tests/protocol.rs` feeds both).  Floats travel as IEEE-754 LE bit
//! patterns (`to_le_bytes`/`from_le_bytes`), so an encode→decode
//! roundtrip is bit-exact — the property the loopback ≡ TCP equivalence
//! suite rests on.

use std::io::{Read, Write};

/// Hard cap on one frame's payload bytes.  Generous for the builtin
/// model (a full FL model is ~7 MB) while bounding what a corrupt or
/// hostile length prefix can make the reader allocate.
pub const MAX_FRAME: usize = 256 << 20;

/// Cap on any single length-prefixed collection (scalars, layers, shape
/// dims).  Keeps `Vec::with_capacity` honest before the data that backs
/// the length has been seen.
pub const MAX_ELEMS: usize = 64 << 20;

// --------------------------------------------------------------- writer

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` values travel as u64 (the wire format is
    /// pointer-width-independent).
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string (u32 byte count).
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 slice (u32 element count, raw LE bits).
    pub fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed usize slice (u32 count, u64 elements) — tensor
    /// shapes.
    pub fn usizes(&mut self, xs: &[usize]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u64(x as u64);
        }
    }
}

// --------------------------------------------------------------- reader

/// Bounds-checked little-endian decoder over a borrowed buffer.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Every message decoder ends with this: trailing garbage after a
    /// well-formed message is a framing error, not padding.
    pub fn finish(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.remaining() == 0, "{} trailing bytes after message", self.remaining());
        Ok(())
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.remaining() >= n,
            "truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn usize(&mut self) -> anyhow::Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("u64 value {v} overflows usize"))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f64(&mut self) -> anyhow::Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// A collection length: capped BEFORE any allocation and checked
    /// against the bytes actually remaining (each element is at least
    /// `min_elem_bytes`), so a hostile prefix cannot reserve memory the
    /// stream does not back.
    fn elems(&mut self, min_elem_bytes: usize, what: &str) -> anyhow::Result<usize> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n <= MAX_ELEMS, "{what} count {n} exceeds cap {MAX_ELEMS}");
        let need = n
            .checked_mul(min_elem_bytes)
            .ok_or_else(|| anyhow::anyhow!("{what} byte count overflows"))?;
        anyhow::ensure!(
            self.remaining() >= need,
            "truncated {what}: {n} elements need {need} bytes, have {}",
            self.remaining()
        );
        Ok(n)
    }

    pub fn str(&mut self) -> anyhow::Result<String> {
        let n = self.elems(1, "string")?;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("invalid UTF-8 in string: {e}"))?
            .to_string())
    }

    pub fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.elems(4, "f32 vector")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    pub fn usizes(&mut self) -> anyhow::Result<Vec<usize>> {
        let n = self.elems(8, "usize vector")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.usize()?);
        }
        Ok(out)
    }
}

// ------------------------------------------------------------- framing

/// Write one `u32-length ++ payload` frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> anyhow::Result<()> {
    anyhow::ensure!(payload.len() <= MAX_FRAME, "frame of {} bytes exceeds cap", payload.len());
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload.  `Ok(None)` = clean EOF at a frame
/// boundary; mid-frame EOF, oversized prefixes and I/O errors are `Err`.
pub fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match r.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let n = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(n <= MAX_FRAME, "incoming frame of {n} bytes exceeds cap {MAX_FRAME}");
    let mut payload = vec![0u8; n];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("truncated frame ({n} byte payload): {e}"))?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrips_are_bit_exact() {
        let mut w = ByteWriter::new();
        w.u8(0xAB);
        w.u32(0xDEADBEEF);
        w.u64(u64::MAX - 1);
        w.f32(f32::from_bits(0x7FC0_0001)); // a signalling-ish NaN pattern
        w.f64(-0.0);
        w.str("smashed/π");
        w.f32s(&[1.5, -0.0, f32::INFINITY]);
        w.usizes(&[32, 14, 14, 32]);
        let bytes = w.into_bytes();

        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 0xAB);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap().to_bits(), 0x7FC0_0001);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.str().unwrap(), "smashed/π");
        let xs = r.f32s().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[0], 1.5);
        assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(xs[2], f32::INFINITY);
        assert_eq!(r.usizes().unwrap(), vec![32, 14, 14, 32]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.f32s(&[1.0; 100]);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = ByteReader::new(&bytes[..cut]);
            assert!(r.f32s().is_err(), "prefix of {cut} bytes decoded");
        }
    }

    #[test]
    fn hostile_length_prefix_cannot_allocate() {
        // Claims u32::MAX f32s with a 4-byte buffer behind it.
        let mut w = ByteWriter::new();
        w.u32(u32::MAX);
        w.u32(0);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut w = ByteWriter::new();
        w.u8(1);
        w.u8(2);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        r.u8().unwrap();
        assert!(r.finish().is_err());
        r.u8().unwrap();
        r.finish().unwrap();
    }

    #[test]
    fn frame_io_roundtrips_and_bounds() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"");
        assert!(read_frame(&mut cur).unwrap().is_none(), "clean EOF");

        // Oversized length prefix rejected before allocation.
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes().to_vec();
        assert!(read_frame(&mut std::io::Cursor::new(huge)).is_err());

        // Mid-frame EOF is an error, not a silent None.
        let mut partial = Vec::new();
        write_frame(&mut partial, b"abcdef").unwrap();
        partial.truncate(7);
        assert!(read_frame(&mut std::io::Cursor::new(partial)).is_err());
    }
}
