//! Privacy model (paper §II-E, eq 17): smashed data leaks less as the
//! client-side model deepens; the constraint log(1 + φ(v)/q) ≥ ε bounds
//! the admissible cuts from below.

use crate::model::ShapeSpec;

/// Privacy leakage metric: log(1 + φ(v)/q) (natural log, monotone in φ).
pub fn leakage_margin(spec: &ShapeSpec, cut: usize) -> f64 {
    (1.0 + spec.phi_fraction(cut)).ln()
}

/// Constraint (17): is cut v admissible at threshold ε?
pub fn cut_feasible(spec: &ShapeSpec, cut: usize, epsilon: f64) -> bool {
    leakage_margin(spec, cut) >= epsilon
}

/// All admissible cuts at threshold ε (ascending).  Since φ(v) is monotone
/// non-decreasing in v, this is always a suffix of the model's cut menu.
pub fn feasible_cuts(spec: &ShapeSpec, epsilon: f64) -> Vec<usize> {
    spec.menu().ids().filter(|&v| cut_feasible(spec, v, epsilon)).collect()
}

/// Smallest admissible cut, if any.
pub fn min_feasible_cut(spec: &ShapeSpec, epsilon: f64) -> Option<usize> {
    feasible_cuts(spec, epsilon).into_iter().next()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;
    use crate::util::json::Json;

    fn toy_spec() -> ShapeSpec {
        // Reuse the model module's toy manifest via JSON to get a ShapeSpec.
        let text = r#"{"format": 1, "train_batch": 2, "eval_batch": 4,
         "shapes": {"toy": {
           "input_shape": [4], "classes": 2, "total_params": 1000,
           "params": [{"name": "w1", "shape": [10], "block": 1},
                      {"name": "w2", "shape": [90], "block": 2},
                      {"name": "w3", "shape": [900], "block": 5}],
           "cuts": {
             "1": {"phi": 10, "client_params": 1, "smashed_shape": [2,3],
                   "flops_client_fwd": 1, "flops_client_bwd": 1,
                   "flops_server_fwd": 1, "flops_server_bwd": 1,
                   "artifacts": {"client_fwd": "a", "server_grad": "b", "client_grad": "c"}},
             "2": {"phi": 100, "client_params": 2, "smashed_shape": [2,3],
                   "flops_client_fwd": 1, "flops_client_bwd": 1,
                   "flops_server_fwd": 1, "flops_server_bwd": 1,
                   "artifacts": {"client_fwd": "a", "server_grad": "b", "client_grad": "c"}},
             "3": {"phi": 100, "client_params": 2, "smashed_shape": [2,3],
                   "flops_client_fwd": 1, "flops_client_bwd": 1,
                   "flops_server_fwd": 1, "flops_server_bwd": 1,
                   "artifacts": {"client_fwd": "a", "server_grad": "b", "client_grad": "c"}},
             "4": {"phi": 100, "client_params": 2, "smashed_shape": [2,3],
                   "flops_client_fwd": 1, "flops_client_bwd": 1,
                   "flops_server_fwd": 1, "flops_server_bwd": 1,
                   "artifacts": {"client_fwd": "a", "server_grad": "b", "client_grad": "c"}}},
           "artifacts": {"full_grad": "f", "eval": "e"}
         }},
         "datasets": {"toyset": "toy"}}"#;
        let m = Manifest::from_json(&Json::parse(text).unwrap()).unwrap();
        m.shapes["toy"].clone()
    }

    #[test]
    fn leakage_monotone_in_cut() {
        let spec = toy_spec();
        let m1 = leakage_margin(&spec, 1);
        let m2 = leakage_margin(&spec, 2);
        assert!(m1 < m2);
        assert!((m1 - (1.0_f64 + 0.01).ln()).abs() < 1e-12);
    }

    #[test]
    fn feasible_set_is_suffix() {
        let spec = toy_spec();
        // ε between margin(1) and margin(2): only cuts 2..4 admissible.
        let eps = 0.05;
        assert_eq!(feasible_cuts(&spec, eps), vec![2, 3, 4]);
        assert_eq!(min_feasible_cut(&spec, eps), Some(2));
    }

    #[test]
    fn everything_feasible_at_zero_eps() {
        let spec = toy_spec();
        assert_eq!(feasible_cuts(&spec, 0.0), vec![1, 2, 3, 4]);
    }

    #[test]
    fn nothing_feasible_at_huge_eps() {
        let spec = toy_spec();
        assert!(feasible_cuts(&spec, 10.0).is_empty());
        assert_eq!(min_feasible_cut(&spec, 10.0), None);
    }
}
