//! Mini property-testing harness (proptest is not in the offline vendor
//! set): run a property over N seeded random cases; on failure report the
//! seed so the case replays deterministically.

use super::rng::Pcg;

pub const DEFAULT_CASES: u64 = 256;

/// Run `prop` over `cases` deterministic PCG streams; panics with the
/// failing seed on the first violation.
pub fn check<F: FnMut(&mut Pcg) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let mut rng = Pcg::new(0x5F1_6A ^ case, case);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case}: {msg}");
        }
    }
}

/// Convenience assertion helpers returning Result<(), String>.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a, $b);
        if (a - b).abs() > $tol * (1.0 + a.abs().max(b.abs())) {
            return Err(format!(
                "{} = {a} != {b} = {} (tol {})",
                stringify!($a),
                stringify!($b),
                $tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", 32, |rng| {
            count += 1;
            let x = rng.uniform();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 8, |rng| {
            let x = rng.uniform();
            prop_assert!(x < 0.0, "uniform is never negative: {x}");
            Ok(())
        });
    }

    #[test]
    fn close_macro_tolerates_scale() {
        fn inner() -> Result<(), String> {
            prop_assert_close!(1000.0_f64, 1000.0001_f64, 1e-6);
            Ok(())
        }
        assert!(inner().is_ok());
    }
}
