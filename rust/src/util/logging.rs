//! Leveled stderr logger with elapsed-time stamps (no env_logger offline).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell_lite::Lazy;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// Minimal once-initialized cell (once_cell crate is in the vendor set but
/// keeping the dependency surface to xla+anyhow only).
mod once_cell_lite {
    use std::sync::OnceLock;

    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Lazy { cell: OnceLock::new(), init }
        }

        pub fn force(&self) -> &T {
            self.cell.get_or_init(&self.init)
        }
    }
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "debug" => Level::Debug,
        "warn" => Level::Warn,
        "error" => Level::Error,
        _ => Level::Info,
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.force().elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }

    #[test]
    fn parses_levels() {
        assert_eq!(level_from_str("debug"), Level::Debug);
        assert_eq!(level_from_str("WARN"), Level::Warn);
        assert_eq!(level_from_str("bogus"), Level::Info);
    }
}
