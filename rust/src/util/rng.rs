//! Deterministic PRNG stack (no `rand` crate offline): PCG-XSH-RR 64/32
//! with SplitMix64 seeding, plus the distributions the simulator needs
//! (uniform, normal via Box–Muller, Rayleigh, exponential, Dirichlet).
//!
//! Determinism is a correctness requirement: every figure run is seeded, so
//! paper-figure CSVs are bit-reproducible across runs and machines.

/// SplitMix64 — used to expand one u64 seed into PCG state/stream.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Mix two words into one well-scrambled sub-seed.  The virtual
/// population keys every per-client stream as
/// `Pcg::new(mix2(run_seed, client_id), STREAM)` — a pure function of its
/// inputs, so any client's state derives on demand in O(1) with no
/// sequential draw order to replay (DESIGN.md §Population).
#[inline]
pub fn mix2(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// Three-way sub-seed mix (e.g. `(run_seed, round, client_id)`).
#[inline]
pub fn mix3(a: u64, b: u64, c: u64) -> u64 {
    mix2(mix2(a, b), c)
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Pcg {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let mut sm2 = stream ^ 0xDA3E_39CB_94B9_5BDB;
        let init_inc = splitmix64(&mut sm2) | 1;
        let mut pcg = Pcg { state: 0, inc: init_inc, spare_normal: None };
        pcg.state = init_state.wrapping_add(init_inc);
        pcg.next_u32();
        pcg
    }

    /// Derive an independent child stream (for per-client channels etc.).
    pub fn child(&mut self, tag: u64) -> Pcg {
        let seed = (self.next_u32() as u64) << 32 | self.next_u32() as u64;
        Pcg::new(seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15), tag)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 32 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4294967296.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (Lemire); bias < 2^-32.
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Rayleigh-distributed amplitude with scale sigma
    /// (block-fading magnitude; |h|^2 is then exponential).
    pub fn rayleigh(&mut self, sigma: f64) -> f64 {
        let u = 1.0 - self.uniform();
        sigma * (-2.0 * u.ln()).sqrt()
    }

    /// Exponential with mean `mean` (Rayleigh power gain |h|^2).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.uniform();
        -mean * u.ln()
    }

    /// Gamma(shape k >= 0) via Marsaglia–Tsang (with boost for k < 1).
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            let g = self.gamma(k + 1.0);
            return g * self.uniform().powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over n categories (non-IID data splits).
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_deterministic_and_asymmetric() {
        assert_eq!(mix2(1, 2), mix2(1, 2));
        assert_ne!(mix2(1, 2), mix2(2, 1), "mix2 must not be symmetric");
        assert_ne!(mix3(1, 2, 3), mix3(1, 3, 2), "mix3 must order its inputs");
        // Streams keyed off consecutive ids must not correlate trivially.
        assert_ne!(mix2(0, 1) ^ mix2(0, 2), mix2(0, 3) ^ mix2(0, 4));
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval_and_centered() {
        let mut r = Pcg::new(7, 0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(9, 0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg::new(11, 0);
        let n = 50_000;
        let m = (0..n).map(|_| r.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((m - 2.5).abs() < 0.1, "mean {m}");
    }

    #[test]
    fn rayleigh_positive_and_mean() {
        let mut r = Pcg::new(13, 0);
        let n = 50_000;
        let sigma = 1.0;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.rayleigh(sigma);
            assert!(x >= 0.0);
            sum += x;
        }
        let want = sigma * (std::f64::consts::PI / 2.0).sqrt();
        assert!((sum / n as f64 - want).abs() < 0.02);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg::new(17, 0);
        for alpha in [0.1, 0.5, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 10);
            assert_eq!(v.len(), 10);
            assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg::new(19, 0);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let i = r.below(7);
            assert!(i < 7);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(23, 0);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg::new(29, 0);
        for k in [0.5, 2.0, 7.5] {
            let n = 30_000;
            let m = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
            assert!((m - k).abs() / k < 0.05, "k={k} mean={m}");
        }
    }
}
