//! Minimal JSON parser/writer (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the results files: objects, arrays, strings (with escapes), numbers,
//! booleans, null.  Parsing is recursive-descent over bytes; numbers are
//! kept as f64 (manifest integers are all < 2^53, checked by the loader).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; errors name the full path.
    pub fn at(&self, path: &[&str]) -> anyhow::Result<&Json> {
        let mut cur = self;
        for (i, key) in path.iter().enumerate() {
            cur = cur.get(key).ok_or_else(|| {
                anyhow::anyhow!("missing json key '{}'", path[..=i].join("."))
            })?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > 2f64.powi(53) {
            anyhow::bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => anyhow::bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => anyhow::bail!("expected object, got {other:?}"),
        }
    }

    pub fn usize_array(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ------------------------------------------------------- writing

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not emitted by our writers).
                            s.push(
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .at(&["b"])
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = s.to_string();
        assert_eq!(Json::parse(&text).unwrap(), s);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn usize_array_and_bounds() {
        let j = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(j.usize_array().unwrap(), vec![1, 2, 3]);
        assert!(Json::parse("[-1]").unwrap().usize_array().is_err());
        assert!(Json::parse("[1.5]").unwrap().usize_array().is_err());
    }

    #[test]
    fn writer_sorted_object_roundtrip() {
        let j = Json::parse(r#"{"z": 1, "a": [true, null, 3.25]}"#).unwrap();
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
        // BTreeMap => deterministic key order.
        assert!(text.find("\"a\"").unwrap() < text.find("\"z\"").unwrap());
    }

    #[test]
    fn at_reports_full_path() {
        let j = Json::parse(r#"{"a": {"b": 1}}"#).unwrap();
        let err = j.at(&["a", "x", "y"]).unwrap_err().to_string();
        assert!(err.contains("a.x"), "{err}");
    }

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
          "format": 1,
          "shapes": {"28x28x1": {"cuts": {"1": {"phi": 832,
            "smashed_shape": [32, 14, 14, 32]}}}}
        }"#;
        let j = Json::parse(text).unwrap();
        let cut = j.at(&["shapes", "28x28x1", "cuts", "1"]).unwrap();
        assert_eq!(cut.at(&["phi"]).unwrap().as_usize().unwrap(), 832);
        assert_eq!(
            cut.at(&["smashed_shape"]).unwrap().usize_array().unwrap(),
            vec![32, 14, 14, 32]
        );
    }
}
