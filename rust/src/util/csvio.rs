//! CSV writing for figure outputs (results/*.csv consumed by plotting).

use std::fs;
use std::io::Write;
use std::path::Path;

pub struct CsvWriter {
    file: fs::File,
    cols: usize,
}

impl CsvWriter {
    /// Create `path` (parents included) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> anyhow::Result<CsvWriter> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut file = fs::File::create(&path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            values.len() == self.cols,
            "csv row has {} values, header has {}",
            values.len(),
            self.cols
        );
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, values: &[f64]) -> anyhow::Result<()> {
        let vals: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        self.row(&vals)
    }
}

/// Format helper: mixed string/number rows.
#[macro_export]
macro_rules! csv_row {
    ($writer:expr, $($v:expr),+ $(,)?) => {
        $writer.row(&[$(format!("{}", $v)),+])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join(format!("sflga_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "x".into()]).unwrap();
        w.row_f64(&[2.5, 3.0]).unwrap();
        drop(w);
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,x\n2.5,3\n");
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn rejects_wrong_arity() {
        let dir = std::env::temp_dir().join(format!("sflga_csv2_{}", std::process::id()));
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        fs::remove_dir_all(dir).unwrap();
    }
}
