//! Seeded pseudorandom permutations over `[0, n)` with O(1) evaluation in
//! BOTH directions — the primitive behind the virtual population's
//! cohort draws and straggler assignment (DESIGN.md §Population).
//!
//! A 4-round Feistel network over the smallest even-bit-width domain
//! `2^(2w) >= n` gives a keyed bijection on the power-of-four domain;
//! cycle walking (re-applying the cipher while the image lands outside
//! `[0, n)`) restricts it to an exact bijection on `[0, n)`.  Both
//! directions are pure functions of `(seed, value)`:
//!
//! * [`SeededPermutation::apply`] — a client's *rank* in the shuffled
//!   order, e.g. "is client i one of the ⌈frac·n⌉ stragglers?" is just
//!   `perm.apply(i) < k`, with the count exact by bijectivity;
//! * [`SeededPermutation::invert`] — the client at a given rank, so a
//!   K-member cohort enumerates in O(K) work and O(K) memory no matter
//!   how large n is: `(0..k).map(|p| perm.invert(p))`.
//!
//! Cost: the walk revisits at most `2^(2w)/n <= 4` candidates on average,
//! each a handful of splitmix rounds — no state, no allocation.

use super::rng::splitmix64;

/// A keyed bijection on `[0, n)`; see the module docs.
#[derive(Clone, Debug)]
pub struct SeededPermutation {
    n: u64,
    half_bits: u32,
    mask: u64,
    keys: [u64; 4],
}

impl SeededPermutation {
    pub fn new(n: u64, seed: u64) -> SeededPermutation {
        assert!(n > 0, "empty domain");
        // Smallest even bit width covering n (minimum domain 4 so the
        // Feistel halves are non-degenerate).
        let bits = (64 - (n - 1).leading_zeros()).max(2);
        let half_bits = bits.div_ceil(2);
        let mask = (1u64 << half_bits) - 1;
        let mut s = seed;
        let keys = [
            splitmix64(&mut s),
            splitmix64(&mut s),
            splitmix64(&mut s),
            splitmix64(&mut s),
        ];
        SeededPermutation { n, half_bits, mask, keys }
    }

    pub fn len(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false // n > 0 by construction
    }

    #[inline]
    fn round_fn(&self, r: u64, key: u64) -> u64 {
        let mut s = r ^ key;
        splitmix64(&mut s) & self.mask
    }

    /// One pass of the 4-round Feistel cipher over the 2w-bit domain.
    #[inline]
    fn feistel(&self, x: u64) -> u64 {
        let mut l = x >> self.half_bits;
        let mut r = x & self.mask;
        for &k in &self.keys {
            let nl = r;
            let nr = l ^ self.round_fn(r, k);
            l = nl;
            r = nr;
        }
        (l << self.half_bits) | r
    }

    #[inline]
    fn feistel_inv(&self, y: u64) -> u64 {
        let mut l = y >> self.half_bits;
        let mut r = y & self.mask;
        for &k in self.keys.iter().rev() {
            let nr = l;
            let nl = r ^ self.round_fn(l, k);
            l = nl;
            r = nr;
        }
        (l << self.half_bits) | r
    }

    /// Forward map: the rank of element `i` under the permutation.
    pub fn apply(&self, i: u64) -> u64 {
        assert!(i < self.n, "element {i} out of domain [0, {})", self.n);
        let mut x = self.feistel(i);
        while x >= self.n {
            x = self.feistel(x);
        }
        x
    }

    /// Inverse map: the element at rank `p`.
    pub fn invert(&self, p: u64) -> u64 {
        assert!(p < self.n, "rank {p} out of domain [0, {})", self.n);
        let mut x = self.feistel_inv(p);
        while x >= self.n {
            x = self.feistel_inv(x);
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_a_bijection_for_awkward_sizes() {
        for n in [1u64, 2, 3, 4, 7, 10, 100, 257, 1000, 4096, 12345] {
            let perm = SeededPermutation::new(n, 42 ^ n);
            let mut seen = vec![false; n as usize];
            for i in 0..n {
                let p = perm.apply(i);
                assert!(p < n, "n={n}: apply({i}) = {p} out of range");
                assert!(!seen[p as usize], "n={n}: rank {p} hit twice");
                seen[p as usize] = true;
                assert_eq!(perm.invert(p), i, "n={n}: invert is not the inverse at {i}");
            }
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = SeededPermutation::new(1000, 7);
        let b = SeededPermutation::new(1000, 7);
        let c = SeededPermutation::new(1000, 8);
        let ranks_a: Vec<u64> = (0..1000).map(|i| a.apply(i)).collect();
        let ranks_b: Vec<u64> = (0..1000).map(|i| b.apply(i)).collect();
        let ranks_c: Vec<u64> = (0..1000).map(|i| c.apply(i)).collect();
        assert_eq!(ranks_a, ranks_b);
        assert_ne!(ranks_a, ranks_c, "seed ignored");
    }

    #[test]
    fn actually_shuffles() {
        // Not the identity, and ranks look scattered: the low block
        // [0, 32) should not map into any 64-wide window too often.
        let perm = SeededPermutation::new(1_000_000, 3);
        let ranks: Vec<u64> = (0..32).map(|i| perm.apply(i)).collect();
        assert!(ranks.iter().enumerate().any(|(i, &p)| p != i as u64));
        let mut sorted = ranks.clone();
        sorted.sort_unstable();
        let spread = sorted.last().unwrap() - sorted.first().unwrap();
        assert!(spread > 10_000, "32 consecutive elements landed in a {spread}-wide window");
    }

    #[test]
    fn huge_domain_is_cheap_in_both_directions() {
        // u64-scale population: evaluating a handful of ranks must not
        // require materializing anything proportional to n.
        let n = 1u64 << 40;
        let perm = SeededPermutation::new(n, 11);
        for p in 0..100 {
            let i = perm.invert(p);
            assert!(i < n);
            assert_eq!(perm.apply(i), p);
        }
    }
}
