//! Shared substrates: JSON, RNG, CLI, logging, stats, CSV, property tests.

pub mod cli;
pub mod csvio;
pub mod json;
pub mod logging;
pub mod perm;
pub mod proptest;
pub mod rng;
pub mod stats;
