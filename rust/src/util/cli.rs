//! Tiny declarative CLI argument parser (clap is not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional subcommands,
//! typed getters with defaults, auto-generated `--help`, and shared typed
//! getters for cross-cutting options (`--threads`, the scenario flags).

use std::collections::BTreeMap;

use crate::data::partition::Partition;
use crate::scenario::{ScenarioConfig, StragglerConfig};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    declared: Vec<(String, String, String)>, // (name, default-or-"", help)
}

impl Args {
    /// Parse `std::env::args()[1..]`: optional subcommand first, then
    /// `--key value|--key=value|--flag` pairs.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                anyhow::bail!("unexpected positional argument '{tok}'");
            };
            if let Some((k, v)) = name.split_once('=') {
                args.values.insert(k.to_string(), v.to_string());
            } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                args.values.insert(name.to_string(), it.next().unwrap());
            } else {
                args.flags.push(name.to_string());
            }
        }
        Ok(args)
    }

    pub fn declare(&mut self, name: &str, default: &str, help: &str) {
        self.declared
            .push((name.to_string(), default.to_string(), help.to_string()));
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.values.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.values.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }

    /// `--<name>-ms N`: a millisecond duration flag (the networked
    /// binaries' deadline/timeout knobs).  `name` is passed WITH the
    /// `-ms` suffix, e.g. `duration_ms("deadline-ms", 10_000)`.
    pub fn duration_ms(
        &self,
        name: &str,
        default_ms: u64,
    ) -> anyhow::Result<std::time::Duration> {
        Ok(std::time::Duration::from_millis(self.parse_or(name, default_ms)?))
    }

    /// `--threads N`: round-engine worker threads.  `0` (the default)
    /// means auto — resolved by `runtime::resolve_threads` to the
    /// `SFLGA_TEST_THREADS` env override or the machine's available
    /// parallelism; `1` forces fully serial execution.
    pub fn threads(&self) -> anyhow::Result<usize> {
        self.parse_or("threads", 0usize)
    }

    /// `--model builtin|vgg|txf`: the model-registry architecture id,
    /// shared by `train`, `optimize`, the networked binaries and the
    /// examples.  Validated against the registry here so every consumer
    /// reports the same "unknown model" error with the menu of options.
    pub fn model(&self) -> anyhow::Result<String> {
        let name = self.str_or("model", "builtin");
        anyhow::ensure!(
            crate::model::registry::MODELS.contains(&name.as_str()),
            "unknown model '{name}' (available: {})",
            crate::model::registry::MODELS.join(", ")
        );
        Ok(name)
    }

    /// The scenario flags, shared by `train`, `optimize`, `figures` and
    /// the examples:
    ///
    /// * `--partition iid|dirichlet:<alpha>|shards:<s>` — data split
    ///   (`--non-iid-alpha A` is accepted as a legacy spelling of
    ///   `dirichlet:A` when `--partition` is absent);
    /// * `--participation R` — per-round client sampling rate in (0, 1];
    /// * `--straggler <frac>x<factor>` — e.g. `0.25x4`: a quarter of the
    ///   clients at a quarter compute speed (`none` disables).
    ///
    /// Defaults reproduce the paper's IID, homogeneous, always-on setup.
    pub fn scenario(&self) -> anyhow::Result<ScenarioConfig> {
        let partition = match (self.get("partition"), self.get("non-iid-alpha")) {
            (Some(p), _) => Partition::parse(p)?,
            (None, Some(a)) => Partition::Dirichlet(
                a.parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("--non-iid-alpha {a}: {e}"))?,
            ),
            (None, None) => Partition::Iid,
        };
        let straggler = match self.get("straggler") {
            Some(s) => StragglerConfig::parse(s)?,
            None => StragglerConfig::default(),
        };
        let cfg = ScenarioConfig {
            partition,
            participation: self.parse_or("participation", 1.0f64)?,
            straggler,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn usage(&self, prog: &str, about: &str) -> String {
        let mut s = format!("{prog} — {about}\n\noptions:\n");
        for (name, default, help) in &self.declared {
            let d = if default.is_empty() {
                String::new()
            } else {
                format!(" [default: {default}]")
            };
            s.push_str(&format!("  --{name:<18} {help}{d}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse(&["train", "--rounds", "100", "--dataset=mnist"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.parse_or("rounds", 0u32).unwrap(), 100);
        assert_eq!(a.str_or("dataset", ""), "mnist");
    }

    #[test]
    fn flags_without_values() {
        let a = parse(&["--verbose", "--seed", "7", "--all"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("all"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.parse_or("seed", 0u64).unwrap(), 7);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.parse_or("clients", 10usize).unwrap(), 10);
        assert!(a.subcommand.is_none());
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["--rounds", "ten"]);
        assert!(a.parse_or("rounds", 0u32).is_err());
    }

    #[test]
    fn duration_flags_parse_millis() {
        use std::time::Duration;
        let a = parse(&["--deadline-ms", "250"]);
        assert_eq!(a.duration_ms("deadline-ms", 10_000).unwrap(), Duration::from_millis(250));
        assert_eq!(a.duration_ms("join-ms", 5_000).unwrap(), Duration::from_millis(5_000));
        assert!(parse(&["--deadline-ms", "soon"]).duration_ms("deadline-ms", 0).is_err());
    }

    #[test]
    fn threads_defaults_to_auto() {
        assert_eq!(parse(&[]).threads().unwrap(), 0);
        assert_eq!(parse(&["--threads", "4"]).threads().unwrap(), 4);
        assert!(parse(&["--threads", "many"]).threads().is_err());
    }

    #[test]
    fn scenario_defaults_and_parsing() {
        let s = parse(&[]).scenario().unwrap();
        assert_eq!(s, crate::scenario::ScenarioConfig::default());

        let s = parse(&[
            "--partition",
            "dirichlet:0.3",
            "--participation",
            "0.5",
            "--straggler",
            "0.25x4",
        ])
        .scenario()
        .unwrap();
        assert_eq!(s.partition, Partition::Dirichlet(0.3));
        assert_eq!(s.participation, 0.5);
        assert_eq!(s.straggler.frac, 0.25);

        // Legacy spelling maps to Dirichlet; --partition wins when both.
        let s = parse(&["--non-iid-alpha", "0.7"]).scenario().unwrap();
        assert_eq!(s.partition, Partition::Dirichlet(0.7));
        let s = parse(&["--partition", "shards:2", "--non-iid-alpha", "0.7"])
            .scenario()
            .unwrap();
        assert_eq!(s.partition, Partition::Shards(2));

        assert!(parse(&["--participation", "0"]).scenario().is_err());
        assert!(parse(&["--partition", "zipf:1"]).scenario().is_err());
        assert!(parse(&["--straggler", "2x2"]).scenario().is_err());
    }

    #[test]
    fn model_flag_validates_against_the_registry() {
        assert_eq!(parse(&[]).model().unwrap(), "builtin");
        assert_eq!(parse(&["--model", "vgg"]).model().unwrap(), "vgg");
        assert_eq!(parse(&["--model=txf"]).model().unwrap(), "txf");
        let err = parse(&["--model", "resnet"]).model().unwrap_err().to_string();
        assert!(err.contains("builtin, vgg, txf"), "{err}");
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(["train".into(), "oops".into()]).is_err());
    }

    #[test]
    fn negative_number_is_value_not_flag() {
        // "--w -1.5": "-1.5" doesn't start with "--" so it's a value.
        let a = parse(&["--w", "-1.5"]);
        assert_eq!(a.parse_or("w", 0.0f64).unwrap(), -1.5);
    }
}
