//! PJRT runtime: loads the HLO-text artifacts produced by the python AOT
//! path and executes them from a dedicated engine thread.
//!
//! Layering rule: this module is the ONLY place PJRT/xla types appear; the
//! coordinator above deals purely in [`Tensor`] buffers, keeping the
//! request path free of python and of FFI details.

pub mod engine;
pub mod exec;
pub mod tensor;

pub use engine::{Engine, Handle};
pub use exec::ModelRuntime;
pub use tensor::Tensor;
