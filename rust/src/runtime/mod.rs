//! Model execution runtime, split into a backend-agnostic facade and
//! pluggable backends (see DESIGN.md §Backends):
//!
//! * [`backend`] — the [`Backend`] trait every execution engine
//!   implements: the five roles (`client_fwd`, `server_grad`,
//!   `client_grad`, `full_grad`, `eval`) over flat f32 buffers.
//! * [`native`] — the default pure-Rust backend: dense/conv/pool forward
//!   and backward on the host, zero external dependencies, on an
//!   im2col + blocked-GEMM fast path ([`native::gemm`]) with the scalar
//!   originals kept as [`native::reference`].
//! * [`scratch`] — reusable per-worker kernel workspace ([`Scratch`] /
//!   [`ScratchHandle`]): the executor owns one arena per worker thread
//!   and routes it through the [`Backend`] `*_with` role variants, on
//!   both the bulk `map` fan-outs and the pipelined [`TaskSession`]
//!   submit/collect path.
//! * `engine` (feature `pjrt`) — the XLA/PJRT engine pool that executes
//!   the HLO-text artifacts produced by `python/compile/aot.py`.  This is
//!   the ONLY place PJRT/xla types appear; the coordinator above deals
//!   purely in [`Tensor`] buffers.
//! * [`transport`] — the [`Transport`] trait the networked coordinator
//!   fans out over: real TCP peers ([`TcpTransport`]) or in-process
//!   [`node::ParticipantNode`]s on the executor ([`LoopbackTransport`]).
//! * [`node`] — the participant-side protocol state machine, shared
//!   verbatim by the loopback transport and the `sfl-participant` binary
//!   (DESIGN.md §Transport).

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod exec;
pub mod native;
pub mod node;
pub mod scratch;
pub mod tensor;
pub mod transport;

pub use backend::Backend;
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Handle};
pub use exec::{
    JobHandle,
    ModelRuntime,
    ParallelExecutor,
    resolve_threads,
    TaskSession,
    THREADS_ENV,
};
pub use native::NativeBackend;
pub use node::ParticipantNode;
pub use scratch::{Scratch, ScratchHandle};
pub use tensor::Tensor;
pub use transport::{Incoming, LoopbackTransport, TcpTransport, Transport};
