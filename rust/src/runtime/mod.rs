//! Model execution runtime, split into a backend-agnostic facade and
//! pluggable backends (see DESIGN.md §Backends):
//!
//! * [`backend`] — the [`Backend`] trait every execution engine
//!   implements: the five roles (`client_fwd`, `server_grad`,
//!   `client_grad`, `full_grad`, `eval`) over flat f32 buffers.
//! * [`native`] — the default pure-Rust backend: dense/conv/pool forward
//!   and backward on the host, zero external dependencies, on an
//!   im2col + blocked-GEMM fast path ([`native::gemm`]) with the scalar
//!   originals kept as [`native::reference`].
//! * [`scratch`] — reusable per-worker kernel workspace ([`Scratch`] /
//!   [`ScratchHandle`]): the executor owns one arena per worker thread
//!   and routes it through the [`Backend`] `*_with` role variants, on
//!   both the bulk `map` fan-outs and the pipelined [`TaskSession`]
//!   submit/collect path.
//! * `engine` (feature `pjrt`) — the XLA/PJRT engine pool that executes
//!   the HLO-text artifacts produced by `python/compile/aot.py`.  This is
//!   the ONLY place PJRT/xla types appear; the coordinator above deals
//!   purely in [`Tensor`] buffers.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod exec;
pub mod native;
pub mod scratch;
pub mod tensor;

pub use backend::Backend;
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Handle};
pub use exec::{
    JobHandle,
    ModelRuntime,
    ParallelExecutor,
    resolve_threads,
    TaskSession,
    THREADS_ENV,
};
pub use native::NativeBackend;
pub use scratch::{Scratch, ScratchHandle};
pub use tensor::Tensor;
