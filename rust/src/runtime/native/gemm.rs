//! The one tuned `f32` GEMM core behind every native conv/dense kernel:
//! a register-blocked `MR×NR` microkernel under GotoBLAS-style cache
//! blocking (`KC` k-panels, `MC×KC` packed A, `KC×NC` packed B), with the
//! layer bias+relu fused into the epilogue of the final k-panel.
//!
//! One routine serves five products (see `ops.rs`): conv fwd
//! (`im2col(x)·W`), conv d_x (`d_out·Wᵀ`), conv d_w (`im2col(x)ᵀ·d_out`),
//! dense fwd (`x·W`) and dense d_x/d_w — transposed operands are handled
//! by the packing routines through strided [`MatView`]s, so no operand is
//! ever materialized transposed.
//!
//! # Microkernel tiers
//!
//! The inner register tile runs on one of two [`Tier`]s behind runtime
//! dispatch ([`active_tier`], cached once per process):
//!
//! * [`Tier::Portable`] — the scalar 8×8 kernel, autovectorized by LLVM;
//!   every platform, the JAX-golden reference tier.
//! * [`Tier::Avx2`] — an explicit AVX2+FMA kernel (x86_64 only, selected
//!   when `is_x86_feature_detected!` confirms both features; degrades to
//!   portable otherwise).  Force a tier with [`GEMM_TIER_ENV`].
//!
//! The tiers are NOT bitwise-interchangeable: FMA contracts each `a·b+c`
//! into one rounding where the portable kernel rounds twice, so SIMD and
//! portable results drift apart by O(ulp) per accumulation step.  They
//! are property-tested against each other to ≤1e-5 relative.  *Within* a
//! tier every determinism guarantee is untouched — the summation order
//! below is tier-independent, so identical inputs on the same tier give
//! bitwise identical outputs from any worker thread.
//!
//! Determinism: for a fixed problem shape the summation order of every
//! output element is fixed — k-panels accumulate in ascending `p` order
//! and panel partials are added to C in ascending panel order — and no
//! read ever observes scratch-buffer history (packing pads edge tiles
//! with explicit zeros).  Because an output element's summation order is
//! independent of which `NC` column panel it lands in, pre-packed B
//! panels ([`pack_b_full`] / [`gemm_packed_b`]) and column-split
//! execution ([`gemm_parallel`]) are bitwise identical to the plain
//! [`gemm`] on the same tier.  The threads=N ≡ threads=1 and
//! split-vs-full bitwise guarantees extend to the GEMM path unchanged.
//! See DESIGN.md §Native backend.

use std::sync::OnceLock;

/// Microkernel tile height (rows of C per register tile).
pub const MR: usize = 8;
/// Microkernel tile width (one 8-lane f32 vector).
pub const NR: usize = 8;
/// Rows of A packed per panel (`MC×KC` ≈ 64 KiB, L2-resident).
const MC: usize = 64;
/// Columns of B packed per panel (`KC×NC` ≈ 256 KiB).
const NC: usize = 256;
/// k-depth of one panel (one `KC×NR` B strip ≈ 8 KiB, L1-resident).
const KC: usize = 256;

/// Env var forcing the microkernel tier: `portable` pins the scalar
/// kernel, `avx2` requests the SIMD tier (clamped to portable when the
/// CPU lacks it), anything else — or unset — auto-detects.  Read once
/// per process and cached ([`active_tier`]).
pub const GEMM_TIER_ENV: &str = "SFLGA_GEMM_TIER";

/// Instruction tier of the GEMM microkernel (see the module docs: tiers
/// are deterministic within themselves, FMA-divergent across each other).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Scalar 8×8 kernel, autovectorized — every platform.
    Portable,
    /// AVX2+FMA 8×8 kernel — x86_64 with runtime-detected support.
    Avx2,
}

impl Tier {
    /// Clamp to what this host can execute: [`Tier::Avx2`] degrades to
    /// [`Tier::Portable`] when AVX2+FMA are absent (or off x86_64), so
    /// forcing a tier is always safe.
    pub fn supported(self) -> Tier {
        match self {
            Tier::Avx2 if avx2_available() => Tier::Avx2,
            _ => Tier::Portable,
        }
    }

    /// Short name for logs and bench JSON ("portable", "avx2").
    pub fn name(self) -> &'static str {
        match self {
            Tier::Portable => "portable",
            Tier::Avx2 => "avx2",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// The process-wide microkernel tier: the [`GEMM_TIER_ENV`] override if
/// set, else the best tier the CPU supports.  Cached on first use so the
/// hot path never re-reads the environment.
pub fn active_tier() -> Tier {
    static TIER: OnceLock<Tier> = OnceLock::new();
    *TIER.get_or_init(|| match std::env::var(GEMM_TIER_ENV).as_deref() {
        Ok("portable") => Tier::Portable,
        _ => Tier::Avx2.supported(),
    })
}

/// Strided read-only view of a row-major matrix (or its transpose):
/// element `(r, c)` lives at `data[r·rs + c·cs]`.
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    data: &'a [f32],
    rs: usize,
    cs: usize,
}

impl<'a> MatView<'a> {
    /// View a row-major `[rows × cols]` buffer as itself.
    pub fn rows(data: &'a [f32], cols: usize) -> MatView<'a> {
        MatView { data, rs: cols, cs: 1 }
    }

    /// View a row-major `[rows × cols]` buffer as its transpose
    /// (`cols × rows`), without copying.
    pub fn transposed(data: &'a [f32], cols: usize) -> MatView<'a> {
        MatView { data, rs: 1, cs: cols }
    }

    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.rs + c * self.cs]
    }

    /// Whether rows are contiguous (`cs == 1`) — enables `copy_from_slice`
    /// fast paths in the packers.
    #[inline(always)]
    fn row_major(&self) -> bool {
        self.cs == 1
    }

    /// Re-view from column `j0` onward: element `(r, c)` of the result
    /// is element `(r, j0 + c)` of `self` (the strides are unchanged, so
    /// this is a zero-copy column offset for panel-parallel splits).
    fn cols_from(&self, j0: usize) -> MatView<'a> {
        MatView { data: &self.data[j0 * self.cs..], rs: self.rs, cs: self.cs }
    }
}

/// What the final k-panel writes into each C element after the product.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// Plain product (gradient GEMMs).
    None,
    /// `+ bias[j]` per output column (linear logits layer).
    Bias(&'a [f32]),
    /// `max(0, · + bias[j])` (hidden conv/dense layers).
    BiasRelu(&'a [f32]),
}

impl<'a> Epilogue<'a> {
    /// The epilogue restricted to output columns `j0..j0+w` (for
    /// panel-parallel column splits computing into a local strip).
    fn slice_cols(self, j0: usize, w: usize) -> Epilogue<'a> {
        match self {
            Epilogue::None => Epilogue::None,
            Epilogue::Bias(b) => Epilogue::Bias(&b[j0..j0 + w]),
            Epilogue::BiasRelu(b) => Epilogue::BiasRelu(&b[j0..j0 + w]),
        }
    }
}

/// Where the driver takes its B panels from.
#[derive(Clone, Copy)]
enum BPanels<'a> {
    /// Pack panels on the fly from a strided view into the `pb` arena.
    View(MatView<'a>),
    /// Pre-packed panels from [`pack_b_full`], consumed sequentially in
    /// the exact `(jc, pc)` order they were written.
    Packed(&'a [f32]),
}

/// `C[m×n] (+)= A[m×k] · B[k×n]`, row-major contiguous C (`ldc == n`),
/// on the process-wide [`active_tier`].
///
/// * `accumulate == false` overwrites C (no pre-zeroing needed);
///   `accumulate == true` adds the product to the existing C (used by
///   conv d_w to sum image contributions in ascending image order) and
///   must be paired with [`Epilogue::None`].
/// * `pa`/`pb` are the packing arenas (see [`crate::runtime::Scratch`]);
///   they are resized to the fixed panel footprint once and fully
///   rewritten before every read.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    a: MatView<'_>,
    b: MatView<'_>,
    ep: Epilogue<'_>,
    accumulate: bool,
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    gemm_driver(active_tier(), c, m, n, k, a, BPanels::View(b), ep, accumulate, pa, pb);
}

/// [`gemm`] on an explicit [`Tier`] (clamped to host support) — the entry
/// point for cross-tier property tests and the tier benchmarks, immune to
/// the [`GEMM_TIER_ENV`] override.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_tier(
    tier: Tier,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    a: MatView<'_>,
    b: MatView<'_>,
    ep: Epilogue<'_>,
    accumulate: bool,
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    gemm_driver(tier.supported(), c, m, n, k, a, BPanels::View(b), ep, accumulate, pa, pb);
}

/// [`gemm_with_tier`] consuming B panels pre-packed by [`pack_b_full`]
/// instead of packing per call — the repeated-B fast path (conv layers
/// multiply every image of a batch against the same weight panels; see
/// `ops.rs`).  Bitwise identical to the view-packing path: the packed
/// bytes are exactly what [`gemm`] would have packed.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_b(
    tier: Tier,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    a: MatView<'_>,
    packed_b: &[f32],
    ep: Epilogue<'_>,
    accumulate: bool,
    pa: &mut Vec<f32>,
) {
    debug_assert_eq!(
        packed_b.len(),
        packed_b_len(k, n),
        "gemm_packed_b: packed panels do not match a {k}x{n} B"
    );
    let mut pb = Vec::new(); // untouched on the packed path
    let panels = BPanels::Packed(packed_b);
    gemm_driver(tier.supported(), c, m, n, k, a, panels, ep, accumulate, pa, &mut pb);
}

/// Length of the packed-panel buffer [`pack_b_full`] produces for a
/// `k×n` B: every `(jc, pc)` panel's NR-column strips, edge strips
/// rounded up to NR with explicit zero padding.
pub fn packed_b_len(k: usize, n: usize) -> usize {
    let mut total = 0;
    for jc in (0..n).step_by(NC) {
        let strips = NC.min(n - jc).div_ceil(NR);
        let mut pc = 0;
        while pc < k {
            let kcw = KC.min(k - pc);
            total += strips * kcw * NR;
            pc += kcw;
        }
    }
    total
}

/// Pack ALL of B's cache panels at once, in the exact `(jc outer, pc
/// inner)` order the GEMM driver consumes them — the hoisted-weight-
/// packing cache ([`gemm_packed_b`]).  Every element of `dst[..len]` is
/// written (padding included), so stale arena contents never leak into
/// results (the NaN-poison contract of [`crate::runtime::Scratch`]).
pub fn pack_b_full(dst: &mut Vec<f32>, b: &MatView<'_>, k: usize, n: usize) {
    dst.resize(packed_b_len(k, n), 0.0);
    let mut off = 0;
    for jc in (0..n).step_by(NC) {
        let ncw = NC.min(n - jc);
        let strips = ncw.div_ceil(NR);
        let mut pc = 0;
        while pc < k {
            let kcw = KC.min(k - pc);
            let seg = strips * kcw * NR;
            pack_b(&mut dst[off..off + seg], b, pc, kcw, jc, ncw);
            off += seg;
            pc += kcw;
        }
    }
}

/// Overwrite-mode [`gemm_with_tier`] with C's columns split into up to
/// `par` NR-aligned contiguous ranges, each computed by a scoped worker
/// thread into a private strip and merged back in ascending range order —
/// the panel-parallel path for large eval batches.
///
/// Bitwise identical to the serial call for every `par`: an output
/// element's f32 summation order depends only on the k-panel schedule,
/// which column partitioning does not touch, and the merge is a disjoint
/// fixed-order overwrite.  `par <= 1` (or too few column strips) runs the
/// plain serial GEMM on `pa`/`pb`; the split path gives each worker
/// transient local packing buffers instead, because the per-worker arena
/// belongs to the executor worker that called us.
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel(
    tier: Tier,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    a: MatView<'_>,
    b: MatView<'_>,
    ep: Epilogue<'_>,
    par: usize,
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    let strips = n.div_ceil(NR);
    let chunks = par.min(strips).max(1);
    if chunks <= 1 || m == 0 {
        gemm_with_tier(tier, c, m, n, k, a, b, ep, false, pa, pb);
        return;
    }
    debug_assert_eq!(c.len(), m * n, "gemm_parallel: C is {} elems, want {m}x{n}", c.len());
    let per = strips.div_ceil(chunks);
    let mut ranges = Vec::with_capacity(chunks);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + per * NR).min(n);
        ranges.push((lo, hi));
        lo = hi;
    }
    let parts: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|&(j0, j1)| {
                s.spawn(move || {
                    let w = j1 - j0;
                    let mut part = vec![0.0f32; m * w];
                    let (mut lpa, mut lpb) = (Vec::new(), Vec::new());
                    gemm_with_tier(
                        tier,
                        &mut part,
                        m,
                        w,
                        k,
                        a,
                        b.cols_from(j0),
                        ep.slice_cols(j0, w),
                        false,
                        &mut lpa,
                        &mut lpb,
                    );
                    part
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("gemm panel worker panicked")).collect()
    });
    // Fixed-order merge: ascending column ranges, disjoint overwrites.
    for (&(j0, j1), part) in ranges.iter().zip(&parts) {
        let w = j1 - j0;
        for (crow, prow) in c.chunks_exact_mut(n).zip(part.chunks_exact(w)) {
            crow[j0..j1].copy_from_slice(prow);
        }
    }
}

/// The shared cache-blocked driver behind every public entry point.
/// `tier` must already be clamped by [`Tier::supported`].
#[allow(clippy::too_many_arguments)]
fn gemm_driver(
    tier: Tier,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    a: MatView<'_>,
    b: BPanels<'_>,
    ep: Epilogue<'_>,
    accumulate: bool,
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    debug_assert_eq!(c.len(), m * n, "gemm: C is {} elems, want {m}x{n}", c.len());
    debug_assert!(
        !accumulate || matches!(ep, Epilogue::None),
        "gemm: accumulate composes across calls; fuse epilogues only on the last one"
    );
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // Degenerate empty product: C (+)= 0, epilogue still applies.
        if !accumulate {
            c.fill(0.0);
        }
        apply_epilogue_rows(c, n, ep);
        return;
    }
    let simd = matches!(tier, Tier::Avx2);
    pa.resize(MC * KC, 0.0);
    if matches!(b, BPanels::View(_)) {
        pb.resize(NC * KC, 0.0);
    }
    let mut packed_off = 0usize;
    for jc in (0..n).step_by(NC) {
        let ncw = NC.min(n - jc);
        let strips = ncw.div_ceil(NR);
        let mut pc = 0;
        while pc < k {
            let kcw = KC.min(k - pc);
            let first = pc == 0;
            let last = pc + kcw == k;
            let panel: &[f32] = match b {
                BPanels::View(bv) => {
                    pack_b(pb, &bv, pc, kcw, jc, ncw);
                    &pb[..strips * kcw * NR]
                }
                BPanels::Packed(p) => {
                    let seg = strips * kcw * NR;
                    let s = &p[packed_off..packed_off + seg];
                    packed_off += seg;
                    s
                }
            };
            for icb in (0..m).step_by(MC) {
                let mcw = MC.min(m - icb);
                pack_a(pa, &a, icb, mcw, pc, kcw);
                for jr in (0..ncw).step_by(NR) {
                    let nrw = NR.min(ncw - jr);
                    let pb_strip = &panel[(jr / NR) * kcw * NR..][..kcw * NR];
                    for ir in (0..mcw).step_by(MR) {
                        let mrw = MR.min(mcw - ir);
                        let pa_strip = &pa[(ir / MR) * kcw * MR..][..kcw * MR];
                        let mut acc = [[0.0f32; NR]; MR];
                        run_microkernel(simd, kcw, pa_strip, pb_strip, &mut acc);
                        store_tile(
                            c,
                            n,
                            icb + ir,
                            jc + jr,
                            mrw,
                            nrw,
                            &acc,
                            first && !accumulate,
                            last,
                            ep,
                        );
                    }
                }
            }
            pc += kcw;
        }
    }
}

/// Tier dispatch for one register tile.  `simd` is only ever true when
/// [`Tier::supported`] confirmed AVX2+FMA on this host.
#[inline(always)]
fn run_microkernel(
    simd: bool,
    kc: usize,
    pa_strip: &[f32],
    pb_strip: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    #[cfg(target_arch = "x86_64")]
    if simd {
        // SAFETY: `simd` implies the driver's tier was clamped through
        // `Tier::supported`, which checked avx2+fma at runtime.
        unsafe { microkernel_avx2(kc, pa_strip, pb_strip, acc) };
        return;
    }
    let _ = simd; // consumed by the cfg arm on x86_64 only
    microkernel(kc, pa_strip, pb_strip, acc);
}

/// The portable register tile: `acc[MR][NR] += pa_strip ⊗ pb_strip` over
/// one k-panel, ascending `p`.  `chunks_exact` walks the strips in
/// MR/NR-sized rows whose lengths the compiler can prove, so the indexed
/// bounds checks of the per-`p` slices elide (see DESIGN.md §Native
/// backend); the fixed-size inner rows keep the loop branch-free and
/// autovectorizable (NR = one 8-lane f32 vector).
#[inline(always)]
fn microkernel(kc: usize, pa_strip: &[f32], pb_strip: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(pa_strip.len() == kc * MR && pb_strip.len() == kc * NR);
    for (arow, brow) in pa_strip.chunks_exact(MR).zip(pb_strip.chunks_exact(NR)) {
        for (accrow, &av) in acc.iter_mut().zip(arow) {
            for (cv, &bv) in accrow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// The AVX2+FMA register tile: 8 ymm accumulators, one `b` vector load
/// and 8 broadcast-FMAs per `p`.  Same ascending-`p` summation order as
/// the portable kernel, but each `a·b + acc` rounds ONCE (fused), which
/// is why the tiers are equivalent only to tolerance, never bitwise.
///
/// # Safety
///
/// Requires AVX2 and FMA at runtime (`Tier::supported` gates every call).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn microkernel_avx2(
    kc: usize,
    pa_strip: &[f32],
    pb_strip: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    use std::arch::x86_64::{
        _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps,
    };
    debug_assert!(pa_strip.len() == kc * MR && pb_strip.len() == kc * NR);
    let mut vacc = [_mm256_setzero_ps(); MR];
    for (v, row) in vacc.iter_mut().zip(acc.iter()) {
        *v = _mm256_loadu_ps(row.as_ptr());
    }
    for p in 0..kc {
        let bvec = _mm256_loadu_ps(pb_strip.as_ptr().add(p * NR));
        let abase = pa_strip.as_ptr().add(p * MR);
        for (i, v) in vacc.iter_mut().enumerate() {
            let avec = _mm256_set1_ps(*abase.add(i));
            *v = _mm256_fmadd_ps(avec, bvec, *v);
        }
    }
    for (v, row) in vacc.iter().zip(acc.iter_mut()) {
        _mm256_storeu_ps(row.as_mut_ptr(), *v);
    }
}

/// Merge one register tile into C: overwrite on the first k-panel of a
/// non-accumulating GEMM, add otherwise; fuse the epilogue on the last.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn store_tile(
    c: &mut [f32],
    ldc: usize,
    i0: usize,
    j0: usize,
    mrw: usize,
    nrw: usize,
    acc: &[[f32; NR]; MR],
    overwrite: bool,
    last: bool,
    ep: Epilogue<'_>,
) {
    for (i, accrow) in acc.iter().enumerate().take(mrw) {
        let base = (i0 + i) * ldc + j0;
        let crow = &mut c[base..base + nrw];
        if overwrite {
            crow.copy_from_slice(&accrow[..nrw]);
        } else {
            for (cv, &av) in crow.iter_mut().zip(&accrow[..nrw]) {
                *cv += av;
            }
        }
        if last {
            apply_epilogue(crow, j0, ep);
        }
    }
}

#[inline(always)]
fn apply_epilogue(crow: &mut [f32], j0: usize, ep: Epilogue<'_>) {
    match ep {
        Epilogue::None => {}
        Epilogue::Bias(bias) => {
            for (cv, &bv) in crow.iter_mut().zip(&bias[j0..j0 + crow.len()]) {
                *cv += bv;
            }
        }
        Epilogue::BiasRelu(bias) => {
            for (cv, &bv) in crow.iter_mut().zip(&bias[j0..j0 + crow.len()]) {
                *cv += bv;
                if *cv < 0.0 {
                    *cv = 0.0;
                }
            }
        }
    }
}

fn apply_epilogue_rows(c: &mut [f32], ldc: usize, ep: Epilogue<'_>) {
    for crow in c.chunks_mut(ldc) {
        apply_epilogue(crow, 0, ep);
    }
}

/// Pack A rows `i0..i0+mc` × k `p0..p0+kc` into MR-row strips, k-major
/// within each strip; rows past `mc` in the last strip are zero-padded so
/// the microkernel never branches on the edge.
fn pack_a(dst: &mut [f32], a: &MatView<'_>, i0: usize, mc: usize, p0: usize, kc: usize) {
    let mut off = 0;
    let mut ir = 0;
    while ir < mc {
        let mrw = MR.min(mc - ir);
        for p in 0..kc {
            let d = &mut dst[off + p * MR..off + (p + 1) * MR];
            for (i, dv) in d.iter_mut().enumerate() {
                *dv = if i < mrw { a.at(i0 + ir + i, p0 + p) } else { 0.0 };
            }
        }
        off += kc * MR;
        ir += MR;
    }
}

/// Pack B k `p0..p0+kc` × columns `j0..j0+nc` into NR-column strips,
/// k-major within each strip, zero-padding the ragged last strip.  The
/// row-major full-strip case (weights, d_out) is a straight `memcpy`.
fn pack_b(dst: &mut [f32], b: &MatView<'_>, p0: usize, kc: usize, j0: usize, nc: usize) {
    let mut off = 0;
    let mut jr = 0;
    while jr < nc {
        let nrw = NR.min(nc - jr);
        if b.row_major() && nrw == NR {
            for p in 0..kc {
                let src = (p0 + p) * b.rs + j0 + jr;
                dst[off + p * NR..off + (p + 1) * NR].copy_from_slice(&b.data[src..src + NR]);
            }
        } else {
            for p in 0..kc {
                let d = &mut dst[off + p * NR..off + (p + 1) * NR];
                for (j, dv) in d.iter_mut().enumerate() {
                    *dv = if j < nrw { b.at(p0 + p, j0 + jr + j) } else { 0.0 };
                }
            }
        }
        off += kc * NR;
        jr += NR;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    /// Naive triple loop with the SAME per-element summation order as the
    /// packed path's single-panel case (ascending k, epilogue last).
    fn naive(
        m: usize,
        n: usize,
        k: usize,
        a: &MatView<'_>,
        b: &MatView<'_>,
        ep: Epilogue<'_>,
        init: Option<&[f32]>,
    ) -> Vec<f32> {
        let mut c = match init {
            Some(c0) => c0.to_vec(),
            None => vec![0.0f32; m * n],
        };
        for i in 0..m {
            for j in 0..n {
                let mut s = if init.is_some() { c[i * n + j] } else { 0.0 };
                for p in 0..k {
                    s += a.at(i, p) * b.at(p, j);
                }
                c[i * n + j] = s;
            }
        }
        for crow in c.chunks_mut(n) {
            apply_epilogue(crow, 0, ep);
        }
        c
    }

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-4 * (1.0 + b.abs())
    }

    fn gen_mat(len: usize, mul: usize, add: usize, modu: usize) -> Vec<f32> {
        (0..len).map(|i| ((i * mul + add) % modu) as f32 / modu as f32 - 0.5).collect()
    }

    #[test]
    fn matches_naive_on_awkward_shapes() {
        // Shapes straddling every blocking edge: below/above MR, NR, MC,
        // NC, KC, and non-multiples of all of them.
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (8, 8, 8),
            (9, 7, 25),
            (13, 10, 300),
            (70, 9, 17),
            (65, 260, 13),
            (31, 33, 257),
        ];
        for &(m, n, k) in &shapes {
            let a = gen_mat(m * k, 37, 11, 97);
            let b = gen_mat(k * n, 53, 29, 89);
            let av = MatView::rows(&a, k);
            let bv = MatView::rows(&b, n);
            let want = naive(m, n, k, &av, &bv, Epilogue::None, None);
            let mut got = vec![0.0f32; m * n];
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            gemm(&mut got, m, n, k, av, bv, Epilogue::None, false, &mut pa, &mut pb);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(close(*g, *w), "({m}x{n}x{k})[{i}]: {g} vs {w}");
            }
        }
    }

    #[test]
    fn transposed_views_read_the_transpose() {
        // A = Xᵀ where X is 4x3 row-major: A is 3x4.
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let at = MatView::transposed(&x, 3);
        assert_eq!(at.at(0, 0), 0.0);
        assert_eq!(at.at(2, 1), x[5]); // X[1][2]
        assert_eq!(at.at(1, 3), x[10]); // X[3][1]
    }

    #[test]
    fn property_strided_operands_and_epilogues() {
        check("gemm-strided-epilogue", 48, |rng| {
            let m = 1 + rng.below(20);
            let n = 1 + rng.below(20);
            let k = 1 + rng.below(40);
            let a_raw: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect();
            let b_raw: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.5).collect();
            // Transposed storage for each operand, half the time.
            let ta = rng.below(2) == 1;
            let tb = rng.below(2) == 1;
            let a_t: Vec<f32>; // column-major storage when transposed
            let av = if ta {
                a_t = (0..k * m).map(|i| a_raw[(i % m) * k + i / m]).collect();
                MatView::transposed(&a_t, m)
            } else {
                MatView::rows(&a_raw, k)
            };
            let b_t: Vec<f32>;
            let bv = if tb {
                b_t = (0..n * k).map(|i| b_raw[(i % k) * n + i / k]).collect();
                MatView::transposed(&b_t, k)
            } else {
                MatView::rows(&b_raw, n)
            };
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let ep = match rng.below(3) {
                0 => Epilogue::None,
                1 => Epilogue::Bias(&bias),
                _ => Epilogue::BiasRelu(&bias),
            };
            let want = naive(m, n, k, &av, &bv, ep, None);
            let mut got = vec![f32::NAN; m * n]; // overwrite mode must not read C
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            gemm(&mut got, m, n, k, av, bv, ep, false, &mut pa, &mut pb);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                prop_assert!(
                    close(*g, *w),
                    "[{i}]: {g} vs {w} (m {m} n {n} k {k} ta {ta} tb {tb})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn accumulate_adds_to_existing_c() {
        let m = 5;
        let n = 6;
        let k = 9;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32).cos()).collect();
        let av = MatView::rows(&a, k);
        let bv = MatView::rows(&b, n);
        let c0: Vec<f32> = (0..m * n).map(|i| i as f32 / 7.0).collect();
        let want = naive(m, n, k, &av, &bv, Epilogue::None, Some(&c0));
        let mut got = c0.clone();
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        gemm(&mut got, m, n, k, av, bv, Epilogue::None, true, &mut pa, &mut pb);
        for (g, w) in got.iter().zip(&want) {
            assert!(close(*g, *w), "{g} vs {w}");
        }
    }

    #[test]
    fn results_are_bitwise_stable_across_dirty_arenas() {
        // The arena contract: no read observes buffer history, so a
        // NaN-poisoned arena must give bitwise the clean-arena answer —
        // on whatever tier is active AND with the tier forced to SIMD.
        let (m, n, k) = (33, 19, 270); // multi-panel in k, ragged tiles
        let a = gen_mat(m * k, 31, 7, 61);
        let b = gen_mat(k * n, 17, 3, 71);
        let bias: Vec<f32> = (0..n).map(|j| j as f32 / 19.0 - 0.4).collect();
        for tier in [active_tier(), Tier::Avx2.supported()] {
            let run = |pa: &mut Vec<f32>, pb: &mut Vec<f32>| {
                let mut c = vec![0.0f32; m * n];
                gemm_with_tier(
                    tier,
                    &mut c,
                    m,
                    n,
                    k,
                    MatView::rows(&a, k),
                    MatView::rows(&b, n),
                    Epilogue::BiasRelu(&bias),
                    false,
                    pa,
                    pb,
                );
                c
            };
            let clean = run(&mut Vec::new(), &mut Vec::new());
            let mut pa = vec![f32::NAN; 7];
            let mut pb = vec![f32::NAN; 100_000];
            let dirty = run(&mut pa, &mut pb);
            for (x, y) in clean.iter().zip(&dirty) {
                assert_eq!(x.to_bits(), y.to_bits(), "{tier:?}: dirty arena changed the result");
            }
        }
    }

    #[test]
    fn empty_k_is_epilogue_only() {
        let bias = [1.0f32, -2.0];
        let mut c = vec![5.0f32, 5.0, 5.0, 5.0];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        let a: [f32; 0] = [];
        gemm(
            &mut c,
            2,
            2,
            0,
            MatView::rows(&a, 0),
            MatView::rows(&a, 2),
            Epilogue::BiasRelu(&bias),
            false,
            &mut pa,
            &mut pb,
        );
        assert_eq!(c, vec![1.0, 0.0, 1.0, 0.0]);
    }

    /// The cross-tier acceptance bound: |simd - portable| ≤ 1e-5·(1+|p|).
    /// On hosts without AVX2 the SIMD tier degrades to portable and the
    /// comparison is trivially exact — the suite still runs everywhere.
    #[allow(clippy::too_many_arguments)]
    fn assert_tiers_close(
        tag: &str,
        m: usize,
        n: usize,
        k: usize,
        av: MatView<'_>,
        bv: MatView<'_>,
        ep: Epilogue<'_>,
    ) {
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        let mut portable = vec![f32::NAN; m * n];
        gemm_with_tier(Tier::Portable, &mut portable, m, n, k, av, bv, ep, false, &mut pa, &mut pb);
        let mut simd = vec![f32::NAN; m * n];
        gemm_with_tier(Tier::Avx2, &mut simd, m, n, k, av, bv, ep, false, &mut pa, &mut pb);
        for (i, (s, p)) in simd.iter().zip(&portable).enumerate() {
            assert!(
                (s - p).abs() <= 1e-5 * (1.0 + p.abs()),
                "{tag}[{i}]: simd {s} vs portable {p} ({m}x{n}x{k})"
            );
        }
    }

    /// SIMD-vs-portable on the satellite's awkward conv-derived shapes:
    /// odd H/W images (m = h·w), off-tile k²·ic / oc, batch-1 single-image
    /// products, plus every blocking edge.
    #[test]
    fn simd_tier_matches_portable_on_awkward_shapes() {
        // (m, n, k) = (h·w, oc, k²·ic) for the conv shapes.
        let shapes = [
            (35usize, 9usize, 75usize), // 5x7 image, oc 9, 5x5x3 taps
            (1, 1, 1),
            (63, 13, 147), // 7x9 image, oc 13, 3x3x.. taps — all off-tile
            (8, 8, 8),
            (9, 7, 25),
            (13, 10, 300),  // multi-KC
            (65, 260, 13),  // multi-NC
            (31, 33, 257),
        ];
        for &(m, n, k) in &shapes {
            let a = gen_mat(m * k, 37, 11, 97);
            let b = gen_mat(k * n, 53, 29, 89);
            let bias = gen_mat(n, 7, 5, 41);
            let av = MatView::rows(&a, k);
            let bv = MatView::rows(&b, n);
            for ep in [Epilogue::None, Epilogue::Bias(&bias), Epilogue::BiasRelu(&bias)] {
                assert_tiers_close("awkward", m, n, k, av, bv, ep);
            }
        }
    }

    #[test]
    fn property_simd_tier_matches_portable() {
        check("gemm-simd-vs-portable", 48, |rng| {
            let m = 1 + rng.below(40);
            let n = 1 + rng.below(30);
            let k = 1 + rng.below(80);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.5).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let ep = match rng.below(3) {
                0 => Epilogue::None,
                1 => Epilogue::Bias(&bias),
                _ => Epilogue::BiasRelu(&bias),
            };
            let av = MatView::rows(&a, k);
            let bv = MatView::rows(&b, n);
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            let mut portable = vec![f32::NAN; m * n];
            gemm_with_tier(
                Tier::Portable,
                &mut portable,
                m,
                n,
                k,
                av,
                bv,
                ep,
                false,
                &mut pa,
                &mut pb,
            );
            let mut simd = vec![f32::NAN; m * n];
            gemm_with_tier(Tier::Avx2, &mut simd, m, n, k, av, bv, ep, false, &mut pa, &mut pb);
            for (i, (s, p)) in simd.iter().zip(&portable).enumerate() {
                prop_assert!(
                    (s - p).abs() <= 1e-5 * (1.0 + p.abs()),
                    "[{i}]: simd {s} vs portable {p} (m {m} n {n} k {k})"
                );
            }
            Ok(())
        });
    }

    /// The hoisted weight-packing path: `pack_b_full` + `gemm_packed_b`
    /// must be BITWISE the on-the-fly packing path — for row-major and
    /// transposed B, across multi-NC and multi-KC panel shapes, on both
    /// tiers, and regardless of what garbage the `pw` arena held before.
    #[test]
    fn packed_b_panels_match_inline_packing_bitwise() {
        let shapes = [(5usize, 9usize, 7usize), (33, 300, 40), (13, 10, 520), (65, 260, 257)];
        for tier in [Tier::Portable, Tier::Avx2.supported()] {
            for &(m, n, k) in &shapes {
                let a = gen_mat(m * k, 37, 11, 97);
                let b = gen_mat(k * n, 53, 29, 89);
                let bt: Vec<f32> = (0..n * k).map(|i| b[(i % k) * n + i / k]).collect();
                let bias = gen_mat(n, 7, 5, 41);
                let av = MatView::rows(&a, k);
                for (bv, tag) in
                    [(MatView::rows(&b, n), "rows"), (MatView::transposed(&bt, k), "transposed")]
                {
                    let (mut pa, mut pb) = (Vec::new(), Vec::new());
                    let mut want = vec![0.0f32; m * n];
                    gemm_with_tier(
                        tier,
                        &mut want,
                        m,
                        n,
                        k,
                        av,
                        bv,
                        Epilogue::BiasRelu(&bias),
                        false,
                        &mut pa,
                        &mut pb,
                    );
                    let mut pw = vec![f32::NAN; 17]; // stale arena contents
                    pack_b_full(&mut pw, &bv, k, n);
                    assert_eq!(pw.len(), packed_b_len(k, n), "{tag}: packed length");
                    assert!(pw.iter().all(|v| v.is_finite()), "{tag}: pack left stale data");
                    let mut got = vec![f32::NAN; m * n];
                    gemm_packed_b(
                        tier,
                        &mut got,
                        m,
                        n,
                        k,
                        av,
                        &pw,
                        Epilogue::BiasRelu(&bias),
                        false,
                        &mut pa,
                    );
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "{tier:?}/{tag} ({m}x{n}x{k})[{i}]: packed {g} vs inline {w}"
                        );
                    }
                }
            }
        }
    }

    /// Panel-parallel column splitting is bitwise the serial GEMM for
    /// every split width, on both tiers, epilogues included.
    #[test]
    fn gemm_parallel_is_bitwise_serial() {
        let shapes = [(13usize, 100usize, 70usize), (32, 300, 64), (5, 8, 9)];
        for tier in [Tier::Portable, Tier::Avx2.supported()] {
            for &(m, n, k) in &shapes {
                let a = gen_mat(m * k, 31, 7, 61);
                let b = gen_mat(k * n, 17, 3, 71);
                let bias = gen_mat(n, 7, 5, 41);
                let av = MatView::rows(&a, k);
                let bv = MatView::rows(&b, n);
                for ep in [Epilogue::None, Epilogue::Bias(&bias), Epilogue::BiasRelu(&bias)] {
                    let (mut pa, mut pb) = (Vec::new(), Vec::new());
                    let mut want = vec![0.0f32; m * n];
                    gemm_with_tier(tier, &mut want, m, n, k, av, bv, ep, false, &mut pa, &mut pb);
                    for par in [1usize, 2, 3, 5, 8] {
                        let mut got = vec![f32::NAN; m * n];
                        gemm_parallel(
                            tier, &mut got, m, n, k, av, bv, ep, par, &mut pa, &mut pb,
                        );
                        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                            assert_eq!(
                                g.to_bits(),
                                w.to_bits(),
                                "{tier:?} par {par} ({m}x{n}x{k})[{i}]: {g} vs serial {w}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn tier_clamp_and_names_are_sane() {
        assert_eq!(Tier::Portable.supported(), Tier::Portable);
        assert_eq!(Tier::Portable.name(), "portable");
        // Whatever the host, the clamp returns something executable and
        // idempotent.
        let t = Tier::Avx2.supported();
        assert_eq!(t.supported(), t);
        // And the cached process-wide tier is itself supported.
        assert_eq!(active_tier().supported(), active_tier());
    }
}
