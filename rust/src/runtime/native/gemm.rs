//! The one tuned `f32` GEMM core behind every native conv/dense kernel:
//! a register-blocked `MR×NR` microkernel under GotoBLAS-style cache
//! blocking (`KC` k-panels, `MC×KC` packed A, `KC×NC` packed B), with the
//! layer bias+relu fused into the epilogue of the final k-panel.
//!
//! One routine serves five products (see `ops.rs`): conv fwd
//! (`im2col(x)·W`), conv d_x (`d_out·Wᵀ`), conv d_w (`im2col(x)ᵀ·d_out`),
//! dense fwd (`x·W`) and dense d_x/d_w — transposed operands are handled
//! by the packing routines through strided [`MatView`]s, so no operand is
//! ever materialized transposed.
//!
//! Determinism: for a fixed problem shape the summation order of every
//! output element is fixed — k-panels accumulate in ascending `p` order
//! and panel partials are added to C in ascending panel order — and no
//! read ever observes scratch-buffer history (packing pads edge tiles
//! with explicit zeros).  Identical inputs therefore produce bitwise
//! identical outputs on every call, from any worker thread: the
//! threads=N ≡ threads=1 and split-vs-full bitwise guarantees extend to
//! the GEMM path unchanged.  See DESIGN.md §Native backend.

/// Microkernel tile height (rows of C per register tile).
pub const MR: usize = 8;
/// Microkernel tile width (one 8-lane f32 vector).
pub const NR: usize = 8;
/// Rows of A packed per panel (`MC×KC` ≈ 64 KiB, L2-resident).
const MC: usize = 64;
/// Columns of B packed per panel (`KC×NC` ≈ 256 KiB).
const NC: usize = 256;
/// k-depth of one panel (one `KC×NR` B strip ≈ 8 KiB, L1-resident).
const KC: usize = 256;

/// Strided read-only view of a row-major matrix (or its transpose):
/// element `(r, c)` lives at `data[r·rs + c·cs]`.
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    data: &'a [f32],
    rs: usize,
    cs: usize,
}

impl<'a> MatView<'a> {
    /// View a row-major `[rows × cols]` buffer as itself.
    pub fn rows(data: &'a [f32], cols: usize) -> MatView<'a> {
        MatView { data, rs: cols, cs: 1 }
    }

    /// View a row-major `[rows × cols]` buffer as its transpose
    /// (`cols × rows`), without copying.
    pub fn transposed(data: &'a [f32], cols: usize) -> MatView<'a> {
        MatView { data, rs: 1, cs: cols }
    }

    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.rs + c * self.cs]
    }

    /// Whether rows are contiguous (`cs == 1`) — enables `copy_from_slice`
    /// fast paths in the packers.
    #[inline(always)]
    fn row_major(&self) -> bool {
        self.cs == 1
    }
}

/// What the final k-panel writes into each C element after the product.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// Plain product (gradient GEMMs).
    None,
    /// `+ bias[j]` per output column (linear logits layer).
    Bias(&'a [f32]),
    /// `max(0, · + bias[j])` (hidden conv/dense layers).
    BiasRelu(&'a [f32]),
}

/// `C[m×n] (+)= A[m×k] · B[k×n]`, row-major contiguous C (`ldc == n`).
///
/// * `accumulate == false` overwrites C (no pre-zeroing needed);
///   `accumulate == true` adds the product to the existing C (used by
///   conv d_w to sum image contributions in ascending image order) and
///   must be paired with [`Epilogue::None`].
/// * `pa`/`pb` are the packing arenas (see [`crate::runtime::Scratch`]);
///   they are resized to the fixed panel footprint once and fully
///   rewritten before every read.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    a: MatView<'_>,
    b: MatView<'_>,
    ep: Epilogue<'_>,
    accumulate: bool,
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    debug_assert_eq!(c.len(), m * n, "gemm: C is {} elems, want {m}x{n}", c.len());
    debug_assert!(
        !accumulate || matches!(ep, Epilogue::None),
        "gemm: accumulate composes across calls; fuse epilogues only on the last one"
    );
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // Degenerate empty product: C (+)= 0, epilogue still applies.
        if !accumulate {
            c.fill(0.0);
        }
        apply_epilogue_rows(c, n, ep);
        return;
    }
    pa.resize(MC * KC, 0.0);
    pb.resize(NC * KC, 0.0);
    for jc in (0..n).step_by(NC) {
        let ncw = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kcw = KC.min(k - pc);
            let first = pc == 0;
            let last = pc + kcw == k;
            pack_b(pb, &b, pc, kcw, jc, ncw);
            for icb in (0..m).step_by(MC) {
                let mcw = MC.min(m - icb);
                pack_a(pa, &a, icb, mcw, pc, kcw);
                for jr in (0..ncw).step_by(NR) {
                    let nrw = NR.min(ncw - jr);
                    let pb_strip = &pb[(jr / NR) * kcw * NR..][..kcw * NR];
                    for ir in (0..mcw).step_by(MR) {
                        let mrw = MR.min(mcw - ir);
                        let pa_strip = &pa[(ir / MR) * kcw * MR..][..kcw * MR];
                        let mut acc = [[0.0f32; NR]; MR];
                        microkernel(kcw, pa_strip, pb_strip, &mut acc);
                        store_tile(
                            c,
                            n,
                            icb + ir,
                            jc + jr,
                            mrw,
                            nrw,
                            &acc,
                            first && !accumulate,
                            last,
                            ep,
                        );
                    }
                }
            }
            pc += kcw;
        }
    }
}

/// The register tile: `acc[MR][NR] += pa_strip ⊗ pb_strip` over one
/// k-panel, ascending `p`.  Fixed-size rows keep the inner loop branch-
/// free and autovectorizable (NR = one 8-lane f32 vector).
#[inline(always)]
fn microkernel(kc: usize, pa_strip: &[f32], pb_strip: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(pa_strip.len() >= kc * MR && pb_strip.len() >= kc * NR);
    for p in 0..kc {
        let arow: &[f32; MR] = pa_strip[p * MR..p * MR + MR].try_into().unwrap();
        let brow: &[f32; NR] = pb_strip[p * NR..p * NR + NR].try_into().unwrap();
        for (accrow, &av) in acc.iter_mut().zip(arow) {
            for (cv, &bv) in accrow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Merge one register tile into C: overwrite on the first k-panel of a
/// non-accumulating GEMM, add otherwise; fuse the epilogue on the last.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn store_tile(
    c: &mut [f32],
    ldc: usize,
    i0: usize,
    j0: usize,
    mrw: usize,
    nrw: usize,
    acc: &[[f32; NR]; MR],
    overwrite: bool,
    last: bool,
    ep: Epilogue<'_>,
) {
    for (i, accrow) in acc.iter().enumerate().take(mrw) {
        let base = (i0 + i) * ldc + j0;
        let crow = &mut c[base..base + nrw];
        if overwrite {
            crow.copy_from_slice(&accrow[..nrw]);
        } else {
            for (cv, &av) in crow.iter_mut().zip(&accrow[..nrw]) {
                *cv += av;
            }
        }
        if last {
            apply_epilogue(crow, j0, ep);
        }
    }
}

#[inline(always)]
fn apply_epilogue(crow: &mut [f32], j0: usize, ep: Epilogue<'_>) {
    match ep {
        Epilogue::None => {}
        Epilogue::Bias(bias) => {
            for (cv, &bv) in crow.iter_mut().zip(&bias[j0..j0 + crow.len()]) {
                *cv += bv;
            }
        }
        Epilogue::BiasRelu(bias) => {
            for (cv, &bv) in crow.iter_mut().zip(&bias[j0..j0 + crow.len()]) {
                *cv += bv;
                if *cv < 0.0 {
                    *cv = 0.0;
                }
            }
        }
    }
}

fn apply_epilogue_rows(c: &mut [f32], ldc: usize, ep: Epilogue<'_>) {
    for crow in c.chunks_mut(ldc) {
        apply_epilogue(crow, 0, ep);
    }
}

/// Pack A rows `i0..i0+mc` × k `p0..p0+kc` into MR-row strips, k-major
/// within each strip; rows past `mc` in the last strip are zero-padded so
/// the microkernel never branches on the edge.
fn pack_a(dst: &mut [f32], a: &MatView<'_>, i0: usize, mc: usize, p0: usize, kc: usize) {
    let mut off = 0;
    let mut ir = 0;
    while ir < mc {
        let mrw = MR.min(mc - ir);
        for p in 0..kc {
            let d = &mut dst[off + p * MR..off + (p + 1) * MR];
            for (i, dv) in d.iter_mut().enumerate() {
                *dv = if i < mrw { a.at(i0 + ir + i, p0 + p) } else { 0.0 };
            }
        }
        off += kc * MR;
        ir += MR;
    }
}

/// Pack B k `p0..p0+kc` × columns `j0..j0+nc` into NR-column strips,
/// k-major within each strip, zero-padding the ragged last strip.  The
/// row-major full-strip case (weights, d_out) is a straight `memcpy`.
fn pack_b(dst: &mut [f32], b: &MatView<'_>, p0: usize, kc: usize, j0: usize, nc: usize) {
    let mut off = 0;
    let mut jr = 0;
    while jr < nc {
        let nrw = NR.min(nc - jr);
        if b.row_major() && nrw == NR {
            for p in 0..kc {
                let src = (p0 + p) * b.rs + j0 + jr;
                dst[off + p * NR..off + (p + 1) * NR].copy_from_slice(&b.data[src..src + NR]);
            }
        } else {
            for p in 0..kc {
                let d = &mut dst[off + p * NR..off + (p + 1) * NR];
                for (j, dv) in d.iter_mut().enumerate() {
                    *dv = if j < nrw { b.at(p0 + p, j0 + jr + j) } else { 0.0 };
                }
            }
        }
        off += kc * NR;
        jr += NR;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    /// Naive triple loop with the SAME per-element summation order as the
    /// packed path's single-panel case (ascending k, epilogue last).
    fn naive(
        m: usize,
        n: usize,
        k: usize,
        a: &MatView<'_>,
        b: &MatView<'_>,
        ep: Epilogue<'_>,
        init: Option<&[f32]>,
    ) -> Vec<f32> {
        let mut c = match init {
            Some(c0) => c0.to_vec(),
            None => vec![0.0f32; m * n],
        };
        for i in 0..m {
            for j in 0..n {
                let mut s = if init.is_some() { c[i * n + j] } else { 0.0 };
                for p in 0..k {
                    s += a.at(i, p) * b.at(p, j);
                }
                c[i * n + j] = s;
            }
        }
        for crow in c.chunks_mut(n) {
            apply_epilogue(crow, 0, ep);
        }
        c
    }

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-4 * (1.0 + b.abs())
    }

    #[test]
    fn matches_naive_on_awkward_shapes() {
        // Shapes straddling every blocking edge: below/above MR, NR, MC,
        // NC, KC, and non-multiples of all of them.
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (8, 8, 8),
            (9, 7, 25),
            (13, 10, 300),
            (70, 9, 17),
            (65, 260, 13),
            (31, 33, 257),
        ];
        for &(m, n, k) in &shapes {
            let a: Vec<f32> =
                (0..m * k).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 - 0.5).collect();
            let b: Vec<f32> =
                (0..k * n).map(|i| ((i * 53 + 29) % 89) as f32 / 89.0 - 0.5).collect();
            let av = MatView::rows(&a, k);
            let bv = MatView::rows(&b, n);
            let want = naive(m, n, k, &av, &bv, Epilogue::None, None);
            let mut got = vec![0.0f32; m * n];
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            gemm(&mut got, m, n, k, av, bv, Epilogue::None, false, &mut pa, &mut pb);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(close(*g, *w), "({m}x{n}x{k})[{i}]: {g} vs {w}");
            }
        }
    }

    #[test]
    fn transposed_views_read_the_transpose() {
        // A = Xᵀ where X is 4x3 row-major: A is 3x4.
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let at = MatView::transposed(&x, 3);
        assert_eq!(at.at(0, 0), 0.0);
        assert_eq!(at.at(2, 1), x[5]); // X[1][2]
        assert_eq!(at.at(1, 3), x[10]); // X[3][1]
    }

    #[test]
    fn property_strided_operands_and_epilogues() {
        check("gemm-strided-epilogue", 48, |rng| {
            let m = 1 + rng.below(20);
            let n = 1 + rng.below(20);
            let k = 1 + rng.below(40);
            let a_raw: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect();
            let b_raw: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.5).collect();
            // Transposed storage for each operand, half the time.
            let ta = rng.below(2) == 1;
            let tb = rng.below(2) == 1;
            let a_t: Vec<f32>; // column-major storage when transposed
            let av = if ta {
                a_t = (0..k * m).map(|i| a_raw[(i % m) * k + i / m]).collect();
                MatView::transposed(&a_t, m)
            } else {
                MatView::rows(&a_raw, k)
            };
            let b_t: Vec<f32>;
            let bv = if tb {
                b_t = (0..n * k).map(|i| b_raw[(i % k) * n + i / k]).collect();
                MatView::transposed(&b_t, k)
            } else {
                MatView::rows(&b_raw, n)
            };
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let ep = match rng.below(3) {
                0 => Epilogue::None,
                1 => Epilogue::Bias(&bias),
                _ => Epilogue::BiasRelu(&bias),
            };
            let want = naive(m, n, k, &av, &bv, ep, None);
            let mut got = vec![f32::NAN; m * n]; // overwrite mode must not read C
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            gemm(&mut got, m, n, k, av, bv, ep, false, &mut pa, &mut pb);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                prop_assert!(
                    close(*g, *w),
                    "[{i}]: {g} vs {w} (m {m} n {n} k {k} ta {ta} tb {tb})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn accumulate_adds_to_existing_c() {
        let m = 5;
        let n = 6;
        let k = 9;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32).cos()).collect();
        let av = MatView::rows(&a, k);
        let bv = MatView::rows(&b, n);
        let c0: Vec<f32> = (0..m * n).map(|i| i as f32 / 7.0).collect();
        let want = naive(m, n, k, &av, &bv, Epilogue::None, Some(&c0));
        let mut got = c0.clone();
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        gemm(&mut got, m, n, k, av, bv, Epilogue::None, true, &mut pa, &mut pb);
        for (g, w) in got.iter().zip(&want) {
            assert!(close(*g, *w), "{g} vs {w}");
        }
    }

    #[test]
    fn results_are_bitwise_stable_across_dirty_arenas() {
        // The arena contract: no read observes buffer history, so a
        // NaN-poisoned arena must give bitwise the clean-arena answer.
        let (m, n, k) = (33, 19, 270); // multi-panel in k, ragged tiles
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 31 + 7) % 61) as f32 / 61.0 - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i * 17 + 3) % 71) as f32 / 71.0 - 0.5).collect();
        let bias: Vec<f32> = (0..n).map(|j| j as f32 / 19.0 - 0.4).collect();
        let run = |pa: &mut Vec<f32>, pb: &mut Vec<f32>| {
            let mut c = vec![0.0f32; m * n];
            gemm(
                &mut c,
                m,
                n,
                k,
                MatView::rows(&a, k),
                MatView::rows(&b, n),
                Epilogue::BiasRelu(&bias),
                false,
                pa,
                pb,
            );
            c
        };
        let clean = run(&mut Vec::new(), &mut Vec::new());
        let mut pa = vec![f32::NAN; 7];
        let mut pb = vec![f32::NAN; 100_000];
        let dirty = run(&mut pa, &mut pb);
        for (x, y) in clean.iter().zip(&dirty) {
            assert_eq!(x.to_bits(), y.to_bits(), "dirty arena changed the result");
        }
    }

    #[test]
    fn empty_k_is_epilogue_only() {
        let bias = [1.0f32, -2.0];
        let mut c = vec![5.0f32, 5.0, 5.0, 5.0];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        let a: [f32; 0] = [];
        gemm(
            &mut c,
            2,
            2,
            0,
            MatView::rows(&a, 0),
            MatView::rows(&a, 2),
            Epilogue::BiasRelu(&bias),
            false,
            &mut pa,
            &mut pb,
        );
        assert_eq!(c, vec![1.0, 0.0, 1.0, 0.0]);
    }
}
