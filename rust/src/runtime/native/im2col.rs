//! im2col/col2im lowering for the SAME-padded stride-1 convs: one image's
//! receptive fields unrolled to a row-major `h·w × k·k·ic` matrix whose
//! column order `(ky, kx, ic)` matches the HWIO weight layout exactly —
//! conv forward is then `col · W`, conv d_w is `colᵀ · d_out`, and conv
//! d_x is `d_out · Wᵀ` scattered back by [`col2im_image`].
//!
//! Both routines walk the column matrix in row-major scan order, so the
//! scatter-add order of every `d_x` element is a fixed function of the
//! geometry — the determinism argument of DESIGN.md §Native backend.

/// Columns of the im2col matrix for a `k×k` conv over `ic` channels.
pub fn col_width(k: usize, ic: usize) -> usize {
    k * k * ic
}

/// Unroll ONE image (`h×w×ic`, NHWC sans batch) into `col`
/// (`h·w × k·k·ic`).  Every element of `col` is written: out-of-image
/// taps are explicit zeros, so the caller may pass arbitrary stale
/// scratch.
pub fn im2col_image(x: &[f32], h: usize, w: usize, ic: usize, k: usize, col: &mut [f32]) {
    debug_assert_eq!(x.len(), h * w * ic);
    debug_assert_eq!(col.len(), h * w * col_width(k, ic));
    let pad = k / 2;
    let mut off = 0;
    for y in 0..h {
        for xo in 0..w {
            for ky in 0..k {
                // Source row sy = y + ky - pad; a whole kx-run of zeros
                // when it falls outside the image.
                if y + ky < pad || y + ky - pad >= h {
                    col[off..off + k * ic].fill(0.0);
                    off += k * ic;
                    continue;
                }
                let sy = y + ky - pad;
                for kx in 0..k {
                    let dst = &mut col[off..off + ic];
                    if xo + kx >= pad && xo + kx - pad < w {
                        let src = (sy * w + xo + kx - pad) * ic;
                        dst.copy_from_slice(&x[src..src + ic]);
                    } else {
                        dst.fill(0.0);
                    }
                    off += ic;
                }
            }
        }
    }
}

/// Inverse scatter-add of [`im2col_image`]: fold a column-space gradient
/// back onto the image, accumulating into `dx` (caller zeroes it).  Taps
/// that fell outside the image are dropped (their forward value was the
/// zero padding).
pub fn col2im_image(col: &[f32], h: usize, w: usize, ic: usize, k: usize, dx: &mut [f32]) {
    debug_assert_eq!(dx.len(), h * w * ic);
    debug_assert_eq!(col.len(), h * w * col_width(k, ic));
    let pad = k / 2;
    let mut off = 0;
    for y in 0..h {
        for xo in 0..w {
            for ky in 0..k {
                if y + ky < pad || y + ky - pad >= h {
                    off += k * ic;
                    continue;
                }
                let sy = y + ky - pad;
                for kx in 0..k {
                    if xo + kx >= pad && xo + kx - pad < w {
                        let dst = (sy * w + xo + kx - pad) * ic;
                        for (dv, &cv) in dx[dst..dst + ic].iter_mut().zip(&col[off..off + ic]) {
                            *dv += cv;
                        }
                    }
                    off += ic;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_is_a_copy() {
        // k=1: the col matrix is the image itself.
        let x: Vec<f32> = (0..2 * 3 * 4).map(|i| i as f32).collect();
        let mut col = vec![f32::NAN; x.len()];
        im2col_image(&x, 2, 3, 4, 1, &mut col);
        assert_eq!(col, x);
    }

    #[test]
    fn col_rows_are_receptive_fields() {
        // 3x3 image, 1 channel, k=3: the center pixel's row is the whole
        // image; the corner row has the matching zero ring.
        let x: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        let mut col = vec![f32::NAN; 9 * 9];
        im2col_image(&x, 3, 3, 1, 3, &mut col);
        // Center output (y=1, x=1) sees the full image in scan order.
        assert_eq!(&col[4 * 9..5 * 9], &x[..]);
        // Top-left output (y=0, x=0): rows/cols above/left are padding.
        assert_eq!(&col[..9], &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 4.0, 5.0]);
    }

    #[test]
    fn col2im_of_ones_counts_tap_multiplicity() {
        // Fold a col matrix of ones: each image pixel receives one unit
        // per window that reads it — k² in the interior, fewer at edges.
        let (h, w, ic, k) = (4usize, 5usize, 2usize, 3usize);
        let col = vec![1.0f32; h * w * col_width(k, ic)];
        let mut dx = vec![0.0f32; h * w * ic];
        col2im_image(&col, h, w, ic, k, &mut dx);
        // Interior pixel (1,1): all 9 windows see it.
        assert_eq!(dx[(w + 1) * ic], 9.0);
        // Corner pixel (0,0): only the 4 windows centered in [0,1]².
        assert_eq!(dx[0], 4.0);
        // Gradient mass conservation: every col entry lands somewhere
        // inside, and ones-cols entries from padding taps are dropped.
        let interior_taps: f32 = dx.iter().sum();
        assert!(interior_taps < (h * w * col_width(k, ic)) as f32);
    }

    #[test]
    fn roundtrip_against_direct_conv() {
        // conv(x, w) via im2col == direct sliding-window sum.
        let (h, w, ic, k, oc) = (4usize, 3usize, 2usize, 3usize, 2usize);
        let x: Vec<f32> = (0..h * w * ic).map(|i| (i as f32 * 0.37).sin()).collect();
        let wt: Vec<f32> = (0..k * k * ic * oc).map(|i| (i as f32 * 0.61).cos()).collect();
        let mut col = vec![0.0f32; h * w * col_width(k, ic)];
        im2col_image(&x, h, w, ic, k, &mut col);
        let kk = col_width(k, ic);
        let pad = k / 2;
        for y in 0..h {
            for xo in 0..w {
                for o in 0..oc {
                    let via_col: f32 = (0..kk)
                        .map(|p| col[(y * w + xo) * kk + p] * wt[p * oc + o])
                        .sum();
                    let mut direct = 0.0f32;
                    for ky in 0..k {
                        for kx in 0..k {
                            if y + ky < pad || y + ky - pad >= h {
                                continue;
                            }
                            if xo + kx < pad || xo + kx - pad >= w {
                                continue;
                            }
                            let (sy, sx) = (y + ky - pad, xo + kx - pad);
                            for i in 0..ic {
                                direct += x[(sy * w + sx) * ic + i]
                                    * wt[((ky * k + kx) * ic + i) * oc + o];
                            }
                        }
                    }
                    assert!(
                        (via_col - direct).abs() < 1e-5,
                        "({y},{xo},{o}): {via_col} vs {direct}"
                    );
                }
            }
        }
    }
}
