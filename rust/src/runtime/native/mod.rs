//! The native pure-Rust backend: executes any architecture in the model
//! registry directly on flat `Vec<f32>` buffers — no Python, JAX, XLA or
//! PJRT anywhere.
//!
//! Execution dispatches on the spec's declarative layer graph
//! (`model::graph`): conv / dense layers for the CNNs, patch-embedding
//! and pre-LN transformer blocks (layernorm → multi-head softmax
//! attention → residual → layernorm → GELU MLP → residual) for the
//! transformer stack.  Manifest-JSON specs recover their graph from the
//! parameter table at parse time; specs without an executable graph are
//! rejected here.  Forward passes record a per-layer tape (inputs,
//! activations, pool argmaxes, attention probabilities, layernorm
//! statistics); backward consumes the tape to produce exactly the VJPs
//! the five roles need.
//!
//! Compute runs on the im2col + blocked-GEMM fast path ([`gemm`],
//! [`im2col`], [`ops`]) with per-call intermediates drawn from a
//! [`Scratch`] arena: the `*_with` role variants take the caller's
//! per-worker [`ScratchHandle`] (the hot path — `ParallelExecutor` owns
//! one arena per worker), while the plain [`Backend`] methods fall back
//! to an internal arena so direct callers (tests, benches) need no
//! setup.  The GEMM microkernel is tiered (AVX2+FMA when the host has
//! it, portable otherwise — see [`gemm`]); each arena carries its tier so
//! a whole forward/backward chain is tier-consistent.  The original
//! scalar kernels are retained in [`reference`] and cross-checked
//! against the fast path by property tests.
//!
//! Eval-only extra parallelism: [`Backend::set_eval_parallelism`] lets
//! the trainer grant spare pool capacity to the forward-only eval path.
//! Large dense layers then split their GEMM by output-column panel
//! ([`gemm::gemm_parallel`]) — a bitwise-neutral partition, since no
//! element's k-summation order changes.  Training roles never see the
//! hint.
//!
//! Numerical semantics are pinned to the JAX reference kernels
//! (`python/compile/kernels/ref.py`) by the golden tests in [`ops`] and
//! the full-model goldens below; split-vs-full gradient equality is exact
//! (bitwise) because both paths share the same kernels, at every cut of
//! every registry architecture (`tests/model_zoo.rs`).

pub mod gemm;
pub mod im2col;
pub mod ops;
pub mod reference;

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::model::{LayerSpec, ShapeSpec};
use crate::tensor::Params;

use ops::Geom;
use super::backend::Backend;
use super::scratch::{Scratch, ScratchHandle};
use super::tensor::Tensor;

/// Per-layer forward records needed by the backward pass.
enum Tape {
    Conv {
        input: Vec<f32>,
        g: Geom,
        k: usize,
        oc: usize,
        act: Vec<f32>,
        idx: Vec<u32>,
        pool: bool,
    },
    Dense {
        input: Vec<f32>,
        din: usize,
        dout: usize,
        out: Vec<f32>,
        relu: bool,
    },
    Embed {
        patches: Vec<f32>,
        g: Geom,
        patch: usize,
        t: usize,
        din: usize,
        dm: usize,
    },
    Txf {
        t: usize,
        dm: usize,
        heads: usize,
        dff: usize,
        input: Vec<f32>,
        m1: Vec<f32>,
        r1: Vec<f32>,
        ln1: Vec<f32>,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        probs: Vec<f32>,
        concat: Vec<f32>,
        res1: Vec<f32>,
        m2: Vec<f32>,
        r2: Vec<f32>,
        ln2: Vec<f32>,
        hpre: Vec<f32>,
        hact: Vec<f32>,
    },
}

/// Parameter arrays owned by a taped layer.
fn tape_params(t: &Tape) -> usize {
    match t {
        Tape::Txf { .. } => 16,
        _ => 2,
    }
}

/// Pure-Rust execution of the split model (all menu cuts, all five roles).
pub struct NativeBackend {
    spec: ShapeSpec,
    layers: Vec<LayerSpec>,
    /// Cumulative parameter-array counts: layer `i` (1-based) owns params
    /// `param_base[i-1]..param_base[i]` of the manifest order.
    param_base: Vec<usize>,
    /// Arena for callers of the plain (scratch-less) role methods.  The
    /// hot path never touches it — the executor hands every worker its
    /// own arena through the `*_with` variants.
    fallback: ScratchHandle,
    /// Extra threads one eval call may use for panel-parallel dense GEMM
    /// (set by [`Backend::set_eval_parallelism`]; 1 = serial).
    eval_par: AtomicUsize,
}

impl NativeBackend {
    /// Take the spec's layer graph and validate its consistency.
    pub fn new(spec: ShapeSpec) -> anyhow::Result<NativeBackend> {
        anyhow::ensure!(
            spec.input_shape.len() == 3,
            "native backend expects [h, w, c] inputs, got {:?}",
            spec.input_shape
        );
        anyhow::ensure!(
            !spec.layers.is_empty(),
            "spec '{}' has no executable layer graph (its parameter table is not a \
             recognized layer chain)",
            spec.key
        );
        let layers: Vec<LayerSpec> = spec.layers.iter().map(|l| l.spec).collect();
        let mut param_base = Vec::with_capacity(layers.len() + 1);
        param_base.push(0usize);
        for l in &layers {
            param_base.push(param_base.last().unwrap() + l.num_params());
        }
        anyhow::ensure!(
            *param_base.last().unwrap() == spec.params.len(),
            "layer graph owns {} parameter arrays, manifest lists {}",
            param_base.last().unwrap(),
            spec.params.len()
        );
        anyhow::ensure!(
            layers[0].in_elems() == spec.input_per_sample(),
            "first layer does not accept the input shape {:?}",
            spec.input_shape
        );
        for pair in layers.windows(2) {
            anyhow::ensure!(
                pair[0].out_elems() == pair[1].in_elems(),
                "activation mismatch between consecutive layers"
            );
        }
        anyhow::ensure!(
            matches!(layers.last(), Some(LayerSpec::Dense { dout, .. }) if *dout == spec.classes),
            "last layer must produce {} logits",
            spec.classes
        );
        Ok(NativeBackend {
            spec,
            layers,
            param_base,
            fallback: ScratchHandle::new(),
            eval_par: AtomicUsize::new(1),
        })
    }

    /// Validate a cut against the menu and resolve it to `(client_params,
    /// client_layers)`.
    fn check_cut(&self, cut: usize) -> anyhow::Result<(usize, usize)> {
        let cut = self.spec.menu().validate(cut)?;
        let nc = self.spec.cut(cut).client_params;
        let blocks = self
            .param_base
            .iter()
            .position(|&b| b == nc)
            .filter(|&bi| bi >= 1 && bi < self.layers.len())
            .ok_or_else(|| {
                anyhow::anyhow!("cut {cut}: client_params {nc} does not align to a layer boundary")
            })?;
        Ok((nc, blocks))
    }

    /// Validate `[batch, input_shape...]` and return the batch size.
    fn batch_of_input(&self, x: &Tensor) -> anyhow::Result<usize> {
        anyhow::ensure!(
            x.shape.len() == 4 && x.shape[1..] == self.spec.input_shape[..],
            "input shape {:?} does not match [b, {:?}]",
            x.shape,
            self.spec.input_shape
        );
        Ok(x.shape[0])
    }

    /// The smashed-data shape at `cut` for an arbitrary batch size.
    fn smashed_shape(&self, cut: usize, batch: usize) -> Vec<usize> {
        let mut s = self.spec.cut(cut).smashed_shape.clone();
        s[0] = batch;
        s
    }

    /// Run layers `first..=last` (1-based), recording the backward tape.
    /// `params` is the contiguous manifest-order slice those layers own.
    /// Kernel intermediates come from `s`; tape buffers are owned.
    fn forward(
        &self,
        s: &mut Scratch,
        params: &[Vec<f32>],
        x: &[f32],
        batch: usize,
        first: usize,
        last: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<Tape>)> {
        let want = self.param_base[last] - self.param_base[first - 1];
        anyhow::ensure!(
            params.len() == want,
            "layers {first}..={last} need {want} params, got {}",
            params.len()
        );
        let mut cur = x.to_vec();
        let mut tapes = Vec::with_capacity(last + 1 - first);
        let mut off = 0usize;
        for blk in first..=last {
            let layer = self.layers[blk - 1];
            let p = &params[off..off + layer.num_params()];
            off += layer.num_params();
            match layer {
                LayerSpec::Conv { h, w, ic, k, oc, pool } => {
                    let g = Geom { b: batch, h, w, c: ic };
                    anyhow::ensure!(cur.len() == g.len(), "layer {blk}: input length mismatch");
                    anyhow::ensure!(p[0].len() == k * k * ic * oc, "layer {blk}: weight length");
                    let act = ops::conv2d_fwd(s, &cur, g, &p[0], k, oc, &p[1], true);
                    if pool {
                        let ag = Geom { b: batch, h, w, c: oc };
                        let (out, idx) = ops::maxpool2x2_fwd(&act, ag);
                        let input = std::mem::replace(&mut cur, out);
                        tapes.push(Tape::Conv { input, g, k, oc, act, idx, pool });
                    } else {
                        let input = std::mem::replace(&mut cur, act.clone());
                        tapes.push(Tape::Conv { input, g, k, oc, act, idx: Vec::new(), pool });
                    }
                }
                LayerSpec::Dense { din, dout, relu } => {
                    anyhow::ensure!(
                        cur.len() == batch * din,
                        "layer {blk}: input length {} != {batch}x{din}",
                        cur.len()
                    );
                    anyhow::ensure!(p[0].len() == din * dout, "layer {blk}: weight length");
                    let out = ops::dense_fwd(s, &cur, batch, din, dout, &p[0], &p[1], relu);
                    let input = std::mem::take(&mut cur);
                    cur = out.clone();
                    tapes.push(Tape::Dense { input, din, dout, out, relu });
                }
                LayerSpec::Embed { h, w, c, patch, dm } => {
                    let g = Geom { b: batch, h, w, c };
                    anyhow::ensure!(cur.len() == g.len(), "layer {blk}: input length mismatch");
                    let (t, din) = ((h / patch) * (w / patch), patch * patch * c);
                    anyhow::ensure!(p[0].len() == din * dm, "layer {blk}: weight length");
                    let patches = ops::patchify(&cur, g, patch);
                    cur = ops::dense_fwd(s, &patches, batch * t, din, dm, &p[0], &p[1], false);
                    tapes.push(Tape::Embed { patches, g, patch, t, din, dm });
                }
                LayerSpec::TxfBlock { tokens: t, dm, heads, dff } => {
                    let rows = batch * t;
                    anyhow::ensure!(
                        cur.len() == rows * dm,
                        "layer {blk}: input length {} != {rows}x{dm}",
                        cur.len()
                    );
                    // p: ln1_g ln1_b wq bq wk bk wv bv wo bo ln2_g ln2_b
                    //    w1 b1 w2 b2 (graph::param_specs order).
                    let (ln1, m1, r1) = ops::layernorm_fwd(&cur, rows, dm, &p[0], &p[1]);
                    let q = ops::dense_fwd(s, &ln1, rows, dm, dm, &p[2], &p[3], false);
                    let k = ops::dense_fwd(s, &ln1, rows, dm, dm, &p[4], &p[5], false);
                    let v = ops::dense_fwd(s, &ln1, rows, dm, dm, &p[6], &p[7], false);
                    let (probs, concat) = ops::mhsa_fwd(s, &q, &k, &v, batch, t, dm, heads);
                    let attn = ops::dense_fwd(s, &concat, rows, dm, dm, &p[8], &p[9], false);
                    let mut res1 = cur.clone();
                    for (r, &a) in res1.iter_mut().zip(&attn) {
                        *r += a;
                    }
                    let (ln2, m2, r2) = ops::layernorm_fwd(&res1, rows, dm, &p[10], &p[11]);
                    let hpre = ops::dense_fwd(s, &ln2, rows, dm, dff, &p[12], &p[13], false);
                    let hact = ops::gelu_fwd(&hpre);
                    let mlp = ops::dense_fwd(s, &hact, rows, dff, dm, &p[14], &p[15], false);
                    let mut out = res1.clone();
                    for (o, &mv) in out.iter_mut().zip(&mlp) {
                        *o += mv;
                    }
                    let input = std::mem::replace(&mut cur, out);
                    tapes.push(Tape::Txf {
                        t,
                        dm,
                        heads,
                        dff,
                        input,
                        m1,
                        r1,
                        ln1,
                        q,
                        k,
                        v,
                        probs,
                        concat,
                        res1,
                        m2,
                        r2,
                        ln2,
                        hpre,
                        hact,
                    });
                }
            }
        }
        Ok((cur, tapes))
    }

    /// Forward-only variant for paths that never backprop (`client_fwd`,
    /// `eval`): no tape, no input clones, no retained activations.
    /// `par > 1` lets big dense layers split their GEMM into output-column
    /// panels across that many threads — bitwise-neutral (see module doc),
    /// only engaged on eval-sized batches where the panels amortize the
    /// spawn cost.
    fn forward_no_tape(
        &self,
        s: &mut Scratch,
        params: &[Vec<f32>],
        x: &[f32],
        batch: usize,
        first: usize,
        last: usize,
        par: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let want = self.param_base[last] - self.param_base[first - 1];
        anyhow::ensure!(
            params.len() == want,
            "layers {first}..={last} need {want} params, got {}",
            params.len()
        );
        let mut cur = x.to_vec();
        let mut off = 0usize;
        for blk in first..=last {
            let layer = self.layers[blk - 1];
            let p = &params[off..off + layer.num_params()];
            off += layer.num_params();
            match layer {
                LayerSpec::Conv { h, w, ic, k, oc, pool } => {
                    let g = Geom { b: batch, h, w, c: ic };
                    anyhow::ensure!(cur.len() == g.len(), "layer {blk}: input length mismatch");
                    anyhow::ensure!(p[0].len() == k * k * ic * oc, "layer {blk}: weight length");
                    let act = ops::conv2d_fwd(s, &cur, g, &p[0], k, oc, &p[1], true);
                    if pool {
                        let ag = Geom { b: batch, h, w, c: oc };
                        (cur, _) = ops::maxpool2x2_fwd(&act, ag);
                    } else {
                        cur = act;
                    }
                }
                LayerSpec::Dense { din, dout, relu } => {
                    anyhow::ensure!(
                        cur.len() == batch * din,
                        "layer {blk}: input length {} != {batch}x{din}",
                        cur.len()
                    );
                    anyhow::ensure!(p[0].len() == din * dout, "layer {blk}: weight length");
                    let pp = if par > 1 && batch >= 32 && dout >= 2 * gemm::NR { par } else { 1 };
                    cur = ops::dense_fwd_par(s, &cur, batch, din, dout, &p[0], &p[1], relu, pp);
                }
                LayerSpec::Embed { h, w, c, patch, dm } => {
                    let g = Geom { b: batch, h, w, c };
                    anyhow::ensure!(cur.len() == g.len(), "layer {blk}: input length mismatch");
                    let (t, din) = ((h / patch) * (w / patch), patch * patch * c);
                    anyhow::ensure!(p[0].len() == din * dm, "layer {blk}: weight length");
                    let patches = ops::patchify(&cur, g, patch);
                    cur = ops::dense_fwd(s, &patches, batch * t, din, dm, &p[0], &p[1], false);
                }
                LayerSpec::TxfBlock { tokens: t, dm, heads, dff } => {
                    let rows = batch * t;
                    anyhow::ensure!(
                        cur.len() == rows * dm,
                        "layer {blk}: input length {} != {rows}x{dm}",
                        cur.len()
                    );
                    let (ln1, _m1, _r1) = ops::layernorm_fwd(&cur, rows, dm, &p[0], &p[1]);
                    let q = ops::dense_fwd(s, &ln1, rows, dm, dm, &p[2], &p[3], false);
                    let k = ops::dense_fwd(s, &ln1, rows, dm, dm, &p[4], &p[5], false);
                    let v = ops::dense_fwd(s, &ln1, rows, dm, dm, &p[6], &p[7], false);
                    let (_probs, concat) = ops::mhsa_fwd(s, &q, &k, &v, batch, t, dm, heads);
                    let attn = ops::dense_fwd(s, &concat, rows, dm, dm, &p[8], &p[9], false);
                    let mut res1 = cur;
                    for (r, &a) in res1.iter_mut().zip(&attn) {
                        *r += a;
                    }
                    let (ln2, _m2, _r2) = ops::layernorm_fwd(&res1, rows, dm, &p[10], &p[11]);
                    let hpre = ops::dense_fwd(s, &ln2, rows, dm, dff, &p[12], &p[13], false);
                    let hact = ops::gelu_fwd(&hpre);
                    let mlp = ops::dense_fwd(s, &hact, rows, dff, dm, &p[14], &p[15], false);
                    cur = res1;
                    for (o, &mv) in cur.iter_mut().zip(&mlp) {
                        *o += mv;
                    }
                }
            }
        }
        Ok(cur)
    }

    /// Backpropagate `d_last` through the taped layers; returns the
    /// parameter gradients (manifest order, aligned with the `params`
    /// slice) and the input cotangent.
    fn backward(
        &self,
        s: &mut Scratch,
        params: &[Vec<f32>],
        tapes: &[Tape],
        d_last: Vec<f32>,
        batch: usize,
    ) -> (Params, Vec<f32>) {
        let mut offs = Vec::with_capacity(tapes.len());
        let mut off = 0usize;
        for tp in tapes {
            offs.push(off);
            off += tape_params(tp);
        }
        debug_assert_eq!(off, params.len());
        let mut grads: Params = vec![Vec::new(); params.len()];
        let mut d = d_last;
        for (tape, &po) in tapes.iter().zip(&offs).rev() {
            match tape {
                Tape::Conv { input, g, k, oc, act, idx, pool } => {
                    let mut d_act =
                        if *pool { ops::maxpool2x2_bwd(idx, &d, act.len()) } else { d };
                    ops::relu_mask(&mut d_act, act);
                    let (d_x, d_w, d_b) = ops::conv2d_bwd(s, input, *g, &params[po], *k, *oc, &d_act);
                    grads[po] = d_w;
                    grads[po + 1] = d_b;
                    d = d_x;
                }
                Tape::Dense { input, din, dout, out, relu } => {
                    if *relu {
                        ops::relu_mask(&mut d, out);
                    }
                    let (d_x, d_w, d_b) =
                        ops::dense_bwd(s, input, batch, *din, *dout, &params[po], &d);
                    grads[po] = d_w;
                    grads[po + 1] = d_b;
                    d = d_x;
                }
                Tape::Embed { patches, g, patch, t, din, dm } => {
                    let (d_p, d_w, d_b) =
                        ops::dense_bwd(s, patches, batch * t, *din, *dm, &params[po], &d);
                    grads[po] = d_w;
                    grads[po + 1] = d_b;
                    d = ops::unpatchify(&d_p, *g, *patch);
                }
                Tape::Txf {
                    t,
                    dm,
                    heads,
                    dff,
                    input,
                    m1,
                    r1,
                    ln1,
                    q,
                    k,
                    v,
                    probs,
                    concat,
                    res1,
                    m2,
                    r2,
                    ln2,
                    hpre,
                    hact,
                } => {
                    let (t, dm, heads, dff) = (*t, *dm, *heads, *dff);
                    let rows = batch * t;
                    let p = &params[po..po + 16];
                    // out = res1 + mlp: d flows into both branches.
                    let (mut d_hact, d_w2, d_b2) =
                        ops::dense_bwd(s, hact, rows, dff, dm, &p[14], &d);
                    ops::gelu_bwd(&mut d_hact, hpre); // now d(hpre)
                    let (d_ln2, d_w1, d_b1) =
                        ops::dense_bwd(s, ln2, rows, dm, dff, &p[12], &d_hact);
                    let (d_r1b, d_g2, d_be2) =
                        ops::layernorm_bwd(res1, m2, r2, &p[10], rows, dm, &d_ln2);
                    let mut d_res1 = d;
                    for (dr, &v2) in d_res1.iter_mut().zip(&d_r1b) {
                        *dr += v2;
                    }
                    // res1 = input + attn: d_res1 flows into both branches.
                    let (d_concat, d_wo, d_bo) =
                        ops::dense_bwd(s, concat, rows, dm, dm, &p[8], &d_res1);
                    let (dq, dk, dv) =
                        ops::mhsa_bwd(s, q, k, v, probs, &d_concat, batch, t, dm, heads);
                    let (mut d_ln1, d_wq, d_bq) =
                        ops::dense_bwd(s, ln1, rows, dm, dm, &p[2], &dq);
                    let (d_ln1_k, d_wk, d_bk) = ops::dense_bwd(s, ln1, rows, dm, dm, &p[4], &dk);
                    let (d_ln1_v, d_wv, d_bv) = ops::dense_bwd(s, ln1, rows, dm, dm, &p[6], &dv);
                    // Fixed accumulation order: q, then k, then v.
                    for (a, (&bk2, &cv)) in d_ln1.iter_mut().zip(d_ln1_k.iter().zip(&d_ln1_v)) {
                        *a = (*a + bk2) + cv;
                    }
                    let (d_x_ln, d_g1, d_be1) =
                        ops::layernorm_bwd(input, m1, r1, &p[0], rows, dm, &d_ln1);
                    let mut d_x = d_res1;
                    for (a, &bv2) in d_x.iter_mut().zip(&d_x_ln) {
                        *a += bv2;
                    }
                    for (slot, g) in grads[po..po + 16].iter_mut().zip([
                        d_g1, d_be1, d_wq, d_bq, d_wk, d_bk, d_wv, d_bv, d_wo, d_bo, d_g2, d_be2,
                        d_w1, d_b1, d_w2, d_b2,
                    ]) {
                        *slot = g;
                    }
                    d = d_x;
                }
            }
        }
        (grads, d)
    }

    fn check_labels(&self, y1h: &Tensor, batch: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            y1h.shape == [batch, self.spec.classes],
            "labels shape {:?} != [{batch}, {}]",
            y1h.shape,
            self.spec.classes
        );
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn spec(&self) -> &ShapeSpec {
        &self.spec
    }

    fn client_fwd(&self, cut: usize, wc: &[Vec<f32>], x: &Tensor) -> anyhow::Result<Tensor> {
        self.client_fwd_with(&self.fallback, cut, wc, x)
    }

    fn client_fwd_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
    ) -> anyhow::Result<Tensor> {
        let (nc, blocks) = self.check_cut(cut)?;
        anyhow::ensure!(wc.len() == nc, "client_fwd: {} params, expected {nc}", wc.len());
        let batch = self.batch_of_input(x)?;
        let mut s = scratch.lock();
        // Training-path role: never uses the eval parallelism hint.
        let out = self.forward_no_tape(&mut s, wc, &x.data, batch, 1, blocks, 1)?;
        Ok(Tensor::new(out, self.smashed_shape(cut, batch)))
    }

    fn server_grad(
        &self,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.server_grad_with(&self.fallback, cut, ws, smashed, y1h)
    }

    fn server_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        let (nc, blocks) = self.check_cut(cut)?;
        let n_server = self.spec.params.len() - nc;
        anyhow::ensure!(
            ws.len() == n_server,
            "server_grad: {} params, expected {n_server}",
            ws.len()
        );
        anyhow::ensure!(
            smashed.shape.len() > 1
                && smashed.shape[1..] == self.spec.cut(cut).smashed_shape[1..],
            "smashed shape {:?} does not match cut {cut}",
            smashed.shape
        );
        let batch = smashed.shape[0];
        self.check_labels(y1h, batch)?;
        let mut s = scratch.lock();
        let (logits, tapes) =
            self.forward(&mut s, ws, &smashed.data, batch, blocks + 1, self.layers.len())?;
        let (loss, d_logits) = ops::softmax_ce(&logits, &y1h.data, batch, self.spec.classes);
        let (g_ws, d_smashed) = self.backward(&mut s, ws, &tapes, d_logits, batch);
        Ok((loss, g_ws, Tensor::new(d_smashed, smashed.shape.clone())))
    }

    fn client_grad(
        &self,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.client_grad_with(&self.fallback, cut, wc, x, g_smashed)
    }

    fn client_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        let (nc, blocks) = self.check_cut(cut)?;
        anyhow::ensure!(wc.len() == nc, "client_grad: {} params, expected {nc}", wc.len());
        let batch = self.batch_of_input(x)?;
        anyhow::ensure!(
            g_smashed.shape == self.smashed_shape(cut, batch),
            "cotangent shape {:?} does not match cut {cut} batch {batch}",
            g_smashed.shape
        );
        let mut s = scratch.lock();
        let (_out, tapes) = self.forward(&mut s, wc, &x.data, batch, 1, blocks)?;
        let (g_wc, _d_x) = self.backward(&mut s, wc, &tapes, g_smashed.data.clone(), batch);
        Ok(g_wc)
    }

    fn full_grad(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, Params)> {
        self.full_grad_with(&self.fallback, w, x, y1h)
    }

    fn full_grad_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params)> {
        let n = self.spec.params.len();
        anyhow::ensure!(w.len() == n, "full_grad: {} params, expected {n}", w.len());
        let batch = self.batch_of_input(x)?;
        self.check_labels(y1h, batch)?;
        let mut s = scratch.lock();
        let (logits, tapes) = self.forward(&mut s, w, &x.data, batch, 1, self.layers.len())?;
        let (loss, d_logits) = ops::softmax_ce(&logits, &y1h.data, batch, self.spec.classes);
        let (g_w, _d_x) = self.backward(&mut s, w, &tapes, d_logits, batch);
        Ok((loss, g_w))
    }

    fn eval(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, f32)> {
        self.eval_with(&self.fallback, w, x, y1h)
    }

    fn eval_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, f32)> {
        let n = self.spec.params.len();
        anyhow::ensure!(w.len() == n, "eval: {} params, expected {n}", w.len());
        let batch = self.batch_of_input(x)?;
        self.check_labels(y1h, batch)?;
        let mut s = scratch.lock();
        let par = self.eval_par.load(Ordering::Relaxed);
        let logits = self.forward_no_tape(&mut s, w, &x.data, batch, 1, self.layers.len(), par)?;
        let loss = ops::ce_loss(&logits, &y1h.data, batch, self.spec.classes);
        let correct = ops::correct_count(&logits, &y1h.data, batch, self.spec.classes);
        Ok((loss, correct))
    }

    fn set_eval_parallelism(&self, workers: usize) {
        // Relaxed is enough: the trainer sets this once before rounds
        // start, and any value yields bitwise-identical results.
        self.eval_par.store(workers.max(1), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::ops::tests::gen_vec;
    use super::*;
    use crate::model::Manifest;
    use crate::tensor;

    fn backend() -> NativeBackend {
        let spec = Manifest::builtin().for_dataset("mnist").unwrap().clone();
        NativeBackend::new(spec).unwrap()
    }

    /// Parameters/inputs from the shared deterministic generator — the
    /// same buffers the JAX golden script builds (array k at offset k·1e6,
    /// x at 2e7, labels (3i+1) mod 10).
    fn golden_setup(be: &NativeBackend) -> (Params, Tensor, Tensor) {
        let spec = be.spec();
        let params: Params = spec
            .params
            .iter()
            .enumerate()
            .map(|(k, p)| gen_vec(k as u64 * 1_000_000, p.size()))
            .collect();
        let batch = 2usize;
        let mut xshape = vec![batch];
        xshape.extend_from_slice(&spec.input_shape);
        let x = Tensor::new(gen_vec(20_000_000, batch * spec.input_per_sample()), xshape);
        let mut y = vec![0.0f32; batch * spec.classes];
        for i in 0..batch {
            y[i * spec.classes + (3 * i + 1) % spec.classes] = 1.0;
        }
        let y1h = Tensor::new(y, vec![batch, spec.classes]);
        (params, x, y1h)
    }

    const GOLD_LOSS: f64 = 3.7887232303619385;
    const GOLD_GRAD_ABSSUM: [f64; 10] = [
        8298.501360177994,
        1473.2559788227081,
        66977.71572766759,
        219.59729354083538,
        313059.0024780063,
        90.47802595794201,
        7924.51078856885,
        16.297020066529512,
        470.6403131179182,
        0.553443807616466,
    ];
    const GOLD_SMASHED_SUM: [f64; 4] =
        [4392.887069702148, 6867.429403662682, 752.670960560441, 592.0061593055725];

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    /// Pin a backend's fallback arena to the portable GEMM tier: goldens
    /// were captured against JAX's non-FMA rounding, and the SIMD tier's
    /// fused multiply-adds round differently (see `gemm`).
    fn pin_portable(be: &NativeBackend) {
        be.fallback.lock().tier = gemm::Tier::Portable;
    }

    #[test]
    fn full_grad_matches_jax_goldens() {
        let be = backend();
        pin_portable(&be);
        let (params, x, y1h) = golden_setup(&be);
        let (loss, g) = be.full_grad(&params, &x, &y1h).unwrap();
        assert!(rel_close(loss as f64, GOLD_LOSS, 1e-3), "loss {loss} vs {GOLD_LOSS}");
        assert_eq!(g.len(), GOLD_GRAD_ABSSUM.len());
        for (k, (buf, &want)) in g.iter().zip(&GOLD_GRAD_ABSSUM).enumerate() {
            let got: f64 = buf.iter().map(|&v| v.abs() as f64).sum();
            assert!(rel_close(got, want, 1e-2), "grad[{k}] |sum| {got} vs {want}");
        }
    }

    #[test]
    fn client_fwd_matches_jax_goldens_at_every_cut() {
        let be = backend();
        pin_portable(&be);
        let (params, x, _y1h) = golden_setup(&be);
        assert_eq!(be.spec().num_cuts(), GOLD_SMASHED_SUM.len());
        for cut in be.spec().menu().ids() {
            let nc = be.spec().cut(cut).client_params;
            let s = be.client_fwd(cut, &params[..nc], &x).unwrap();
            assert_eq!(s.shape, be.smashed_shape(cut, 2));
            let sum: f64 = s.data.iter().map(|&v| v as f64).sum();
            let want = GOLD_SMASHED_SUM[cut - 1];
            assert!(rel_close(sum, want, 1e-3), "cut {cut}: smashed sum {sum} vs {want}");
        }
    }

    #[test]
    fn split_gradient_equals_full_gradient_exactly() {
        let be = backend();
        let (params, x, y1h) = golden_setup(&be);
        let (loss_full, g_full) = be.full_grad(&params, &x, &y1h).unwrap();
        for cut in be.spec().menu().ids() {
            let nc = be.spec().cut(cut).client_params;
            let smashed = be.client_fwd(cut, &params[..nc], &x).unwrap();
            let (loss_split, g_ws, g_s) =
                be.server_grad(cut, &params[nc..], &smashed, &y1h).unwrap();
            let mut g_split = be.client_grad(cut, &params[..nc], &x, &g_s).unwrap();
            g_split.extend(g_ws);
            // Both paths run the identical kernels on identical buffers,
            // so the equality is exact, not approximate.
            assert_eq!(loss_full, loss_split, "cut {cut} loss");
            let diff = tensor::max_abs_diff(&g_split, &g_full);
            assert!(diff == 0.0, "cut {cut}: split grad differs by {diff}");
        }
    }

    /// The scratch-aware role variants are the hot path; they must agree
    /// bitwise with the fallback-arena plain methods, through ANY handle.
    #[test]
    fn scratch_variants_agree_bitwise_with_plain_roles() {
        let be = backend();
        let (params, x, y1h) = golden_setup(&be);
        let fresh = ScratchHandle::new();
        let (loss_a, g_a) = be.full_grad(&params, &x, &y1h).unwrap();
        let (loss_b, g_b) = be.full_grad_with(&fresh, &params, &x, &y1h).unwrap();
        assert_eq!(loss_a, loss_b);
        assert_eq!(tensor::max_abs_diff(&g_a, &g_b), 0.0);
        // Reusing the now-dirty arena changes nothing.
        let (loss_c, g_c) = be.full_grad_with(&fresh, &params, &x, &y1h).unwrap();
        assert_eq!(loss_a, loss_c);
        assert_eq!(tensor::max_abs_diff(&g_a, &g_c), 0.0);
        let nc = be.spec().cut(2).client_params;
        let s_a = be.client_fwd(2, &params[..nc], &x).unwrap();
        let s_b = be.client_fwd_with(&fresh, 2, &params[..nc], &x).unwrap();
        assert_eq!(s_a, s_b);
        let (ls_a, _gw, gs_a) = be.server_grad(2, &params[nc..], &s_a, &y1h).unwrap();
        let (ls_b, _gw, gs_b) = be.server_grad_with(&fresh, 2, &params[nc..], &s_a, &y1h).unwrap();
        assert_eq!(ls_a, ls_b);
        assert_eq!(gs_a, gs_b);
        let gc_a = be.client_grad(2, &params[..nc], &x, &gs_a).unwrap();
        let gc_b = be.client_grad_with(&fresh, 2, &params[..nc], &x, &gs_a).unwrap();
        assert_eq!(tensor::max_abs_diff(&gc_a, &gc_b), 0.0);
        let ev_a = be.eval(&params, &x, &y1h).unwrap();
        let ev_b = be.eval_with(&fresh, &params, &x, &y1h).unwrap();
        assert_eq!(ev_a, ev_b);
    }

    /// Panel-parallel eval is an optimization channel: whatever worker
    /// count the trainer grants, eval results stay bitwise identical, and
    /// the hint never leaks into training-path roles.
    #[test]
    fn eval_parallelism_is_bitwise_neutral() {
        let be = backend();
        let spec = be.spec().clone();
        let params: Params = spec
            .params
            .iter()
            .enumerate()
            .map(|(k, p)| gen_vec(k as u64 * 1_000_000, p.size()))
            .collect();
        // Batch 32 clears forward_no_tape's engagement threshold, so the
        // fc layers really do take the gemm_parallel path.
        let batch = 32usize;
        let mut xshape = vec![batch];
        xshape.extend_from_slice(&spec.input_shape);
        let x = Tensor::new(gen_vec(40_000_000, batch * spec.input_per_sample()), xshape);
        let mut y = vec![0.0f32; batch * spec.classes];
        for i in 0..batch {
            y[i * spec.classes + (5 * i + 3) % spec.classes] = 1.0;
        }
        let y1h = Tensor::new(y, vec![batch, spec.classes]);
        let serial = be.eval(&params, &x, &y1h).unwrap();
        for workers in [2usize, 3, 5] {
            be.set_eval_parallelism(workers);
            assert_eq!(be.eval(&params, &x, &y1h).unwrap(), serial, "workers {workers}");
        }
        let smashed = be.client_fwd(2, &params[..4], &x).unwrap();
        be.set_eval_parallelism(1);
        assert_eq!(be.client_fwd(2, &params[..4], &x).unwrap(), smashed);
    }

    #[test]
    fn eval_returns_loss_and_correct_count() {
        let be = backend();
        let (params, x, y1h) = golden_setup(&be);
        let (loss, correct) = be.eval(&params, &x, &y1h).unwrap();
        let (loss_full, _g) = be.full_grad(&params, &x, &y1h).unwrap();
        assert_eq!(loss, loss_full);
        // JAX golden: neither random-param prediction is correct.
        assert_eq!(correct, 0.0);
    }

    #[test]
    fn shape_errors_are_reported_not_panicked() {
        let be = backend();
        let (params, x, y1h) = golden_setup(&be);
        assert!(be.client_fwd(0, &params[..2], &x).is_err());
        assert!(be.client_fwd(5, &params[..2], &x).is_err());
        assert!(be.client_fwd(1, &params[..4], &x).is_err());
        let bad_x = Tensor::zeros(&[2, 27, 28, 1]);
        assert!(be.client_fwd(1, &params[..2], &bad_x).is_err());
        let bad_y = Tensor::zeros(&[3, 10]);
        assert!(be.full_grad(&params, &x, &bad_y).is_err());
    }

    #[test]
    fn graphless_spec_is_rejected_with_a_clear_error() {
        let mut spec = Manifest::builtin().for_dataset("mnist").unwrap().clone();
        spec.layers.clear();
        let err = NativeBackend::new(spec).unwrap_err().to_string();
        assert!(err.contains("layer graph"), "{err}");
    }

    #[test]
    fn batch_size_is_taken_from_the_input() {
        // The same backend serves train- and eval-sized batches.
        let be = backend();
        let (params, _x, _y) = golden_setup(&be);
        for batch in [1usize, 3, 5] {
            let x = Tensor::zeros(&[batch, 28, 28, 1]);
            let s = be.client_fwd(2, &params[..4], &x).unwrap();
            assert_eq!(s.shape[0], batch);
        }
    }

    #[test]
    fn cifar_shape_builds_and_splits_exactly() {
        let spec = Manifest::builtin().for_dataset("cifar10").unwrap().clone();
        let be = NativeBackend::new(spec).unwrap();
        let spec = be.spec().clone();
        let params: Params = spec
            .params
            .iter()
            .enumerate()
            .map(|(k, p)| gen_vec(k as u64 * 1_000_000, p.size()))
            .collect();
        let batch = 2usize;
        let x = Tensor::new(
            gen_vec(30_000_000, batch * spec.input_per_sample()),
            vec![batch, 32, 32, 3],
        );
        let mut y = vec![0.0f32; batch * spec.classes];
        for i in 0..batch {
            y[i * spec.classes + (7 * i + 2) % spec.classes] = 1.0;
        }
        let y1h = Tensor::new(y, vec![batch, spec.classes]);
        let (loss_full, g_full) = be.full_grad(&params, &x, &y1h).unwrap();
        assert!(loss_full.is_finite());
        for cut in spec.menu().ids() {
            let nc = spec.cut(cut).client_params;
            let smashed = be.client_fwd(cut, &params[..nc], &x).unwrap();
            let (_l, g_ws, g_s) = be.server_grad(cut, &params[nc..], &smashed, &y1h).unwrap();
            let mut g_split = be.client_grad(cut, &params[..nc], &x, &g_s).unwrap();
            g_split.extend(g_ws);
            assert!(tensor::max_abs_diff(&g_split, &g_full) == 0.0, "cut {cut}");
        }
    }
}
