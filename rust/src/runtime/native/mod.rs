//! The native pure-Rust backend: executes the paper's split CNN directly
//! on flat `Vec<f32>` buffers — no Python, JAX, XLA or PJRT anywhere.
//!
//! The block structure is derived from the manifest's parameter shapes
//! (4-d weight -> conv5x5+relu+maxpool2, 2-d weight -> dense, last block
//! linear), which makes this backend work for every shape key the
//! manifest describes rather than hard-coding the MNIST/CIFAR geometry.
//! Forward passes record a per-block tape (inputs, post-relu activations,
//! pool argmaxes); backward consumes the tape to produce exactly the VJPs
//! the five roles need.
//!
//! Compute runs on the im2col + blocked-GEMM fast path ([`gemm`],
//! [`im2col`], [`ops`]) with per-call intermediates drawn from a
//! [`Scratch`] arena: the `*_with` role variants take the caller's
//! per-worker [`ScratchHandle`] (the hot path — `ParallelExecutor` owns
//! one arena per worker), while the plain [`Backend`] methods fall back
//! to an internal arena so direct callers (tests, benches) need no
//! setup.  The GEMM microkernel is tiered (AVX2+FMA when the host has
//! it, portable otherwise — see [`gemm`]); each arena carries its tier so
//! a whole forward/backward chain is tier-consistent.  The original
//! scalar kernels are retained in [`reference`] and cross-checked
//! against the fast path by property tests.
//!
//! Eval-only extra parallelism: [`Backend::set_eval_parallelism`] lets
//! the trainer grant spare pool capacity to the forward-only eval path.
//! Large dense layers then split their GEMM by output-column panel
//! ([`gemm::gemm_parallel`]) — a bitwise-neutral partition, since no
//! element's k-summation order changes.  Training roles never see the
//! hint.
//!
//! Numerical semantics are pinned to the JAX reference kernels
//! (`python/compile/kernels/ref.py`) by the golden tests in [`ops`] and
//! the full-model goldens below; split-vs-full gradient equality is exact
//! (bitwise) because both paths share the same kernels.

pub mod gemm;
pub mod im2col;
pub mod ops;
pub mod reference;

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::model::{NUM_CUTS, ShapeSpec};
use crate::tensor::Params;

use ops::Geom;
use super::backend::Backend;
use super::scratch::{Scratch, ScratchHandle};
use super::tensor::Tensor;

/// Static description of one block, derived from the manifest shapes.
#[derive(Clone, Copy, Debug)]
enum BlockDesc {
    /// conv `k`x`k` SAME + relu + maxpool2x2 on an `h`x`w`x`ic` input.
    Conv { h: usize, w: usize, ic: usize, k: usize, oc: usize },
    /// dense `din` -> `dout`, relu unless it is the logits layer.
    Dense { din: usize, dout: usize, relu: bool },
}

/// Per-block forward records needed by the backward pass.
enum Tape {
    Conv { input: Vec<f32>, g: Geom, k: usize, oc: usize, act: Vec<f32>, idx: Vec<u32> },
    Dense { input: Vec<f32>, din: usize, dout: usize, out: Vec<f32>, relu: bool },
}

/// Pure-Rust execution of the split model (all cuts, all five roles).
pub struct NativeBackend {
    spec: ShapeSpec,
    blocks: Vec<BlockDesc>,
    /// Arena for callers of the plain (scratch-less) role methods.  The
    /// hot path never touches it — the executor hands every worker its
    /// own arena through the `*_with` variants.
    fallback: ScratchHandle,
    /// Extra threads one eval call may use for panel-parallel dense GEMM
    /// (set by [`Backend::set_eval_parallelism`]; 1 = serial).
    eval_par: AtomicUsize,
}

impl NativeBackend {
    /// Derive the block table from `spec` and validate its consistency.
    pub fn new(spec: ShapeSpec) -> anyhow::Result<NativeBackend> {
        anyhow::ensure!(
            spec.input_shape.len() == 3,
            "native backend expects [h, w, c] inputs, got {:?}",
            spec.input_shape
        );
        anyhow::ensure!(
            !spec.params.is_empty() && spec.params.len() % 2 == 0,
            "native backend expects (weight, bias) parameter pairs"
        );
        let n_blocks = spec.params.len() / 2;
        let (mut h, mut w, mut c) =
            (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
        let mut blocks = Vec::with_capacity(n_blocks);
        for bi in 0..n_blocks {
            let wshape = &spec.params[2 * bi].shape;
            let bshape = &spec.params[2 * bi + 1].shape;
            let wname = &spec.params[2 * bi].name;
            anyhow::ensure!(bshape.len() == 1, "{wname}: bias must be rank 1");
            match wshape.len() {
                4 => {
                    let k = wshape[0];
                    let oc = wshape[3];
                    anyhow::ensure!(wshape[1] == k && k % 2 == 1, "{wname}: bad kernel");
                    anyhow::ensure!(wshape[2] == c, "{wname}: in-channels {} != {c}", wshape[2]);
                    anyhow::ensure!(bshape[0] == oc, "{wname}: bias/filters mismatch");
                    anyhow::ensure!(h % 2 == 0 && w % 2 == 0, "{wname}: pool needs even h/w");
                    blocks.push(BlockDesc::Conv { h, w, ic: c, k, oc });
                    h /= 2;
                    w /= 2;
                    c = oc;
                }
                2 => {
                    let (din, dout) = (wshape[0], wshape[1]);
                    anyhow::ensure!(
                        din == h * w * c,
                        "{wname}: dense fan-in {din} != upstream {}",
                        h * w * c
                    );
                    anyhow::ensure!(bshape[0] == dout, "{wname}: bias/out mismatch");
                    blocks.push(BlockDesc::Dense { din, dout, relu: bi + 1 < n_blocks });
                    h = 1;
                    w = 1;
                    c = dout;
                }
                r => anyhow::bail!("{wname}: unsupported weight rank {r}"),
            }
        }
        anyhow::ensure!(
            matches!(blocks.last(), Some(BlockDesc::Dense { dout, .. }) if *dout == spec.classes),
            "last block must produce {} logits",
            spec.classes
        );
        Ok(NativeBackend {
            spec,
            blocks,
            fallback: ScratchHandle::new(),
            eval_par: AtomicUsize::new(1),
        })
    }

    fn check_cut(&self, cut: usize) -> anyhow::Result<usize> {
        anyhow::ensure!((1..=NUM_CUTS).contains(&cut), "cut {cut} out of range");
        let nc = self.spec.cut(cut).client_params;
        anyhow::ensure!(
            nc % 2 == 0 && nc / 2 < self.blocks.len(),
            "cut {cut}: client_params {nc} does not align to a block boundary"
        );
        Ok(nc)
    }

    /// Validate `[batch, input_shape...]` and return the batch size.
    fn batch_of_input(&self, x: &Tensor) -> anyhow::Result<usize> {
        anyhow::ensure!(
            x.shape.len() == 4 && x.shape[1..] == self.spec.input_shape[..],
            "input shape {:?} does not match [b, {:?}]",
            x.shape,
            self.spec.input_shape
        );
        Ok(x.shape[0])
    }

    /// The smashed-data shape at `cut` for an arbitrary batch size.
    fn smashed_shape(&self, cut: usize, batch: usize) -> Vec<usize> {
        let mut s = self.spec.cut(cut).smashed_shape.clone();
        s[0] = batch;
        s
    }

    /// Run blocks `first..=last` (1-based), recording the backward tape.
    /// Kernel intermediates come from `s`; tape buffers are owned.
    fn forward(
        &self,
        s: &mut Scratch,
        params: &[Vec<f32>],
        x: &[f32],
        batch: usize,
        first: usize,
        last: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<Tape>)> {
        anyhow::ensure!(
            params.len() == 2 * (last + 1 - first),
            "blocks {first}..={last} need {} params, got {}",
            2 * (last + 1 - first),
            params.len()
        );
        let mut cur = x.to_vec();
        let mut tapes = Vec::with_capacity(last + 1 - first);
        for (bi, blk) in (first..=last).enumerate() {
            let wt = &params[2 * bi];
            let bias = &params[2 * bi + 1];
            match self.blocks[blk - 1] {
                BlockDesc::Conv { h, w, ic, k, oc } => {
                    let g = Geom { b: batch, h, w, c: ic };
                    anyhow::ensure!(cur.len() == g.len(), "block {blk}: input length mismatch");
                    anyhow::ensure!(wt.len() == k * k * ic * oc, "block {blk}: weight length");
                    let act = ops::conv2d_fwd(s, &cur, g, wt, k, oc, bias, true);
                    let ag = Geom { b: batch, h, w, c: oc };
                    let (out, idx) = ops::maxpool2x2_fwd(&act, ag);
                    let input = std::mem::replace(&mut cur, out);
                    tapes.push(Tape::Conv { input, g, k, oc, act, idx });
                }
                BlockDesc::Dense { din, dout, relu } => {
                    anyhow::ensure!(
                        cur.len() == batch * din,
                        "block {blk}: input length {} != {batch}x{din}",
                        cur.len()
                    );
                    anyhow::ensure!(wt.len() == din * dout, "block {blk}: weight length");
                    let out = ops::dense_fwd(s, &cur, batch, din, dout, wt, bias, relu);
                    let input = std::mem::take(&mut cur);
                    cur = out.clone();
                    tapes.push(Tape::Dense { input, din, dout, out, relu });
                }
            }
        }
        Ok((cur, tapes))
    }

    /// Forward-only variant for paths that never backprop (`client_fwd`,
    /// `eval`): no tape, no input clones, no retained activations.
    /// `par > 1` lets big dense layers split their GEMM into output-column
    /// panels across that many threads — bitwise-neutral (see module doc),
    /// only engaged on eval-sized batches where the panels amortize the
    /// spawn cost.
    fn forward_no_tape(
        &self,
        s: &mut Scratch,
        params: &[Vec<f32>],
        x: &[f32],
        batch: usize,
        first: usize,
        last: usize,
        par: usize,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            params.len() == 2 * (last + 1 - first),
            "blocks {first}..={last} need {} params, got {}",
            2 * (last + 1 - first),
            params.len()
        );
        let mut cur = x.to_vec();
        for (bi, blk) in (first..=last).enumerate() {
            let wt = &params[2 * bi];
            let bias = &params[2 * bi + 1];
            match self.blocks[blk - 1] {
                BlockDesc::Conv { h, w, ic, k, oc } => {
                    let g = Geom { b: batch, h, w, c: ic };
                    anyhow::ensure!(cur.len() == g.len(), "block {blk}: input length mismatch");
                    anyhow::ensure!(wt.len() == k * k * ic * oc, "block {blk}: weight length");
                    let act = ops::conv2d_fwd(s, &cur, g, wt, k, oc, bias, true);
                    let ag = Geom { b: batch, h, w, c: oc };
                    (cur, _) = ops::maxpool2x2_fwd(&act, ag);
                }
                BlockDesc::Dense { din, dout, relu } => {
                    anyhow::ensure!(
                        cur.len() == batch * din,
                        "block {blk}: input length {} != {batch}x{din}",
                        cur.len()
                    );
                    anyhow::ensure!(wt.len() == din * dout, "block {blk}: weight length");
                    let p = if par > 1 && batch >= 32 && dout >= 2 * gemm::NR { par } else { 1 };
                    cur = ops::dense_fwd_par(s, &cur, batch, din, dout, wt, bias, relu, p);
                }
            }
        }
        Ok(cur)
    }

    /// Backpropagate `d_last` through the taped blocks; returns the
    /// parameter gradients (manifest order) and the input cotangent.
    fn backward(
        &self,
        s: &mut Scratch,
        params: &[Vec<f32>],
        tapes: &[Tape],
        d_last: Vec<f32>,
        batch: usize,
    ) -> (Params, Vec<f32>) {
        let mut grads: Params = vec![Vec::new(); params.len()];
        let mut d = d_last;
        for (bi, tape) in tapes.iter().enumerate().rev() {
            let wt = &params[2 * bi];
            match tape {
                Tape::Conv { input, g, k, oc, act, idx } => {
                    let mut d_act = ops::maxpool2x2_bwd(idx, &d, act.len());
                    ops::relu_mask(&mut d_act, act);
                    let (d_x, d_w, d_b) = ops::conv2d_bwd(s, input, *g, wt, *k, *oc, &d_act);
                    grads[2 * bi] = d_w;
                    grads[2 * bi + 1] = d_b;
                    d = d_x;
                }
                Tape::Dense { input, din, dout, out, relu } => {
                    if *relu {
                        ops::relu_mask(&mut d, out);
                    }
                    let (d_x, d_w, d_b) = ops::dense_bwd(s, input, batch, *din, *dout, wt, &d);
                    grads[2 * bi] = d_w;
                    grads[2 * bi + 1] = d_b;
                    d = d_x;
                }
            }
        }
        (grads, d)
    }

    fn check_labels(&self, y1h: &Tensor, batch: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            y1h.shape == [batch, self.spec.classes],
            "labels shape {:?} != [{batch}, {}]",
            y1h.shape,
            self.spec.classes
        );
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn spec(&self) -> &ShapeSpec {
        &self.spec
    }

    fn client_fwd(&self, cut: usize, wc: &[Vec<f32>], x: &Tensor) -> anyhow::Result<Tensor> {
        self.client_fwd_with(&self.fallback, cut, wc, x)
    }

    fn client_fwd_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
    ) -> anyhow::Result<Tensor> {
        let nc = self.check_cut(cut)?;
        anyhow::ensure!(wc.len() == nc, "client_fwd: {} params, expected {nc}", wc.len());
        let batch = self.batch_of_input(x)?;
        let mut s = scratch.lock();
        // Training-path role: never uses the eval parallelism hint.
        let out = self.forward_no_tape(&mut s, wc, &x.data, batch, 1, nc / 2, 1)?;
        Ok(Tensor::new(out, self.smashed_shape(cut, batch)))
    }

    fn server_grad(
        &self,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.server_grad_with(&self.fallback, cut, ws, smashed, y1h)
    }

    fn server_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        let nc = self.check_cut(cut)?;
        let n_server = self.spec.params.len() - nc;
        anyhow::ensure!(
            ws.len() == n_server,
            "server_grad: {} params, expected {n_server}",
            ws.len()
        );
        anyhow::ensure!(
            smashed.shape.len() > 1
                && smashed.shape[1..] == self.spec.cut(cut).smashed_shape[1..],
            "smashed shape {:?} does not match cut {cut}",
            smashed.shape
        );
        let batch = smashed.shape[0];
        self.check_labels(y1h, batch)?;
        let first = nc / 2 + 1;
        let mut s = scratch.lock();
        let (logits, tapes) =
            self.forward(&mut s, ws, &smashed.data, batch, first, self.blocks.len())?;
        let (loss, d_logits) = ops::softmax_ce(&logits, &y1h.data, batch, self.spec.classes);
        let (g_ws, d_smashed) = self.backward(&mut s, ws, &tapes, d_logits, batch);
        Ok((loss, g_ws, Tensor::new(d_smashed, smashed.shape.clone())))
    }

    fn client_grad(
        &self,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.client_grad_with(&self.fallback, cut, wc, x, g_smashed)
    }

    fn client_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        let nc = self.check_cut(cut)?;
        anyhow::ensure!(wc.len() == nc, "client_grad: {} params, expected {nc}", wc.len());
        let batch = self.batch_of_input(x)?;
        anyhow::ensure!(
            g_smashed.shape == self.smashed_shape(cut, batch),
            "cotangent shape {:?} does not match cut {cut} batch {batch}",
            g_smashed.shape
        );
        let mut s = scratch.lock();
        let (_out, tapes) = self.forward(&mut s, wc, &x.data, batch, 1, nc / 2)?;
        let (g_wc, _d_x) = self.backward(&mut s, wc, &tapes, g_smashed.data.clone(), batch);
        Ok(g_wc)
    }

    fn full_grad(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, Params)> {
        self.full_grad_with(&self.fallback, w, x, y1h)
    }

    fn full_grad_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params)> {
        let n = self.spec.params.len();
        anyhow::ensure!(w.len() == n, "full_grad: {} params, expected {n}", w.len());
        let batch = self.batch_of_input(x)?;
        self.check_labels(y1h, batch)?;
        let mut s = scratch.lock();
        let (logits, tapes) = self.forward(&mut s, w, &x.data, batch, 1, self.blocks.len())?;
        let (loss, d_logits) = ops::softmax_ce(&logits, &y1h.data, batch, self.spec.classes);
        let (g_w, _d_x) = self.backward(&mut s, w, &tapes, d_logits, batch);
        Ok((loss, g_w))
    }

    fn eval(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, f32)> {
        self.eval_with(&self.fallback, w, x, y1h)
    }

    fn eval_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, f32)> {
        let n = self.spec.params.len();
        anyhow::ensure!(w.len() == n, "eval: {} params, expected {n}", w.len());
        let batch = self.batch_of_input(x)?;
        self.check_labels(y1h, batch)?;
        let mut s = scratch.lock();
        let par = self.eval_par.load(Ordering::Relaxed);
        let logits = self.forward_no_tape(&mut s, w, &x.data, batch, 1, self.blocks.len(), par)?;
        let loss = ops::ce_loss(&logits, &y1h.data, batch, self.spec.classes);
        let correct = ops::correct_count(&logits, &y1h.data, batch, self.spec.classes);
        Ok((loss, correct))
    }

    fn set_eval_parallelism(&self, workers: usize) {
        // Relaxed is enough: the trainer sets this once before rounds
        // start, and any value yields bitwise-identical results.
        self.eval_par.store(workers.max(1), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::ops::tests::gen_vec;
    use super::*;
    use crate::model::Manifest;
    use crate::tensor;

    fn backend() -> NativeBackend {
        let spec = Manifest::builtin().for_dataset("mnist").unwrap().clone();
        NativeBackend::new(spec).unwrap()
    }

    /// Parameters/inputs from the shared deterministic generator — the
    /// same buffers the JAX golden script builds (array k at offset k·1e6,
    /// x at 2e7, labels (3i+1) mod 10).
    fn golden_setup(be: &NativeBackend) -> (Params, Tensor, Tensor) {
        let spec = be.spec();
        let params: Params = spec
            .params
            .iter()
            .enumerate()
            .map(|(k, p)| gen_vec(k as u64 * 1_000_000, p.size()))
            .collect();
        let batch = 2usize;
        let mut xshape = vec![batch];
        xshape.extend_from_slice(&spec.input_shape);
        let x = Tensor::new(gen_vec(20_000_000, batch * spec.input_per_sample()), xshape);
        let mut y = vec![0.0f32; batch * spec.classes];
        for i in 0..batch {
            y[i * spec.classes + (3 * i + 1) % spec.classes] = 1.0;
        }
        let y1h = Tensor::new(y, vec![batch, spec.classes]);
        (params, x, y1h)
    }

    const GOLD_LOSS: f64 = 3.7887232303619385;
    const GOLD_GRAD_ABSSUM: [f64; 10] = [
        8298.501360177994,
        1473.2559788227081,
        66977.71572766759,
        219.59729354083538,
        313059.0024780063,
        90.47802595794201,
        7924.51078856885,
        16.297020066529512,
        470.6403131179182,
        0.553443807616466,
    ];
    const GOLD_SMASHED_SUM: [f64; 4] =
        [4392.887069702148, 6867.429403662682, 752.670960560441, 592.0061593055725];

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    /// Pin a backend's fallback arena to the portable GEMM tier: goldens
    /// were captured against JAX's non-FMA rounding, and the SIMD tier's
    /// fused multiply-adds round differently (see `gemm`).
    fn pin_portable(be: &NativeBackend) {
        be.fallback.lock().tier = gemm::Tier::Portable;
    }

    #[test]
    fn full_grad_matches_jax_goldens() {
        let be = backend();
        pin_portable(&be);
        let (params, x, y1h) = golden_setup(&be);
        let (loss, g) = be.full_grad(&params, &x, &y1h).unwrap();
        assert!(rel_close(loss as f64, GOLD_LOSS, 1e-3), "loss {loss} vs {GOLD_LOSS}");
        assert_eq!(g.len(), GOLD_GRAD_ABSSUM.len());
        for (k, (buf, &want)) in g.iter().zip(&GOLD_GRAD_ABSSUM).enumerate() {
            let got: f64 = buf.iter().map(|&v| v.abs() as f64).sum();
            assert!(rel_close(got, want, 1e-2), "grad[{k}] |sum| {got} vs {want}");
        }
    }

    #[test]
    fn client_fwd_matches_jax_goldens_at_every_cut() {
        let be = backend();
        pin_portable(&be);
        let (params, x, _y1h) = golden_setup(&be);
        for cut in 1..=NUM_CUTS {
            let nc = be.spec().cut(cut).client_params;
            let s = be.client_fwd(cut, &params[..nc], &x).unwrap();
            assert_eq!(s.shape, be.smashed_shape(cut, 2));
            let sum: f64 = s.data.iter().map(|&v| v as f64).sum();
            let want = GOLD_SMASHED_SUM[cut - 1];
            assert!(rel_close(sum, want, 1e-3), "cut {cut}: smashed sum {sum} vs {want}");
        }
    }

    #[test]
    fn split_gradient_equals_full_gradient_exactly() {
        let be = backend();
        let (params, x, y1h) = golden_setup(&be);
        let (loss_full, g_full) = be.full_grad(&params, &x, &y1h).unwrap();
        for cut in 1..=NUM_CUTS {
            let nc = be.spec().cut(cut).client_params;
            let smashed = be.client_fwd(cut, &params[..nc], &x).unwrap();
            let (loss_split, g_ws, g_s) =
                be.server_grad(cut, &params[nc..], &smashed, &y1h).unwrap();
            let mut g_split = be.client_grad(cut, &params[..nc], &x, &g_s).unwrap();
            g_split.extend(g_ws);
            // Both paths run the identical kernels on identical buffers,
            // so the equality is exact, not approximate.
            assert_eq!(loss_full, loss_split, "cut {cut} loss");
            let diff = tensor::max_abs_diff(&g_split, &g_full);
            assert!(diff == 0.0, "cut {cut}: split grad differs by {diff}");
        }
    }

    /// The scratch-aware role variants are the hot path; they must agree
    /// bitwise with the fallback-arena plain methods, through ANY handle.
    #[test]
    fn scratch_variants_agree_bitwise_with_plain_roles() {
        let be = backend();
        let (params, x, y1h) = golden_setup(&be);
        let fresh = ScratchHandle::new();
        let (loss_a, g_a) = be.full_grad(&params, &x, &y1h).unwrap();
        let (loss_b, g_b) = be.full_grad_with(&fresh, &params, &x, &y1h).unwrap();
        assert_eq!(loss_a, loss_b);
        assert_eq!(tensor::max_abs_diff(&g_a, &g_b), 0.0);
        // Reusing the now-dirty arena changes nothing.
        let (loss_c, g_c) = be.full_grad_with(&fresh, &params, &x, &y1h).unwrap();
        assert_eq!(loss_a, loss_c);
        assert_eq!(tensor::max_abs_diff(&g_a, &g_c), 0.0);
        let nc = be.spec().cut(2).client_params;
        let s_a = be.client_fwd(2, &params[..nc], &x).unwrap();
        let s_b = be.client_fwd_with(&fresh, 2, &params[..nc], &x).unwrap();
        assert_eq!(s_a, s_b);
        let (ls_a, _gw, gs_a) = be.server_grad(2, &params[nc..], &s_a, &y1h).unwrap();
        let (ls_b, _gw, gs_b) = be.server_grad_with(&fresh, 2, &params[nc..], &s_a, &y1h).unwrap();
        assert_eq!(ls_a, ls_b);
        assert_eq!(gs_a, gs_b);
        let gc_a = be.client_grad(2, &params[..nc], &x, &gs_a).unwrap();
        let gc_b = be.client_grad_with(&fresh, 2, &params[..nc], &x, &gs_a).unwrap();
        assert_eq!(tensor::max_abs_diff(&gc_a, &gc_b), 0.0);
        let ev_a = be.eval(&params, &x, &y1h).unwrap();
        let ev_b = be.eval_with(&fresh, &params, &x, &y1h).unwrap();
        assert_eq!(ev_a, ev_b);
    }

    /// Panel-parallel eval is an optimization channel: whatever worker
    /// count the trainer grants, eval results stay bitwise identical, and
    /// the hint never leaks into training-path roles.
    #[test]
    fn eval_parallelism_is_bitwise_neutral() {
        let be = backend();
        let spec = be.spec().clone();
        let params: Params = spec
            .params
            .iter()
            .enumerate()
            .map(|(k, p)| gen_vec(k as u64 * 1_000_000, p.size()))
            .collect();
        // Batch 32 clears forward_no_tape's engagement threshold, so the
        // fc layers really do take the gemm_parallel path.
        let batch = 32usize;
        let mut xshape = vec![batch];
        xshape.extend_from_slice(&spec.input_shape);
        let x = Tensor::new(gen_vec(40_000_000, batch * spec.input_per_sample()), xshape);
        let mut y = vec![0.0f32; batch * spec.classes];
        for i in 0..batch {
            y[i * spec.classes + (5 * i + 3) % spec.classes] = 1.0;
        }
        let y1h = Tensor::new(y, vec![batch, spec.classes]);
        let serial = be.eval(&params, &x, &y1h).unwrap();
        for workers in [2usize, 3, 5] {
            be.set_eval_parallelism(workers);
            assert_eq!(be.eval(&params, &x, &y1h).unwrap(), serial, "workers {workers}");
        }
        let smashed = be.client_fwd(2, &params[..4], &x).unwrap();
        be.set_eval_parallelism(1);
        assert_eq!(be.client_fwd(2, &params[..4], &x).unwrap(), smashed);
    }

    #[test]
    fn eval_returns_loss_and_correct_count() {
        let be = backend();
        let (params, x, y1h) = golden_setup(&be);
        let (loss, correct) = be.eval(&params, &x, &y1h).unwrap();
        let (loss_full, _g) = be.full_grad(&params, &x, &y1h).unwrap();
        assert_eq!(loss, loss_full);
        // JAX golden: neither random-param prediction is correct.
        assert_eq!(correct, 0.0);
    }

    #[test]
    fn shape_errors_are_reported_not_panicked() {
        let be = backend();
        let (params, x, y1h) = golden_setup(&be);
        assert!(be.client_fwd(0, &params[..2], &x).is_err());
        assert!(be.client_fwd(5, &params[..2], &x).is_err());
        assert!(be.client_fwd(1, &params[..4], &x).is_err());
        let bad_x = Tensor::zeros(&[2, 27, 28, 1]);
        assert!(be.client_fwd(1, &params[..2], &bad_x).is_err());
        let bad_y = Tensor::zeros(&[3, 10]);
        assert!(be.full_grad(&params, &x, &bad_y).is_err());
    }

    #[test]
    fn batch_size_is_taken_from_the_input() {
        // The same backend serves train- and eval-sized batches.
        let be = backend();
        let (params, _x, _y) = golden_setup(&be);
        for batch in [1usize, 3, 5] {
            let x = Tensor::zeros(&[batch, 28, 28, 1]);
            let s = be.client_fwd(2, &params[..4], &x).unwrap();
            assert_eq!(s.shape[0], batch);
        }
    }

    #[test]
    fn cifar_shape_builds_and_splits_exactly() {
        let spec = Manifest::builtin().for_dataset("cifar10").unwrap().clone();
        let be = NativeBackend::new(spec).unwrap();
        let spec = be.spec().clone();
        let params: Params = spec
            .params
            .iter()
            .enumerate()
            .map(|(k, p)| gen_vec(k as u64 * 1_000_000, p.size()))
            .collect();
        let batch = 2usize;
        let x = Tensor::new(
            gen_vec(30_000_000, batch * spec.input_per_sample()),
            vec![batch, 32, 32, 3],
        );
        let mut y = vec![0.0f32; batch * spec.classes];
        for i in 0..batch {
            y[i * spec.classes + (7 * i + 2) % spec.classes] = 1.0;
        }
        let y1h = Tensor::new(y, vec![batch, spec.classes]);
        let (loss_full, g_full) = be.full_grad(&params, &x, &y1h).unwrap();
        assert!(loss_full.is_finite());
        for cut in 1..=NUM_CUTS {
            let nc = spec.cut(cut).client_params;
            let smashed = be.client_fwd(cut, &params[..nc], &x).unwrap();
            let (_l, g_ws, g_s) = be.server_grad(cut, &params[nc..], &smashed, &y1h).unwrap();
            let mut g_split = be.client_grad(cut, &params[..nc], &x, &g_s).unwrap();
            g_split.extend(g_ws);
            assert!(tensor::max_abs_diff(&g_split, &g_full) == 0.0, "cut {cut}");
        }
    }
}
