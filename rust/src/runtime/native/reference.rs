//! The scalar reference kernels: the original autovectorized triple-loop
//! conv/dense implementations, retained verbatim after the im2col+GEMM
//! fast path (`ops.rs`) replaced them on the hot path.
//!
//! They exist to pin semantics, not to be fast: the property tests in
//! [`super::ops`] cross-check the GEMM path against these on awkward
//! shapes, the golden tests below pin them to JAX CPU, and
//! `benches/bench_kernels.rs` uses them as the speedup baseline.  The
//! `xv != 0.0` skip-heuristic is kept HERE only — it pays on branchy
//! scalar loops over post-relu activations but is pure branch overhead
//! inside a packed GEMM, so the fast path dropped it.

use super::ops::Geom;

/// SAME conv2d, stride 1, square odd kernel `k`, NHWC x HWIO -> NHWC,
/// with bias add and optional relu applied in a second pass.
pub fn conv2d_fwd(
    x: &[f32],
    g: Geom,
    wt: &[f32],
    k: usize,
    oc: usize,
    bias: &[f32],
    relu: bool,
) -> Vec<f32> {
    let Geom { b, h, w, c: ic } = g;
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(wt.len(), k * k * ic * oc);
    debug_assert_eq!(bias.len(), oc);
    let pad = k / 2;
    let mut out = vec![0.0f32; b * h * w * oc];
    for n in 0..b {
        for y in 0..h {
            for ky in 0..k {
                // Source row sy = y + ky - pad, skipped outside the image.
                if y + ky < pad || y + ky - pad >= h {
                    continue;
                }
                let sy = y + ky - pad;
                for xo in 0..w {
                    let obase = ((n * h + y) * w + xo) * oc;
                    for kx in 0..k {
                        if xo + kx < pad || xo + kx - pad >= w {
                            continue;
                        }
                        let sx = xo + kx - pad;
                        let xbase = ((n * h + sy) * w + sx) * ic;
                        let wbase = (ky * k + kx) * ic * oc;
                        for i in 0..ic {
                            let xv = x[xbase + i];
                            if xv != 0.0 {
                                let wrow = &wt[wbase + i * oc..wbase + (i + 1) * oc];
                                let orow = &mut out[obase..obase + oc];
                                for (o, &wv) in orow.iter_mut().zip(wrow) {
                                    *o += xv * wv;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    for row in out.chunks_mut(oc) {
        for (o, &bv) in row.iter_mut().zip(bias) {
            *o += bv;
            if relu && *o < 0.0 {
                *o = 0.0;
            }
        }
    }
    out
}

/// Backward of [`conv2d_fwd`] *without* the activation: the caller masks
/// `d_out` by the relu derivative first.  Returns `(d_x, d_w, d_b)`.
pub fn conv2d_bwd(
    x: &[f32],
    g: Geom,
    wt: &[f32],
    k: usize,
    oc: usize,
    d_out: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let Geom { b, h, w, c: ic } = g;
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(d_out.len(), b * h * w * oc);
    let pad = k / 2;
    let mut d_x = vec![0.0f32; x.len()];
    let mut d_w = vec![0.0f32; wt.len()];
    let mut d_b = vec![0.0f32; oc];
    for row in d_out.chunks(oc) {
        for (db, &dv) in d_b.iter_mut().zip(row) {
            *db += dv;
        }
    }
    for n in 0..b {
        for y in 0..h {
            for ky in 0..k {
                if y + ky < pad || y + ky - pad >= h {
                    continue;
                }
                let sy = y + ky - pad;
                for xo in 0..w {
                    let obase = ((n * h + y) * w + xo) * oc;
                    let dorow = &d_out[obase..obase + oc];
                    for kx in 0..k {
                        if xo + kx < pad || xo + kx - pad >= w {
                            continue;
                        }
                        let sx = xo + kx - pad;
                        let xbase = ((n * h + sy) * w + sx) * ic;
                        let wbase = (ky * k + kx) * ic * oc;
                        for i in 0..ic {
                            let wrow = &wt[wbase + i * oc..wbase + (i + 1) * oc];
                            let mut acc = 0.0f32;
                            for (&dv, &wv) in dorow.iter().zip(wrow) {
                                acc += dv * wv;
                            }
                            d_x[xbase + i] += acc;
                            let xv = x[xbase + i];
                            if xv != 0.0 {
                                let dwrow = &mut d_w[wbase + i * oc..wbase + (i + 1) * oc];
                                for (dw, &dv) in dwrow.iter_mut().zip(dorow) {
                                    *dw += xv * dv;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    (d_x, d_w, d_b)
}

/// Dense layer `out = x @ w + b`, optional relu.  `x` is `[bsz, din]`,
/// `wt` is `[din, dout]` row-major.
pub fn dense_fwd(
    x: &[f32],
    bsz: usize,
    din: usize,
    dout: usize,
    wt: &[f32],
    bias: &[f32],
    relu: bool,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), bsz * din);
    debug_assert_eq!(wt.len(), din * dout);
    debug_assert_eq!(bias.len(), dout);
    let mut out = vec![0.0f32; bsz * dout];
    for n in 0..bsz {
        let xrow = &x[n * din..(n + 1) * din];
        let orow = &mut out[n * dout..(n + 1) * dout];
        orow.copy_from_slice(bias);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv != 0.0 {
                let wrow = &wt[i * dout..(i + 1) * dout];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        if relu {
            for o in orow.iter_mut() {
                if *o < 0.0 {
                    *o = 0.0;
                }
            }
        }
    }
    out
}

/// Backward of [`dense_fwd`] without the activation (caller masks first).
/// Returns `(d_x, d_w, d_b)`.
pub fn dense_bwd(
    x: &[f32],
    bsz: usize,
    din: usize,
    dout: usize,
    wt: &[f32],
    d_out: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), bsz * din);
    debug_assert_eq!(d_out.len(), bsz * dout);
    let mut d_x = vec![0.0f32; bsz * din];
    let mut d_w = vec![0.0f32; wt.len()];
    let mut d_b = vec![0.0f32; dout];
    for n in 0..bsz {
        let dorow = &d_out[n * dout..(n + 1) * dout];
        for (db, &dv) in d_b.iter_mut().zip(dorow) {
            *db += dv;
        }
        let xrow = &x[n * din..(n + 1) * din];
        let dxrow = &mut d_x[n * din..(n + 1) * din];
        for i in 0..din {
            let wrow = &wt[i * dout..(i + 1) * dout];
            let mut acc = 0.0f32;
            for (&dv, &wv) in dorow.iter().zip(wrow) {
                acc += dv * wv;
            }
            dxrow[i] = acc;
            let xv = xrow[i];
            if xv != 0.0 {
                let dwrow = &mut d_w[i * dout..(i + 1) * dout];
                for (dw, &dv) in dwrow.iter_mut().zip(dorow) {
                    *dw += xv * dv;
                }
            }
        }
    }
    (d_x, d_w, d_b)
}

#[cfg(test)]
mod tests {
    use super::super::ops::tests::gen_vec;
    use super::*;

    fn fsum(v: &[f32]) -> f64 {
        v.iter().map(|&x| x as f64).sum()
    }

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    const CONV_G: Geom = Geom { b: 2, h: 6, w: 5, c: 3 };

    // The same JAX CPU goldens as the fast path (`ops::tests`): the
    // reference keeps its own copy so a regression in either path is
    // attributed unambiguously.
    #[test]
    fn reference_conv_matches_jax() {
        let x = gen_vec(0, 180);
        let w = gen_vec(180, 300);
        let b = gen_vec(480, 4);
        let out = conv2d_fwd(&x, CONV_G, &w, 5, 4, &b, true);
        assert!(close(fsum(&out), 46.72308349609375, 1e-4), "sum {}", fsum(&out));
        let d_out = gen_vec(484, 240);
        let (d_x, d_w, d_b) = conv2d_bwd(&x, CONV_G, &w, 5, 4, &d_out);
        assert!(close(fsum(&d_x), 0.0796661376953125, 1e-3), "d_x {}", fsum(&d_x));
        assert!(close(fsum(&d_w), 1.1000213623046875, 1e-3), "d_w {}", fsum(&d_w));
        assert!(close(fsum(&d_b), -1.5546875, 1e-3), "d_b {}", fsum(&d_b));
    }

    #[test]
    fn reference_dense_matches_jax() {
        let x = gen_vec(904, 21);
        let w = gen_vec(925, 35);
        let b = gen_vec(960, 5);
        let out = dense_fwd(&x, 3, 7, 5, &w, &b, true);
        assert!(close(fsum(&out), 1.689208984375, 1e-4), "dense {}", fsum(&out));
    }
}
