//! The scalar reference kernels: the original autovectorized triple-loop
//! conv/dense implementations, retained verbatim after the im2col+GEMM
//! fast path (`ops.rs`) replaced them on the hot path.
//!
//! They exist to pin semantics, not to be fast: the property tests in
//! [`super::ops`] cross-check the GEMM path against these on awkward
//! shapes, the golden tests below pin them to JAX CPU, and
//! `benches/bench_kernels.rs` uses them as the speedup baseline.  The
//! `xv != 0.0` skip-heuristic is kept HERE only — it pays on branchy
//! scalar loops over post-relu activations but is pure branch overhead
//! inside a packed GEMM, so the fast path dropped it.

use super::ops::Geom;

/// SAME conv2d, stride 1, square odd kernel `k`, NHWC x HWIO -> NHWC,
/// with bias add and optional relu applied in a second pass.
pub fn conv2d_fwd(
    x: &[f32],
    g: Geom,
    wt: &[f32],
    k: usize,
    oc: usize,
    bias: &[f32],
    relu: bool,
) -> Vec<f32> {
    let Geom { b, h, w, c: ic } = g;
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(wt.len(), k * k * ic * oc);
    debug_assert_eq!(bias.len(), oc);
    let pad = k / 2;
    let mut out = vec![0.0f32; b * h * w * oc];
    for n in 0..b {
        for y in 0..h {
            for ky in 0..k {
                // Source row sy = y + ky - pad, skipped outside the image.
                if y + ky < pad || y + ky - pad >= h {
                    continue;
                }
                let sy = y + ky - pad;
                for xo in 0..w {
                    let obase = ((n * h + y) * w + xo) * oc;
                    for kx in 0..k {
                        if xo + kx < pad || xo + kx - pad >= w {
                            continue;
                        }
                        let sx = xo + kx - pad;
                        let xbase = ((n * h + sy) * w + sx) * ic;
                        let wbase = (ky * k + kx) * ic * oc;
                        for i in 0..ic {
                            let xv = x[xbase + i];
                            if xv != 0.0 {
                                let wrow = &wt[wbase + i * oc..wbase + (i + 1) * oc];
                                let orow = &mut out[obase..obase + oc];
                                for (o, &wv) in orow.iter_mut().zip(wrow) {
                                    *o += xv * wv;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    for row in out.chunks_mut(oc) {
        for (o, &bv) in row.iter_mut().zip(bias) {
            *o += bv;
            if relu && *o < 0.0 {
                *o = 0.0;
            }
        }
    }
    out
}

/// Backward of [`conv2d_fwd`] *without* the activation: the caller masks
/// `d_out` by the relu derivative first.  Returns `(d_x, d_w, d_b)`.
pub fn conv2d_bwd(
    x: &[f32],
    g: Geom,
    wt: &[f32],
    k: usize,
    oc: usize,
    d_out: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let Geom { b, h, w, c: ic } = g;
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(d_out.len(), b * h * w * oc);
    let pad = k / 2;
    let mut d_x = vec![0.0f32; x.len()];
    let mut d_w = vec![0.0f32; wt.len()];
    let mut d_b = vec![0.0f32; oc];
    for row in d_out.chunks(oc) {
        for (db, &dv) in d_b.iter_mut().zip(row) {
            *db += dv;
        }
    }
    for n in 0..b {
        for y in 0..h {
            for ky in 0..k {
                if y + ky < pad || y + ky - pad >= h {
                    continue;
                }
                let sy = y + ky - pad;
                for xo in 0..w {
                    let obase = ((n * h + y) * w + xo) * oc;
                    let dorow = &d_out[obase..obase + oc];
                    for kx in 0..k {
                        if xo + kx < pad || xo + kx - pad >= w {
                            continue;
                        }
                        let sx = xo + kx - pad;
                        let xbase = ((n * h + sy) * w + sx) * ic;
                        let wbase = (ky * k + kx) * ic * oc;
                        for i in 0..ic {
                            let wrow = &wt[wbase + i * oc..wbase + (i + 1) * oc];
                            let mut acc = 0.0f32;
                            for (&dv, &wv) in dorow.iter().zip(wrow) {
                                acc += dv * wv;
                            }
                            d_x[xbase + i] += acc;
                            let xv = x[xbase + i];
                            if xv != 0.0 {
                                let dwrow = &mut d_w[wbase + i * oc..wbase + (i + 1) * oc];
                                for (dw, &dv) in dwrow.iter_mut().zip(dorow) {
                                    *dw += xv * dv;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    (d_x, d_w, d_b)
}

/// Dense layer `out = x @ w + b`, optional relu.  `x` is `[bsz, din]`,
/// `wt` is `[din, dout]` row-major.
pub fn dense_fwd(
    x: &[f32],
    bsz: usize,
    din: usize,
    dout: usize,
    wt: &[f32],
    bias: &[f32],
    relu: bool,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), bsz * din);
    debug_assert_eq!(wt.len(), din * dout);
    debug_assert_eq!(bias.len(), dout);
    let mut out = vec![0.0f32; bsz * dout];
    for n in 0..bsz {
        let xrow = &x[n * din..(n + 1) * din];
        let orow = &mut out[n * dout..(n + 1) * dout];
        orow.copy_from_slice(bias);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv != 0.0 {
                let wrow = &wt[i * dout..(i + 1) * dout];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        if relu {
            for o in orow.iter_mut() {
                if *o < 0.0 {
                    *o = 0.0;
                }
            }
        }
    }
    out
}

/// Backward of [`dense_fwd`] without the activation (caller masks first).
/// Returns `(d_x, d_w, d_b)`.
pub fn dense_bwd(
    x: &[f32],
    bsz: usize,
    din: usize,
    dout: usize,
    wt: &[f32],
    d_out: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), bsz * din);
    debug_assert_eq!(d_out.len(), bsz * dout);
    let mut d_x = vec![0.0f32; bsz * din];
    let mut d_w = vec![0.0f32; wt.len()];
    let mut d_b = vec![0.0f32; dout];
    for n in 0..bsz {
        let dorow = &d_out[n * dout..(n + 1) * dout];
        for (db, &dv) in d_b.iter_mut().zip(dorow) {
            *db += dv;
        }
        let xrow = &x[n * din..(n + 1) * din];
        let dxrow = &mut d_x[n * din..(n + 1) * din];
        for i in 0..din {
            let wrow = &wt[i * dout..(i + 1) * dout];
            let mut acc = 0.0f32;
            for (&dv, &wv) in dorow.iter().zip(wrow) {
                acc += dv * wv;
            }
            dxrow[i] = acc;
            let xv = xrow[i];
            if xv != 0.0 {
                let dwrow = &mut d_w[i * dout..(i + 1) * dout];
                for (dw, &dv) in dwrow.iter_mut().zip(dorow) {
                    *dw += xv * dv;
                }
            }
        }
    }
    (d_x, d_w, d_b)
}

/// Scalar layernorm twin of [`super::ops::layernorm_fwd`], with f64 row
/// statistics: the independent oracle the ≤1e-5 property tests compare
/// the fast path against.
pub fn layernorm_fwd(
    x: &[f32],
    rows: usize,
    d: usize,
    gamma: &[f32],
    beta: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let eps = super::ops::LN_EPS as f64;
    let mut out = vec![0.0f32; rows * d];
    let mut mean = vec![0.0f32; rows];
    let mut rstd = vec![0.0f32; rows];
    for n in 0..rows {
        let xrow = &x[n * d..(n + 1) * d];
        let mu: f64 = xrow.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var: f64 =
            xrow.iter().map(|&v| (v as f64 - mu) * (v as f64 - mu)).sum::<f64>() / d as f64;
        let rs = 1.0 / (var + eps).sqrt();
        mean[n] = mu as f32;
        rstd[n] = rs as f32;
        for j in 0..d {
            out[n * d + j] =
                (((xrow[j] as f64 - mu) * rs) * gamma[j] as f64 + beta[j] as f64) as f32;
        }
    }
    (out, mean, rstd)
}

/// Scalar twin of [`super::ops::layernorm_bwd`] (f64 accumulation).
#[allow(clippy::too_many_arguments)]
pub fn layernorm_bwd(
    x: &[f32],
    mean: &[f32],
    rstd: &[f32],
    gamma: &[f32],
    rows: usize,
    d: usize,
    dy: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut d_x = vec![0.0f32; rows * d];
    let mut d_g = vec![0.0f64; d];
    let mut d_b = vec![0.0f64; d];
    for n in 0..rows {
        let (mu, rs) = (mean[n] as f64, rstd[n] as f64);
        let xrow = &x[n * d..(n + 1) * d];
        let dyrow = &dy[n * d..(n + 1) * d];
        let mut a = 0.0f64;
        let mut b = 0.0f64;
        for j in 0..d {
            let g = dyrow[j] as f64 * gamma[j] as f64;
            a += g;
            b += g * (xrow[j] as f64 - mu) * rs;
        }
        a /= d as f64;
        b /= d as f64;
        for j in 0..d {
            let xhat = (xrow[j] as f64 - mu) * rs;
            d_x[n * d + j] = (rs * (dyrow[j] as f64 * gamma[j] as f64 - a - xhat * b)) as f32;
            d_g[j] += dyrow[j] as f64 * xhat;
            d_b[j] += dyrow[j] as f64;
        }
    }
    let to32 = |v: Vec<f64>| v.into_iter().map(|x| x as f32).collect::<Vec<f32>>();
    (d_x, to32(d_g), to32(d_b))
}

/// Scalar GELU twin (tanh approximation, f64 arithmetic).
pub fn gelu_fwd(x: &[f32]) -> Vec<f32> {
    let c = (2.0f64 / std::f64::consts::PI).sqrt();
    x.iter()
        .map(|&v| {
            let v = v as f64;
            let u = c * (v + 0.044715 * v * v * v);
            (0.5 * v * (1.0 + u.tanh())) as f32
        })
        .collect()
}

/// Scalar GELU VJP twin: multiplies `d` in place by dGELU/dx at `x_pre`.
pub fn gelu_bwd(d: &mut [f32], x_pre: &[f32]) {
    let c = (2.0f64 / std::f64::consts::PI).sqrt();
    for (dv, &v) in d.iter_mut().zip(x_pre) {
        let v = v as f64;
        let u = c * (v + 0.044715 * v * v * v);
        let t = u.tanh();
        let du = c * (1.0 + 3.0 * 0.044715 * v * v);
        *dv = (*dv as f64 * (0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * du)) as f32;
    }
}

/// Scalar multi-head attention twin of [`super::ops::mhsa_fwd`]: naive
/// f64 loops, softmax in f64.  Returns `(probs, concat)` in the same
/// `[b, heads, t, t]` / `[b·t, dm]` layouts.
pub fn mhsa_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    t: usize,
    dm: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>) {
    let dh = dm / heads;
    let scale = 1.0 / (dh as f64).sqrt();
    let mut probs = vec![0.0f32; b * heads * t * t];
    let mut concat = vec![0.0f32; b * t * dm];
    let at = |buf: &[f32], n: usize, i: usize, off: usize, l: usize| {
        buf[(n * t + i) * dm + off + l] as f64
    };
    for n in 0..b {
        for hd in 0..heads {
            let off = hd * dh;
            let pbase = (n * heads + hd) * t * t;
            for i in 0..t {
                let mut row = vec![0.0f64; t];
                for (j, r) in row.iter_mut().enumerate() {
                    let mut s = 0.0f64;
                    for l in 0..dh {
                        s += at(q, n, i, off, l) * at(k, n, j, off, l);
                    }
                    *r = s * scale;
                }
                let m = row.iter().fold(f64::NEG_INFINITY, |a, &x| a.max(x));
                let se: f64 = row.iter().map(|&x| (x - m).exp()).sum();
                for (j, &r) in row.iter().enumerate() {
                    probs[pbase + i * t + j] = ((r - m).exp() / se) as f32;
                }
            }
            for i in 0..t {
                for l in 0..dh {
                    let mut s = 0.0f64;
                    for j in 0..t {
                        s += probs[pbase + i * t + j] as f64 * at(v, n, j, off, l);
                    }
                    concat[(n * t + i) * dm + off + l] = s as f32;
                }
            }
        }
    }
    (probs, concat)
}

/// Scalar twin of [`super::ops::mhsa_bwd`] (naive f64 loops).
#[allow(clippy::too_many_arguments)]
pub fn mhsa_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    d_concat: &[f32],
    b: usize,
    t: usize,
    dm: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let dh = dm / heads;
    let scale = 1.0 / (dh as f64).sqrt();
    let mut d_q = vec![0.0f32; b * t * dm];
    let mut d_k = vec![0.0f32; b * t * dm];
    let mut d_v = vec![0.0f32; b * t * dm];
    let at = |buf: &[f32], n: usize, i: usize, off: usize, l: usize| {
        buf[(n * t + i) * dm + off + l] as f64
    };
    for n in 0..b {
        for hd in 0..heads {
            let off = hd * dh;
            let pbase = (n * heads + hd) * t * t;
            // dP, then the softmax VJP with the score scale folded in.
            let mut ds = vec![0.0f64; t * t];
            for i in 0..t {
                for j in 0..t {
                    let mut s = 0.0f64;
                    for l in 0..dh {
                        s += at(d_concat, n, i, off, l) * at(v, n, j, off, l);
                    }
                    ds[i * t + j] = s;
                }
                let dot: f64 = (0..t)
                    .map(|j| ds[i * t + j] * probs[pbase + i * t + j] as f64)
                    .sum();
                for j in 0..t {
                    ds[i * t + j] =
                        scale * probs[pbase + i * t + j] as f64 * (ds[i * t + j] - dot);
                }
            }
            for i in 0..t {
                for l in 0..dh {
                    let mut sq = 0.0f64;
                    let mut sk = 0.0f64;
                    let mut sv = 0.0f64;
                    for j in 0..t {
                        sq += ds[i * t + j] * at(k, n, j, off, l);
                        sk += ds[j * t + i] * at(q, n, j, off, l);
                        sv += probs[pbase + j * t + i] as f64 * at(d_concat, n, j, off, l);
                    }
                    d_q[(n * t + i) * dm + off + l] = sq as f32;
                    d_k[(n * t + i) * dm + off + l] = sk as f32;
                    d_v[(n * t + i) * dm + off + l] = sv as f32;
                }
            }
        }
    }
    (d_q, d_k, d_v)
}

#[cfg(test)]
mod tests {
    use super::super::ops::tests::gen_vec;
    use super::*;

    fn fsum(v: &[f32]) -> f64 {
        v.iter().map(|&x| x as f64).sum()
    }

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    const CONV_G: Geom = Geom { b: 2, h: 6, w: 5, c: 3 };

    // The same JAX CPU goldens as the fast path (`ops::tests`): the
    // reference keeps its own copy so a regression in either path is
    // attributed unambiguously.
    #[test]
    fn reference_conv_matches_jax() {
        let x = gen_vec(0, 180);
        let w = gen_vec(180, 300);
        let b = gen_vec(480, 4);
        let out = conv2d_fwd(&x, CONV_G, &w, 5, 4, &b, true);
        assert!(close(fsum(&out), 46.72308349609375, 1e-4), "sum {}", fsum(&out));
        let d_out = gen_vec(484, 240);
        let (d_x, d_w, d_b) = conv2d_bwd(&x, CONV_G, &w, 5, 4, &d_out);
        assert!(close(fsum(&d_x), 0.0796661376953125, 1e-3), "d_x {}", fsum(&d_x));
        assert!(close(fsum(&d_w), 1.1000213623046875, 1e-3), "d_w {}", fsum(&d_w));
        assert!(close(fsum(&d_b), -1.5546875, 1e-3), "d_b {}", fsum(&d_b));
    }

    #[test]
    fn reference_dense_matches_jax() {
        let x = gen_vec(904, 21);
        let w = gen_vec(925, 35);
        let b = gen_vec(960, 5);
        let out = dense_fwd(&x, 3, 7, 5, &w, &b, true);
        assert!(close(fsum(&out), 1.689208984375, 1e-4), "dense {}", fsum(&out));
    }

    /// Central finite difference of `<f(x), d_out>` along coordinate `p`.
    fn fd_probe(mut f: impl FnMut(&[f32]) -> Vec<f32>, x: &[f32], d_out: &[f32], p: usize) -> f64 {
        let h = 1e-3f32;
        let dot = |out: &[f32]| -> f64 {
            out.iter().zip(d_out).map(|(&o, &d)| (o * d) as f64).sum()
        };
        let mut xp = x.to_vec();
        xp[p] += h;
        let up = dot(&f(&xp));
        xp[p] -= 2.0 * h;
        let dn = dot(&f(&xp));
        (up - dn) / (2.0 * h as f64)
    }

    // The new reference twins are validated by calculus (finite
    // differences), not JAX goldens — they are themselves the oracle the
    // fast-path property tests compare against.
    #[test]
    fn reference_layernorm_bwd_matches_finite_difference() {
        let (rows, d) = (3usize, 11usize);
        let x = gen_vec(2_000, rows * d);
        let gamma: Vec<f32> = gen_vec(2_100, d).iter().map(|v| 1.0 + v * 0.3).collect();
        let beta = gen_vec(2_200, d);
        let dy = gen_vec(2_300, rows * d);
        let (_out, mean, rstd) = layernorm_fwd(&x, rows, d, &gamma, &beta);
        let (d_x, d_g, d_b) = layernorm_bwd(&x, &mean, &rstd, &gamma, rows, d, &dy);
        for p in [0usize, 7, rows * d - 1] {
            let fd = fd_probe(|xx| layernorm_fwd(xx, rows, d, &gamma, &beta).0, &x, &dy, p);
            assert!(
                (fd - d_x[p] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "d_x[{p}]: fd {fd} vs analytic {}",
                d_x[p]
            );
        }
        for p in [0usize, d - 1] {
            let fd = fd_probe(|gg| layernorm_fwd(&x, rows, d, gg, &beta).0, &gamma, &dy, p);
            assert!(
                (fd - d_g[p] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "d_g[{p}]: fd {fd} vs analytic {}",
                d_g[p]
            );
            let fdb = fd_probe(|bb| layernorm_fwd(&x, rows, d, &gamma, bb).0, &beta, &dy, p);
            assert!(
                (fdb - d_b[p] as f64).abs() < 2e-2 * (1.0 + fdb.abs()),
                "d_b[{p}]: fd {fdb} vs analytic {}",
                d_b[p]
            );
        }
    }

    #[test]
    fn reference_gelu_bwd_matches_finite_difference() {
        let x = gen_vec(3_000, 9);
        let dy = gen_vec(3_100, 9);
        let mut d = dy.clone();
        gelu_bwd(&mut d, &x);
        for p in 0..x.len() {
            let fd = fd_probe(gelu_fwd, &x, &dy, p);
            assert!(
                (fd - d[p] as f64).abs() < 1e-2 * (1.0 + fd.abs()),
                "gelu d[{p}]: fd {fd} vs analytic {}",
                d[p]
            );
        }
        // GELU values bracket the identity: gelu(x) ≈ x for large x, ≈ 0
        // for very negative x.
        let y = gelu_fwd(&[5.0, -5.0, 0.0]);
        assert!((y[0] - 5.0).abs() < 1e-3 && y[1].abs() < 1e-3 && y[2] == 0.0);
    }

    #[test]
    fn reference_mhsa_bwd_matches_finite_difference() {
        let (b, t, heads, dh) = (1usize, 4usize, 2usize, 3usize);
        let dm = heads * dh;
        let q = gen_vec(4_000, b * t * dm);
        let k = gen_vec(4_100, b * t * dm);
        let v = gen_vec(4_200, b * t * dm);
        let d_cat = gen_vec(4_300, b * t * dm);
        let (probs, _cat) = mhsa_fwd(&q, &k, &v, b, t, dm, heads);
        let (d_q, d_k, d_v) = mhsa_bwd(&q, &k, &v, &probs, &d_cat, b, t, dm, heads);
        for p in [0usize, 5, b * t * dm - 1] {
            let fd = fd_probe(|qq| mhsa_fwd(qq, &k, &v, b, t, dm, heads).1, &q, &d_cat, p);
            assert!(
                (fd - d_q[p] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "d_q[{p}]: fd {fd} vs analytic {}",
                d_q[p]
            );
            let fdk = fd_probe(|kk| mhsa_fwd(&q, kk, &v, b, t, dm, heads).1, &k, &d_cat, p);
            assert!(
                (fdk - d_k[p] as f64).abs() < 2e-2 * (1.0 + fdk.abs()),
                "d_k[{p}]: fd {fdk} vs analytic {}",
                d_k[p]
            );
            let fdv = fd_probe(|vv| mhsa_fwd(&q, &k, vv, b, t, dm, heads).1, &v, &d_cat, p);
            assert!(
                (fdv - d_v[p] as f64).abs() < 2e-2 * (1.0 + fdv.abs()),
                "d_v[{p}]: fd {fdv} vs analytic {}",
                d_v[p]
            );
        }
    }
}
