//! Pure-Rust neural-net primitives for the native backend: SAME-padded
//! conv2d, 2x2 max-pool, dense layers and softmax cross-entropy, each with
//! its backward pass.
//!
//! Layout conventions match the AOT artifacts exactly: activations are
//! NHWC, conv weights are HWIO, dense weights are `[in, out]`, everything
//! row-major `f32`.
//!
//! The conv and dense kernels run on the im2col + blocked-GEMM fast path
//! (see [`super::gemm`] / [`super::im2col`] and DESIGN.md §Native
//! backend): one register-blocked microkernel serves conv fwd
//! (`im2col(x)·W`), conv d_x (`d_out·Wᵀ` then col2im), conv d_w
//! (`im2col(x)ᵀ·d_out`) and the dense matmuls, with the bias+relu fused
//! into the GEMM epilogue.  Intermediates (the im2col matrix, packed
//! panels) live in a caller-provided [`Scratch`] arena and are reused
//! across calls; outputs are freshly allocated because the backward tape
//! retains them.  The conv kernels pack their weight operand into the
//! arena's `pw` cache ONCE per layer call ([`super::gemm::pack_b_full`])
//! and replay the packed panels across every image of the batch —
//! bitwise identical to per-image packing, minus `(b-1)` redundant packs.
//! Every GEMM runs on the arena's microkernel tier (`scratch.tier`), so a
//! worker's whole chain is tier-consistent.  The original scalar loops
//! are kept in [`super::reference`] and cross-checked against this path
//! by the property tests below.
//!
//! Golden values in the tests below were produced by JAX CPU (see
//! DESIGN.md §Native backend) from the same deterministic inputs, so the
//! semantics — padding offsets, pooling tie-breaks, loss scaling — are
//! pinned to the reference implementation rather than to this code.

use crate::runtime::scratch::Scratch;

use super::gemm::{
    gemm_packed_b, gemm_parallel, gemm_with_tier, pack_b_full, Epilogue, MatView,
};
use super::im2col::{col2im_image, col_width, im2col_image};

/// Image geometry of an NHWC activation buffer.
#[derive(Clone, Copy, Debug)]
pub struct Geom {
    pub b: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Geom {
    pub fn len(&self) -> usize {
        self.b * self.h * self.w * self.c
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// SAME conv2d, stride 1, square odd kernel `k`, NHWC x HWIO -> NHWC,
/// with bias add and optional relu fused into the GEMM epilogue.
///
/// Lowering: per image, `out_n = im2col(x_n) · W` — one `h·w × k·k·ic`
/// by `k·k·ic × oc` GEMM.  Per-image (rather than whole-batch) lowering
/// bounds the im2col scratch to one image regardless of batch size and
/// makes each output row's summation order batch-independent.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fwd(
    scratch: &mut Scratch,
    x: &[f32],
    g: Geom,
    wt: &[f32],
    k: usize,
    oc: usize,
    bias: &[f32],
    relu: bool,
) -> Vec<f32> {
    let Geom { b, h, w, c: ic } = g;
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(wt.len(), k * k * ic * oc);
    debug_assert_eq!(bias.len(), oc);
    let m = h * w;
    let kk = col_width(k, ic);
    let mut out = vec![0.0f32; b * m * oc];
    let tier = scratch.tier;
    let Scratch { col, pa, pw, .. } = scratch;
    col.resize(m * kk, 0.0);
    // Hoisted weight packing: W's panels are identical for every image of
    // the batch, so pack once and replay (bitwise ≡ packing per image).
    pack_b_full(pw, &MatView::rows(wt, oc), kk, oc);
    let ep = if relu { Epilogue::BiasRelu(bias) } else { Epilogue::Bias(bias) };
    for n in 0..b {
        im2col_image(&x[n * m * ic..(n + 1) * m * ic], h, w, ic, k, col);
        gemm_packed_b(
            tier,
            &mut out[n * m * oc..(n + 1) * m * oc],
            m,
            oc,
            kk,
            MatView::rows(col, kk),
            pw,
            ep,
            false,
            pa,
        );
    }
    out
}

/// Backward of [`conv2d_fwd`] *without* the activation: the caller masks
/// `d_out` by the relu derivative first.  Returns `(d_x, d_w, d_b)`.
///
/// Per image: `d_x` is `d_out_n · Wᵀ` scattered back by col2im, and `d_w`
/// accumulates `im2col(x_n)ᵀ · d_out_n` in ascending image order (fixed
/// summation order — see DESIGN.md).
pub fn conv2d_bwd(
    scratch: &mut Scratch,
    x: &[f32],
    g: Geom,
    wt: &[f32],
    k: usize,
    oc: usize,
    d_out: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let Geom { b, h, w, c: ic } = g;
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(d_out.len(), b * h * w * oc);
    let m = h * w;
    let kk = col_width(k, ic);
    let mut d_x = vec![0.0f32; x.len()];
    let mut d_w = vec![0.0f32; wt.len()];
    let mut d_b = vec![0.0f32; oc];
    for row in d_out.chunks(oc) {
        for (db, &dv) in d_b.iter_mut().zip(row) {
            *db += dv;
        }
    }
    let tier = scratch.tier;
    let Scratch { col, dcol, pa, pb, pw, .. } = scratch;
    col.resize(m * kk, 0.0);
    dcol.resize(m * kk, 0.0);
    // Hoisted weight packing for the d_x GEMMs: Wᵀ's panels are shared by
    // every image.  (The d_w GEMM's B operand is the per-image d_out row
    // block, so it keeps packing on the fly.)
    pack_b_full(pw, &MatView::transposed(wt, oc), oc, kk);
    for n in 0..b {
        let dorow = &d_out[n * m * oc..(n + 1) * m * oc];
        // d_x_n: column-space cotangent, folded back onto the image.
        gemm_packed_b(
            tier,
            dcol,
            m,
            kk,
            oc,
            MatView::rows(dorow, oc),
            pw,
            Epilogue::None,
            false,
            pa,
        );
        col2im_image(dcol, h, w, ic, k, &mut d_x[n * m * ic..(n + 1) * m * ic]);
        // d_w += im2col(x_n)ᵀ · d_out_n.
        im2col_image(&x[n * m * ic..(n + 1) * m * ic], h, w, ic, k, col);
        gemm_with_tier(
            tier,
            &mut d_w,
            kk,
            oc,
            m,
            MatView::transposed(col, kk),
            MatView::rows(dorow, oc),
            Epilogue::None,
            true,
            pa,
            pb,
        );
    }
    (d_x, d_w, d_b)
}

/// 2x2 max-pool, stride 2, VALID.  Returns the pooled buffer and the flat
/// input index of each window's max (first max in row-major scan order —
/// the same tie-break XLA's select-and-scatter uses).
pub fn maxpool2x2_fwd(x: &[f32], g: Geom) -> (Vec<f32>, Vec<u32>) {
    let Geom { b, h, w, c } = g;
    debug_assert_eq!(x.len(), g.len());
    debug_assert!(h % 2 == 0 && w % 2 == 0, "pool needs even h/w, got {h}x{w}");
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; b * oh * ow * c];
    let mut idx = vec![0u32; out.len()];
    for n in 0..b {
        for y in 0..oh {
            for xo in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0usize;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let src = ((n * h + 2 * y + dy) * w + 2 * xo + dx) * c + ch;
                            if x[src] > best {
                                best = x[src];
                                bi = src;
                            }
                        }
                    }
                    let o = ((n * oh + y) * ow + xo) * c + ch;
                    out[o] = best;
                    idx[o] = bi as u32;
                }
            }
        }
    }
    (out, idx)
}

/// Backward of [`maxpool2x2_fwd`]: routes each output gradient to the
/// recorded argmax position.
pub fn maxpool2x2_bwd(idx: &[u32], d_out: &[f32], in_len: usize) -> Vec<f32> {
    debug_assert_eq!(idx.len(), d_out.len());
    let mut d_x = vec![0.0f32; in_len];
    for (&i, &dv) in idx.iter().zip(d_out) {
        d_x[i as usize] += dv;
    }
    d_x
}

/// Dense layer `out = x @ w + b`, optional relu fused into the GEMM
/// epilogue.  `x` is `[bsz, din]`, `wt` is `[din, dout]` row-major.
#[allow(clippy::too_many_arguments)]
pub fn dense_fwd(
    scratch: &mut Scratch,
    x: &[f32],
    bsz: usize,
    din: usize,
    dout: usize,
    wt: &[f32],
    bias: &[f32],
    relu: bool,
) -> Vec<f32> {
    dense_fwd_par(scratch, x, bsz, din, dout, wt, bias, relu, 1)
}

/// [`dense_fwd`] with the output columns split across up to `par` scoped
/// worker threads ([`gemm_parallel`]) — the panel-parallel eval path for
/// large batches.  Bitwise identical to the serial call for every `par`
/// (column splits do not touch any element's summation order).
#[allow(clippy::too_many_arguments)]
pub fn dense_fwd_par(
    scratch: &mut Scratch,
    x: &[f32],
    bsz: usize,
    din: usize,
    dout: usize,
    wt: &[f32],
    bias: &[f32],
    relu: bool,
    par: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), bsz * din);
    debug_assert_eq!(wt.len(), din * dout);
    debug_assert_eq!(bias.len(), dout);
    let mut out = vec![0.0f32; bsz * dout];
    let tier = scratch.tier;
    let Scratch { pa, pb, .. } = scratch;
    let ep = if relu { Epilogue::BiasRelu(bias) } else { Epilogue::Bias(bias) };
    gemm_parallel(
        tier,
        &mut out,
        bsz,
        dout,
        din,
        MatView::rows(x, din),
        MatView::rows(wt, dout),
        ep,
        par,
        pa,
        pb,
    );
    out
}

/// Backward of [`dense_fwd`] without the activation (caller masks first).
/// Returns `(d_x, d_w, d_b)`: `d_x = d_out · Wᵀ`, `d_w = xᵀ · d_out` —
/// both on the GEMM core via transposed views, no operand materialized.
pub fn dense_bwd(
    scratch: &mut Scratch,
    x: &[f32],
    bsz: usize,
    din: usize,
    dout: usize,
    wt: &[f32],
    d_out: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), bsz * din);
    debug_assert_eq!(d_out.len(), bsz * dout);
    let mut d_x = vec![0.0f32; bsz * din];
    let mut d_w = vec![0.0f32; wt.len()];
    let mut d_b = vec![0.0f32; dout];
    for row in d_out.chunks(dout) {
        for (db, &dv) in d_b.iter_mut().zip(row) {
            *db += dv;
        }
    }
    let tier = scratch.tier;
    let Scratch { pa, pb, .. } = scratch;
    gemm_with_tier(
        tier,
        &mut d_x,
        bsz,
        din,
        dout,
        MatView::rows(d_out, dout),
        MatView::transposed(wt, dout),
        Epilogue::None,
        false,
        pa,
        pb,
    );
    gemm_with_tier(
        tier,
        &mut d_w,
        din,
        dout,
        bsz,
        MatView::transposed(x, din),
        MatView::rows(d_out, dout),
        Epilogue::None,
        false,
        pa,
        pb,
    );
    (d_x, d_w, d_b)
}

/// In-place relu VJP: zero the gradient wherever the recorded
/// post-activation is not positive.
pub fn relu_mask(d: &mut [f32], act: &[f32]) {
    debug_assert_eq!(d.len(), act.len());
    for (dv, &av) in d.iter_mut().zip(act) {
        if av <= 0.0 {
            *dv = 0.0;
        }
    }
}

/// Mean softmax cross-entropy with one-hot labels; returns the scalar loss
/// and `d loss / d logits` (the `(p - y)/B` cotangent).
pub fn softmax_ce(logits: &[f32], y1h: &[f32], bsz: usize, classes: usize) -> (f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), bsz * classes);
    debug_assert_eq!(y1h.len(), bsz * classes);
    let mut d = vec![0.0f32; logits.len()];
    let mut loss = 0.0f64;
    for n in 0..bsz {
        let lrow = &logits[n * classes..(n + 1) * classes];
        let yrow = &y1h[n * classes..(n + 1) * classes];
        let m = lrow.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut se = 0.0f32;
        for &v in lrow {
            se += (v - m).exp();
        }
        let lse = se.ln();
        let drow = &mut d[n * classes..(n + 1) * classes];
        for j in 0..classes {
            let logp = lrow[j] - m - lse;
            loss -= (yrow[j] * logp) as f64;
            drow[j] = (logp.exp() - yrow[j]) / bsz as f32;
        }
    }
    ((loss / bsz as f64) as f32, d)
}

/// Loss-only variant of [`softmax_ce`] for evaluation paths: identical
/// arithmetic, no gradient buffer allocated.
pub fn ce_loss(logits: &[f32], y1h: &[f32], bsz: usize, classes: usize) -> f32 {
    debug_assert_eq!(logits.len(), bsz * classes);
    debug_assert_eq!(y1h.len(), bsz * classes);
    let mut loss = 0.0f64;
    for n in 0..bsz {
        let lrow = &logits[n * classes..(n + 1) * classes];
        let yrow = &y1h[n * classes..(n + 1) * classes];
        let m = lrow.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut se = 0.0f32;
        for &v in lrow {
            se += (v - m).exp();
        }
        let lse = se.ln();
        for (l, y) in lrow.iter().zip(yrow) {
            loss -= (y * (l - m - lse)) as f64;
        }
    }
    (loss / bsz as f64) as f32
}

/// Count of rows where argmax(logits) == argmax(y1h) (first max wins ties,
/// matching `jnp.argmax`).
pub fn correct_count(logits: &[f32], y1h: &[f32], bsz: usize, classes: usize) -> f32 {
    let argmax = |row: &[f32]| {
        let mut bi = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                bi = j;
            }
        }
        bi
    };
    let mut correct = 0usize;
    for n in 0..bsz {
        let lrow = &logits[n * classes..(n + 1) * classes];
        let yrow = &y1h[n * classes..(n + 1) * classes];
        if argmax(lrow) == argmax(yrow) {
            correct += 1;
        }
    }
    correct as f32
}

/// Layernorm epsilon, shared by the fast path and the scalar reference.
pub const LN_EPS: f32 = 1e-5;

/// Row-wise layernorm with learned gain/shift: `out = (x - μ)·rstd·γ + β`
/// over rows of width `d`.  Returns `(out, mean, rstd)`; the per-row
/// statistics feed [`layernorm_bwd`].  All reductions are sequential f32
/// in ascending index order (fixed summation order — see DESIGN.md).
pub fn layernorm_fwd(
    x: &[f32],
    rows: usize,
    d: usize,
    gamma: &[f32],
    beta: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(gamma.len(), d);
    debug_assert_eq!(beta.len(), d);
    let mut out = vec![0.0f32; rows * d];
    let mut mean = vec![0.0f32; rows];
    let mut rstd = vec![0.0f32; rows];
    for n in 0..rows {
        let xrow = &x[n * d..(n + 1) * d];
        let mut s = 0.0f32;
        for &v in xrow {
            s += v;
        }
        let mu = s / d as f32;
        let mut var = 0.0f32;
        for &v in xrow {
            var += (v - mu) * (v - mu);
        }
        let rs = 1.0 / (var / d as f32 + LN_EPS).sqrt();
        mean[n] = mu;
        rstd[n] = rs;
        let orow = &mut out[n * d..(n + 1) * d];
        for j in 0..d {
            orow[j] = (xrow[j] - mu) * rs * gamma[j] + beta[j];
        }
    }
    (out, mean, rstd)
}

/// Backward of [`layernorm_fwd`].  Returns `(d_x, d_gamma, d_beta)`;
/// `d_gamma`/`d_beta` accumulate across rows in ascending row order.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_bwd(
    x: &[f32],
    mean: &[f32],
    rstd: &[f32],
    gamma: &[f32],
    rows: usize,
    d: usize,
    dy: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(dy.len(), rows * d);
    let mut d_x = vec![0.0f32; rows * d];
    let mut d_g = vec![0.0f32; d];
    let mut d_b = vec![0.0f32; d];
    for n in 0..rows {
        let xrow = &x[n * d..(n + 1) * d];
        let dyrow = &dy[n * d..(n + 1) * d];
        let (mu, rs) = (mean[n], rstd[n]);
        // a = mean(dy·γ), b = mean(dy·γ·x̂) over the row.
        let mut a = 0.0f32;
        let mut bsum = 0.0f32;
        for j in 0..d {
            let g = dyrow[j] * gamma[j];
            a += g;
            bsum += g * (xrow[j] - mu) * rs;
        }
        a /= d as f32;
        bsum /= d as f32;
        let dxrow = &mut d_x[n * d..(n + 1) * d];
        for j in 0..d {
            let xhat = (xrow[j] - mu) * rs;
            dxrow[j] = rs * (dyrow[j] * gamma[j] - a - xhat * bsum);
            d_g[j] += dyrow[j] * xhat;
            d_b[j] += dyrow[j];
        }
    }
    (d_x, d_g, d_b)
}

const GELU_C: f32 = 0.797_884_56; // sqrt(2/π)
const GELU_A: f32 = 0.044715;

/// Elementwise GELU (tanh approximation, the variant transformer stacks
/// standardized on): `0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))`.
pub fn gelu_fwd(x: &[f32]) -> Vec<f32> {
    x.iter()
        .map(|&v| {
            let u = GELU_C * (v + GELU_A * v * v * v);
            0.5 * v * (1.0 + u.tanh())
        })
        .collect()
}

/// In-place GELU VJP: multiplies `d` by dGELU/dx at the *pre-activation*
/// values `x_pre`.
pub fn gelu_bwd(d: &mut [f32], x_pre: &[f32]) {
    debug_assert_eq!(d.len(), x_pre.len());
    for (dv, &v) in d.iter_mut().zip(x_pre) {
        let u = GELU_C * (v + GELU_A * v * v * v);
        let t = u.tanh();
        let du = GELU_C * (1.0 + 3.0 * GELU_A * v * v);
        *dv *= 0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * du;
    }
}

/// In-place row-wise softmax over rows of width `d` (max-subtracted,
/// sequential f32 — the attention-score normalizer).
pub fn softmax_rows(x: &mut [f32], rows: usize, d: usize) {
    debug_assert_eq!(x.len(), rows * d);
    for row in x.chunks_mut(d) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut se = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            se += *v;
        }
        let inv = 1.0 / se;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Cut an NHWC batch into non-overlapping `patch`x`patch` tokens:
/// `[b, h, w, c] -> [b·T, p·p·c]` with the token grid row-major and each
/// token flattened `(dy, dx, ch)` — the patch-embedding lowering.
pub fn patchify(x: &[f32], g: Geom, patch: usize) -> Vec<f32> {
    let Geom { b, h, w, c } = g;
    debug_assert_eq!(x.len(), g.len());
    debug_assert!(h % patch == 0 && w % patch == 0);
    let (gh, gw) = (h / patch, w / patch);
    let ppc = patch * patch * c;
    let mut out = vec![0.0f32; b * gh * gw * ppc];
    for n in 0..b {
        for py in 0..gh {
            for px in 0..gw {
                let tok = (n * gh + py) * gw + px;
                for dy in 0..patch {
                    let src = ((n * h + py * patch + dy) * w + px * patch) * c;
                    let dst = tok * ppc + dy * patch * c;
                    out[dst..dst + patch * c].copy_from_slice(&x[src..src + patch * c]);
                }
            }
        }
    }
    out
}

/// Inverse of [`patchify`] for the backward pass: scatters token-space
/// gradients back onto the image (a pure permutation — exact).
pub fn unpatchify(dp: &[f32], g: Geom, patch: usize) -> Vec<f32> {
    let Geom { b, h, w, c } = g;
    let (gh, gw) = (h / patch, w / patch);
    let ppc = patch * patch * c;
    debug_assert_eq!(dp.len(), b * gh * gw * ppc);
    let mut out = vec![0.0f32; g.len()];
    for n in 0..b {
        for py in 0..gh {
            for px in 0..gw {
                let tok = (n * gh + py) * gw + px;
                for dy in 0..patch {
                    let dst = ((n * h + py * patch + dy) * w + px * patch) * c;
                    let src = tok * ppc + dy * patch * c;
                    out[dst..dst + patch * c].copy_from_slice(&dp[src..src + patch * c]);
                }
            }
        }
    }
    out
}

/// Copy head `hd`'s `dh` columns out of an interleaved `[n·t, dm]` buffer
/// into a contiguous `[t, dh]` staging slice.
fn gather_head(src: &[f32], dst: &mut [f32], n: usize, t: usize, dm: usize, off: usize, dh: usize) {
    for i in 0..t {
        let s = (n * t + i) * dm + off;
        dst[i * dh..(i + 1) * dh].copy_from_slice(&src[s..s + dh]);
    }
}

/// Inverse of [`gather_head`]: write a `[t, dh]` staging slice back into
/// head `hd`'s columns.
fn scatter_head(src: &[f32], dst: &mut [f32], n: usize, t: usize, dm: usize, off: usize, dh: usize) {
    for i in 0..t {
        let d = (n * t + i) * dm + off;
        dst[d..d + dh].copy_from_slice(&src[i * dh..(i + 1) * dh]);
    }
}

/// Multi-head softmax attention core on projected Q/K/V buffers
/// (`[b·t, dm]`, heads side by side): per (sample, head),
/// `P = softmax(Qh·Khᵀ/√dh)` and `Oh = P·Vh`, heads re-concatenated into
/// `[b·t, dm]`.  Returns `(probs, concat)` — `probs` is `[b, heads, t, t]`
/// and is retained by the tape for the backward pass.
///
/// Head slices are gathered into contiguous arena staging so every GEMM
/// runs on the tiered microkernel; (sample, head) pairs run in a fixed
/// ascending order and each output element is written exactly once, so
/// the determinism contract extends verbatim.
pub fn mhsa_fwd(
    scratch: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    t: usize,
    dm: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(q.len(), b * t * dm);
    debug_assert_eq!(k.len(), q.len());
    debug_assert_eq!(v.len(), q.len());
    debug_assert!(heads >= 1 && dm % heads == 0);
    let dh = dm / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = vec![0.0f32; b * heads * t * t];
    let mut concat = vec![0.0f32; b * t * dm];
    let tier = scratch.tier;
    let Scratch { pa, pb, qh, kh, vh, oh, .. } = scratch;
    qh.resize(t * dh, 0.0);
    kh.resize(t * dh, 0.0);
    vh.resize(t * dh, 0.0);
    oh.resize(t * dh, 0.0);
    for n in 0..b {
        for hd in 0..heads {
            let off = hd * dh;
            gather_head(q, qh, n, t, dm, off, dh);
            gather_head(k, kh, n, t, dm, off, dh);
            gather_head(v, vh, n, t, dm, off, dh);
            let p = &mut probs[(n * heads + hd) * t * t..(n * heads + hd + 1) * t * t];
            // Scores straight into the tape chunk, scaled, softmaxed in place.
            gemm_with_tier(
                tier,
                p,
                t,
                t,
                dh,
                MatView::rows(qh, dh),
                MatView::transposed(kh, dh),
                Epilogue::None,
                false,
                pa,
                pb,
            );
            for s in p.iter_mut() {
                *s *= scale;
            }
            softmax_rows(p, t, t);
            gemm_with_tier(
                tier,
                oh,
                t,
                dh,
                t,
                MatView::rows(p, t),
                MatView::rows(vh, dh),
                Epilogue::None,
                false,
                pa,
                pb,
            );
            scatter_head(oh, &mut concat, n, t, dm, off, dh);
        }
    }
    (probs, concat)
}

/// Backward of [`mhsa_fwd`]: given the taped `probs` and the cotangent of
/// the concatenated head outputs, returns `(d_q, d_k, d_v)`.
#[allow(clippy::too_many_arguments)]
pub fn mhsa_bwd(
    scratch: &mut Scratch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    d_concat: &[f32],
    b: usize,
    t: usize,
    dm: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(probs.len(), b * heads * t * t);
    debug_assert_eq!(d_concat.len(), b * t * dm);
    let dh = dm / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut d_q = vec![0.0f32; b * t * dm];
    let mut d_k = vec![0.0f32; b * t * dm];
    let mut d_v = vec![0.0f32; b * t * dm];
    let tier = scratch.tier;
    let Scratch { pa, pb, qh, kh, vh, oh, sd, .. } = scratch;
    qh.resize(t * dh, 0.0);
    kh.resize(t * dh, 0.0);
    vh.resize(t * dh, 0.0);
    oh.resize(t * dh, 0.0);
    sd.resize(t * t, 0.0);
    for n in 0..b {
        for hd in 0..heads {
            let off = hd * dh;
            gather_head(q, qh, n, t, dm, off, dh);
            gather_head(k, kh, n, t, dm, off, dh);
            gather_head(v, vh, n, t, dm, off, dh);
            gather_head(d_concat, oh, n, t, dm, off, dh);
            let p = &probs[(n * heads + hd) * t * t..(n * heads + hd + 1) * t * t];
            // dP = dOh · Vhᵀ.
            gemm_with_tier(
                tier,
                sd,
                t,
                t,
                dh,
                MatView::rows(oh, dh),
                MatView::transposed(vh, dh),
                Epilogue::None,
                false,
                pa,
                pb,
            );
            // dVh = Pᵀ · dOh — staged through vh, whose gather is no
            // longer needed once dP is out.
            gemm_with_tier(
                tier,
                vh,
                t,
                dh,
                t,
                MatView::transposed(p, t),
                MatView::rows(oh, dh),
                Epilogue::None,
                false,
                pa,
                pb,
            );
            scatter_head(vh, &mut d_v, n, t, dm, off, dh);
            // Softmax VJP in place, with the 1/√dh score scale folded in:
            // dS = scale · P ⊙ (dP − rowsum(dP ⊙ P)).
            for i in 0..t {
                let prow = &p[i * t..(i + 1) * t];
                let srow = &mut sd[i * t..(i + 1) * t];
                let mut dot = 0.0f32;
                for j in 0..t {
                    dot += srow[j] * prow[j];
                }
                for j in 0..t {
                    srow[j] = scale * prow[j] * (srow[j] - dot);
                }
            }
            // dQh = dS · Kh (oh's cotangent gather is consumed already).
            gemm_with_tier(
                tier,
                oh,
                t,
                dh,
                t,
                MatView::rows(sd, t),
                MatView::rows(kh, dh),
                Epilogue::None,
                false,
                pa,
                pb,
            );
            scatter_head(oh, &mut d_q, n, t, dm, off, dh);
            // dKh = dSᵀ · Qh — staged through vh again.
            gemm_with_tier(
                tier,
                vh,
                t,
                dh,
                t,
                MatView::transposed(sd, t),
                MatView::rows(qh, dh),
                Epilogue::None,
                false,
                pa,
                pb,
            );
            scatter_head(vh, &mut d_k, n, t, dm, off, dh);
        }
    }
    (d_q, d_k, d_v)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::super::reference;
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    /// Deterministic dyadic-rational generator shared with the JAX golden
    /// script: exact in f32 on every platform.
    pub(crate) fn gen(i: u64) -> f32 {
        let h = (i as u32).wrapping_mul(2654435761);
        ((h >> 16) & 0xFF) as f32 / 256.0 - 0.5
    }

    pub(crate) fn gen_vec(offset: u64, n: usize) -> Vec<f32> {
        (0..n as u64).map(|j| gen(offset + j)).collect()
    }

    fn fsum(v: &[f32]) -> f64 {
        v.iter().map(|&x| x as f64).sum()
    }

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    /// The satellite acceptance comparator: |a-b| ≤ 1e-5·(1+|b|).
    fn assert_close_1e5(tag: &str, got: &[f32], want: &[f32]) {
        assert_eq!(got.len(), want.len(), "{tag}: length");
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "{tag}[{i}]: fast {a} vs reference {b}"
            );
        }
    }

    // Golden values from JAX CPU (lax.conv_general_dilated / reduce_window
    // / log_softmax) on the same generated inputs; offsets follow the
    // generation order in the golden script.
    const X_CONV: u64 = 0; // (2,6,5,3) = 180
    const W_CONV: u64 = 180; // (5,5,3,4) = 300
    const B_CONV: u64 = 480; // (4,)
    const DO_CONV: u64 = 484; // (2,6,5,4) = 240
    const X_POOL: u64 = 724; // (2,4,6,3) = 144
    const DO_POOL: u64 = 868; // (2,2,3,3) = 36
    const X_DENSE: u64 = 904; // (3,7) = 21
    const W_DENSE: u64 = 925; // (7,5) = 35
    const B_DENSE: u64 = 960; // (5,)
    const LOGITS: u64 = 965; // (4,10) = 40, scaled by 4

    const CONV_G: Geom = Geom { b: 2, h: 6, w: 5, c: 3 };
    const POOL_G: Geom = Geom { b: 2, h: 4, w: 6, c: 3 };

    #[test]
    fn conv2d_fwd_matches_jax() {
        let x = gen_vec(X_CONV, 180);
        let w = gen_vec(W_CONV, 300);
        let b = gen_vec(B_CONV, 4);
        // Goldens pin against JAX CPU through the portable tier: the SIMD
        // tier's FMA rounds differently (it is pinned against portable by
        // the gemm property tests instead).
        let mut s = Scratch::portable();
        let out = conv2d_fwd(&mut s, &x, CONV_G, &w, 5, 4, &b, true);
        assert!(close(fsum(&out), 46.72308349609375, 1e-4), "sum {}", fsum(&out));
        // out[0, 0, 1, 2] with OC=4: ((0*6+0)*5+1)*4+2 = 6.
        assert!((out[6] - 0.755523681640625).abs() < 1e-5, "probe {}", out[6]);
    }

    #[test]
    fn conv2d_bwd_matches_jax() {
        let x = gen_vec(X_CONV, 180);
        let w = gen_vec(W_CONV, 300);
        let d_out = gen_vec(DO_CONV, 240);
        let mut s = Scratch::portable();
        let (d_x, d_w, d_b) = conv2d_bwd(&mut s, &x, CONV_G, &w, 5, 4, &d_out);
        assert!(close(fsum(&d_x), 0.0796661376953125, 1e-3), "d_x {}", fsum(&d_x));
        assert!(close(fsum(&d_w), 1.1000213623046875, 1e-3), "d_w {}", fsum(&d_w));
        assert!(close(fsum(&d_b), -1.5546875, 1e-3), "d_b {}", fsum(&d_b));
    }

    #[test]
    fn maxpool_matches_jax() {
        let x = gen_vec(X_POOL, 144);
        let (out, idx) = maxpool2x2_fwd(&x, POOL_G);
        assert_eq!(out.len(), 2 * 2 * 3 * 3);
        assert!(close(fsum(&out), 10.84375, 1e-5), "pool {}", fsum(&out));
        let d_out = gen_vec(DO_POOL, 36);
        let d_x = maxpool2x2_bwd(&idx, &d_out, x.len());
        assert!(close(fsum(&d_x), -0.08984375, 1e-4), "pool bwd {}", fsum(&d_x));
        // Gradient mass is conserved by max-pool routing.
        assert!((fsum(&d_x) - fsum(&d_out)).abs() < 1e-5);
    }

    #[test]
    fn dense_fwd_matches_jax() {
        let x = gen_vec(X_DENSE, 21);
        let w = gen_vec(W_DENSE, 35);
        let b = gen_vec(B_DENSE, 5);
        let mut s = Scratch::portable();
        let out = dense_fwd(&mut s, &x, 3, 7, 5, &w, &b, true);
        assert!(close(fsum(&out), 1.689208984375, 1e-4), "dense {}", fsum(&out));
    }

    #[test]
    fn dense_bwd_is_consistent_with_finite_difference() {
        let x = gen_vec(X_DENSE, 21);
        let mut w = gen_vec(W_DENSE, 35);
        let b = gen_vec(B_DENSE, 5);
        let d_out = gen_vec(40, 15);
        let mut s = Scratch::new();
        let (_d_x, d_w, _d_b) = dense_bwd(&mut s, &x, 3, 7, 5, &w, &d_out);
        // <d_w, e> ≈ (f(w + h e) - f(w - h e)) / 2h with f = <out, d_out>.
        let probe = 9usize;
        let h = 1e-3f32;
        let dot = |out: &[f32]| -> f64 {
            out.iter().zip(&d_out).map(|(&o, &d)| (o * d) as f64).sum()
        };
        w[probe] += h;
        let up = dot(&dense_fwd(&mut s, &x, 3, 7, 5, &w, &b, false));
        w[probe] -= 2.0 * h;
        let dn = dot(&dense_fwd(&mut s, &x, 3, 7, 5, &w, &b, false));
        let fd = (up - dn) / (2.0 * h as f64);
        assert!(
            (fd - d_w[probe] as f64).abs() < 1e-3 * (1.0 + fd.abs()),
            "fd {fd} vs analytic {}",
            d_w[probe]
        );
    }

    /// The satellite shapes the tiling must survive: odd H/W, channel
    /// counts off the MR/NR=8 tiles, batch 1 — fast path ≡ scalar
    /// reference to 1e-5 on forward AND all three backward outputs.
    #[test]
    fn gemm_path_matches_reference_on_awkward_shapes() {
        // (b, h, w, ic, k, oc)
        let cases = [
            (1usize, 5usize, 7usize, 3usize, 5usize, 9usize), // odd h/w, off-tile ic/oc
            (1, 1, 1, 1, 1, 1),                               // degenerate 1x1
            (2, 6, 5, 3, 3, 4),                               // the golden geometry, k=3
            (1, 3, 9, 7, 5, 13),                              // oc crossing one NR tile
            (3, 7, 2, 5, 3, 8),                               // narrow image, exact NR
        ];
        let mut s = Scratch::new();
        for (ci, &(b, h, w, ic, k, oc)) in cases.iter().enumerate() {
            let g = Geom { b, h, w, c: ic };
            let base = 10_000 * ci as u64;
            let x = gen_vec(base, g.len());
            let wt = gen_vec(base + 1_000, k * k * ic * oc);
            let bias = gen_vec(base + 2_000, oc);
            let d_out = gen_vec(base + 3_000, b * h * w * oc);
            for relu in [false, true] {
                let fast = conv2d_fwd(&mut s, &x, g, &wt, k, oc, &bias, relu);
                let slow = reference::conv2d_fwd(&x, g, &wt, k, oc, &bias, relu);
                assert_close_1e5(&format!("case {ci} fwd(relu={relu})"), &fast, &slow);
            }
            let (dx_f, dw_f, db_f) = conv2d_bwd(&mut s, &x, g, &wt, k, oc, &d_out);
            let (dx_s, dw_s, db_s) = reference::conv2d_bwd(&x, g, &wt, k, oc, &d_out);
            assert_close_1e5(&format!("case {ci} d_x"), &dx_f, &dx_s);
            assert_close_1e5(&format!("case {ci} d_w"), &dw_f, &dw_s);
            assert_close_1e5(&format!("case {ci} d_b"), &db_f, &db_s);
        }
    }

    #[test]
    fn property_conv_gemm_equals_reference() {
        let mut s = Scratch::new();
        check("conv-gemm-vs-reference", 48, |rng| {
            let b = 1 + rng.below(2);
            let h = 1 + rng.below(7);
            let w = 1 + rng.below(7);
            let ic = 1 + rng.below(4);
            let oc = 1 + rng.below(9);
            let k = [1usize, 3, 5][rng.below(3)];
            let g = Geom { b, h, w, c: ic };
            let x: Vec<f32> = (0..g.len()).map(|_| rng.normal() as f32 * 0.5).collect();
            let wt: Vec<f32> =
                (0..k * k * ic * oc).map(|_| rng.normal() as f32 * 0.5).collect();
            let bias: Vec<f32> = (0..oc).map(|_| rng.normal() as f32 * 0.5).collect();
            let d_out: Vec<f32> =
                (0..b * h * w * oc).map(|_| rng.normal() as f32 * 0.5).collect();
            let fast = conv2d_fwd(&mut s, &x, g, &wt, k, oc, &bias, true);
            let slow = reference::conv2d_fwd(&x, g, &wt, k, oc, &bias, true);
            for (i, (a, bb)) in fast.iter().zip(&slow).enumerate() {
                prop_assert!(
                    (a - bb).abs() <= 1e-5 * (1.0 + bb.abs()),
                    "fwd[{i}]: {a} vs {bb} (b{b} {h}x{w}x{ic} k{k} oc{oc})"
                );
            }
            let (dx_f, dw_f, db_f) = conv2d_bwd(&mut s, &x, g, &wt, k, oc, &d_out);
            let (dx_s, dw_s, db_s) = reference::conv2d_bwd(&x, g, &wt, k, oc, &d_out);
            for (tag, f, r) in [("d_x", &dx_f, &dx_s), ("d_w", &dw_f, &dw_s), ("d_b", &db_f, &db_s)]
            {
                for (i, (a, bb)) in f.iter().zip(r.iter()).enumerate() {
                    prop_assert!(
                        (a - bb).abs() <= 1e-5 * (1.0 + bb.abs()),
                        "{tag}[{i}]: {a} vs {bb} (b{b} {h}x{w}x{ic} k{k} oc{oc})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_dense_gemm_equals_reference() {
        let mut s = Scratch::new();
        check("dense-gemm-vs-reference", 48, |rng| {
            let bsz = 1 + rng.below(6);
            let din = 1 + rng.below(50);
            let dout = 1 + rng.below(20);
            let x: Vec<f32> = (0..bsz * din).map(|_| rng.normal() as f32 * 0.5).collect();
            let wt: Vec<f32> = (0..din * dout).map(|_| rng.normal() as f32 * 0.5).collect();
            let bias: Vec<f32> = (0..dout).map(|_| rng.normal() as f32 * 0.5).collect();
            let d_out: Vec<f32> = (0..bsz * dout).map(|_| rng.normal() as f32 * 0.5).collect();
            let fast = dense_fwd(&mut s, &x, bsz, din, dout, &wt, &bias, true);
            let slow = reference::dense_fwd(&x, bsz, din, dout, &wt, &bias, true);
            for (i, (a, bb)) in fast.iter().zip(&slow).enumerate() {
                prop_assert!(
                    (a - bb).abs() <= 1e-5 * (1.0 + bb.abs()),
                    "fwd[{i}]: {a} vs {bb} ({bsz}x{din}x{dout})"
                );
            }
            let (dx_f, dw_f, db_f) = dense_bwd(&mut s, &x, bsz, din, dout, &wt, &d_out);
            let (dx_s, dw_s, db_s) = reference::dense_bwd(&x, bsz, din, dout, &wt, &d_out);
            for (tag, f, r) in [("d_x", &dx_f, &dx_s), ("d_w", &dw_f, &dw_s), ("d_b", &db_f, &db_s)]
            {
                for (i, (a, bb)) in f.iter().zip(r.iter()).enumerate() {
                    prop_assert!(
                        (a - bb).abs() <= 1e-5 * (1.0 + bb.abs()),
                        "{tag}[{i}]: {a} vs {bb} ({bsz}x{din}x{dout})"
                    );
                }
            }
            Ok(())
        });
    }

    /// The scratch-arena purity contract (DESIGN.md): results are bitwise
    /// identical whatever stale garbage the arena carries — this is what
    /// lets per-worker arenas coexist with threads=N ≡ threads=1.
    #[test]
    fn results_do_not_depend_on_scratch_contents() {
        let x = gen_vec(X_CONV, 180);
        let w = gen_vec(W_CONV, 300);
        let b = gen_vec(B_CONV, 4);
        let d_out = gen_vec(DO_CONV, 240);
        let run = |s: &mut Scratch| {
            let fwd = conv2d_fwd(s, &x, CONV_G, &w, 5, 4, &b, true);
            let (dx, dw, db) = conv2d_bwd(s, &x, CONV_G, &w, 5, 4, &d_out);
            let dn = dense_fwd(s, &fwd[..20], 4, 5, 3, &w[..15], &b[..3], true);
            [fwd, dx, dw, db, dn].concat()
        };
        let clean = run(&mut Scratch::new());
        let mut dirty = Scratch::new();
        dirty.col = vec![f32::NAN; 7];
        dirty.dcol = vec![f32::NAN; 100_000];
        dirty.pa = vec![f32::NAN; 13];
        dirty.pb = vec![f32::NAN; 64];
        dirty.pw = vec![f32::NAN; 33]; // the hoisted packed-weight cache
        let poisoned = run(&mut dirty);
        assert_eq!(clean.len(), poisoned.len());
        for (i, (a, bb)) in clean.iter().zip(&poisoned).enumerate() {
            assert_eq!(a.to_bits(), bb.to_bits(), "[{i}]: {a} vs {bb} under dirty scratch");
        }
    }

    /// Panel-parallel dense forward is BITWISE the serial one for every
    /// split width — the eval path may fan dense GEMM columns out to idle
    /// workers without perturbing a single bit.
    #[test]
    fn dense_fwd_par_matches_serial_bitwise() {
        let (bsz, din, dout) = (32usize, 97usize, 130usize);
        let x = gen_vec(50_000, bsz * din);
        let wt = gen_vec(60_000, din * dout);
        let bias = gen_vec(70_000, dout);
        let mut s = Scratch::new();
        for relu in [false, true] {
            let want = dense_fwd(&mut s, &x, bsz, din, dout, &wt, &bias, relu);
            for par in [2usize, 3, 4, 7] {
                let got = dense_fwd_par(&mut s, &x, bsz, din, dout, &wt, &bias, relu, par);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "par {par} relu {relu} [{i}]: {g} vs serial {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn softmax_ce_matches_jax() {
        let logits: Vec<f32> = gen_vec(LOGITS, 40).iter().map(|&v| v * 4.0).collect();
        let mut y1h = vec![0.0f32; 40];
        for n in 0..4 {
            y1h[n * 10 + n % 10] = 1.0;
        }
        let (loss, d) = softmax_ce(&logits, &y1h, 4, 10);
        assert!(close(loss as f64, 3.093003273010254, 1e-5), "loss {loss}");
        let sumabs: f64 = d.iter().map(|&v| v.abs() as f64).sum();
        assert!(close(sumabs, 1.8301606494933367, 1e-4), "grad |sum| {sumabs}");
        // Each row of (p - y)/B sums to zero.
        for n in 0..4 {
            let s: f64 = d[n * 10..(n + 1) * 10].iter().map(|&v| v as f64).sum();
            assert!(s.abs() < 1e-6, "row {n} grad sum {s}");
        }
    }

    #[test]
    fn ce_loss_equals_softmax_ce_loss() {
        let logits: Vec<f32> = gen_vec(LOGITS, 40).iter().map(|&v| v * 4.0).collect();
        let mut y1h = vec![0.0f32; 40];
        for n in 0..4 {
            y1h[n * 10 + n % 10] = 1.0;
        }
        let (loss, _d) = softmax_ce(&logits, &y1h, 4, 10);
        assert_eq!(ce_loss(&logits, &y1h, 4, 10), loss);
    }

    #[test]
    fn relu_mask_zeroes_nonpositive_lanes() {
        let mut d = vec![1.0f32, 2.0, 3.0, 4.0];
        relu_mask(&mut d, &[0.5, 0.0, -1.0, 2.0]);
        assert_eq!(d, vec![1.0, 0.0, 0.0, 4.0]);
    }

    #[test]
    fn correct_count_ties_take_first_max() {
        // logits row 0 ties classes 0/1 -> argmax 0; y1h row 0 is class 0.
        let logits = vec![1.0f32, 1.0, 0.0, 0.0, 0.0, 1.0];
        let y1h = vec![1.0f32, 0.0, 0.0, 0.0, 0.0, 1.0];
        assert_eq!(correct_count(&logits, &y1h, 2, 3), 2.0);
    }

    #[test]
    fn softmax_rows_normalizes_each_row() {
        let mut x = gen_vec(80_000, 5 * 7).iter().map(|&v| v * 3.0).collect::<Vec<_>>();
        softmax_rows(&mut x, 5, 7);
        for (n, row) in x.chunks(7).enumerate() {
            let s: f64 = row.iter().map(|&v| v as f64).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {n} sums to {s}");
            assert!(row.iter().all(|&v| v >= 0.0), "row {n} has negative mass");
        }
    }

    #[test]
    fn patchify_roundtrips_and_conserves_gradient_mass() {
        let g = Geom { b: 2, h: 8, w: 4, c: 3 };
        let x = gen_vec(81_000, g.len());
        let p = patchify(&x, g, 2);
        assert_eq!(p.len(), x.len()); // pure permutation
        let back = unpatchify(&p, g, 2);
        assert_eq!(back, x);
        // Probe the layout: token 0 of image 0 starts at pixel (0,0).
        assert_eq!(p[0], x[0]);
        assert_eq!(&p[2 * 3..2 * 3 + 3], &x[4 * 3..4 * 3 + 3]); // (dy=1,dx=0)
    }

    /// Satellite acceptance: layernorm fast path ≡ scalar reference to
    /// 1e-5 on awkward row widths (including d=1, where var=0 and rstd
    /// saturates at 1/√ε).
    #[test]
    fn property_layernorm_equals_reference() {
        check("layernorm-vs-reference", 48, |rng| {
            let rows = 1 + rng.below(6);
            let d = 1 + rng.below(40);
            let x: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32 * 0.5).collect();
            let gamma: Vec<f32> = (0..d).map(|_| 1.0 + rng.normal() as f32 * 0.2).collect();
            let beta: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.5).collect();
            let dy: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32 * 0.5).collect();
            let (out_f, mean_f, rstd_f) = layernorm_fwd(&x, rows, d, &gamma, &beta);
            let (out_r, mean_r, rstd_r) = reference::layernorm_fwd(&x, rows, d, &gamma, &beta);
            // Error scale grows with rstd (tiny-variance rows amplify the
            // f32-vs-f64 statistics gap), so fold the worst row in.
            let amp = 1.0 + rstd_r.iter().fold(0.0f32, |a, &v| a.max(v));
            for (tag, f, r) in
                [("out", &out_f, &out_r), ("mean", &mean_f, &mean_r), ("rstd", &rstd_f, &rstd_r)]
            {
                for (i, (a, b)) in f.iter().zip(r.iter()).enumerate() {
                    prop_assert!(
                        (a - b).abs() <= 1e-5 * (1.0 + b.abs()) * amp,
                        "{tag}[{i}]: {a} vs {b} ({rows}x{d})"
                    );
                }
            }
            let (dx_f, dg_f, db_f) = layernorm_bwd(&x, &mean_f, &rstd_f, &gamma, rows, d, &dy);
            let (dx_r, dg_r, db_r) =
                reference::layernorm_bwd(&x, &mean_r, &rstd_r, &gamma, rows, d, &dy);
            for (tag, f, r) in [("d_x", &dx_f, &dx_r), ("d_g", &dg_f, &dg_r), ("d_b", &db_f, &db_r)]
            {
                for (i, (a, b)) in f.iter().zip(r.iter()).enumerate() {
                    prop_assert!(
                        (a - b).abs() <= 2e-5 * (1.0 + b.abs()) * amp,
                        "{tag}[{i}]: {a} vs {b} ({rows}x{d})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_gelu_equals_reference() {
        check("gelu-vs-reference", 48, |rng| {
            let n = 1 + rng.below(64);
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 2.0).collect();
            let d0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let y_f = gelu_fwd(&x);
            let y_r = reference::gelu_fwd(&x);
            for (i, (a, b)) in y_f.iter().zip(&y_r).enumerate() {
                prop_assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "fwd[{i}]: {a} vs {b}");
            }
            let mut d_f = d0.clone();
            gelu_bwd(&mut d_f, &x);
            let mut d_r = d0;
            reference::gelu_bwd(&mut d_r, &x);
            for (i, (a, b)) in d_f.iter().zip(&d_r).enumerate() {
                prop_assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "bwd[{i}]: {a} vs {b}");
            }
            Ok(())
        });
    }

    /// Satellite acceptance: the GEMM-path attention core ≡ the f64
    /// scalar reference to 1e-5 on awkward token counts / head widths.
    #[test]
    fn property_mhsa_equals_reference() {
        let mut s = Scratch::new();
        check("mhsa-vs-reference", 32, |rng| {
            let b = 1 + rng.below(2);
            let t = 1 + rng.below(9);
            let heads = 1 + rng.below(3);
            let dh = 1 + rng.below(9);
            let dm = heads * dh;
            let mk = |scale: f32| -> Vec<f32> {
                (0..b * t * dm).map(|_| rng.normal() as f32 * scale).collect()
            };
            let (q, k, v) = (mk(0.5), mk(0.5), mk(0.5));
            let d_cat = mk(0.5);
            let (p_f, cat_f) = mhsa_fwd(&mut s, &q, &k, &v, b, t, dm, heads);
            let (p_r, cat_r) = reference::mhsa_fwd(&q, &k, &v, b, t, dm, heads);
            for (tag, f, r) in [("probs", &p_f, &p_r), ("concat", &cat_f, &cat_r)] {
                for (i, (a, bb)) in f.iter().zip(r.iter()).enumerate() {
                    prop_assert!(
                        (a - bb).abs() <= 1e-5 * (1.0 + bb.abs()),
                        "{tag}[{i}]: {a} vs {bb} (b{b} t{t} h{heads} dh{dh})"
                    );
                }
            }
            let (dq_f, dk_f, dv_f) = mhsa_bwd(&mut s, &q, &k, &v, &p_f, &d_cat, b, t, dm, heads);
            let (dq_r, dk_r, dv_r) = reference::mhsa_bwd(&q, &k, &v, &p_r, &d_cat, b, t, dm, heads);
            for (tag, f, r) in [("d_q", &dq_f, &dq_r), ("d_k", &dk_f, &dk_r), ("d_v", &dv_f, &dv_r)]
            {
                for (i, (a, bb)) in f.iter().zip(r.iter()).enumerate() {
                    prop_assert!(
                        (a - bb).abs() <= 2e-5 * (1.0 + bb.abs()),
                        "{tag}[{i}]: {a} vs {bb} (b{b} t{t} h{heads} dh{dh})"
                    );
                }
            }
            Ok(())
        });
    }

    /// The scratch-purity contract extends to the attention staging
    /// buffers: NaN-poisoned gathers change nothing, bitwise.
    #[test]
    fn attention_does_not_depend_on_scratch_contents() {
        let (b, t, heads, dh) = (2usize, 5usize, 2usize, 4usize);
        let dm = heads * dh;
        let q = gen_vec(90_000, b * t * dm);
        let k = gen_vec(91_000, b * t * dm);
        let v = gen_vec(92_000, b * t * dm);
        let d_cat = gen_vec(93_000, b * t * dm);
        let run = |s: &mut Scratch| {
            let (p, cat) = mhsa_fwd(s, &q, &k, &v, b, t, dm, heads);
            let (dq, dk, dv) = mhsa_bwd(s, &q, &k, &v, &p, &d_cat, b, t, dm, heads);
            [p, cat, dq, dk, dv].concat()
        };
        let clean = run(&mut Scratch::new());
        let mut dirty = Scratch::new();
        dirty.pa = vec![f32::NAN; 13];
        dirty.pb = vec![f32::NAN; 64];
        dirty.qh = vec![f32::NAN; 1000];
        dirty.kh = vec![f32::NAN; 3];
        dirty.vh = vec![f32::NAN; 77];
        dirty.oh = vec![f32::NAN; 500];
        dirty.sd = vec![f32::NAN; 9];
        let poisoned = run(&mut dirty);
        assert_eq!(clean.len(), poisoned.len());
        for (i, (a, bb)) in clean.iter().zip(&poisoned).enumerate() {
            assert_eq!(a.to_bits(), bb.to_bits(), "[{i}]: {a} vs {bb} under dirty scratch");
        }
    }
}
