//! Typed execution facade: a backend-agnostic [`ModelRuntime`] that the
//! coordinator, figures and examples talk to.  The actual compute lives
//! behind the [`Backend`] trait — the pure-Rust [`NativeBackend`] by
//! default, the PJRT engine pool with `--features pjrt`.

use crate::model::{Manifest, ShapeSpec};
use crate::tensor::Params;

use super::backend::Backend;
use super::native::NativeBackend;
use super::tensor::Tensor;

/// All executable roles for one dataset shape, dispatched to a backend.
pub struct ModelRuntime {
    backend: Box<dyn Backend>,
}

impl ModelRuntime {
    /// Native pure-Rust runtime for `dataset` — works from a clean
    /// checkout with no artifacts, Python or PJRT.
    pub fn native(manifest: &Manifest, dataset: &str) -> anyhow::Result<Self> {
        let spec = manifest.for_dataset(dataset)?.clone();
        Ok(ModelRuntime { backend: Box::new(NativeBackend::new(spec)?) })
    }

    /// Wrap an already-constructed backend.
    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        ModelRuntime { backend }
    }

    /// PJRT runtime over the AOT artifacts (see `python/compile/aot.py`),
    /// pooled across [`super::engine::default_lanes`] engine threads.
    #[cfg(feature = "pjrt")]
    pub fn load(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        dataset: &str,
    ) -> anyhow::Result<Self> {
        let lanes = super::engine::default_lanes();
        Self::load_pooled(artifact_dir, manifest, dataset, lanes)
    }

    /// PJRT runtime with an explicit engine-pool size (1 = serial).
    #[cfg(feature = "pjrt")]
    pub fn load_pooled(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        dataset: &str,
        lanes: usize,
    ) -> anyhow::Result<Self> {
        let backend = super::engine::PjrtBackend::load(artifact_dir, manifest, dataset, lanes)?;
        Ok(ModelRuntime { backend: Box::new(backend) })
    }

    /// Backend name ("native", "pjrt") for logging and reports.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn spec(&self) -> &ShapeSpec {
        self.backend.spec()
    }

    /// Smashed data S = ℓ(w^c; x) — eq (1).
    pub fn client_fwd(&self, cut: usize, wc: &[Vec<f32>], x: &Tensor) -> anyhow::Result<Tensor> {
        self.backend.client_fwd(cut, wc, x)
    }

    /// Server FP+BP: returns (loss, server grads g^{s,n}, smashed grads
    /// s^n) — eqs (2)(3)(4).
    pub fn server_grad(
        &self,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.backend.server_grad(cut, ws, smashed, y1h)
    }

    /// Client BP with injected (aggregated) smashed-gradient — eq (6).
    pub fn client_grad(
        &self,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.backend.client_grad(cut, wc, x, g_smashed)
    }

    /// FL baseline: (loss, full gradient).
    pub fn full_grad(
        &self,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params)> {
        self.backend.full_grad(w, x, y1h)
    }

    /// Eval batch: (mean loss, correct count).
    pub fn eval(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, f32)> {
        self.backend.eval(w, x, y1h)
    }

    /// Train-batch input shape [batch, h, w, c].
    pub fn input_shape(&self, batch: usize) -> Vec<usize> {
        let mut s = vec![batch];
        s.extend_from_slice(&self.spec().input_shape);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_loads_every_dataset() {
        let m = Manifest::builtin();
        for ds in ["mnist", "fmnist", "cifar10"] {
            let rt = ModelRuntime::native(&m, ds).unwrap();
            assert_eq!(rt.backend_name(), "native");
            assert_eq!(rt.spec().key, m.datasets[ds]);
        }
        assert!(ModelRuntime::native(&m, "imagenet").is_err());
    }

    #[test]
    fn input_shape_prepends_batch() {
        let m = Manifest::builtin();
        let rt = ModelRuntime::native(&m, "cifar10").unwrap();
        assert_eq!(rt.input_shape(7), vec![7, 32, 32, 3]);
    }
}
