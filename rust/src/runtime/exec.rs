//! Typed execution facade: a backend-agnostic [`ModelRuntime`] that the
//! coordinator, figures and examples talk to, plus the [`ParallelExecutor`]
//! that fans independent per-client backend calls across scoped worker
//! threads, each owning a reusable kernel [`Scratch`](super::Scratch)
//! arena.  The actual
//! compute lives behind the [`Backend`] trait — the pure-Rust
//! [`NativeBackend`] by default, the PJRT engine pool with
//! `--features pjrt`.

use std::sync::mpsc;
use std::sync::Mutex;

use crate::model::{Manifest, ShapeSpec};
use crate::tensor::Params;

use super::backend::Backend;
use super::native::NativeBackend;
use super::scratch::ScratchHandle;
use super::tensor::Tensor;

/// Env var overriding the auto thread count (CI exercises the threaded
/// round engine by exporting `SFLGA_TEST_THREADS=4` over `cargo test`).
pub const THREADS_ENV: &str = "SFLGA_TEST_THREADS";

/// Resolve a requested worker-thread count: `0` means auto — the
/// [`THREADS_ENV`] override if set, else the machine's available
/// parallelism.  Any explicit `n >= 1` is taken verbatim.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var(THREADS_ENV) {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Fans independent per-index jobs (the per-client `client_fwd` /
/// `server_grad` / `client_grad` / `full_grad` calls of a round phase)
/// across `std::thread::scope` workers, in two flavors:
///
/// * [`ParallelExecutor::map`] / [`ParallelExecutor::map_with_scratch`] —
///   a bulk-synchronous fan-out: all `n` jobs are known up front, the
///   call returns when every one finished.  Worker `k` of `w` computes
///   indices `k, k+w, k+2w, …`.
/// * [`ParallelExecutor::session`] — the dependency-driven *pipelined*
///   API: jobs are submitted one at a time ([`TaskSession::submit`]) into
///   a shared queue, each returning a [`JobHandle`] (a per-job completion
///   channel).  Workers drain the queue as fast as their current job
///   allows, so a long chain submitted for participant 0 never stalls
///   participant 1's — the round engine fuses client-fwd → server FP/BP
///   (→ client-bwd) into ONE submitted chain per participant and only
///   barriers where the math does (the eq-5 broadcast aggregation).
///
/// The executor owns one kernel [`Scratch`](super::Scratch) arena per
/// worker thread; both APIs hand worker `k` its own arena handle, so the
/// backend's im2col/packing buffers are reused across every job a worker
/// runs, with zero cross-worker contention.
///
/// Determinism contract (both APIs): results come back in *submission /
/// index order* — `map` scatters into index slots, `session` buffers each
/// result in its handle's channel so the caller collects in whatever
/// fixed order it likes, regardless of completion order.  Jobs must be
/// pure functions of their inputs (the [`Backend`] contract: scratch
/// contents never influence results), so which worker runs a job — and
/// when it completes relative to its peers — cannot affect any value.
/// That makes `threads = N` bitwise equal to `threads = 1` even though
/// the pipelined path executes jobs in a nondeterministic real-time
/// order (`tests/determinism.rs`).
pub struct ParallelExecutor {
    threads: usize,
    /// One arena per worker; `arenas[k]` is only ever locked by worker
    /// `k` during a `map_with_scratch` call (and by the caller thread on
    /// the serial path, which uses `arenas[0]`).
    arenas: Vec<ScratchHandle>,
}

impl ParallelExecutor {
    /// `requested = 0` → auto (see [`resolve_threads`]); `1` → run every
    /// job inline on the caller thread (no spawns at all).
    pub fn new(requested: usize) -> ParallelExecutor {
        let threads = resolve_threads(requested);
        let arenas = (0..threads).map(|_| ScratchHandle::new()).collect();
        ParallelExecutor { threads, arenas }
    }

    /// The resolved worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Compute `f(0..n)`, in parallel when the executor has more than one
    /// worker, returning results in index order.  The first error (in
    /// index order of the worker that hit it) aborts the round.
    pub fn map<T, F>(&self, n: usize, f: F) -> anyhow::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> anyhow::Result<T> + Sync,
    {
        self.map_with_scratch(n, |_, i| f(i))
    }

    /// [`ParallelExecutor::map`] where each job additionally receives its
    /// worker's scratch arena — the round engine's hot path (backends
    /// reuse kernel intermediates across all the jobs a worker runs).
    pub fn map_with_scratch<T, F>(&self, n: usize, f: F) -> anyhow::Result<Vec<T>>
    where
        T: Send,
        F: Fn(&ScratchHandle, usize) -> anyhow::Result<T> + Sync,
    {
        let w = self.threads.min(n);
        if w <= 1 {
            let scratch = &self.arenas[0];
            return (0..n).map(|i| f(scratch, i)).collect();
        }
        let f = &f;
        let arenas = &self.arenas;
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        std::thread::scope(|s| -> anyhow::Result<()> {
            let handles: Vec<_> = (0..w)
                .map(|k| {
                    s.spawn(move || -> anyhow::Result<Vec<(usize, T)>> {
                        let scratch = &arenas[k];
                        (k..n).step_by(w).map(|i| Ok((i, f(scratch, i)?))).collect()
                    })
                })
                .collect();
            for h in handles {
                let part = h.join().expect("round worker panicked")?;
                for (i, v) in part {
                    out[i] = Some(v);
                }
            }
            Ok(())
        })?;
        Ok(out.into_iter().map(|v| v.expect("worker skipped an index")).collect())
    }

    /// Open a pipelined task session: `f` receives a [`TaskSession`] it
    /// can [`submit`](TaskSession::submit) jobs into at any point; every
    /// submitted job runs on one of this executor's workers (each with
    /// its own scratch arena) and reports through its [`JobHandle`].
    ///
    /// Unlike [`ParallelExecutor::map`], there is no per-phase barrier:
    /// a job starts the moment a worker frees up, so independent chains
    /// overlap and late submissions (e.g. a deferred evaluation) ride the
    /// same queue as the round's fan-out.  The session itself IS a
    /// barrier at close: `session` returns only after every submitted job
    /// completed (scoped-thread join), so borrows captured by jobs are
    /// released when the call returns.  Handles may outlive the session —
    /// each buffers its result — which is how the round engine collects a
    /// deferred eval submitted into an earlier phase.
    ///
    /// With one thread, `submit` runs each job eagerly inline (arena 0) —
    /// the fully serial schedule the determinism suite compares against.
    pub fn session<'env, R>(
        &'env self,
        f: impl FnOnce(&TaskSession<'env>) -> anyhow::Result<R>,
    ) -> anyhow::Result<R> {
        if self.threads <= 1 {
            return f(&TaskSession { tx: None, serial_arena: Some(&self.arenas[0]) });
        }
        let (tx, rx) = mpsc::channel::<Job<'env>>();
        let queue = Mutex::new(rx);
        std::thread::scope(|s| {
            for arena in &self.arenas {
                let queue = &queue;
                s.spawn(move || {
                    loop {
                        // Dequeue under the lock, run with it released.
                        let job = {
                            let q = queue.lock().expect("session queue poisoned");
                            q.recv()
                        };
                        match job {
                            Ok(job) => job(arena),
                            Err(_) => break, // session closed and queue drained
                        }
                    }
                });
            }
            let sess = TaskSession { tx: Some(tx), serial_arena: None };
            f(&sess)
            // `sess` (and its Sender) drop here; workers drain what is
            // left in the queue, then exit; the scope joins them all.
        })
    }
}

// ---------------------------------------------------------------- sessions

/// A queued unit of work: runs on some worker with that worker's arena.
type Job<'env> = Box<dyn FnOnce(&ScratchHandle) + Send + 'env>;

/// A pipelined job-submission scope (see [`ParallelExecutor::session`]).
/// Jobs submitted here may borrow anything that outlives the `session`
/// call — the round engine submits zero-copy closures over the live
/// `wc`/`ws` parameter slices exactly like the `map` path.
pub struct TaskSession<'env> {
    /// Parallel path: the shared job queue feeding the session's workers.
    tx: Option<mpsc::Sender<Job<'env>>>,
    /// Serial path (`threads == 1`): jobs execute eagerly on this arena
    /// at submit time — the reference schedule.
    serial_arena: Option<&'env ScratchHandle>,
}

impl<'env> TaskSession<'env> {
    /// Submit one job; returns its completion channel.  Jobs are started
    /// in submission order but complete in any order; the handle buffers
    /// the result, so collecting handles in submission order yields an
    /// in-order reduction over out-of-order completions.
    pub fn submit<T, F>(&self, job: F) -> JobHandle<T>
    where
        T: Send + 'env,
        F: FnOnce(&ScratchHandle) -> anyhow::Result<T> + Send + 'env,
    {
        if let Some(arena) = self.serial_arena {
            return JobHandle { rx: None, eager: Some(job(arena)) };
        }
        let (rtx, rrx) = mpsc::channel();
        let boxed: Job<'env> = Box::new(move |scratch| {
            // A dropped receiver just means the caller abandoned the
            // handle (e.g. an earlier job already errored the round).
            let _ = rtx.send(job(scratch));
        });
        self.tx
            .as_ref()
            .expect("parallel session has a queue")
            .send(boxed)
            .expect("session workers exited before the session closed");
        JobHandle { rx: Some(rrx), eager: None }
    }
}

/// One submitted job's completion channel ([`TaskSession::submit`]).
/// `wait` blocks until the job's result lands (or returns immediately on
/// the serial path / once the result is buffered).
pub struct JobHandle<T> {
    rx: Option<mpsc::Receiver<anyhow::Result<T>>>,
    eager: Option<anyhow::Result<T>>,
}

impl<T> JobHandle<T> {
    /// Block for this job's result.  Consumes the handle: one job, one
    /// completion.
    pub fn wait(mut self) -> anyhow::Result<T> {
        if let Some(r) = self.eager.take() {
            return r;
        }
        match self.rx.take().expect("job handle has a channel").recv() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("pipelined job dropped without completing (worker panicked)"),
        }
    }
}

/// All executable roles for one dataset shape, dispatched to a backend.
pub struct ModelRuntime {
    backend: Box<dyn Backend>,
}

impl ModelRuntime {
    /// Native pure-Rust runtime for `dataset` — works from a clean
    /// checkout with no artifacts, Python or PJRT.
    pub fn native(manifest: &Manifest, dataset: &str) -> anyhow::Result<Self> {
        let spec = manifest.for_dataset(dataset)?.clone();
        Ok(ModelRuntime { backend: Box::new(NativeBackend::new(spec)?) })
    }

    /// Wrap an already-constructed backend.
    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        ModelRuntime { backend }
    }

    /// PJRT runtime over the AOT artifacts (see `python/compile/aot.py`),
    /// pooled across [`super::engine::default_lanes`] engine threads.
    #[cfg(feature = "pjrt")]
    pub fn load(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        dataset: &str,
    ) -> anyhow::Result<Self> {
        let lanes = super::engine::default_lanes();
        Self::load_pooled(artifact_dir, manifest, dataset, lanes)
    }

    /// PJRT runtime with an explicit engine-pool size (1 = serial).
    #[cfg(feature = "pjrt")]
    pub fn load_pooled(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        dataset: &str,
        lanes: usize,
    ) -> anyhow::Result<Self> {
        let backend = super::engine::PjrtBackend::load(artifact_dir, manifest, dataset, lanes)?;
        Ok(ModelRuntime { backend: Box::new(backend) })
    }

    /// Backend name ("native", "pjrt") for logging and reports.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Whether the backend accepts arbitrary leading batch sizes (see
    /// [`Backend::dynamic_batch`]).
    pub fn dynamic_batch(&self) -> bool {
        self.backend.dynamic_batch()
    }

    pub fn spec(&self) -> &ShapeSpec {
        self.backend.spec()
    }

    /// Smashed data S = ℓ(w^c; x) — eq (1).
    pub fn client_fwd(&self, cut: usize, wc: &[Vec<f32>], x: &Tensor) -> anyhow::Result<Tensor> {
        self.backend.client_fwd(cut, wc, x)
    }

    /// [`ModelRuntime::client_fwd`] with a worker scratch arena.
    pub fn client_fwd_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
    ) -> anyhow::Result<Tensor> {
        self.backend.client_fwd_with(scratch, cut, wc, x)
    }

    /// Server FP+BP: returns (loss, server grads g^{s,n}, smashed grads
    /// s^n) — eqs (2)(3)(4).
    pub fn server_grad(
        &self,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.backend.server_grad(cut, ws, smashed, y1h)
    }

    /// [`ModelRuntime::server_grad`] with a worker scratch arena.
    pub fn server_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.backend.server_grad_with(scratch, cut, ws, smashed, y1h)
    }

    /// Client BP with injected (aggregated) smashed-gradient — eq (6).
    pub fn client_grad(
        &self,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.backend.client_grad(cut, wc, x, g_smashed)
    }

    /// [`ModelRuntime::client_grad`] with a worker scratch arena.
    pub fn client_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.backend.client_grad_with(scratch, cut, wc, x, g_smashed)
    }

    /// FL baseline: (loss, full gradient).
    pub fn full_grad(
        &self,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params)> {
        self.backend.full_grad(w, x, y1h)
    }

    /// [`ModelRuntime::full_grad`] with a worker scratch arena.
    pub fn full_grad_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params)> {
        self.backend.full_grad_with(scratch, w, x, y1h)
    }

    /// Eval batch: (mean loss, correct count).
    pub fn eval(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, f32)> {
        self.backend.eval(w, x, y1h)
    }

    /// [`ModelRuntime::eval`] with a worker scratch arena.
    pub fn eval_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, f32)> {
        self.backend.eval_with(scratch, w, x, y1h)
    }

    /// Train-batch input shape [batch, h, w, c].
    pub fn input_shape(&self, batch: usize) -> Vec<usize> {
        let mut s = vec![batch];
        s.extend_from_slice(&self.spec().input_shape);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_loads_every_dataset() {
        let m = Manifest::builtin();
        for ds in ["mnist", "fmnist", "cifar10"] {
            let rt = ModelRuntime::native(&m, ds).unwrap();
            assert_eq!(rt.backend_name(), "native");
            assert_eq!(rt.spec().key, m.datasets[ds]);
        }
        assert!(ModelRuntime::native(&m, "imagenet").is_err());
    }

    #[test]
    fn input_shape_prepends_batch() {
        let m = Manifest::builtin();
        let rt = ModelRuntime::native(&m, "cifar10").unwrap();
        assert_eq!(rt.input_shape(7), vec![7, 32, 32, 3]);
    }

    #[test]
    fn parallel_map_preserves_index_order() {
        for threads in [1usize, 2, 4, 7] {
            let ex = ParallelExecutor::new(threads);
            assert_eq!(ex.threads(), threads);
            let got = ex.map(11, |i| Ok(i * i)).unwrap();
            assert_eq!(got, (0..11).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn parallel_map_handles_fewer_jobs_than_workers() {
        let ex = ParallelExecutor::new(8);
        assert_eq!(ex.map(1, |i| Ok(i + 40)).unwrap(), vec![40]);
        assert_eq!(ex.map(0, |i| Ok(i)).unwrap(), Vec::<usize>::new());
    }

    #[test]
    fn parallel_map_propagates_errors() {
        let ex = ParallelExecutor::new(4);
        let res: anyhow::Result<Vec<usize>> =
            ex.map(10, |i| if i == 6 { anyhow::bail!("job {i} failed") } else { Ok(i) });
        assert!(res.unwrap_err().to_string().contains("job 6"));
    }

    #[test]
    fn map_with_scratch_hands_each_worker_one_arena() {
        // Workers leave a breadcrumb in their arena: every job a worker
        // ran must have seen the same arena, and arenas stay warm across
        // map calls (the reuse property the kernels rely on).
        let ex = ParallelExecutor::new(3);
        let marks = ex
            .map_with_scratch(9, |scratch, i| {
                let mut s = scratch.lock();
                s.col.push(i as f32);
                Ok(s.col.len())
            })
            .unwrap();
        // 9 jobs over 3 workers: each arena saw exactly 3 jobs, so the
        // per-arena lengths are a permutation-in-slots of 1..=3.
        let total: usize = {
            let mut per_arena_final = std::collections::BTreeMap::new();
            for (i, &len) in marks.iter().enumerate() {
                per_arena_final.insert(i % 3, len);
            }
            per_arena_final.values().sum()
        };
        assert_eq!(total, 9, "each of 3 arenas should end at 3 pushes: {marks:?}");
        // A second map reuses the same arenas (warm buffers).
        let lens = ex.map_with_scratch(3, |scratch, _| Ok(scratch.lock().col.len())).unwrap();
        assert!(lens.iter().all(|&l| l >= 3), "arenas were not reused: {lens:?}");
    }

    #[test]
    fn serial_map_with_scratch_uses_one_arena() {
        let ex = ParallelExecutor::new(1);
        ex.map_with_scratch(5, |scratch, i| {
            let mut s = scratch.lock();
            s.pa.push(i as f32);
            Ok(())
        })
        .unwrap();
        // All five jobs funneled through arena 0.
        let len = ex.map_with_scratch(1, |scratch, _| Ok(scratch.lock().pa.len())).unwrap()[0];
        assert_eq!(len, 5);
    }

    #[test]
    fn resolve_threads_prefers_explicit_request() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    /// The pipelining property itself: job 0 is slow, jobs 1..n are fast,
    /// so completions arrive OUT of submission order (fast jobs do not
    /// wait behind the slow one — no phase barrier), yet collecting the
    /// handles in submission order still yields an in-order reduction.
    #[test]
    fn session_reduces_in_order_over_out_of_order_completions() {
        let ex = ParallelExecutor::new(4);
        let completion_order = std::sync::Mutex::new(Vec::new());
        let results = ex
            .session(|sess| {
                let handles: Vec<_> = (0..8usize)
                    .map(|i| {
                        let order = &completion_order;
                        sess.submit(move |_| {
                            if i == 0 {
                                std::thread::sleep(std::time::Duration::from_millis(60));
                            }
                            order.lock().unwrap().push(i);
                            Ok(i * i)
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
            })
            .unwrap();
        assert_eq!(results, (0..8).map(|i| i * i).collect::<Vec<_>>());
        let order = completion_order.into_inner().unwrap();
        assert_eq!(order.len(), 8);
        // With 4 workers and job 0 sleeping, some fast job finished first:
        // phase fusion is demonstrably active (no barrier on job 0).
        assert_ne!(order[0], 0, "job 0 slept 60ms yet completed first — jobs were serialized");
    }

    #[test]
    fn serial_session_runs_jobs_eagerly_in_submission_order() {
        let ex = ParallelExecutor::new(1);
        let completion_order = std::sync::Mutex::new(Vec::new());
        let results = ex
            .session(|sess| {
                let handles: Vec<_> = (0..5usize)
                    .map(|i| {
                        let order = &completion_order;
                        sess.submit(move |_| {
                            order.lock().unwrap().push(i);
                            Ok(i + 10)
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
            })
            .unwrap();
        assert_eq!(results, vec![10, 11, 12, 13, 14]);
        assert_eq!(*completion_order.lock().unwrap(), (0..5).collect::<Vec<_>>());
    }

    #[test]
    fn session_propagates_job_errors_and_runs_the_rest() {
        for threads in [1usize, 3] {
            let ex = ParallelExecutor::new(threads);
            let outcome: anyhow::Result<Vec<usize>> = ex.session(|sess| {
                let handles: Vec<_> = (0..6usize)
                    .map(|i| {
                        sess.submit(move |_| {
                            if i == 2 {
                                anyhow::bail!("job {i} failed");
                            }
                            Ok(i)
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect()
            });
            assert!(outcome.unwrap_err().to_string().contains("job 2"));
        }
    }

    /// Handles buffer their results, so a handle may be collected AFTER
    /// its session closed — the deferred-eval pattern the round engine
    /// uses to overlap round t's evaluation with round t+1's fan-out.
    #[test]
    fn job_handles_outlive_their_session() {
        for threads in [1usize, 4] {
            let ex = ParallelExecutor::new(threads);
            let handle = ex
                .session(|sess| {
                    let h = sess.submit(|_| Ok(41));
                    let inline = sess.submit(|_| Ok(1)).wait()?;
                    Ok((h, inline))
                })
                .unwrap();
            let (h, inline) = handle;
            assert_eq!(inline, 1);
            assert_eq!(h.wait().unwrap(), 41);
        }
    }

    #[test]
    fn session_jobs_draw_from_the_executor_arenas() {
        let ex = ParallelExecutor::new(2);
        // Each job leaves one breadcrumb in whatever arena its worker
        // owns; across all arenas every job must have run exactly once.
        ex.session(|sess| {
            let handles: Vec<_> = (0..6usize)
                .map(|i| {
                    sess.submit(move |scratch| {
                        scratch.lock().dcol.push(i as f32);
                        Ok(())
                    })
                })
                .collect();
            handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
        })
        .unwrap();
        let total: usize = ex.arenas.iter().map(|a| a.lock().dcol.len()).sum();
        assert_eq!(total, 6, "every session job must land in exactly one worker arena");
        // A later map call reuses the same (now warm) arenas.
        let lens = ex.map_with_scratch(2, |scratch, _| Ok(scratch.lock().dcol.len())).unwrap();
        assert!(lens.iter().any(|&l| l > 0), "session arenas were not reused: {lens:?}");
    }

    /// A fused chain (several backend calls in one submitted job) on a
    /// multi-worker session gives the same values as the serial path.
    #[test]
    fn fused_chains_match_serial_bitwise() {
        let run = |threads: usize| -> Vec<f64> {
            let ex = ParallelExecutor::new(threads);
            ex.session(|sess| {
                let handles: Vec<_> = (0..5usize)
                    .map(|i| {
                        sess.submit(move |_| {
                            // Stage 1 then stage 2, chained with no barrier.
                            let a = (i as f64 + 1.0).sqrt();
                            let b = a.ln() + a * 3.0;
                            Ok(b)
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect()
            })
            .unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(
            serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            parallel.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
    }
}
