//! Typed execution facade: binds the manifest's artifact roles to the
//! engine and converts between coordinator state (`tensor::Params`) and
//! engine tensors.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::engine::Engine;
use super::tensor::Tensor;
use crate::model::{Manifest, ShapeSpec, CUT_ROLES, NUM_CUTS};
use crate::tensor::Params;

/// Default engine-pool size: PJRT executables are single-lane per engine
/// thread, so N independent clients' compute parallelizes across lanes.
pub fn default_lanes() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(2).clamp(1, 4))
        .unwrap_or(1)
}

/// All compiled computations for one dataset shape, with typed wrappers
/// for the five artifact roles.  Holds a pool of engines (each owning its
/// own PJRT client + compiled executables); calls are distributed
/// round-robin, so independent per-client executions run concurrently.
pub struct ModelRuntime {
    engines: Vec<Engine>,
    next: AtomicUsize,
    spec: ShapeSpec,
}

impl ModelRuntime {
    /// Compile every artifact of `dataset`'s shape (12 per-cut + 2 global)
    /// on `default_lanes()` engines.
    pub fn load(artifact_dir: &Path, manifest: &Manifest, dataset: &str) -> anyhow::Result<Self> {
        Self::load_pooled(artifact_dir, manifest, dataset, default_lanes())
    }

    /// Compile on an explicit number of engine lanes (1 = serial).
    pub fn load_pooled(
        artifact_dir: &Path,
        manifest: &Manifest,
        dataset: &str,
        lanes: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(lanes > 0, "need at least one engine lane");
        let spec = manifest.for_dataset(dataset)?.clone();
        let mut entries = Vec::new();
        for cut in &spec.cuts {
            for role in CUT_ROLES {
                entries.push((
                    format!("v{}_{role}", cut.cut),
                    cut.artifacts[role].clone(),
                ));
            }
        }
        for (role, file) in &spec.artifacts {
            entries.push((role.clone(), file.clone()));
        }
        let engines = (0..lanes)
            .map(|_| Engine::load_artifacts(artifact_dir, &entries))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ModelRuntime { engines, next: AtomicUsize::new(0), spec })
    }

    pub fn lanes(&self) -> usize {
        self.engines.len()
    }

    fn engine(&self) -> &Engine {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.engines.len();
        &self.engines[i]
    }

    pub fn spec(&self) -> &ShapeSpec {
        &self.spec
    }

    fn params_to_tensors(&self, params: &Params, offset: usize) -> Vec<Tensor> {
        params
            .iter()
            .enumerate()
            .map(|(i, buf)| Tensor::new(buf.clone(), self.spec.params[offset + i].shape.clone()))
            .collect()
    }

    /// Smashed data S = ℓ(w^c; x) — eq (1).
    pub fn client_fwd(&self, cut: usize, wc: &Params, x: &Tensor) -> anyhow::Result<Tensor> {
        self.check_cut(cut)?;
        let mut inputs = self.params_to_tensors(wc, 0);
        inputs.push(x.clone());
        let mut out = self.engine().handle().execute(&format!("v{cut}_client_fwd"), inputs)?;
        anyhow::ensure!(out.len() == 1, "client_fwd returned {} outputs", out.len());
        Ok(out.pop().unwrap())
    }

    /// Server FP+BP: returns (loss, server grads g^{s,n}, smashed grads s^n)
    /// — eqs (2)(3)(4).
    pub fn server_grad(
        &self,
        cut: usize,
        ws: &Params,
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.check_cut(cut)?;
        let nc = self.spec.cut(cut).client_params;
        let mut inputs = self.params_to_tensors(ws, nc);
        inputs.push(smashed.clone());
        inputs.push(y1h.clone());
        let mut out = self.engine().handle().execute(&format!("v{cut}_server_grad"), inputs)?;
        let n_server = self.spec.params.len() - nc;
        anyhow::ensure!(
            out.len() == 1 + n_server + 1,
            "server_grad returned {} outputs, expected {}",
            out.len(),
            2 + n_server
        );
        let g_smashed = out.pop().unwrap();
        let loss = out[0].item();
        let g_ws: Params = out.drain(1..).map(|t| t.data).collect();
        Ok((loss, g_ws, g_smashed))
    }

    /// Client BP with injected (aggregated) smashed-gradient — eq (6).
    pub fn client_grad(
        &self,
        cut: usize,
        wc: &Params,
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.check_cut(cut)?;
        let mut inputs = self.params_to_tensors(wc, 0);
        inputs.push(x.clone());
        inputs.push(g_smashed.clone());
        let out = self.engine().handle().execute(&format!("v{cut}_client_grad"), inputs)?;
        anyhow::ensure!(out.len() == wc.len(), "client_grad output arity mismatch");
        Ok(out.into_iter().map(|t| t.data).collect())
    }

    /// FL baseline: (loss, full gradient).
    pub fn full_grad(&self, w: &Params, x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, Params)> {
        let mut inputs = self.params_to_tensors(w, 0);
        inputs.push(x.clone());
        inputs.push(y1h.clone());
        let mut out = self.engine().handle().execute("full_grad", inputs)?;
        anyhow::ensure!(out.len() == 1 + w.len(), "full_grad output arity mismatch");
        let loss = out[0].item();
        let g: Params = out.drain(1..).map(|t| t.data).collect();
        Ok((loss, g))
    }

    /// Eval batch: (mean loss, correct count).
    pub fn eval(&self, w: &Params, x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, f32)> {
        let mut inputs = self.params_to_tensors(w, 0);
        inputs.push(x.clone());
        inputs.push(y1h.clone());
        let out = self.engine().handle().execute("eval", inputs)?;
        anyhow::ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((out[0].item(), out[1].item()))
    }

    fn check_cut(&self, cut: usize) -> anyhow::Result<()> {
        anyhow::ensure!((1..=NUM_CUTS).contains(&cut), "cut {cut} out of range");
        Ok(())
    }

    /// Train-batch input shape [batch, h, w, c].
    pub fn input_shape(&self, batch: usize) -> Vec<usize> {
        let mut s = vec![batch];
        s.extend_from_slice(&self.spec.input_shape);
        s
    }
}
