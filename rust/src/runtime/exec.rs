//! Typed execution facade: a backend-agnostic [`ModelRuntime`] that the
//! coordinator, figures and examples talk to, plus the [`ParallelExecutor`]
//! that fans independent per-client backend calls across a PERSISTENT
//! worker pool — spawned once at construction, each worker owning a
//! reusable kernel [`Scratch`](super::Scratch) arena for its whole
//! lifetime.  The actual compute lives behind the [`Backend`] trait — the
//! pure-Rust [`NativeBackend`] by default, the PJRT engine pool with
//! `--features pjrt`.

use std::marker::PhantomData;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::model::{Manifest, ShapeSpec};
use crate::tensor::Params;

use super::backend::Backend;
use super::native::NativeBackend;
use super::scratch::ScratchHandle;
use super::tensor::Tensor;

/// Env var overriding the auto thread count (CI exercises the threaded
/// round engine by exporting `SFLGA_TEST_THREADS=4` over `cargo test`).
pub const THREADS_ENV: &str = "SFLGA_TEST_THREADS";

/// Resolve a requested worker-thread count: `0` means auto — the
/// [`THREADS_ENV`] override if set, else the machine's available
/// parallelism.  Any explicit `n >= 1` is taken verbatim.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var(THREADS_ENV) {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Fans independent per-index jobs (the per-client `client_fwd` /
/// `server_grad` / `client_grad` / `full_grad` calls of a round phase)
/// across a PERSISTENT worker pool, in two flavors:
///
/// * [`ParallelExecutor::map`] / [`ParallelExecutor::map_with_scratch`] —
///   a bulk-synchronous fan-out: all `n` jobs are known up front, the
///   call returns when every one finished, results in index order.
/// * [`ParallelExecutor::session`] — the dependency-driven *pipelined*
///   API: jobs are submitted one at a time ([`TaskSession::submit`]) into
///   the pool queue, each returning a [`JobHandle`] (a per-job completion
///   channel).  Workers drain the queue as fast as their current job
///   allows, so a long chain submitted for participant 0 never stalls
///   participant 1's — the round engine fuses client-fwd → server FP/BP
///   (→ client-bwd) into ONE submitted chain per participant and only
///   barriers where the math does (the eq-5 broadcast aggregation).
///
/// Pool lifecycle: `new` spawns `threads` OS workers ONCE; they live
/// until the executor drops (which closes the queue and joins them).
/// Worker `k` owns `arenas[k]` — one kernel
/// [`Scratch`](super::Scratch) arena per worker — for its whole lifetime,
/// so the backend's im2col/packing buffers stay warm across every map
/// call, session, and round of training, with zero cross-worker
/// contention and zero per-session thread spawns.  A session is just a
/// QUEUE EPOCH: submitted jobs carry a ticket on the session's completion
/// counter, and closing the session blocks until the count drains to
/// zero — that drain is the barrier that lets jobs borrow caller state
/// (`'env`) while the queue itself is `'static`.
///
/// Determinism contract (both APIs): results come back in *submission /
/// index order* — `map` collects handles in index order, `session`
/// buffers each result in its handle's channel so the caller collects in
/// whatever fixed order it likes, regardless of completion order.  Jobs
/// must be pure functions of their inputs (the [`Backend`] contract:
/// scratch contents never influence results), so which worker runs a job
/// — and when it completes relative to its peers — cannot affect any
/// value.  That makes `threads = N` bitwise equal to `threads = 1` even
/// though the pool executes jobs in a nondeterministic real-time order
/// (`tests/determinism.rs`).
///
/// A panicking job does NOT kill its worker: the panic is caught, the
/// job's waiter gets a "worker panicked" error from
/// [`JobHandle::wait`], and the pool keeps serving (`pool_survives_job_panics`).
pub struct ParallelExecutor {
    threads: usize,
    /// One arena per worker; worker `k` holds a clone of `arenas[k]` and
    /// is its only hot-path locker (the caller thread uses `arenas[0]`
    /// directly on the serial path).
    arenas: Vec<ScratchHandle>,
    /// Sending half of the persistent pool queue (`None` when
    /// `threads <= 1`: the serial path never spawns).  Dropped first in
    /// `Drop` to end every worker's `recv` loop.
    injector: Option<mpsc::Sender<PoolJob>>,
    /// The pool threads, joined on drop.
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ParallelExecutor {
    /// `requested = 0` → auto (see [`resolve_threads`]); `1` → run every
    /// job inline on the caller thread (no spawns at all).
    pub fn new(requested: usize) -> ParallelExecutor {
        let threads = resolve_threads(requested);
        let arenas: Vec<ScratchHandle> = (0..threads).map(|_| ScratchHandle::new()).collect();
        let (injector, workers) = if threads > 1 {
            let (tx, rx) = mpsc::channel::<PoolJob>();
            let queue = Arc::new(Mutex::new(rx));
            let workers = arenas
                .iter()
                .map(|arena| {
                    let queue = Arc::clone(&queue);
                    let arena = arena.clone();
                    std::thread::spawn(move || {
                        loop {
                            // Dequeue under the lock, run with it released.
                            let job = {
                                let q = queue.lock().unwrap_or_else(|e| e.into_inner());
                                q.recv()
                            };
                            match job {
                                // Catch job panics so one bad job cannot
                                // kill the worker for the rest of the
                                // process: the job's epoch ticket and
                                // result sender drop inside the catch, so
                                // its waiter errors and its session still
                                // drains.
                                Ok(job) => {
                                    let _ = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(|| job(&arena)),
                                    );
                                }
                                Err(_) => break, // executor dropped: queue closed
                            }
                        }
                    })
                })
                .collect();
            (Some(tx), workers)
        } else {
            (None, Vec::new())
        };
        ParallelExecutor { threads, arenas, injector, workers }
    }

    /// The resolved worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Compute `f(0..n)`, in parallel when the executor has more than one
    /// worker, returning results in index order.  The first error (in
    /// index order) aborts the round.
    pub fn map<T, F>(&self, n: usize, f: F) -> anyhow::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> anyhow::Result<T> + Sync,
    {
        self.map_with_scratch(n, |_, i| f(i))
    }

    /// [`ParallelExecutor::map`] where each job additionally receives its
    /// worker's scratch arena — the round engine's hot path (backends
    /// reuse kernel intermediates across all the jobs a worker runs).
    /// Implemented as one [`ParallelExecutor::session`] submitting all
    /// `n` jobs up front and collecting the handles in index order.
    pub fn map_with_scratch<T, F>(&self, n: usize, f: F) -> anyhow::Result<Vec<T>>
    where
        T: Send,
        F: Fn(&ScratchHandle, usize) -> anyhow::Result<T> + Sync,
    {
        if self.threads <= 1 || n <= 1 {
            let scratch = &self.arenas[0];
            return (0..n).map(|i| f(scratch, i)).collect();
        }
        let f = &f;
        self.session(|sess| {
            let handles: Vec<_> =
                (0..n).map(|i| sess.submit(move |scratch| f(scratch, i))).collect();
            handles.into_iter().map(JobHandle::wait).collect()
        })
    }

    /// Open a pipelined task session: `f` receives a [`TaskSession`] it
    /// can [`submit`](TaskSession::submit) jobs into at any point; every
    /// submitted job runs on one of the pool's persistent workers (each
    /// with its own scratch arena) and reports through its [`JobHandle`].
    ///
    /// Unlike a per-phase barrier, a job starts the moment a worker frees
    /// up, so independent chains overlap and late submissions (e.g. a
    /// deferred evaluation) ride the same queue as the round's fan-out.
    /// The session itself IS a barrier at close: `session` returns only
    /// after every submitted job completed (the epoch drain), so borrows
    /// captured by jobs are released when the call returns.  Handles may
    /// outlive the session — each buffers its result — which is how the
    /// round engine collects a deferred eval submitted into an earlier
    /// phase.
    ///
    /// With one thread, `submit` runs each job eagerly inline (arena 0) —
    /// the fully serial schedule the determinism suite compares against.
    pub fn session<'env, R>(
        &'env self,
        f: impl FnOnce(&TaskSession<'env>) -> anyhow::Result<R>,
    ) -> anyhow::Result<R> {
        if self.threads <= 1 {
            return f(&TaskSession {
                injector: None,
                epoch: None,
                serial_arena: Some(&self.arenas[0]),
                _variance: PhantomData,
            });
        }
        let epoch = Arc::new(EpochState::default());
        // Declared BEFORE `sess` so that on unwind the session drops
        // first and the guard still blocks until every already-submitted
        // job finished — only then may the `'env` borrows those jobs
        // captured go away.
        let drain = DrainGuard(&epoch);
        let sess = TaskSession {
            injector: self.injector.as_ref(),
            epoch: Some(Arc::clone(&epoch)),
            serial_arena: None,
            _variance: PhantomData,
        };
        let out = f(&sess);
        drop(sess);
        drop(drain); // the epoch barrier: all submitted jobs completed
        out
    }
}

impl Drop for ParallelExecutor {
    fn drop(&mut self) {
        // Closing the injector ends every worker's `recv` loop; join so
        // no detached thread outlives the executor.
        drop(self.injector.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------- sessions

/// A queued unit of work: runs on some pool worker with that worker's
/// arena.  `'env` is the lifetime of the borrows the job captures.
type EnvJob<'env> = Box<dyn FnOnce(&ScratchHandle) + Send + 'env>;

/// What actually travels through the persistent pool queue: a
/// lifetime-erased [`EnvJob`] (see the SAFETY argument in
/// [`TaskSession::submit`] — the session's epoch drain is what makes the
/// erasure sound).
type PoolJob = EnvJob<'static>;

/// One session's completion accounting: `outstanding` counts submitted-
/// but-unfinished jobs; the session close blocks on it reaching zero.
#[derive(Default)]
struct EpochState {
    outstanding: Mutex<usize>,
    done: Condvar,
}

impl EpochState {
    /// Poison-tolerant lock: the counter is updated in tiny panic-free
    /// sections, and the drain runs in `Drop` where a second panic would
    /// abort the process.
    fn count(&self) -> MutexGuard<'_, usize> {
        self.outstanding.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn enter(&self) {
        *self.count() += 1;
    }

    fn exit(&self) {
        let mut n = self.count();
        *n -= 1;
        if *n == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every entered job has exited.
    fn drain(&self) {
        let mut n = self.count();
        while *n > 0 {
            n = self.done.wait(n).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Held by an in-flight job; dropping it (normal return, panic, or the
/// job never reaching a worker) exits the epoch — the drain barrier
/// counts COMPLETION, not submission.
struct EpochTicket(Arc<EpochState>);

impl Drop for EpochTicket {
    fn drop(&mut self) {
        self.0.exit();
    }
}

/// Blocks on the session's epoch when dropped — including during unwind,
/// so a panicking session body still waits for its in-flight jobs before
/// their borrows die.
struct DrainGuard<'a>(&'a EpochState);

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        self.0.drain();
    }
}

/// A pipelined job-submission scope (see [`ParallelExecutor::session`]).
/// Jobs submitted here may borrow anything that outlives the `session`
/// call — the round engine submits zero-copy closures over the live
/// `wc`/`ws` parameter slices exactly like the `map` path.
pub struct TaskSession<'env> {
    /// Parallel path: the executor's persistent pool queue.
    injector: Option<&'env mpsc::Sender<PoolJob>>,
    /// Parallel path: this session's completion epoch — every submitted
    /// job holds a ticket; session close drains to zero.
    epoch: Option<Arc<EpochState>>,
    /// Serial path (`threads == 1`): jobs execute eagerly on this arena
    /// at submit time — the reference schedule.
    serial_arena: Option<&'env ScratchHandle>,
    /// Force invariance in `'env`: jobs are lifetime-erased on their way
    /// into the `'static` pool queue ([`TaskSession::submit`]'s
    /// transmute), so the compiler must never be allowed to shrink a
    /// session's `'env` and admit shorter-lived borrows.
    _variance: PhantomData<fn(&'env ()) -> &'env ()>,
}

impl<'env> TaskSession<'env> {
    /// Submit one job; returns its completion channel.  Jobs are started
    /// in submission order but complete in any order; the handle buffers
    /// the result, so collecting handles in submission order yields an
    /// in-order reduction over out-of-order completions.
    pub fn submit<T, F>(&self, job: F) -> JobHandle<T>
    where
        T: Send + 'env,
        F: FnOnce(&ScratchHandle) -> anyhow::Result<T> + Send + 'env,
    {
        if let Some(arena) = self.serial_arena {
            return JobHandle { rx: None, eager: Some(job(arena)) };
        }
        let epoch = self.epoch.as_ref().expect("parallel session has an epoch");
        epoch.enter();
        let ticket = EpochTicket(Arc::clone(epoch));
        let (rtx, rrx) = mpsc::channel();
        let boxed: EnvJob<'env> = Box::new(move |scratch| {
            // The ticket drops (epoch exit) only after the job body AND
            // the result send, panics included — the drain barrier counts
            // real completion.  A dropped receiver just means the caller
            // abandoned the handle (e.g. an earlier job already errored
            // the round).
            let _ticket = ticket;
            let _ = rtx.send(job(scratch));
        });
        // SAFETY: erasing `'env` to `'static` is sound because no borrow
        // the job captures can end before the job has fully run: (1) the
        // session's `DrainGuard` blocks the `session` call (normal return
        // AND unwind) until this job's ticket dropped, i.e. until after
        // the closure executed or was destroyed unrun; (2) `'env` strictly
        // outlives that `session` call — it is a universal region of
        // `ParallelExecutor::session`, bounded below by the drain; (3)
        // `TaskSession` is invariant in `'env` (`_variance`), so callers
        // cannot shrink the session's region to sneak in shorter-lived
        // borrows; (4) the queue itself (`&'env self`) outlives the
        // session.  The erased job thus never observes a dangling
        // reference even though its type says `'static`.
        let job = unsafe { std::mem::transmute::<EnvJob<'env>, PoolJob>(boxed) };
        let sent = self
            .injector
            .expect("parallel session has the pool injector")
            .send(job);
        // A send failure returns the job — dropping it releases the
        // ticket, so the session cannot deadlock on a dead pool.
        sent.expect("executor workers exited before the session closed");
        JobHandle { rx: Some(rrx), eager: None }
    }
}

/// One submitted job's completion channel ([`TaskSession::submit`]).
/// `wait` blocks until the job's result lands (or returns immediately on
/// the serial path / once the result is buffered).
pub struct JobHandle<T> {
    rx: Option<mpsc::Receiver<anyhow::Result<T>>>,
    eager: Option<anyhow::Result<T>>,
}

impl<T> JobHandle<T> {
    /// Block for this job's result.  Consumes the handle: one job, one
    /// completion.
    pub fn wait(mut self) -> anyhow::Result<T> {
        if let Some(r) = self.eager.take() {
            return r;
        }
        match self.rx.take().expect("job handle has a channel").recv() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("pipelined job dropped without completing (worker panicked)"),
        }
    }
}

/// All executable roles for one dataset shape, dispatched to a backend.
pub struct ModelRuntime {
    backend: Box<dyn Backend>,
}

impl ModelRuntime {
    /// Native pure-Rust runtime for `dataset` — works from a clean
    /// checkout with no artifacts, Python or PJRT.
    pub fn native(manifest: &Manifest, dataset: &str) -> anyhow::Result<Self> {
        let spec = manifest.for_dataset(dataset)?.clone();
        Ok(ModelRuntime { backend: Box::new(NativeBackend::new(spec)?) })
    }

    /// Wrap an already-constructed backend.
    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        ModelRuntime { backend }
    }

    /// PJRT runtime over the AOT artifacts (see `python/compile/aot.py`),
    /// pooled across [`super::engine::default_lanes`] engine threads.
    #[cfg(feature = "pjrt")]
    pub fn load(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        dataset: &str,
    ) -> anyhow::Result<Self> {
        let lanes = super::engine::default_lanes();
        Self::load_pooled(artifact_dir, manifest, dataset, lanes)
    }

    /// PJRT runtime with an explicit engine-pool size (1 = serial).
    #[cfg(feature = "pjrt")]
    pub fn load_pooled(
        artifact_dir: &std::path::Path,
        manifest: &Manifest,
        dataset: &str,
        lanes: usize,
    ) -> anyhow::Result<Self> {
        let backend = super::engine::PjrtBackend::load(artifact_dir, manifest, dataset, lanes)?;
        Ok(ModelRuntime { backend: Box::new(backend) })
    }

    /// Backend name ("native", "pjrt") for logging and reports.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Whether the backend accepts arbitrary leading batch sizes (see
    /// [`Backend::dynamic_batch`]).
    pub fn dynamic_batch(&self) -> bool {
        self.backend.dynamic_batch()
    }

    pub fn spec(&self) -> &ShapeSpec {
        self.backend.spec()
    }

    /// Smashed data S = ℓ(w^c; x) — eq (1).
    pub fn client_fwd(&self, cut: usize, wc: &[Vec<f32>], x: &Tensor) -> anyhow::Result<Tensor> {
        self.backend.client_fwd(cut, wc, x)
    }

    /// [`ModelRuntime::client_fwd`] with a worker scratch arena.
    pub fn client_fwd_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
    ) -> anyhow::Result<Tensor> {
        self.backend.client_fwd_with(scratch, cut, wc, x)
    }

    /// Server FP+BP: returns (loss, server grads g^{s,n}, smashed grads
    /// s^n) — eqs (2)(3)(4).
    pub fn server_grad(
        &self,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.backend.server_grad(cut, ws, smashed, y1h)
    }

    /// [`ModelRuntime::server_grad`] with a worker scratch arena.
    pub fn server_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.backend.server_grad_with(scratch, cut, ws, smashed, y1h)
    }

    /// Client BP with injected (aggregated) smashed-gradient — eq (6).
    pub fn client_grad(
        &self,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.backend.client_grad(cut, wc, x, g_smashed)
    }

    /// [`ModelRuntime::client_grad`] with a worker scratch arena.
    pub fn client_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.backend.client_grad_with(scratch, cut, wc, x, g_smashed)
    }

    /// FL baseline: (loss, full gradient).
    pub fn full_grad(
        &self,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params)> {
        self.backend.full_grad(w, x, y1h)
    }

    /// [`ModelRuntime::full_grad`] with a worker scratch arena.
    pub fn full_grad_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params)> {
        self.backend.full_grad_with(scratch, w, x, y1h)
    }

    /// Eval batch: (mean loss, correct count).
    pub fn eval(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, f32)> {
        self.backend.eval(w, x, y1h)
    }

    /// [`ModelRuntime::eval`] with a worker scratch arena.
    pub fn eval_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, f32)> {
        self.backend.eval_with(scratch, w, x, y1h)
    }

    /// Grant eval calls up to `workers` internal threads — see
    /// [`Backend::set_eval_parallelism`]; bitwise-neutral by contract.
    pub fn set_eval_parallelism(&self, workers: usize) {
        self.backend.set_eval_parallelism(workers);
    }

    /// Train-batch input shape [batch, h, w, c].
    pub fn input_shape(&self, batch: usize) -> Vec<usize> {
        let mut s = vec![batch];
        s.extend_from_slice(&self.spec().input_shape);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_loads_every_dataset() {
        let m = Manifest::builtin();
        for ds in ["mnist", "fmnist", "cifar10"] {
            let rt = ModelRuntime::native(&m, ds).unwrap();
            assert_eq!(rt.backend_name(), "native");
            assert_eq!(rt.spec().key, m.datasets[ds]);
        }
        assert!(ModelRuntime::native(&m, "imagenet").is_err());
    }

    #[test]
    fn input_shape_prepends_batch() {
        let m = Manifest::builtin();
        let rt = ModelRuntime::native(&m, "cifar10").unwrap();
        assert_eq!(rt.input_shape(7), vec![7, 32, 32, 3]);
    }

    #[test]
    fn parallel_map_preserves_index_order() {
        for threads in [1usize, 2, 4, 7] {
            let ex = ParallelExecutor::new(threads);
            assert_eq!(ex.threads(), threads);
            let got = ex.map(11, |i| Ok(i * i)).unwrap();
            assert_eq!(got, (0..11).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn parallel_map_handles_fewer_jobs_than_workers() {
        let ex = ParallelExecutor::new(8);
        assert_eq!(ex.map(1, |i| Ok(i + 40)).unwrap(), vec![40]);
        assert_eq!(ex.map(0, |i| Ok(i)).unwrap(), Vec::<usize>::new());
    }

    #[test]
    fn parallel_map_propagates_errors() {
        let ex = ParallelExecutor::new(4);
        let res: anyhow::Result<Vec<usize>> =
            ex.map(10, |i| if i == 6 { anyhow::bail!("job {i} failed") } else { Ok(i) });
        assert!(res.unwrap_err().to_string().contains("job 6"));
    }

    #[test]
    fn map_with_scratch_hands_each_worker_one_arena() {
        // Jobs leave a breadcrumb in whichever worker arena they ran on:
        // across the pool's arenas every job must have landed exactly
        // once (queue scheduling is dynamic, so no per-index assignment
        // is assumed), and the arenas stay warm across map calls — the
        // reuse property the kernels rely on.
        let ex = ParallelExecutor::new(3);
        ex.map_with_scratch(9, |scratch, i| {
            scratch.lock().col.push(i as f32);
            Ok(())
        })
        .unwrap();
        let total: usize = ex.arenas.iter().map(|a| a.lock().col.len()).sum();
        assert_eq!(total, 9, "every job must land in exactly one worker arena");
        // A second map draws from the SAME (now warm) arenas: it pushes
        // nothing, and the breadcrumb total is unchanged.
        ex.map_with_scratch(3, |scratch, _| Ok(scratch.lock().col.len())).unwrap();
        let total: usize = ex.arenas.iter().map(|a| a.lock().col.len()).sum();
        assert_eq!(total, 9, "arenas were not reused warm across map calls");
    }

    /// The pool is persistent: the same OS threads serve every map call
    /// and session over the executor's lifetime — no per-session spawns.
    #[test]
    fn pool_workers_persist_across_sessions() {
        let ex = ParallelExecutor::new(3);
        let ids = std::sync::Mutex::new(std::collections::HashSet::new());
        for _ in 0..2 {
            ex.session(|sess| {
                let handles: Vec<_> = (0..6usize)
                    .map(|_| {
                        let ids = &ids;
                        sess.submit(move |_| {
                            ids.lock().unwrap().insert(std::thread::current().id());
                            Ok(())
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
            })
            .unwrap();
        }
        let ids = ids.into_inner().unwrap();
        assert!(
            !ids.is_empty() && ids.len() <= 3,
            "12 jobs across 2 sessions ran on {} distinct threads (pool has 3)",
            ids.len()
        );
    }

    /// A panicking job must not take down its pool worker: the waiter
    /// gets an error, the session still closes, and the executor keeps
    /// serving afterwards.
    #[test]
    fn pool_survives_job_panics() {
        let ex = ParallelExecutor::new(2);
        let err = ex
            .session(|sess| {
                let bad = sess.submit(|_| -> anyhow::Result<usize> { panic!("job exploded") });
                let good = sess.submit(|_| Ok(7usize));
                assert_eq!(good.wait()?, 7);
                bad.wait()
            })
            .unwrap_err();
        assert!(err.to_string().contains("worker panicked"), "unexpected error: {err}");
        // Both workers are still alive for subsequent calls.
        assert_eq!(ex.map(4, |i| Ok(i * 2)).unwrap(), vec![0, 2, 4, 6]);
    }

    #[test]
    fn serial_map_with_scratch_uses_one_arena() {
        let ex = ParallelExecutor::new(1);
        ex.map_with_scratch(5, |scratch, i| {
            let mut s = scratch.lock();
            s.pa.push(i as f32);
            Ok(())
        })
        .unwrap();
        // All five jobs funneled through arena 0.
        let len = ex.map_with_scratch(1, |scratch, _| Ok(scratch.lock().pa.len())).unwrap()[0];
        assert_eq!(len, 5);
    }

    #[test]
    fn resolve_threads_prefers_explicit_request() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    /// The pipelining property itself: job 0 is slow, jobs 1..n are fast,
    /// so completions arrive OUT of submission order (fast jobs do not
    /// wait behind the slow one — no phase barrier), yet collecting the
    /// handles in submission order still yields an in-order reduction.
    #[test]
    fn session_reduces_in_order_over_out_of_order_completions() {
        let ex = ParallelExecutor::new(4);
        let completion_order = std::sync::Mutex::new(Vec::new());
        let results = ex
            .session(|sess| {
                let handles: Vec<_> = (0..8usize)
                    .map(|i| {
                        let order = &completion_order;
                        sess.submit(move |_| {
                            if i == 0 {
                                std::thread::sleep(std::time::Duration::from_millis(60));
                            }
                            order.lock().unwrap().push(i);
                            Ok(i * i)
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
            })
            .unwrap();
        assert_eq!(results, (0..8).map(|i| i * i).collect::<Vec<_>>());
        let order = completion_order.into_inner().unwrap();
        assert_eq!(order.len(), 8);
        // With 4 workers and job 0 sleeping, some fast job finished first:
        // phase fusion is demonstrably active (no barrier on job 0).
        assert_ne!(order[0], 0, "job 0 slept 60ms yet completed first — jobs were serialized");
    }

    #[test]
    fn serial_session_runs_jobs_eagerly_in_submission_order() {
        let ex = ParallelExecutor::new(1);
        let completion_order = std::sync::Mutex::new(Vec::new());
        let results = ex
            .session(|sess| {
                let handles: Vec<_> = (0..5usize)
                    .map(|i| {
                        let order = &completion_order;
                        sess.submit(move |_| {
                            order.lock().unwrap().push(i);
                            Ok(i + 10)
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
            })
            .unwrap();
        assert_eq!(results, vec![10, 11, 12, 13, 14]);
        assert_eq!(*completion_order.lock().unwrap(), (0..5).collect::<Vec<_>>());
    }

    #[test]
    fn session_propagates_job_errors_and_runs_the_rest() {
        for threads in [1usize, 3] {
            let ex = ParallelExecutor::new(threads);
            let outcome: anyhow::Result<Vec<usize>> = ex.session(|sess| {
                let handles: Vec<_> = (0..6usize)
                    .map(|i| {
                        sess.submit(move |_| {
                            if i == 2 {
                                anyhow::bail!("job {i} failed");
                            }
                            Ok(i)
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect()
            });
            assert!(outcome.unwrap_err().to_string().contains("job 2"));
        }
    }

    /// Handles buffer their results, so a handle may be collected AFTER
    /// its session closed — the deferred-eval pattern the round engine
    /// uses to overlap round t's evaluation with round t+1's fan-out.
    #[test]
    fn job_handles_outlive_their_session() {
        for threads in [1usize, 4] {
            let ex = ParallelExecutor::new(threads);
            let handle = ex
                .session(|sess| {
                    let h = sess.submit(|_| Ok(41));
                    let inline = sess.submit(|_| Ok(1)).wait()?;
                    Ok((h, inline))
                })
                .unwrap();
            let (h, inline) = handle;
            assert_eq!(inline, 1);
            assert_eq!(h.wait().unwrap(), 41);
        }
    }

    #[test]
    fn session_jobs_draw_from_the_executor_arenas() {
        let ex = ParallelExecutor::new(2);
        // Each job leaves one breadcrumb in whatever arena its worker
        // owns; across all arenas every job must have run exactly once.
        ex.session(|sess| {
            let handles: Vec<_> = (0..6usize)
                .map(|i| {
                    sess.submit(move |scratch| {
                        scratch.lock().dcol.push(i as f32);
                        Ok(())
                    })
                })
                .collect();
            handles.into_iter().map(JobHandle::wait).collect::<anyhow::Result<Vec<_>>>()
        })
        .unwrap();
        let total: usize = ex.arenas.iter().map(|a| a.lock().dcol.len()).sum();
        assert_eq!(total, 6, "every session job must land in exactly one worker arena");
        // A later map call draws from the same (now warm) arenas: it adds
        // no breadcrumbs, so the total is unchanged.
        ex.map_with_scratch(2, |scratch, _| Ok(scratch.lock().dcol.len())).unwrap();
        let total: usize = ex.arenas.iter().map(|a| a.lock().dcol.len()).sum();
        assert_eq!(total, 6, "session arenas were not reused warm");
    }

    /// A fused chain (several backend calls in one submitted job) on a
    /// multi-worker session gives the same values as the serial path.
    #[test]
    fn fused_chains_match_serial_bitwise() {
        let run = |threads: usize| -> Vec<f64> {
            let ex = ParallelExecutor::new(threads);
            ex.session(|sess| {
                let handles: Vec<_> = (0..5usize)
                    .map(|i| {
                        sess.submit(move |_| {
                            // Stage 1 then stage 2, chained with no barrier.
                            let a = (i as f64 + 1.0).sqrt();
                            let b = a.ln() + a * 3.0;
                            Ok(b)
                        })
                    })
                    .collect();
                handles.into_iter().map(JobHandle::wait).collect()
            })
            .unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(
            serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            parallel.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
    }
}
