//! The participant-side state machine: one [`ParticipantNode`] services
//! the compute half of the protocol — eq-1 client forwards, eq-6
//! client-side VJPs and FL local steps — against its own lazily-derived
//! batch stream.
//!
//! This is the SAME code whether the node runs inside the coordinator
//! process (the loopback transport) or behind a TCP socket in the
//! `sfl-participant` binary: both paths call [`ParticipantNode::handle`]
//! on decoded [`Msg`] values.  Since the wire encoding is bit-exact for
//! f32 (`protocol::wire`) and the node's kernels are the deterministic
//! native backend, loopback and TCP runs are bitwise identical by
//! construction — the property `tests/net_equivalence.rs` pins.
//!
//! A node is stateless across rounds except for the ONE in-flight
//! forward context a [`Msg::BwdReq`] resolves by `seq`: the coordinator
//! owns every model parameter and every reduction (see
//! DESIGN.md §Transport).

use crate::data::partition::Partition;
use crate::data::population::ClientSampler;
use crate::model::registry;
use crate::protocol::{Msg, RunSetup, PROTO_VERSION};
use crate::runtime::{ModelRuntime, Tensor};
use crate::tensor::{self, Params};

/// The forward context cached between a [`Msg::FwdReq`] and its
/// [`Msg::BwdReq`]: the VJP needs the same weights and batch the forward
/// ran on.  At most one is in flight per participant (the coordinator's
/// per-epoch fwd→bwd discipline); a fresh FwdReq replaces a stale one,
/// so round restarts after a fault need no extra reset handshake.
struct FwdCtx {
    seq: u64,
    cut: usize,
    wc: Params,
    x: Tensor,
}

/// Per-run state configured by [`Msg::Welcome`].
struct NodeState {
    rt: ModelRuntime,
    sampler: ClientSampler,
    ctx: Option<FwdCtx>,
}

/// One participant's protocol engine; see the module docs.
pub struct ParticipantNode {
    id: u64,
    state: Option<NodeState>,
}

impl ParticipantNode {
    pub fn new(id: u64) -> ParticipantNode {
        ParticipantNode { id, state: None }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// The Join message this node opens its session with.
    pub fn join_msg(&self) -> Msg {
        Msg::Join { client: self.id, version: PROTO_VERSION }
    }

    /// Whether a [`Msg::Welcome`] has configured this node.
    pub fn ready(&self) -> bool {
        self.state.is_some()
    }

    fn state(&mut self) -> anyhow::Result<&mut NodeState> {
        self.state
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("request before welcome (node not configured)"))
    }

    /// Service one coordinator message; returns the responses to send
    /// back (empty for control messages).  An `Err` is a protocol
    /// violation — the TCP binary exits on it (the coordinator observes
    /// the drop), the loopback transport surfaces it as a gone peer.
    pub fn handle(&mut self, msg: &Msg) -> anyhow::Result<Vec<Msg>> {
        match msg {
            Msg::Welcome { setup } => {
                self.configure(setup)?;
                Ok(Vec::new())
            }
            // Mid-run admission accept: configure exactly as a Welcome
            // does (participants are stateless between rounds, so a
            // rejoiner needs no model state — the round index is carried
            // by every FwdReq/FullReq's step key) and drop any forward
            // context a previous session left behind.
            Msg::Sync { setup, .. } => {
                self.configure(setup)?;
                Ok(Vec::new())
            }
            Msg::FwdReq { seq, cut, step, wc } => {
                let id = self.id;
                let st = self.state()?;
                // The decoder only checks cut ≥ 1; membership in the
                // peer-agreed menu is validated here, against the model
                // the RunSetup configured.
                let cut = st.rt.spec().menu().validate(*cut as usize)?;
                let nc = st.rt.spec().cut(cut).client_params;
                anyhow::ensure!(
                    wc.len() == nc,
                    "fwd-req at cut {cut} carries {} layers, client side has {nc}",
                    wc.len()
                );
                // The participant derives its OWN batch — a pure function
                // of (seed, client, step), bitwise the batch the
                // in-process trainer materializes for this client.
                let (x, labels) = st.sampler.batch(id, *step);
                let smashed = st.rt.client_fwd(cut, wc, &x)?;
                st.ctx = Some(FwdCtx { seq: *seq, cut, wc: wc.clone(), x });
                Ok(vec![Msg::FwdOk { seq: *seq, smashed, labels }])
            }
            Msg::BwdReq { seq, cotangent } => {
                let st = self.state()?;
                let ctx = st
                    .ctx
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("bwd-req with no forward in flight"))?;
                anyhow::ensure!(
                    ctx.seq == *seq,
                    "bwd-req seq {seq} does not match in-flight forward seq {}",
                    ctx.seq
                );
                let grad = st.rt.client_grad(ctx.cut, &ctx.wc, &ctx.x, cotangent)?;
                Ok(vec![Msg::BwdOk { seq: *seq, grad }])
            }
            Msg::FullReq { seq, step0, tau, lr, w } => {
                let id = self.id;
                let st = self.state()?;
                // Exactly the trainer's FL local-step loop: per-epoch
                // batch → full grad → SGD step, loss τ-averaged in f64.
                let mut w = w.clone();
                let mut loss_sum = 0.0f64;
                for e in 0..*tau as u64 {
                    let (x, y) = st.sampler.batch(id, step0 + e);
                    let (loss, g) = st.rt.full_grad(&w, &x, &y)?;
                    loss_sum += loss as f64;
                    tensor::sgd_step(&mut w, &g, *lr);
                }
                Ok(vec![Msg::FullOk { seq: *seq, loss: loss_sum / *tau as f64, w }])
            }
            Msg::RoundDone { .. } => {
                if let Some(st) = self.state.as_mut() {
                    st.ctx = None;
                }
                Ok(Vec::new())
            }
            Msg::Shutdown => Ok(Vec::new()),
            other => anyhow::bail!("unexpected {} message at a participant", other.name()),
        }
    }

    fn configure(&mut self, setup: &RunSetup) -> anyhow::Result<()> {
        let manifest = registry::manifest(&setup.model)?;
        let rt = ModelRuntime::native(&manifest, &setup.dataset)?;
        // Both binaries resolve the menu from the model id independently;
        // the announced length pins them to the same registry vintage.
        anyhow::ensure!(
            rt.spec().num_cuts() == setup.num_cuts as usize,
            "model '{}' has {} cuts here, coordinator announced {}",
            setup.model,
            rt.spec().num_cuts(),
            setup.num_cuts
        );
        let sampler = ClientSampler::new(
            rt.spec(),
            &setup.dataset,
            Partition::parse(&setup.partition)?,
            setup.samples_per_client,
            setup.seed,
        );
        self.state = Some(NodeState { rt, sampler, ctx: None });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> RunSetup {
        RunSetup {
            dataset: "mnist".into(),
            seed: 17,
            partition: "iid".into(),
            samples_per_client: 64,
            model: "builtin".into(),
            num_cuts: 4,
        }
    }

    fn welcomed(id: u64) -> ParticipantNode {
        let mut node = ParticipantNode::new(id);
        node.handle(&Msg::Welcome { setup: setup() }).unwrap();
        node
    }

    #[test]
    fn fwd_bwd_cycle_produces_client_grad() {
        let mut node = welcomed(0);
        let manifest = crate::model::Manifest::builtin();
        let rt = ModelRuntime::native(&manifest, "mnist").unwrap();
        let cut = 2usize;
        let nc = rt.spec().cut(cut).client_params;
        let w0 = crate::data::init::init_params(rt.spec(), 17 ^ 0x1417);
        let wc: Params = w0[..nc].to_vec();

        let out = node
            .handle(&Msg::FwdReq { seq: 5, cut: cut as u32, step: 0, wc: wc.clone() })
            .unwrap();
        let (smashed, labels) = match &out[..] {
            [Msg::FwdOk { seq: 5, smashed, labels }] => (smashed.clone(), labels.clone()),
            other => panic!("unexpected response {other:?}"),
        };
        // The node's forward matches a direct backend call bitwise.
        let sampler = ClientSampler::new(rt.spec(), "mnist", Partition::Iid, 64, 17);
        let (x, y) = sampler.batch(0, 0);
        assert_eq!(smashed, rt.client_fwd(cut, &wc, &x).unwrap());
        assert_eq!(labels, y);

        let cot = Tensor::new(vec![0.01; smashed.len()], smashed.shape.clone());
        let out = node.handle(&Msg::BwdReq { seq: 5, cotangent: cot.clone() }).unwrap();
        match &out[..] {
            [Msg::BwdOk { seq: 5, grad }] => {
                assert_eq!(grad, &rt.client_grad(cut, &wc, &x, &cot).unwrap());
            }
            other => panic!("unexpected response {other:?}"),
        }
        // Context consumed: a second bwd-req is a protocol violation.
        assert!(node.handle(&Msg::BwdReq { seq: 5, cotangent: cot }).is_err());
    }

    #[test]
    fn protocol_violations_are_errors() {
        let mut node = ParticipantNode::new(1);
        assert!(!node.ready());
        // Any compute request before Welcome fails.
        assert!(node
            .handle(&Msg::FwdReq { seq: 0, cut: 1, step: 0, wc: Params::new() })
            .is_err());
        let mut node = welcomed(1);
        assert!(node.ready());
        // Wrong layer count for the cut.
        assert!(node.handle(&Msg::FwdReq { seq: 0, cut: 2, step: 0, wc: Params::new() }).is_err());
        // Seq mismatch between fwd and bwd.
        let manifest = crate::model::Manifest::builtin();
        let rt = ModelRuntime::native(&manifest, "mnist").unwrap();
        let nc = rt.spec().cut(1).client_params;
        let wc = crate::data::init::init_params(rt.spec(), 17 ^ 0x1417)[..nc].to_vec();
        node.handle(&Msg::FwdReq { seq: 7, cut: 1, step: 0, wc }).unwrap();
        let bad = Tensor::new(vec![0.0], vec![1]);
        assert!(node.handle(&Msg::BwdReq { seq: 8, cotangent: bad }).is_err());
        // A coordinator-bound message arriving at a participant.
        assert!(node.handle(&Msg::Join { client: 0, version: PROTO_VERSION }).is_err());
    }

    #[test]
    fn sync_configures_and_clears_inflight_context() {
        // A fresh node is configured by Sync exactly as by Welcome…
        let mut node = ParticipantNode::new(3);
        node.handle(&Msg::Sync { round: 2, setup: setup() }).unwrap();
        assert!(node.ready());
        // …and a Sync on an already-running node (coordinator-blip
        // rejoin) drops any stale forward context.
        let manifest = crate::model::Manifest::builtin();
        let rt = ModelRuntime::native(&manifest, "mnist").unwrap();
        let nc = rt.spec().cut(1).client_params;
        let wc = crate::data::init::init_params(rt.spec(), 17 ^ 0x1417)[..nc].to_vec();
        node.handle(&Msg::FwdReq { seq: 9, cut: 1, step: 0, wc }).unwrap();
        node.handle(&Msg::Sync { round: 3, setup: setup() }).unwrap();
        let cot = Tensor::new(vec![0.0], vec![1]);
        assert!(node.handle(&Msg::BwdReq { seq: 9, cotangent: cot }).is_err());
    }

    #[test]
    fn out_of_menu_cut_is_a_clean_error() {
        // The decoder lets any cut ≥ 1 through; the node is the menu
        // gate.  builtin has 4 cuts, so 5 must be rejected, not panic.
        let mut node = welcomed(4);
        let err = node
            .handle(&Msg::FwdReq { seq: 0, cut: 5, step: 0, wc: Params::new() })
            .unwrap_err()
            .to_string();
        assert!(err.contains("menu"), "{err}");
    }

    #[test]
    fn menu_length_mismatch_is_rejected_at_configure() {
        let mut node = ParticipantNode::new(5);
        let mut s = setup();
        s.num_cuts = 7; // coordinator from a different registry vintage
        let err = node.handle(&Msg::Welcome { setup: s }).unwrap_err().to_string();
        assert!(err.contains("announced"), "{err}");
        assert!(!node.ready());
    }

    #[test]
    fn nonbuiltin_model_configures_from_the_registry() {
        let mut node = ParticipantNode::new(6);
        let mut s = setup();
        s.model = "txf".into();
        s.num_cuts = 3;
        node.handle(&Msg::Welcome { setup: s }).unwrap();
        assert!(node.ready());
        // A builtin-menu cut past txf's 3-cut menu is now out of range.
        assert!(node
            .handle(&Msg::FwdReq { seq: 0, cut: 4, step: 0, wc: Params::new() })
            .is_err());
    }

    #[test]
    fn round_done_clears_inflight_context() {
        let mut node = welcomed(2);
        let manifest = crate::model::Manifest::builtin();
        let rt = ModelRuntime::native(&manifest, "mnist").unwrap();
        let nc = rt.spec().cut(1).client_params;
        let wc = crate::data::init::init_params(rt.spec(), 17 ^ 0x1417)[..nc].to_vec();
        node.handle(&Msg::FwdReq { seq: 3, cut: 1, step: 0, wc }).unwrap();
        node.handle(&Msg::RoundDone { round: 0 }).unwrap();
        let cot = Tensor::new(vec![0.0], vec![1]);
        assert!(node.handle(&Msg::BwdReq { seq: 3, cotangent: cot }).is_err());
    }
}
