//! Coordinator-side transport abstraction (DESIGN.md §Transport): how the
//! networked trainer reaches its participants.
//!
//! [`Transport`] exposes exactly what the fault-tolerant round engine
//! needs — send a [`Msg`] to a participant, await the next inbound event
//! with a timeout, and drop a peer from the live set.  Two
//! implementations:
//!
//! * [`TcpTransport`] — real processes over length-prefixed TCP frames.
//!   One reader thread per peer feeds a single event queue; a closed or
//!   broken connection surfaces as [`Incoming::Gone`], which the round
//!   engine treats like a deadline miss (drop + renormalize).
//! * [`LoopbackTransport`] — in-process [`ParticipantNode`]s driven over
//!   the existing [`ParallelExecutor`] fan-out (`map` runs on the
//!   persistent worker pool's session path).  `send` buffers requests;
//!   `recv` flushes the batch in ONE parallel sweep and queues the
//!   responses **in ascending participant order**.  Delivery order is
//!   deterministic and the compute is the same [`ParticipantNode`] code
//!   the TCP binary runs, so loopback ≡ TCP bitwise and the executor's
//!   threads=N ≡ 1 guarantee carries over unchanged.
//!
//! The round engine never relies on arrival order (responses are slotted
//! by participant id and reduced in ascending order), so the two
//! implementations — and any delivery timing chaos injects on the TCP
//! one — are observationally identical below the deadline.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::protocol::wire::{read_frame, write_frame};
use crate::protocol::{Msg, PROTO_VERSION};
use crate::runtime::node::ParticipantNode;
use crate::runtime::ParallelExecutor;
use crate::warn_log;

/// One inbound transport event.
#[derive(Debug)]
pub enum Incoming {
    /// A decoded message from a live participant.
    Msg(Msg),
    /// The participant is unreachable (EOF, I/O error, decode error, or a
    /// failed send).  The engine drops it from the cohort.
    Gone(String),
}

/// What the networked coordinator requires of a peer link; see the
/// module docs.
pub trait Transport {
    /// Live participant ids, ascending — the round engine's cohort and
    /// its fixed reduction order.
    fn clients(&self) -> Vec<u64>;

    /// Send `msg` to participant `id`.  Best-effort: a send to a dead
    /// peer is not an error here — the failure surfaces as
    /// [`Incoming::Gone`] from [`Transport::recv`], keeping ALL fault
    /// handling on one path.
    fn send(&mut self, id: u64, msg: &Msg);

    /// Await the next event, up to `timeout`.  `None` = nothing arrived
    /// (the caller checks its phase deadline and decides who to drop).
    fn recv(&mut self, timeout: Duration) -> Option<(u64, Incoming)>;

    /// Remove `id` from the live set (and close its link, if any).
    fn drop_client(&mut self, id: u64);

    /// Bound how long any single [`Transport::send`] may block.  Without
    /// it a peer that stops reading (SIGSTOP, black-holed link) wedges
    /// the coordinator mid-write once the socket buffer fills — e.g. an
    /// FL [`Msg::FullReq`] ships the whole model, far more than a socket
    /// buffers — and the fault policy can never fire.  A timed-out write
    /// is a failed send (⇒ [`Incoming::Gone`]).  Default: no-op, for
    /// transports whose sends cannot block.
    fn set_io_deadline(&mut self, _deadline: Duration) {}

    /// Poll for participants dialing in mid-run (churn: a dropped peer
    /// reconnecting, or a brand-new late joiner).  Non-blocking; returns
    /// the newly-admitted ids, which the round engine must configure with
    /// a [`Msg::Sync`] before their first round.  Default: none — for
    /// transports with a fixed peer set (the fault-injection mocks).
    fn accept_new(&mut self) -> Vec<u64> {
        Vec::new()
    }

    /// Restore the transport to its initial peer set with fresh,
    /// unconfigured participants — the transport half of
    /// `NetTrainer::reset`, which re-Welcomes everyone.  Returns `false`
    /// when the transport cannot recreate peers (TCP: remote processes
    /// are not ours to respawn) — the engine then refuses the reset.
    fn reset_peers(&mut self) -> bool {
        false
    }
}

// ------------------------------------------------------------------ tcp

/// Coordinator side of the TCP transport; see the module docs.
///
/// Owns the listener after the rendezvous so the run can keep admitting
/// peers mid-run ([`Transport::accept_new`] — churn rejoins).  Every
/// connection carries a per-id **generation** number: a rejoining peer
/// bumps its id's generation, and [`Transport::recv`] discards events
/// stamped with an older one, so a dead incarnation's terminal `Gone`
/// (its reader thread firing after the socket finally times out) can
/// never fault the rejoined live incarnation.
pub struct TcpTransport {
    /// Kept for mid-run admissions; non-blocking.
    listener: Option<TcpListener>,
    /// Write halves, keyed by claimed client id.
    peers: BTreeMap<u64, TcpStream>,
    /// Per-id connection generation; bumped on each rejoin of that id.
    gens: BTreeMap<u64, u64>,
    tx: Sender<(u64, u64, Incoming)>,
    rx: Receiver<(u64, u64, Incoming)>,
    /// Locally-generated events (failed sends) drain before the socket
    /// queue so a dead peer is reported exactly once, promptly.
    pending: VecDeque<(u64, Incoming)>,
    /// Applied to every accepted stream (including rejoiners) once set.
    io_deadline: Option<Duration>,
}

impl TcpTransport {
    /// Accept `expected` participants on `listener` within `deadline`.
    ///
    /// Each connection must open with a [`Msg::Join`] (or a
    /// [`Msg::Rejoin`] from a participant whose dialer re-armed while the
    /// coordinator restarted) claiming a unique client id at the current
    /// [`PROTO_VERSION`]; violators are dropped without poisoning the
    /// rendezvous.  Returns once `expected` peers
    /// joined — or at the deadline with however many did (the caller
    /// decides whether a partial federation may proceed; at least one
    /// joined peer is required).  The listener stays owned by the
    /// transport so dropped or late peers can be admitted mid-run via
    /// [`Transport::accept_new`].
    pub fn accept(
        listener: TcpListener,
        expected: usize,
        deadline: Duration,
    ) -> anyhow::Result<TcpTransport> {
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel();
        let mut t = TcpTransport {
            listener: Some(listener),
            peers: BTreeMap::new(),
            gens: BTreeMap::new(),
            tx,
            rx,
            pending: VecDeque::new(),
            io_deadline: None,
        };
        let t0 = Instant::now();
        while t.peers.len() < expected && t0.elapsed() < deadline {
            match t.listener.as_ref().expect("listener present").accept() {
                Ok((stream, addr)) => {
                    // Rejoin is accepted here too: a coordinator resumed
                    // from a checkpoint rendezvouses with surviving
                    // participants whose re-armed dialers open with Rejoin.
                    match Self::rendezvous(stream, addr, &t.peers, true) {
                        Ok((id, stream)) => {
                            if let Err(e) = t.register(id, stream) {
                                warn_log!("rejected connection from {addr}: {e:#}");
                            }
                        }
                        Err(e) => warn_log!("rejected connection from {addr}: {e:#}"),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        anyhow::ensure!(
            !t.peers.is_empty(),
            "no participant joined within {deadline:?} (expected {expected})"
        );
        Ok(t)
    }

    /// Wire a validated connection into the live set: bump the id's
    /// generation, start its reader, remember its write half.
    fn register(&mut self, id: u64, stream: TcpStream) -> anyhow::Result<()> {
        if let Some(deadline) = self.io_deadline {
            stream.set_write_timeout(Some(deadline))?;
        }
        let gen = self.gens.get(&id).map_or(0, |g| g + 1);
        self.gens.insert(id, gen);
        let reader = stream.try_clone()?;
        spawn_reader(id, gen, reader, self.tx.clone());
        self.peers.insert(id, stream);
        Ok(())
    }

    /// Validate one connection's handshake: a [`Msg::Join`] — or, when
    /// `allow_rejoin` (mid-run admission), a [`Msg::Rejoin`] — claiming
    /// an id that is not currently live, at the current protocol version.
    fn rendezvous(
        stream: TcpStream,
        addr: SocketAddr,
        peers: &BTreeMap<u64, TcpStream>,
        allow_rejoin: bool,
    ) -> anyhow::Result<(u64, TcpStream)> {
        // Accepted sockets may inherit the listener's non-blocking mode on
        // some platforms; the frame reader wants blocking I/O.
        stream.set_nonblocking(false)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        let mut reader = stream.try_clone()?;
        let payload = read_frame(&mut reader)?
            .ok_or_else(|| anyhow::anyhow!("{addr} closed before joining"))?;
        let (client, version) = match Msg::decode(&payload)? {
            Msg::Join { client, version } => (client, version),
            Msg::Rejoin { client, version } if allow_rejoin => (client, version),
            other => anyhow::bail!("{addr} opened with {} instead of join", other.name()),
        };
        anyhow::ensure!(
            version == PROTO_VERSION,
            "{addr} speaks protocol v{version}, coordinator is v{PROTO_VERSION}"
        );
        anyhow::ensure!(!peers.contains_key(&client), "client id {client} already joined");
        stream.set_read_timeout(None)?;
        Ok((client, stream))
    }

    /// Participants that joined (live), ascending.
    pub fn joined(&self) -> Vec<u64> {
        self.peers.keys().copied().collect()
    }
}

/// Per-peer reader: frames → decoded messages → the shared event queue;
/// EOF and errors become ONE terminal [`Incoming::Gone`].  Every event is
/// stamped with the connection's generation so the transport can discard
/// leftovers from a replaced (rejoined) incarnation.
fn spawn_reader(id: u64, gen: u64, stream: TcpStream, tx: Sender<(u64, u64, Incoming)>) {
    std::thread::spawn(move || {
        let mut reader = BufReader::new(stream);
        loop {
            match read_frame(&mut reader) {
                Ok(Some(payload)) => match Msg::decode(&payload) {
                    Ok(msg) => {
                        if tx.send((id, gen, Incoming::Msg(msg))).is_err() {
                            return; // transport dropped; nobody listening
                        }
                    }
                    Err(e) => {
                        let _ =
                            tx.send((id, gen, Incoming::Gone(format!("decode error: {e:#}"))));
                        return;
                    }
                },
                Ok(None) => {
                    let _ = tx.send((id, gen, Incoming::Gone("connection closed".into())));
                    return;
                }
                Err(e) => {
                    let _ = tx.send((id, gen, Incoming::Gone(format!("read error: {e:#}"))));
                    return;
                }
            }
        }
    });
}

impl Transport for TcpTransport {
    fn clients(&self) -> Vec<u64> {
        self.peers.keys().copied().collect()
    }

    fn send(&mut self, id: u64, msg: &Msg) {
        let Some(stream) = self.peers.get_mut(&id) else { return };
        if let Err(e) = write_frame(stream, &msg.encode()) {
            self.pending.push_back((id, Incoming::Gone(format!("send failed: {e:#}"))));
            self.peers.remove(&id);
        }
    }

    fn recv(&mut self, timeout: Duration) -> Option<(u64, Incoming)> {
        if let Some(ev) = self.pending.pop_front() {
            return Some(ev);
        }
        let t_end = Instant::now() + timeout;
        loop {
            let left = t_end.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(left) {
                Ok((id, gen, ev)) => {
                    if self.gens.get(&id) == Some(&gen) {
                        return Some((id, ev));
                    }
                    // A replaced incarnation's leftover (its reader fired
                    // after the id rejoined under a newer generation):
                    // silently discard, or a stale Gone would fault the
                    // live rejoined peer.
                }
                Err(RecvTimeoutError::Timeout) => return None,
                // Every reader exited (all peers gone) — nothing will
                // arrive.
                Err(RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    fn drop_client(&mut self, id: u64) {
        if let Some(stream) = self.peers.remove(&id) {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
    }

    fn set_io_deadline(&mut self, deadline: Duration) {
        self.io_deadline = Some(deadline);
        for (id, stream) in &self.peers {
            if let Err(e) = stream.set_write_timeout(Some(deadline)) {
                warn_log!("peer {id}: set_write_timeout failed: {e}");
            }
        }
    }

    fn accept_new(&mut self) -> Vec<u64> {
        let mut admitted = Vec::new();
        loop {
            let Some(listener) = self.listener.as_ref() else { break };
            match listener.accept() {
                Ok((stream, addr)) => {
                    match Self::rendezvous(stream, addr, &self.peers, true) {
                        Ok((id, stream)) => match self.register(id, stream) {
                            Ok(()) => admitted.push(id),
                            Err(e) => warn_log!("rejected rejoin from {addr}: {e:#}"),
                        },
                        Err(e) => warn_log!("rejected connection from {addr}: {e:#}"),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    warn_log!("listener error during mid-run accept: {e}");
                    break;
                }
            }
        }
        admitted.sort_unstable();
        admitted
    }
}

// ------------------------------------------------------------- loopback

/// In-process transport over [`ParticipantNode`]s; see the module docs.
pub struct LoopbackTransport {
    /// All nodes ever joined, ascending id (dropped ids stay allocated —
    /// the live set gates delivery).
    nodes: Vec<(u64, std::sync::Mutex<ParticipantNode>)>,
    live: BTreeSet<u64>,
    /// The rendezvous-time peer set, for [`Transport::reset_peers`].
    initial_ids: Vec<u64>,
    /// Ids scheduled by [`LoopbackTransport::schedule_admit`]; drained by
    /// the next [`Transport::accept_new`] poll — the in-process analogue
    /// of a churn trace's arrivals dialing the TCP listener.
    pending_admits: Vec<u64>,
    outbox: Vec<(u64, Msg)>,
    inbox: VecDeque<(u64, Incoming)>,
    pool: ParallelExecutor,
}

impl LoopbackTransport {
    /// A federation of `ids` in-process participants sharing one worker
    /// pool (`threads` as in [`ParallelExecutor::new`]).
    pub fn new(ids: &[u64], threads: usize) -> anyhow::Result<LoopbackTransport> {
        let mut sorted = ids.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        anyhow::ensure!(sorted.len() == ids.len(), "duplicate participant ids in {ids:?}");
        Ok(LoopbackTransport {
            nodes: sorted
                .iter()
                .map(|&id| (id, std::sync::Mutex::new(ParticipantNode::new(id))))
                .collect(),
            live: sorted.iter().copied().collect(),
            initial_ids: sorted.clone(),
            pending_admits: Vec::new(),
            outbox: Vec::new(),
            inbox: VecDeque::new(),
            pool: ParallelExecutor::new(threads),
        })
    }

    /// Schedule a (re)join: `id` will be admitted as a FRESH, unconfigured
    /// [`ParticipantNode`] at the next [`Transport::accept_new`] poll,
    /// exactly like a new process dialing the TCP listener.  Admitting a
    /// currently-live id is a no-op (a real dialer would be rejected at
    /// the rendezvous).
    pub fn schedule_admit(&mut self, id: u64) {
        self.pending_admits.push(id);
    }

    /// Deliver every buffered request in one parallel sweep: node `i`'s
    /// messages run in order on one worker (fan-out across nodes via the
    /// executor's session path), then ALL responses enqueue in ascending
    /// node order — a deterministic schedule for every thread count.
    fn flush(&mut self) {
        if self.outbox.is_empty() {
            return;
        }
        let outbox = std::mem::take(&mut self.outbox);
        let nodes = &self.nodes;
        // Per-node request batches, ascending node order.
        let batches: Vec<(usize, Vec<&Msg>)> = nodes
            .iter()
            .enumerate()
            .filter_map(|(slot, (id, _))| {
                let msgs: Vec<&Msg> =
                    outbox.iter().filter(|(to, _)| to == id).map(|(_, m)| m).collect();
                (!msgs.is_empty()).then_some((slot, msgs))
            })
            .collect();
        let batches_ref = &batches;
        // T is the NODE's Result: a protocol violation inside one node
        // must surface as that peer's Gone event, not abort the sweep.
        let results: Vec<anyhow::Result<Vec<Msg>>> = self
            .pool
            .map(batches.len(), |j| {
                let (slot, msgs) = &batches_ref[j];
                let mut node = nodes[*slot].1.lock().expect("participant node poisoned");
                let mut run = || -> anyhow::Result<Vec<Msg>> {
                    let mut out = Vec::new();
                    for m in msgs {
                        out.extend(node.handle(m)?);
                    }
                    Ok(out)
                };
                Ok(run())
            })
            .expect("loopback sweep never fails at the executor level");
        for ((slot, _), result) in batches.iter().zip(results) {
            let id = nodes[*slot].0;
            match result {
                Ok(msgs) => {
                    self.inbox.extend(msgs.into_iter().map(|m| (id, Incoming::Msg(m))))
                }
                Err(e) => {
                    self.live.remove(&id);
                    self.inbox.push_back((id, Incoming::Gone(format!("node error: {e:#}"))));
                }
            }
        }
    }
}

impl Transport for LoopbackTransport {
    fn clients(&self) -> Vec<u64> {
        self.live.iter().copied().collect()
    }

    fn send(&mut self, id: u64, msg: &Msg) {
        if self.live.contains(&id) {
            self.outbox.push((id, msg.clone()));
        }
    }

    fn recv(&mut self, _timeout: Duration) -> Option<(u64, Incoming)> {
        if self.inbox.is_empty() {
            self.flush();
        }
        self.inbox.pop_front()
    }

    fn drop_client(&mut self, id: u64) {
        self.live.remove(&id);
        self.outbox.retain(|(to, _)| *to != id);
    }

    fn accept_new(&mut self) -> Vec<u64> {
        let mut admitted = Vec::new();
        for id in std::mem::take(&mut self.pending_admits) {
            if self.live.contains(&id) {
                continue; // a live id cannot rejoin (TCP rendezvous parity)
            }
            let fresh = std::sync::Mutex::new(ParticipantNode::new(id));
            match self.nodes.binary_search_by_key(&id, |(nid, _)| *nid) {
                // A dropped peer rejoining: replace its slot with a fresh
                // node — churn restarts the PROCESS, not just the link.
                Ok(slot) => self.nodes[slot].1 = fresh,
                Err(slot) => self.nodes.insert(slot, (id, fresh)),
            }
            self.live.insert(id);
            admitted.push(id);
        }
        admitted.sort_unstable();
        admitted.dedup();
        admitted
    }

    fn reset_peers(&mut self) -> bool {
        self.nodes = self
            .initial_ids
            .iter()
            .map(|&id| (id, std::sync::Mutex::new(ParticipantNode::new(id))))
            .collect();
        self.live = self.initial_ids.iter().copied().collect();
        self.pending_admits.clear();
        self.outbox.clear();
        self.inbox.clear();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::RunSetup;

    fn welcome() -> Msg {
        Msg::Welcome {
            setup: RunSetup {
                dataset: "mnist".into(),
                seed: 17,
                partition: "iid".into(),
                samples_per_client: 64,
                model: "builtin".into(),
                num_cuts: 4,
            },
        }
    }

    #[test]
    fn loopback_delivers_in_ascending_id_order() {
        let mut t = LoopbackTransport::new(&[2, 0, 5], 1).unwrap();
        assert_eq!(t.clients(), vec![0, 2, 5]);
        // Welcomes produce no responses; a fwd-req per node does, and the
        // responses arrive 0, 2, 5 regardless of send order.
        for id in [5u64, 0, 2] {
            t.send(id, &welcome());
        }
        let manifest = crate::model::Manifest::builtin();
        let rt = crate::runtime::ModelRuntime::native(&manifest, "mnist").unwrap();
        let nc = rt.spec().cut(1).client_params;
        let wc = crate::data::init::init_params(rt.spec(), 17 ^ 0x1417)[..nc].to_vec();
        for (i, id) in [5u64, 2, 0].iter().enumerate() {
            t.send(*id, &Msg::FwdReq { seq: i as u64, cut: 1, step: 0, wc: wc.clone() });
        }
        let mut order = Vec::new();
        while let Some((id, ev)) = t.recv(Duration::from_millis(1)) {
            match ev {
                Incoming::Msg(Msg::FwdOk { .. }) => order.push(id),
                other => panic!("unexpected event from {id}: {other:?}"),
            }
        }
        assert_eq!(order, vec![0, 2, 5]);
    }

    #[test]
    fn loopback_drop_silences_a_peer() {
        let mut t = LoopbackTransport::new(&[0, 1], 1).unwrap();
        t.send(0, &welcome());
        t.send(1, &welcome());
        while t.recv(Duration::from_millis(1)).is_some() {}
        t.drop_client(1);
        assert_eq!(t.clients(), vec![0]);
        t.send(1, &Msg::RoundDone { round: 0 });
        assert!(t.recv(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn loopback_node_error_surfaces_as_gone() {
        let mut t = LoopbackTransport::new(&[0], 1).unwrap();
        // Compute before Welcome is a protocol violation inside the node.
        t.send(0, &Msg::FwdReq { seq: 0, cut: 1, step: 0, wc: Vec::new() });
        match t.recv(Duration::from_millis(1)) {
            Some((0, Incoming::Gone(_))) => {}
            other => panic!("expected gone, got {other:?}"),
        }
        assert!(t.clients().is_empty());
    }

    #[test]
    fn duplicate_loopback_ids_rejected() {
        assert!(LoopbackTransport::new(&[1, 1], 1).is_err());
    }

    #[test]
    fn loopback_admission_rejoins_fresh_and_skips_live_ids() {
        let mut t = LoopbackTransport::new(&[0, 1], 1).unwrap();
        t.send(0, &welcome());
        t.send(1, &welcome());
        while t.recv(Duration::from_millis(1)).is_some() {}
        t.drop_client(1);
        // Live id 0 cannot rejoin; dropped id 1 and brand-new id 3 can.
        t.schedule_admit(0);
        t.schedule_admit(1);
        t.schedule_admit(3);
        assert_eq!(t.accept_new(), vec![1, 3]);
        assert_eq!(t.clients(), vec![0, 1, 3]);
        // The rejoined node is FRESH (unconfigured): compute before a
        // Sync is a protocol violation surfacing as its Gone event.
        t.send(1, &Msg::FwdReq { seq: 0, cut: 1, step: 0, wc: Vec::new() });
        match t.recv(Duration::from_millis(1)) {
            Some((1, Incoming::Gone(_))) => {}
            other => panic!("expected gone from fresh rejoiner, got {other:?}"),
        }
        // Nothing pending → accept_new is an empty poll.
        assert!(t.accept_new().is_empty());
    }

    #[test]
    fn loopback_reset_restores_the_initial_peer_set() {
        let mut t = LoopbackTransport::new(&[0, 2], 1).unwrap();
        t.send(0, &welcome());
        t.send(2, &welcome());
        while t.recv(Duration::from_millis(1)).is_some() {}
        t.drop_client(2);
        t.schedule_admit(7);
        t.accept_new();
        assert_eq!(t.clients(), vec![0, 7]);
        assert!(t.reset_peers());
        assert_eq!(t.clients(), vec![0, 2]);
        // Peers are fresh again: compute before Welcome errors.
        t.send(2, &Msg::FwdReq { seq: 0, cut: 1, step: 0, wc: Vec::new() });
        match t.recv(Duration::from_millis(1)) {
            Some((2, Incoming::Gone(_))) => {}
            other => panic!("expected gone from reset peer, got {other:?}"),
        }
    }

    /// A peer that joins and then never reads must not wedge the
    /// coordinator in `send`: once its socket buffer fills, the write
    /// deadline turns the blocked send into that peer's Gone event.
    #[test]
    fn blocked_send_hits_io_deadline_and_surfaces_gone() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        // The regression this guards against is an unbounded blocking
        // write, so a hang IS the failure mode — abort instead.
        let done = Arc::new(AtomicBool::new(false));
        {
            let done = done.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_secs(120));
                if !done.load(Ordering::SeqCst) {
                    eprintln!("blocked_send_hits_io_deadline_and_surfaces_gone wedged");
                    std::process::abort();
                }
            });
        }

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            write_frame(&mut s, &Msg::Join { client: 0, version: PROTO_VERSION }.encode())
                .unwrap();
            s // keep the connection open, never read from it
        });
        let mut t = TcpTransport::accept(listener, 1, Duration::from_secs(30)).unwrap();
        let _peer_stream = peer.join().unwrap();
        t.set_io_deadline(Duration::from_millis(200));

        // ~8 MB frame — far beyond any default socket buffer, so the
        // write must block and then time out.
        let w = vec![vec![0.0f32; 2_000_000]];
        t.send(0, &Msg::FullReq { seq: 1, step0: 0, tau: 1, lr: 0.1, w });
        match t.recv(Duration::from_secs(5)) {
            Some((0, Incoming::Gone(_))) => {}
            other => panic!("expected gone after blocked send, got {other:?}"),
        }
        assert!(t.clients().is_empty());
        done.store(true, Ordering::SeqCst);
    }
}
