//! Reusable kernel scratch memory: a per-worker arena for the native
//! backend's im2col buffers and packed GEMM panels, so the training hot
//! path stops reallocating multi-hundred-KB intermediates on every
//! forward/backward call.
//!
//! Ownership model (see DESIGN.md §Native backend):
//!
//! * [`Scratch`] is the arena itself — named growable `f32` buffers
//!   (im2col staging, packed GEMM panels, per-head attention gathers)
//!   that the kernels resize (never shrink) to the largest
//!   shape they have seen.  A steady-state round performs ZERO scratch
//!   allocations.  It also carries the GEMM microkernel [`Tier`] every
//!   kernel call through this arena runs on (defaulting to the
//!   process-wide [`active_tier`]), so one worker's whole forward/backward
//!   chain is tier-consistent and tests can pin an arena to the portable
//!   tier.
//! * [`ScratchHandle`] is the cheap, cloneable handle the rest of the
//!   runtime passes around (`Arc<Mutex<Scratch>>`).  The
//!   [`super::ParallelExecutor`] owns one arena per worker thread and
//!   hands worker `k` its own handle — on the bulk `map` fan-outs and on
//!   the pipelined session path alike, where worker `k` runs every job
//!   it dequeues against its arena — so hot-path locks are uncontended.
//! * Correctness NEVER depends on scratch contents: every kernel fully
//!   overwrites the region it later reads (packing pads with explicit
//!   zeros; im2col writes every column).  Results are therefore bitwise
//!   identical whatever stale data an arena carries — the property the
//!   threads=N ≡ threads=1 guarantee needs, tested by
//!   `native::ops::tests::results_do_not_depend_on_scratch_contents`.

use std::sync::{Arc, Mutex, MutexGuard};

use super::native::gemm::{active_tier, Tier};

/// Reusable kernel workspace: im2col/col2im staging plus the packed GEMM
/// panels.  Buffers grow to a high-water mark and are reused in place.
#[derive(Debug)]
pub struct Scratch {
    /// GEMM microkernel tier every call through this arena runs on.
    /// Defaults to the process-wide [`active_tier`]; tests pin it to
    /// [`Tier::Portable`] for JAX-golden comparisons (FMA in the SIMD
    /// tier rounds differently — see `native::gemm`).
    pub tier: Tier,
    /// im2col matrix of one image: `h·w × k·k·ic`.
    pub col: Vec<f32>,
    /// Column-space gradient of one image (col2im input), same shape.
    pub dcol: Vec<f32>,
    /// Packed A panel (`MC × KC`, MR-row strips, k-major).
    pub pa: Vec<f32>,
    /// Packed B panel (`KC × NC`, NR-column strips, k-major).
    pub pb: Vec<f32>,
    /// Hoisted packed-weight panels (`pack_b_full` output): a conv layer
    /// packs its weight matrix here ONCE per call and replays the panels
    /// across every image of the batch (`gemm_packed_b`).
    pub pw: Vec<f32>,
    /// Per-head attention gathers (`t × dh` each): query, key and value
    /// head slices copied out of the interleaved `[rows, dm]` buffers so
    /// the per-head GEMMs run on contiguous operands (`native::ops::mhsa_fwd`).
    pub qh: Vec<f32>,
    pub kh: Vec<f32>,
    pub vh: Vec<f32>,
    /// Per-head output / cotangent staging (`t × dh`).
    pub oh: Vec<f32>,
    /// Per-head score-gradient staging (`t × t`, `mhsa_bwd`).
    pub sd: Vec<f32>,
}

impl Default for Scratch {
    fn default() -> Scratch {
        Scratch {
            tier: active_tier(),
            col: Vec::new(),
            dcol: Vec::new(),
            pa: Vec::new(),
            pb: Vec::new(),
            pw: Vec::new(),
            qh: Vec::new(),
            kh: Vec::new(),
            vh: Vec::new(),
            oh: Vec::new(),
            sd: Vec::new(),
        }
    }
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// An arena pinned to the portable GEMM tier, for cross-implementation
    /// golden tests that must not see FMA rounding.
    pub fn portable() -> Scratch {
        Scratch { tier: Tier::Portable, ..Scratch::default() }
    }

    /// Current high-water footprint in bytes (diagnostics/benches).
    pub fn capacity_bytes(&self) -> usize {
        (self.col.capacity()
            + self.dcol.capacity()
            + self.pa.capacity()
            + self.pb.capacity()
            + self.pw.capacity()
            + self.qh.capacity()
            + self.kh.capacity()
            + self.vh.capacity()
            + self.oh.capacity()
            + self.sd.capacity())
            * std::mem::size_of::<f32>()
    }
}

/// Shared handle to one [`Scratch`] arena.  Clones refer to the same
/// arena; lock scope is one backend call.
#[derive(Clone, Debug, Default)]
pub struct ScratchHandle(Arc<Mutex<Scratch>>);

impl ScratchHandle {
    pub fn new() -> ScratchHandle {
        ScratchHandle::default()
    }

    /// Lock the arena for one kernel invocation.  Workers own disjoint
    /// arenas, so this never contends on the hot path.
    pub fn lock(&self) -> MutexGuard<'_, Scratch> {
        self.0.lock().expect("scratch arena mutex poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_starts_empty_and_tracks_capacity() {
        let s = Scratch::new();
        assert_eq!(s.capacity_bytes(), 0);
        let h = ScratchHandle::new();
        h.lock().col.resize(16, 0.0);
        assert!(h.lock().capacity_bytes() >= 16 * 4);
    }

    #[test]
    fn handle_clones_share_one_arena() {
        let h = ScratchHandle::new();
        let h2 = h.clone();
        h.lock().pa.push(1.0);
        assert_eq!(h2.lock().pa.len(), 1);
    }
}
