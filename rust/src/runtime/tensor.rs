//! Host-side tensor: flat f32 buffer + shape.  With the `pjrt` feature it
//! also converts to/from PJRT Literals at the engine boundary.

/// A host tensor (f32, row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "tensor data/shape mismatch: {} vs {:?}", data.len(), shape);
        Tensor { data, shape }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: vec![v], shape: vec![] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Scalar accessor (panics if not a 1-element tensor).
    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar tensor {:?}", self.shape);
        self.data[0]
    }

    #[cfg(feature = "pjrt")]
    pub(crate) fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.len() == 1 {
            return Ok(lit);
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    pub(crate) fn from_literal(lit: &xla::Literal) -> anyhow::Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(data, dims))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.len(), 4);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn bad_shape_panics() {
        Tensor::new(vec![1.0], vec![2, 2]);
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip() {
        let t = Tensor::new((0..6).map(|i| i as f32).collect(), vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn scalar_literal_roundtrip() {
        // 0-d tensors travel as rank-1 length-1; PJRT outputs of rank 0
        // come back with empty dims.
        let t = Tensor::new(vec![7.0], vec![1]);
        let lit = t.to_literal().unwrap();
        assert_eq!(Tensor::from_literal(&lit).unwrap().data, vec![7.0]);
    }
}
