//! PJRT execution engine (feature `pjrt`): a dedicated thread owns the
//! (non-Send) PJRT client and every compiled executable; the rest of the
//! coordinator talks to it through a cloneable [`Handle`] over mpsc
//! channels.  [`PjrtBackend`] pools several engines and implements the
//! [`Backend`] trait over them.
//!
//! This is the runtime half of the AOT bridge: HLO text artifacts from
//! `python/compile/aot.py` are parsed with `HloModuleProto::from_text_file`
//! (text, NOT serialized protos — xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit instruction ids) and compiled once at startup; the training hot
//! path then only moves f32 buffers.
//!
//! Building this module requires the `xla` (xla-rs) crate and a local PJRT
//! toolchain; see DESIGN.md §Backends.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;

use crate::model::{CUT_ROLES, Manifest, ShapeSpec};
use crate::tensor::Params;

use super::backend::Backend;
use super::tensor::Tensor;

/// Default engine-pool size: PJRT executables are single-lane per engine
/// thread, so N independent clients' compute parallelizes across lanes.
pub fn default_lanes() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(2).clamp(1, 4))
        .unwrap_or(1)
}

enum Request {
    Execute {
        exe: usize,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<anyhow::Result<Vec<Tensor>>>,
    },
    Shutdown,
}

/// Cloneable, Send handle to the engine thread.
#[derive(Clone)]
pub struct Handle {
    tx: mpsc::Sender<Request>,
    names: BTreeMap<String, usize>,
}

impl Handle {
    /// Execute a loaded computation by name. Blocks until the result is
    /// back on the host.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        let exe = *self
            .names
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown computation '{name}'"))?;
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { exe, inputs, reply })
            .map_err(|_| anyhow::anyhow!("engine thread terminated"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread dropped reply"))?
    }

    pub fn computations(&self) -> Vec<String> {
        self.names.keys().cloned().collect()
    }
}

/// The engine: owns the thread; dropping shuts it down.
pub struct Engine {
    handle: Handle,
    join: Option<thread::JoinHandle<()>>,
}

impl Engine {
    /// Load and compile `files` = [(name, path)] on a fresh engine thread.
    pub fn load(files: Vec<(String, PathBuf)>) -> anyhow::Result<Engine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let names: BTreeMap<String, usize> = files
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.clone(), i))
            .collect();
        anyhow::ensure!(names.len() == files.len(), "duplicate computation names");

        let join = thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(files, rx, ready_tx))?;

        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Engine { handle: Handle { tx, names }, join: Some(join) })
    }

    /// Convenience: load a set of manifest artifacts from `dir`.
    /// `entries` = [(logical name, file name)].
    pub fn load_artifacts(dir: &Path, entries: &[(String, String)]) -> anyhow::Result<Engine> {
        let files = entries
            .iter()
            .map(|(name, file)| (name.clone(), dir.join(file)))
            .collect();
        Engine::load(files)
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_main(
    files: Vec<(String, PathBuf)>,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    let setup = || -> anyhow::Result<(xla::PjRtClient, Vec<xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu()?;
        let mut exes = Vec::with_capacity(files.len());
        for (name, path) in &files {
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
                anyhow::anyhow!("loading artifact '{name}' from {}: {e}", path.display())
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling artifact '{name}': {e}"))?;
            crate::debug!("compiled '{name}' in {:.2}s", t0.elapsed().as_secs_f64());
            exes.push(exe);
        }
        Ok((client, exes))
    };

    let (_client, exes) = match setup() {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Execute { exe, inputs, reply } => {
                let _ = reply.send(run_one(&exes[exe], inputs));
            }
        }
    }
}

fn run_one(exe: &xla::PjRtLoadedExecutable, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
    let literals = inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<anyhow::Result<Vec<_>>>()?;
    let result = exe.execute::<xla::Literal>(&literals)?;
    // Single device, single result buffer; aot.py lowers return_tuple=True.
    let tuple = result[0][0].to_literal_sync()?;
    let parts = tuple.to_tuple()?;
    parts.iter().map(Tensor::from_literal).collect()
}

/// PJRT realization of the [`Backend`] trait: all compiled computations
/// for one dataset shape, with typed wrappers for the five artifact
/// roles.  Holds a pool of engines (each owning its own PJRT client +
/// compiled executables); calls are distributed round-robin, so
/// independent per-client executions run concurrently.
///
/// The scratch-aware `*_with` role variants are inherited from the trait
/// defaults (they ignore the arena handle): PJRT keeps its working
/// memory device-side, so there are no host intermediates to reuse.
pub struct PjrtBackend {
    engines: Vec<Engine>,
    next: AtomicUsize,
    spec: ShapeSpec,
}

impl PjrtBackend {
    /// Compile every artifact of `dataset`'s shape (12 per-cut + 2
    /// global) on `lanes` engines (1 = serial).
    pub fn load(
        artifact_dir: &Path,
        manifest: &Manifest,
        dataset: &str,
        lanes: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(lanes > 0, "need at least one engine lane");
        let spec = manifest.for_dataset(dataset)?.clone();
        let mut entries = Vec::new();
        for cut in &spec.cuts {
            for role in CUT_ROLES {
                entries.push((format!("v{}_{role}", cut.cut), cut.artifacts[role].clone()));
            }
        }
        for (role, file) in &spec.artifacts {
            entries.push((role.clone(), file.clone()));
        }
        let engines = (0..lanes)
            .map(|_| Engine::load_artifacts(artifact_dir, &entries))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(PjrtBackend { engines, next: AtomicUsize::new(0), spec })
    }

    pub fn lanes(&self) -> usize {
        self.engines.len()
    }

    fn engine(&self) -> &Engine {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.engines.len();
        &self.engines[i]
    }

    fn params_to_tensors(&self, params: &[Vec<f32>], offset: usize) -> Vec<Tensor> {
        params
            .iter()
            .enumerate()
            .map(|(i, buf)| Tensor::new(buf.clone(), self.spec.params[offset + i].shape.clone()))
            .collect()
    }

    fn check_cut(&self, cut: usize) -> anyhow::Result<()> {
        self.spec.menu().validate(cut)?;
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn spec(&self) -> &ShapeSpec {
        &self.spec
    }

    /// AOT executables are compiled for exact input shapes — no
    /// remainder tail batches (the coordinator enforces divisibility).
    fn dynamic_batch(&self) -> bool {
        false
    }

    fn client_fwd(&self, cut: usize, wc: &[Vec<f32>], x: &Tensor) -> anyhow::Result<Tensor> {
        self.check_cut(cut)?;
        let mut inputs = self.params_to_tensors(wc, 0);
        inputs.push(x.clone());
        let mut out = self.engine().handle().execute(&format!("v{cut}_client_fwd"), inputs)?;
        anyhow::ensure!(out.len() == 1, "client_fwd returned {} outputs", out.len());
        Ok(out.pop().unwrap())
    }

    fn server_grad(
        &self,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        self.check_cut(cut)?;
        let nc = self.spec.cut(cut).client_params;
        let mut inputs = self.params_to_tensors(ws, nc);
        inputs.push(smashed.clone());
        inputs.push(y1h.clone());
        let mut out = self.engine().handle().execute(&format!("v{cut}_server_grad"), inputs)?;
        let n_server = self.spec.params.len() - nc;
        anyhow::ensure!(
            out.len() == 1 + n_server + 1,
            "server_grad returned {} outputs, expected {}",
            out.len(),
            2 + n_server
        );
        let g_smashed = out.pop().unwrap();
        let loss = out[0].item();
        let g_ws: Params = out.drain(1..).map(|t| t.data).collect();
        Ok((loss, g_ws, g_smashed))
    }

    fn client_grad(
        &self,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        self.check_cut(cut)?;
        let mut inputs = self.params_to_tensors(wc, 0);
        inputs.push(x.clone());
        inputs.push(g_smashed.clone());
        let out = self.engine().handle().execute(&format!("v{cut}_client_grad"), inputs)?;
        anyhow::ensure!(out.len() == wc.len(), "client_grad output arity mismatch");
        Ok(out.into_iter().map(|t| t.data).collect())
    }

    fn full_grad(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, Params)> {
        let mut inputs = self.params_to_tensors(w, 0);
        inputs.push(x.clone());
        inputs.push(y1h.clone());
        let mut out = self.engine().handle().execute("full_grad", inputs)?;
        anyhow::ensure!(out.len() == 1 + w.len(), "full_grad output arity mismatch");
        let loss = out[0].item();
        let g: Params = out.drain(1..).map(|t| t.data).collect();
        Ok((loss, g))
    }

    fn eval(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, f32)> {
        let mut inputs = self.params_to_tensors(w, 0);
        inputs.push(x.clone());
        inputs.push(y1h.clone());
        let out = self.engine().handle().execute("eval", inputs)?;
        anyhow::ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((out[0].item(), out[1].item()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn unknown_name_is_error_without_engine_thread_crash() {
        let Some(dir) = artifacts_dir() else { return };
        let m = crate::model::Manifest::load(&dir).unwrap();
        let spec = m.for_dataset("mnist").unwrap();
        let file = spec.cut(1).artifacts["client_fwd"].clone();
        let engine = Engine::load_artifacts(&dir, &[("cf".to_string(), file)]).unwrap();
        let h = engine.handle();
        assert!(h.execute("nope", vec![]).is_err());
        assert_eq!(h.computations(), vec!["cf".to_string()]);
    }

    #[test]
    fn executes_client_fwd_with_zero_params() {
        let Some(dir) = artifacts_dir() else { return };
        let m = crate::model::Manifest::load(&dir).unwrap();
        let spec = m.for_dataset("mnist").unwrap();
        let cut = spec.cut(1);
        let file = cut.artifacts["client_fwd"].clone();
        let engine = Engine::load_artifacts(&dir, &[("cf".to_string(), file)]).unwrap();
        let h = engine.handle();

        let mut inputs: Vec<Tensor> = spec.params[..cut.client_params]
            .iter()
            .map(|p| Tensor::zeros(&p.shape))
            .collect();
        let mut xshape = vec![spec.train_batch];
        xshape.extend_from_slice(&spec.input_shape);
        inputs.push(Tensor::zeros(&xshape));

        let out = h.execute("cf", inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, cut.smashed_shape);
        // Zero weights + zero biases → relu(conv(0)) = 0 everywhere.
        assert!(out[0].data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn load_missing_file_fails_cleanly() {
        let err = Engine::load(vec![("x".into(), PathBuf::from("/nonexistent.hlo.txt"))]);
        assert!(err.is_err());
    }
}
