//! The execution-backend contract: every way of running the split model —
//! the pure-Rust [`super::NativeBackend`], the PJRT engine pool behind the
//! `pjrt` feature — implements this trait, and everything above the
//! runtime ([`crate::coordinator`], figures, examples) is written against
//! it.
//!
//! Contract (see DESIGN.md §Backend trait):
//! * Parameters travel as flat `f32` buffers in manifest order
//!   ([`crate::tensor::Params`]); activations as [`Tensor`]s.
//! * `cut` is the paper's v, drawn from the model's cut menu
//!   (`spec.menu()`); the client owns the leading
//!   `spec.cut(v).client_params` parameter arrays.
//! * Batch size is taken from the input tensor's leading dimension, so
//!   train and eval batches need no separate entry points.
//! * Implementations must be deterministic: identical inputs produce
//!   identical outputs (the coordinator's seeding guarantees rely on it).
//! * Every role is a PURE function of its arguments, and the trait is
//!   `Send + Sync`: the round engine's [`super::ParallelExecutor`] issues
//!   per-client calls from its persistent pool workers against
//!   one shared backend instance, and the bitwise threads=N ≡ threads=1
//!   guarantee (`tests/determinism.rs`) holds only if no call observes
//!   mutable state from another.  Cache or pool internally behind locks if
//!   you must, but results may depend only on the inputs.
//! * Each role also has a `*_with` variant taking a [`ScratchHandle`] —
//!   reusable workspace for kernel intermediates.  The executor owns one
//!   arena per worker thread and routes the hot path through these.
//!   Scratch is an OPTIMIZATION channel only: results must be bitwise
//!   identical whatever the arena contains (the native backend's kernels
//!   fully overwrite every region they read), and backends without
//!   reusable intermediates (pjrt) simply inherit the defaults, which
//!   ignore the handle.

use crate::model::ShapeSpec;
use crate::tensor::Params;

use super::scratch::ScratchHandle;
use super::tensor::Tensor;

/// One executable realization of the split model's five roles.
pub trait Backend: Send + Sync {
    /// Short human-readable backend name ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// The model/shape metadata this backend was built for.
    fn spec(&self) -> &ShapeSpec;

    /// Whether this backend accepts arbitrary leading batch sizes.  AOT
    /// backends compiled for fixed input shapes return false; the
    /// coordinator then requires the test set to split into whole eval
    /// batches instead of sending a remainder tail batch.
    fn dynamic_batch(&self) -> bool {
        true
    }

    /// Smashed data S = ℓ(w^c; x) — eq (1).
    fn client_fwd(&self, cut: usize, wc: &[Vec<f32>], x: &Tensor) -> anyhow::Result<Tensor>;

    /// Server FP+BP: (loss, server grads g^{s,n}, smashed grads s^n) —
    /// eqs (2)(3)(4).
    fn server_grad(
        &self,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)>;

    /// Client BP with an injected (aggregated) smashed-gradient cotangent
    /// — eq (6).
    fn client_grad(
        &self,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params>;

    /// FL baseline: (loss, full-model gradient).
    fn full_grad(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, Params)>;

    /// Eval batch: (mean loss, correct count).
    fn eval(&self, w: &[Vec<f32>], x: &Tensor, y1h: &Tensor) -> anyhow::Result<(f32, f32)>;

    // ---- scratch-aware variants (the round engine's hot path) ----
    //
    // Defaults ignore the handle and defer to the plain role — correct
    // for backends with no host-side intermediates to reuse.  The native
    // backend overrides all five to draw im2col/packing buffers from the
    // worker's arena instead of reallocating per call.

    /// [`Backend::client_fwd`] drawing intermediates from `scratch`.
    fn client_fwd_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
    ) -> anyhow::Result<Tensor> {
        let _ = scratch;
        self.client_fwd(cut, wc, x)
    }

    /// [`Backend::server_grad`] drawing intermediates from `scratch`.
    fn server_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        ws: &[Vec<f32>],
        smashed: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params, Tensor)> {
        let _ = scratch;
        self.server_grad(cut, ws, smashed, y1h)
    }

    /// [`Backend::client_grad`] drawing intermediates from `scratch`.
    fn client_grad_with(
        &self,
        scratch: &ScratchHandle,
        cut: usize,
        wc: &[Vec<f32>],
        x: &Tensor,
        g_smashed: &Tensor,
    ) -> anyhow::Result<Params> {
        let _ = scratch;
        self.client_grad(cut, wc, x, g_smashed)
    }

    /// [`Backend::full_grad`] drawing intermediates from `scratch`.
    fn full_grad_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, Params)> {
        let _ = scratch;
        self.full_grad(w, x, y1h)
    }

    /// [`Backend::eval`] drawing intermediates from `scratch`.
    fn eval_with(
        &self,
        scratch: &ScratchHandle,
        w: &[Vec<f32>],
        x: &Tensor,
        y1h: &Tensor,
    ) -> anyhow::Result<(f32, f32)> {
        let _ = scratch;
        self.eval(w, x, y1h)
    }

    /// Hint: up to `workers` extra threads may be used INSIDE one
    /// `eval`/`eval_with` call (the trainer grants the pool capacity its
    /// eval jobs cannot fill on their own).  Like scratch, this is an
    /// OPTIMIZATION channel only — results must be bitwise identical for
    /// every value (the native backend splits large dense GEMMs by output
    /// column, which touches no element's summation order).  The default
    /// ignores the hint.
    fn set_eval_parallelism(&self, workers: usize) {
        let _ = workers;
    }
}
