//! Latency model (paper §II-C/D, eqs 12–16 and 29).
//!
//! Communication: l = X(v) / r with X(v) the smashed-data (or gradient)
//! bit size at cut v.  Computation: l = D·γ(v) / f with γ per-sample FLOPs
//! and f device FLOPS capacity (the paper writes CPU cycles; we use FLOPs
//! uniformly — the ratio structure, which is all the optimizer sees, is
//! identical).

use crate::model::{CutSpec, ShapeSpec};

/// Computation capabilities (defaults = paper §V-A1: client 0.1 GHz,
/// server total 100 GHz, i.e. client ~1e8, server ~1e11 FLOPS).
#[derive(Clone, Debug)]
pub struct ComputeConfig {
    /// Max client compute f^{n,c}_max in FLOPS (constraint 30b is
    /// per-client; see `client_flops` for the heterogeneous draw).
    pub f_client_max: f64,
    /// Heterogeneity spread in [0, 1): client n's capacity is drawn once
    /// as f_client_max · U(1 − spread, 1].  0 = homogeneous (paper §V-A).
    pub f_client_spread: f64,
    /// Explicit per-client capacities in FLOPS, overriding the
    /// max/spread draw when non-empty.  The scenario engine resolves
    /// spread + straggler multipliers into this table once per deployment
    /// so that per-round participant *subsets* keep each client's
    /// hardware stable (see [`crate::scenario::StragglerConfig`]).
    pub client_caps: Vec<f64>,
    /// Total server compute f^s_max (shared across clients) in FLOPS.
    pub f_server_total: f64,
    /// Samples processed per client per round (D^n in eqs 14–16).
    pub samples_per_round: usize,
    /// Bits per transmitted scalar (f32 = 32).
    pub bits_per_scalar: f64,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        ComputeConfig {
            f_client_max: 0.1e9,
            f_client_spread: 0.0,
            client_caps: Vec::new(),
            f_server_total: 100e9,
            samples_per_round: 32,
            bits_per_scalar: 32.0,
        }
    }
}

impl ComputeConfig {
    /// Per-client FLOPS capacities f^{n,c}_max — fixed hardware.  An
    /// explicit [`ComputeConfig::client_caps`] table wins; otherwise
    /// capacities are drawn once per deployment from the spread
    /// (deterministic in `seed`).
    pub fn client_flops(&self, n: usize, seed: u64) -> Vec<f64> {
        if !self.client_caps.is_empty() {
            assert!(
                self.client_caps.len() >= n,
                "client_caps has {} entries for {} clients",
                self.client_caps.len(),
                n
            );
            return self.client_caps[..n].to_vec();
        }
        if self.f_client_spread <= 0.0 {
            return vec![self.f_client_max; n];
        }
        let mut rng = crate::util::rng::Pcg::new(seed, 0xF10C);
        (0..n)
            .map(|_| self.f_client_max * rng.range(1.0 - self.f_client_spread, 1.0))
            .collect()
    }
}

/// X_t(v): bits of smashed data for one round's samples (eq 12/13).
/// Uplink additionally carries the labels (classes one-hot);
/// the downlink gradient has the same size as the smashed data.
pub fn smashed_bits(cut: &CutSpec, cfg: &ComputeConfig) -> f64 {
    cut.smashed_per_sample() as f64 * cfg.samples_per_round as f64 * cfg.bits_per_scalar
}

/// Label bits per round (uplink only; one-hot f32 like the artifacts).
pub fn label_bits(spec: &ShapeSpec, cfg: &ComputeConfig) -> f64 {
    spec.classes as f64 * cfg.samples_per_round as f64 * cfg.bits_per_scalar
}

/// Model bits (for FL / SFL client-model aggregation traffic).
pub fn model_bits(num_params: usize, cfg: &ComputeConfig) -> f64 {
    num_params as f64 * cfg.bits_per_scalar
}

/// Communication latency l = X / r (eqs 12, 13). Infinite if r == 0.
pub fn comm_latency(bits: f64, rate: f64) -> f64 {
    if rate <= 0.0 {
        f64::INFINITY
    } else {
        bits / rate
    }
}

/// Client-side FP latency (eq 14): D^n γ_F^c(v) / f^n.
pub fn client_fwd_latency(cut: &CutSpec, cfg: &ComputeConfig, f_client: f64) -> f64 {
    cfg.samples_per_round as f64 * cut.flops_client_fwd / f_client
}

/// Client-side BP latency (eq 16): D^n γ_B^c(v) / f^n.
pub fn client_bwd_latency(cut: &CutSpec, cfg: &ComputeConfig, f_client: f64) -> f64 {
    cfg.samples_per_round as f64 * cut.flops_client_bwd / f_client
}

/// Server-side FP+BP latency (eq 15): D^n (γ_F^s + γ_B^s) / f^{s,n}.
pub fn server_latency(cut: &CutSpec, cfg: &ComputeConfig, f_server_n: f64) -> f64 {
    cfg.samples_per_round as f64 * (cut.flops_server_fwd + cut.flops_server_bwd) / f_server_n
}

/// Per-client round legs, combined per eq (29):
/// l_t = max_n{uplink + client FP + server} + max_n{downlink + client BP}.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientRoundLatency {
    pub uplink: f64,
    pub client_fwd: f64,
    pub server: f64,
    pub downlink: f64,
    pub client_bwd: f64,
}

impl ClientRoundLatency {
    pub fn uplink_leg(&self) -> f64 {
        self.uplink + self.client_fwd + self.server
    }

    pub fn downlink_leg(&self) -> f64 {
        self.downlink + self.client_bwd
    }
}

/// Total round latency across clients (eq 29).
pub fn round_latency(legs: &[ClientRoundLatency]) -> f64 {
    let up = legs.iter().map(|l| l.uplink_leg()).fold(0.0, f64::max);
    let down = legs.iter().map(|l| l.downlink_leg()).fold(0.0, f64::max);
    up + down
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;
    use super::*;

    fn toy_cut() -> CutSpec {
        CutSpec {
            cut: 1,
            phi: 100,
            client_params: 2,
            smashed_shape: vec![32, 10, 10, 4],
            flops_client_fwd: 1e6,
            flops_client_bwd: 2e6,
            flops_server_fwd: 3e6,
            flops_server_bwd: 4e6,
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn smashed_bits_counts_per_sample_elems() {
        let cfg = ComputeConfig { samples_per_round: 32, ..Default::default() };
        // 10*10*4 = 400 elems/sample * 32 samples * 32 bits
        assert_eq!(smashed_bits(&toy_cut(), &cfg), 400.0 * 32.0 * 32.0);
    }

    #[test]
    fn comm_latency_div_and_infinite() {
        assert_eq!(comm_latency(1e6, 1e6), 1.0);
        assert!(comm_latency(1.0, 0.0).is_infinite());
    }

    #[test]
    fn compute_latencies_match_formulas() {
        let cut = toy_cut();
        let cfg = ComputeConfig { samples_per_round: 10, ..Default::default() };
        assert!((client_fwd_latency(&cut, &cfg, 1e7) - 10.0 * 1e6 / 1e7).abs() < 1e-12);
        assert!((client_bwd_latency(&cut, &cfg, 1e7) - 10.0 * 2e6 / 1e7).abs() < 1e-12);
        assert!((server_latency(&cut, &cfg, 1e9) - 10.0 * 7e6 / 1e9).abs() < 1e-12);
    }

    #[test]
    fn round_latency_is_max_plus_max() {
        let leg = |uplink, client_fwd, server, downlink, client_bwd| ClientRoundLatency {
            uplink,
            client_fwd,
            server,
            downlink,
            client_bwd,
        };
        let legs = vec![leg(1.0, 1.0, 1.0, 5.0, 0.0), leg(4.0, 0.0, 0.0, 1.0, 1.0)];
        // up legs: 3.0, 4.0 → 4.0; down legs: 5.0, 2.0 → 5.0.
        assert_eq!(round_latency(&legs), 9.0);
    }

    #[test]
    fn straggler_dominates() {
        let mut legs = vec![ClientRoundLatency::default(); 5];
        legs[3].uplink = 100.0;
        assert_eq!(round_latency(&legs), 100.0);
    }
}
