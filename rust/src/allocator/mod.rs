//! P2.1 convex resource allocation: bandwidth, power and server-CPU split
//! minimizing the per-round latency bound χ + ψ (paper §IV-B1).
//!
//! [`build_problem`] assembles one round's instance from the system
//! models (channel gains, smashed-data sizes at the cut, per-client
//! compute capacities — including scenario straggler profiles via
//! [`ComputeConfig::client_flops`]); [`solver`] bisects on the uplink-leg
//! bound χ with a bandwidth-pricing inner step, built on the
//! golden-section / monotone-bisection primitives in [`golden`].

pub mod golden;
pub mod solver;

pub use solver::{Allocation, RoundProblem};

use crate::latency::{self, ComputeConfig};
use crate::model::{CutSpec, ShapeSpec};
use crate::wireless::{ChannelState, NetConfig};

/// Build the P2.1 instance for one round at cut v from the system models.
///
/// Heterogeneous clients (comp.f_client_spread > 0) get per-client FP/BP
/// latencies a_n, d_n via `ComputeConfig::client_flops`; the deployment
/// draw is keyed on the number of clients so it is stable across rounds.
pub fn build_problem(
    spec: &ShapeSpec,
    cut: &CutSpec,
    net: &NetConfig,
    comp: &ComputeConfig,
    state: &ChannelState,
) -> RoundProblem {
    let n = state.gains.len();
    let x_smashed = latency::smashed_bits(cut, comp);
    let x_up = x_smashed + latency::label_bits(spec, comp);
    let f_clients = comp.client_flops(n, n as u64);
    let a: Vec<f64> = f_clients
        .iter()
        .map(|&f| latency::client_fwd_latency(cut, comp, f))
        .collect();
    let d: Vec<f64> = f_clients
        .iter()
        .map(|&f| latency::client_bwd_latency(cut, comp, f))
        .collect();
    let c = vec![
        comp.samples_per_round as f64 * (cut.flops_server_fwd + cut.flops_server_bwd);
        n
    ];
    RoundProblem {
        x_up_bits: x_up,
        x_down_bits: x_smashed,
        gains: state.gains.clone(),
        a,
        d,
        c,
        b_total: net.bandwidth,
        f_total: comp.f_server_total,
        p_max: net.p_max,
        p_server: net.p_server,
        n0: net.n0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg;
    use crate::wireless::{avg_gain, rate};

    fn toy_problem(rng: &mut Pcg, n: usize) -> RoundProblem {
        let gains = (0..n)
            .map(|_| avg_gain(rng.range(0.05, 0.5)) * rng.exponential(1.0).max(0.05))
            .collect();
        RoundProblem {
            x_up_bits: rng.range(1e5, 1e7),
            x_down_bits: rng.range(1e5, 1e7),
            gains,
            a: (0..n).map(|_| rng.range(0.001, 0.5)).collect(),
            d: (0..n).map(|_| rng.range(0.001, 0.5)).collect(),
            c: (0..n).map(|_| rng.range(1e7, 1e10)).collect(),
            b_total: 20e6,
            f_total: 100e9,
            p_max: crate::wireless::dbm_to_watt(25.0),
            p_server: crate::wireless::dbm_to_watt(33.0),
            n0: crate::wireless::dbm_to_watt(-174.0),
        }
    }

    #[test]
    fn solve_respects_budgets() {
        check("budgets", 48, |rng| {
            let n = 1 + rng.below(6);
            let p = toy_problem(rng, n);
            let sol = p.solve();
            let sb: f64 = sol.bandwidth.iter().sum();
            let sf: f64 = sol.f_server.iter().sum();
            prop_assert!(sb <= p.b_total * 1.001, "bandwidth over budget: {sb}");
            prop_assert!(sf <= p.f_total * 1.001, "server FLOPS over budget: {sf}");
            prop_assert!(sol.power.iter().all(|&pw| pw <= p.p_max * 1.0001),
                "power exceeds p_max");
            Ok(())
        });
    }

    #[test]
    fn solve_meets_its_own_chi() {
        check("chi-consistency", 48, |rng| {
            let n = 1 + rng.below(5);
            let p = toy_problem(rng, n);
            let sol = p.solve();
            for i in 0..p.num_clients() {
                let r = rate(sol.bandwidth[i], sol.power[i], p.gains[i], p.n0);
                let leg = p.a[i] + p.x_up_bits / r + p.c[i] / sol.f_server[i];
                prop_assert!(
                    leg <= sol.chi * (1.0 + 1e-4),
                    "client {i} leg {leg} > chi {}",
                    sol.chi
                );
            }
            Ok(())
        });
    }

    #[test]
    fn solve_never_worse_than_equal_split() {
        check("optimal-vs-equal", 48, |rng| {
            let n = 1 + rng.below(6);
            let p = toy_problem(rng, n);
            let opt = p.solve();
            let eq = p.solve_equal();
            prop_assert!(
                opt.chi <= eq.chi * (1.0 + 1e-6),
                "optimized chi {} > equal chi {}",
                opt.chi,
                eq.chi
            );
            // ψ identical by construction (no free variables).
            prop_assert!((opt.psi - eq.psi).abs() < 1e-9, "psi mismatch");
            Ok(())
        });
    }

    #[test]
    fn solve_matches_brute_force_two_clients() {
        // 2-client grid search over bandwidth & CPU splits.
        check("vs-grid", 12, |rng| {
            let p = toy_problem(rng, 2);
            let sol = p.solve();
            let grid = 200;
            let mut best = f64::INFINITY;
            for i in 1..grid {
                let b0 = p.b_total * i as f64 / grid as f64;
                let b1 = p.b_total - b0;
                for j in 1..grid {
                    let f0 = p.f_total * j as f64 / grid as f64;
                    let f1 = p.f_total - f0;
                    let leg = |k: usize, b: f64, f: f64| {
                        let r = rate(b, p.p_max, p.gains[k], p.n0);
                        p.a[k] + p.x_up_bits / r + p.c[k] / f
                    };
                    best = best.min(leg(0, b0, f0).max(leg(1, b1, f1)));
                }
            }
            prop_assert!(
                sol.chi <= best * 1.02 + 1e-9,
                "solver chi {} worse than grid best {best}",
                sol.chi
            );
            Ok(())
        });
    }

    #[test]
    fn more_bandwidth_never_hurts() {
        check("bandwidth-monotone", 24, |rng| {
            let n = 1 + rng.below(4);
            let p1 = toy_problem(rng, n);
            let mut p2 = p1.clone();
            p2.b_total *= 2.0;
            let c1 = p1.solve().chi;
            let c2 = p2.solve().chi;
            prop_assert!(c2 <= c1 * (1.0 + 1e-6), "chi rose with bandwidth: {c1} -> {c2}");
            Ok(())
        });
    }

    #[test]
    fn psi_closed_form() {
        let mut rng = Pcg::new(5, 5);
        let p = toy_problem(&mut rng, 3);
        let psi = p.psi_star();
        let want = (0..3)
            .map(|i| p.x_down_bits / rate(p.b_total, p.p_server, p.gains[i], p.n0) + p.d[i])
            .fold(0.0f64, f64::max);
        assert!((psi - want).abs() < 1e-12);
    }

    #[test]
    fn build_problem_uses_manifest_numbers() {
        use crate::model::Manifest;
        let m = Manifest::builtin();
        let spec = m.for_dataset("mnist").unwrap();
        let cut = spec.cut(2);
        let net = NetConfig::default();
        let comp = ComputeConfig::default();
        let st = ChannelState { gains: vec![1e-10; 4] };
        let p = build_problem(spec, cut, &net, &comp, &st);
        // v=2 smashed: 7*7*64 = 3136 per sample; labels 10 per sample.
        assert_eq!(p.x_down_bits, 3136.0 * 32.0 * 32.0);
        assert_eq!(p.x_up_bits, (3136.0 + 10.0) * 32.0 * 32.0);
        assert_eq!(p.num_clients(), 4);
        let sol = p.solve();
        assert!(sol.chi.is_finite() && sol.psi.is_finite());
    }
}
