//! P2.1 — per-round convex resource allocation (paper §IV-B1).
//!
//!   min χ + ψ  s.t.  (30b)(30c)(30d)(30f)(31b)(31c)
//!
//! Structure exploited (all standard for this problem class):
//! * transmit power: rate is increasing in p, so p_n* = p_max (30c tight);
//! * client CPU: both legs improve with more client FLOPS, so f^c* = f^c_max;
//! * ψ (downlink leg, eq 31c) has no free variables once p/f^c are pinned —
//!   the broadcast uses the whole band at server power — so ψ* is computed
//!   in closed form;
//! * χ (uplink leg, eq 31b) couples the bandwidth split {B_n} (30f) and the
//!   server-CPU split {f^s_n} (30d).  We bisect on χ and test feasibility
//!   by pricing bandwidth with a multiplier μ: for fixed μ each client
//!   solves a 1-D unimodal problem min_B [required-server-FLOPS(B) + μ·B]
//!   (golden section); Σ B_n(μ) is decreasing in μ, so an outer bisection
//!   on μ meets the bandwidth budget, and feasibility is Σ f_n ≤ f_total.
//!
//! This matches the paper's "resolved by existing convex optimization
//! methods (e.g. CVX)" step with a dependency-free solver; the property
//! tests validate optimality against brute-force grids.

use super::golden::{bisect_first_true, golden_min};
use crate::wireless::rate;

/// One round's P2.1 instance (everything in SI units; latencies seconds).
#[derive(Clone, Debug)]
pub struct RoundProblem {
    /// Uplink bits per client: smashed data + labels, X_t(v) (eq 12).
    pub x_up_bits: f64,
    /// Downlink broadcast bits (aggregated gradient), eq 13.
    pub x_down_bits: f64,
    /// Instantaneous channel gains g_t^n.
    pub gains: Vec<f64>,
    /// Client forward-prop latency a_n = D γ_F^c / f^c_max (eq 14), fixed.
    pub a: Vec<f64>,
    /// Client backward-prop latency d_n = D γ_B^c / f^c_max (eq 16), fixed.
    pub d: Vec<f64>,
    /// Server FLOPs needed per round per client: D (γ_F^s + γ_B^s) (eq 15).
    pub c: Vec<f64>,
    /// Total uplink bandwidth B (30f).
    pub b_total: f64,
    /// Total server FLOPS f^s_max (30d).
    pub f_total: f64,
    pub p_max: f64,
    pub p_server: f64,
    pub n0: f64,
}

/// Solved allocation + the achieved auxiliary variables (χ, ψ).
#[derive(Clone, Debug)]
pub struct Allocation {
    pub bandwidth: Vec<f64>,
    pub power: Vec<f64>,
    pub f_server: Vec<f64>,
    /// Uplink-leg latency bound χ_t (eq 31b).
    pub chi: f64,
    /// Downlink-leg latency bound ψ_t (eq 31c).
    pub psi: f64,
}

impl Allocation {
    pub fn total_latency(&self) -> f64 {
        self.chi + self.psi
    }
}

// Iteration budgets, tuned in the §Perf pass (EXPERIMENTS.md): 60/72
// iterations gave χ to ~1e-18 relative — far beyond what the simulation
// needs.  36/28 keeps every optimality/consistency property test green
// (χ within 2% of a 200×200 grid optimum) at ~6× lower solve cost.
const BISECT_ITERS: usize = 36;
const GOLDEN_ITERS: usize = 28;

impl RoundProblem {
    pub fn num_clients(&self) -> usize {
        self.gains.len()
    }

    fn check(&self) {
        let n = self.num_clients();
        assert!(n > 0, "empty problem");
        assert_eq!(self.a.len(), n);
        assert_eq!(self.d.len(), n);
        assert_eq!(self.c.len(), n);
        assert!(self.b_total > 0.0 && self.f_total > 0.0);
    }

    /// Downlink-leg bound ψ* = max_n (X_down / r_n^D + d_n) — closed form.
    pub fn psi_star(&self) -> f64 {
        self.gains
            .iter()
            .zip(&self.d)
            .map(|(&g, &d)| {
                let r = rate(self.b_total, self.p_server, g, self.n0);
                if r <= 0.0 {
                    f64::INFINITY
                } else {
                    self.x_down_bits / r + d
                }
            })
            .fold(0.0, f64::max)
    }

    /// Minimum bandwidth for client n to push X_up bits within `t` seconds,
    /// or None if the capacity limit p·g/(N0·ln2) can't reach that rate.
    fn b_required(&self, n: usize, t: f64) -> Option<f64> {
        if t <= 0.0 {
            return None;
        }
        let need = self.x_up_bits / t;
        // r(B) increases in B but saturates at p g / (N0 ln 2).
        let cap = self.p_max * self.gains[n] / (self.n0 * std::f64::consts::LN_2);
        if need >= cap * (1.0 - 1e-12) {
            return None;
        }
        // Grow an upper bracket, then bisect r(B) ≥ need.
        let mut hi = 1.0;
        while rate(hi, self.p_max, self.gains[n], self.n0) < need {
            hi *= 2.0;
            if hi > 1e15 {
                return None;
            }
        }
        bisect_first_true(0.0, hi, BISECT_ITERS, |b| {
            rate(b, self.p_max, self.gains[n], self.n0) >= need
        })
    }

    /// Server FLOPS client n needs if granted bandwidth `b`, under
    /// uplink-leg deadline χ: c_n / (χ - a_n - comm_time(b)).
    fn f_needed(&self, n: usize, chi: f64, b: f64) -> f64 {
        let r = rate(b, self.p_max, self.gains[n], self.n0);
        if r <= 0.0 {
            return f64::INFINITY;
        }
        let slack = chi - self.a[n] - self.x_up_bits / r;
        if slack <= 0.0 {
            f64::INFINITY
        } else {
            self.c[n] / slack
        }
    }

    /// For bandwidth price μ, each client's optimal (b_n, f_n); returns
    /// (Σb, Σf, allocation) or None if some client can't meet χ at all.
    fn priced_allocation(&self, chi: f64, mu: f64) -> Option<(f64, f64, Vec<(f64, f64)>)> {
        let n = self.num_clients();
        let mut total_b = 0.0;
        let mut total_f = 0.0;
        let mut alloc = Vec::with_capacity(n);
        for i in 0..n {
            let t_n = chi - self.a[i];
            // Smallest bandwidth that leaves any compute slack at all.
            let b_min = self.b_required(i, t_n)?;
            let b_lo = b_min * (1.0 + 1e-9) + 1e-9;
            let b_hi = self.b_total;
            if b_lo >= b_hi {
                return None;
            }
            let (b_opt, _) = golden_min(b_lo, b_hi, GOLDEN_ITERS, |b| {
                self.f_needed(i, chi, b) + mu * b
            });
            let f_opt = self.f_needed(i, chi, b_opt);
            if !f_opt.is_finite() {
                return None;
            }
            total_b += b_opt;
            total_f += f_opt;
            alloc.push((b_opt, f_opt));
        }
        Some((total_b, total_f, alloc))
    }

    /// Is uplink-leg deadline χ feasible within (30d) and (30f)?
    /// Returns the allocation when it is.
    fn chi_feasible(&self, chi: f64) -> Option<Vec<(f64, f64)>> {
        // Try the bandwidth-greedy end first (μ ≈ 0): min Σf.
        let (b0, f0, alloc0) = self.priced_allocation(chi, 0.0)?;
        if b0 <= self.b_total && f0 <= self.f_total {
            return Some(alloc0);
        }
        if f0 > self.f_total {
            // Even with maximal bandwidth the CPU budget fails: since
            // raising μ only *shrinks* bandwidth and *raises* Σf, no μ helps.
            return None;
        }
        // b0 > b_total: raise μ until Σb fits, then check Σf.
        // Find a μ_hi bracket where bandwidth fits.
        let mut mu_hi = 1e-9;
        loop {
            match self.priced_allocation(chi, mu_hi) {
                None => return None,
                Some((b, _, _)) if b <= self.b_total => break,
                Some(_) => {
                    mu_hi *= 8.0;
                    if mu_hi > 1e18 {
                        return None;
                    }
                }
            }
        }
        let mut lo = 0.0;
        let mut hi = mu_hi;
        for _ in 0..BISECT_ITERS {
            let mid = 0.5 * (lo + hi);
            match self.priced_allocation(chi, mid) {
                Some((b, _, _)) if b <= self.b_total => hi = mid,
                _ => lo = mid,
            }
        }
        let (b, f, alloc) = self.priced_allocation(chi, hi)?;
        (b <= self.b_total * (1.0 + 1e-6) && f <= self.f_total).then_some(alloc)
    }

    /// χ for the *equal-split* allocation (also the bisection's upper
    /// bound): B/N bandwidth and f_total/N server FLOPS each.
    pub fn equal_chi(&self) -> f64 {
        let n = self.num_clients() as f64;
        self.gains
            .iter()
            .enumerate()
            .map(|(i, &g)| {
                let r = rate(self.b_total / n, self.p_max, g, self.n0);
                let comm = if r > 0.0 { self.x_up_bits / r } else { f64::INFINITY };
                self.a[i] + comm + self.c[i] / (self.f_total / n)
            })
            .fold(0.0, f64::max)
    }

    /// The equal-split baseline allocation (used by Fig. 6/8 benchmarks).
    pub fn solve_equal(&self) -> Allocation {
        self.check();
        let n = self.num_clients();
        Allocation {
            bandwidth: vec![self.b_total / n as f64; n],
            power: vec![self.p_max; n],
            f_server: vec![self.f_total / n as f64; n],
            chi: self.equal_chi(),
            psi: self.psi_star(),
        }
    }

    /// Solve P2.1 to the bisection tolerance.
    pub fn solve(&self) -> Allocation {
        self.check();
        let psi = self.psi_star();
        let chi_hi = self.equal_chi();
        if !chi_hi.is_finite() {
            // Channel so bad even equal split is infinite; return the
            // equal allocation (caller sees infinite latency).
            return self.solve_equal();
        }
        // Lower bound: every client at least needs its FP time plus the
        // capacity-limit transmission time.
        let chi_lo = (0..self.num_clients())
            .map(|i| {
                let cap =
                    self.p_max * self.gains[i] / (self.n0 * std::f64::consts::LN_2);
                self.a[i] + self.x_up_bits / cap
            })
            .fold(0.0f64, f64::max);

        let mut lo = chi_lo;
        let mut hi = chi_hi * (1.0 + 1e-9);
        if self.chi_feasible(hi).is_none() {
            // Numerical edge: equal split claims chi_hi but the priced
            // search can't certify it; fall back to equal.
            return self.solve_equal();
        }
        for _ in 0..BISECT_ITERS {
            let mid = 0.5 * (lo + hi);
            if self.chi_feasible(mid).is_some() {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        let alloc = self
            .chi_feasible(hi)
            .expect("hi retained feasibility through bisection");
        Allocation {
            bandwidth: alloc.iter().map(|&(b, _)| b).collect(),
            power: vec![self.p_max; self.num_clients()],
            f_server: alloc.iter().map(|&(_, f)| f).collect(),
            chi: hi,
            psi,
        }
    }
}
