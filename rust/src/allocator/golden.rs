//! Golden-section search for 1-D unimodal minimization, plus generic
//! monotone bisection — the numeric primitives behind the P2.1 solver.

/// Minimize a unimodal `f` on [lo, hi]; returns (argmin, min).
pub fn golden_min<F: Fn(f64) -> f64>(mut lo: f64, mut hi: f64, iters: usize, f: F) -> (f64, f64) {
    debug_assert!(lo <= hi);
    const INV_PHI: f64 = 0.618_033_988_749_894_8;
    let mut x1 = hi - INV_PHI * (hi - lo);
    let mut x2 = lo + INV_PHI * (hi - lo);
    let mut f1 = f(x1);
    let mut f2 = f(x2);
    for _ in 0..iters {
        if f1 <= f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - INV_PHI * (hi - lo);
            f1 = f(x1);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + INV_PHI * (hi - lo);
            f2 = f(x2);
        }
    }
    let xm = 0.5 * (lo + hi);
    (xm, f(xm))
}

/// Smallest x in [lo, hi] with pred(x) true, assuming pred is monotone
/// (false..false true..true). Returns None if pred(hi) is false.
pub fn bisect_first_true<F: Fn(f64) -> bool>(
    lo: f64,
    hi: f64,
    iters: usize,
    pred: F,
) -> Option<f64> {
    if !pred(hi) {
        return None;
    }
    if pred(lo) {
        return Some(lo);
    }
    let (mut lo, mut hi) = (lo, hi);
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        if pred(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_finds_parabola_min() {
        let (x, fx) = golden_min(-10.0, 10.0, 80, |x| (x - 3.0).powi(2) + 1.0);
        assert!((x - 3.0).abs() < 1e-6, "x = {x}");
        assert!((fx - 1.0).abs() < 1e-10);
    }

    #[test]
    fn golden_handles_boundary_min() {
        let (x, _) = golden_min(0.0, 5.0, 80, |x| x); // min at lo
        assert!(x < 1e-6);
        let (x, _) = golden_min(0.0, 5.0, 80, |x| -x); // min at hi
        assert!((x - 5.0).abs() < 1e-6);
    }

    #[test]
    fn golden_asymmetric_unimodal() {
        // min of x + 1/x on (0, inf) is at x=1.
        let (x, fx) = golden_min(1e-3, 100.0, 100, |x| x + 1.0 / x);
        assert!((x - 1.0).abs() < 1e-4, "x = {x}");
        assert!((fx - 2.0).abs() < 1e-8);
    }

    #[test]
    fn bisect_finds_threshold() {
        let x = bisect_first_true(0.0, 10.0, 60, |x| x >= 7.25).unwrap();
        assert!((x - 7.25).abs() < 1e-9);
    }

    #[test]
    fn bisect_none_when_never_true() {
        assert!(bisect_first_true(0.0, 1.0, 60, |_| false).is_none());
    }

    #[test]
    fn bisect_lo_when_always_true() {
        assert_eq!(bisect_first_true(2.0, 3.0, 60, |_| true), Some(2.0));
    }
}
